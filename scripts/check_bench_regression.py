#!/usr/bin/env python
"""Benchmark regression gate.

Two layers, both exiting non-zero on violation so CI/smoke can gate on
them:

  * schema validation (always): ``BENCH_engine.json`` must be
    schema_version 4 with the serving / mutable-serving / roofline /
    peak-memory columns present in every row (the mutation columns —
    warm re-finalize, batched route, evictions — are nullable: convex
    rows don't run the mutated sweep) plus the scale columns —
    ``shards`` / ``comm_level_bytes`` / ``edge_build_s``; the report
    must carry at least one hierarchical row (shards > 1, C >= 100k,
    purity >= 0.99, per-level comm bytes) and the C=16384
    ``knn-approx`` convex row must match the exact ``knn`` row's
    purity within slack while beating its edge-build wall-clock;
    ``BENCH_robustness.json`` must be schema_version 1 with the
    robustness row keys; ``BENCH_serving.json`` must be schema_version
    1 with the loadgen row keys, >= 2 closed-loop concurrency points,
    and a passing batched-beats-direct criterion at every point the
    loadgen marked ``pass``.
  * ``--quick``: re-run the cheapest engine row (kmeans-device, C=256)
    through the real ``bench_engine_scale`` path into a temp file and
    compare it against the committed baseline row under per-metric
    tolerances — exact for protocol invariants (comm bytes, recovered
    K'), a small slack for quality (purity), and generous multipliers
    for wall clock / memory (CI containers are noisy; the gate exists
    to catch order-of-magnitude regressions and schema drift, not 10%
    jitter).

Run from anywhere:  python scripts/check_bench_regression.py --quick
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (ROOT, os.path.join(ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

ENGINE_JSON = os.path.join(ROOT, "BENCH_engine.json")
ROBUSTNESS_JSON = os.path.join(ROOT, "BENCH_robustness.json")
SERVING_JSON = os.path.join(ROOT, "BENCH_serving.json")

ENGINE_SCHEMA_VERSION = 4
ROBUSTNESS_SCHEMA_VERSION = 1
SERVING_SCHEMA_VERSION = 1

ENGINE_ROW_KEYS = {
    "clients", "algorithm", "phases", "purity", "n_clusters_recovered",
    "comm_bytes", "device_peak_bytes", "device_peak_bytes_source",
    "route_probes", "route_p50_ms", "route_p99_ms", "routes_per_s",
    "finalize_repeats", "finalize_p50_ms", "finalize_p99_ms", "kernels",
    # schema 3: mutable-serving columns (nullable on non-mutated rows)
    "reupload_frac", "churn", "live_clients", "evictions",
    "drift_after_mutation", "refinalize_threshold", "refinalize_fired",
    "refinalize_warm_p50_ms", "route_batch_ms", "batched_routes_per_s",
    # schema 4: hierarchical / approximate-edge scale columns
    # (comm_level_bytes is null on flat rows, edge_build_s on non-convex)
    "shards", "comm_level_bytes", "edge_build_s",
}

HIER_MIN_CLIENTS = 100_000
HIER_MIN_PURITY = 0.99
ROBUSTNESS_ROW_KEYS = {"sweep", "scenario", "aggregator", "purity"}

SERVING_ROW_KEYS = {
    "mode", "batched", "callers", "rate", "max_batch", "max_wait_ms",
    "queue_depth", "ingest_waves", "backpressure", "flush_size_p50",
    "flush_size_p95", "flush_size_max", "queue_depth_p95",
    "staleness_at_serve_p95", "refinalize_under_load_ms", "drops",
    "n_requests", "n_errors", "timeouts", "qps", "route_p50_ms",
    "route_p99_ms", "duration_s", "clients", "clusters", "sketch_dim",
}
SERVING_MIN_CLOSED_POINTS = 2

# --quick tolerances vs the committed baseline row
PURITY_SLACK = 0.02          # absolute purity drop allowed
TIME_MULT, TIME_SLACK_S = 2.5, 2.0
MEM_MULT, MEM_SLACK_B = 4.0, 2 << 30
ROUTE_MULT, ROUTE_SLACK_MS = 4.0, 10.0


def _load(path: str) -> dict:
    if not os.path.exists(path):
        print(f"[bench-gate] FAIL: missing {path}")
        raise SystemExit(1)
    with open(path) as f:
        return json.load(f)


def _check(failures: list, ok: bool, msg: str) -> None:
    print(f"[bench-gate] {'ok  ' if ok else 'FAIL'} {msg}")
    if not ok:
        failures.append(msg)


def validate_engine(report: dict, failures: list) -> None:
    _check(failures,
           report.get("schema_version") == ENGINE_SCHEMA_VERSION,
           f"engine schema_version == {ENGINE_SCHEMA_VERSION} "
           f"(got {report.get('schema_version')})")
    rows = report.get("rows") or []
    _check(failures, bool(rows), "engine report has rows")
    for i, row in enumerate(rows):
        missing = ENGINE_ROW_KEYS - set(row)
        _check(failures, not missing,
               f"engine row {i} ({row.get('algorithm')}/C{row.get('clients')})"
               f" has required keys" + (f"; missing {sorted(missing)}"
                                        if missing else ""))
        if missing:
            continue
        _check(failures, row["device_peak_bytes"] is not None
               and row["device_peak_bytes"] > 0,
               f"engine row {i} device_peak_bytes non-null "
               f"({row['device_peak_bytes']}, "
               f"source={row.get('device_peak_bytes_source')})")
    _validate_hierarchical(rows, failures)
    _validate_knn_approx(rows, failures)


def _validate_hierarchical(rows: list, failures: list) -> None:
    """Schema 4: the report must prove the million-client path — at
    least one two-level row at C >= 100k recovering the planted
    clusters, with the per-level comm accounting filled in."""
    hier = [r for r in rows
            if r.get("shards", 1) > 1 and r["clients"] >= HIER_MIN_CLIENTS]
    _check(failures, bool(hier),
           f"engine report has a hierarchical row (shards > 1, "
           f"C >= {HIER_MIN_CLIENTS})")
    for row in hier:
        tag = (f"{row['algorithm']}@S{row['shards']}/C{row['clients']}")
        _check(failures, row["purity"] >= HIER_MIN_PURITY,
               f"hierarchical row {tag} purity {row['purity']:.4f} >= "
               f"{HIER_MIN_PURITY}")
        clb = row.get("comm_level_bytes") or {}
        ok = (clb.get("level0") and clb.get("level1")
              and clb["level1"] < clb["level0"])
        _check(failures, bool(ok),
               f"hierarchical row {tag} comm_level_bytes present with "
               f"level1 < level0 (got {clb})")


def _validate_knn_approx(rows: list, failures: list) -> None:
    """Schema 4: the C=16384 knn-approx convex row must match the exact
    knn row's purity (within the quick-check slack) while beating its
    standalone edge-build wall-clock."""
    def find(edges):
        for r in rows:
            if (r["algorithm"].startswith("convex")
                    and r.get("edges") == edges and r["clients"] == 16384):
                return r
        return None
    exact, approx = find("knn"), find("knn-approx")
    _check(failures, approx is not None,
           "engine report has the convex knn-approx C=16384 row")
    if approx is None or exact is None:
        if exact is None:
            _check(failures, False,
                   "engine report has the convex knn C=16384 row")
        return
    _check(failures, approx["purity"] >= exact["purity"] - PURITY_SLACK,
           f"knn-approx purity {approx['purity']:.3f} >= knn "
           f"{exact['purity']:.3f} - {PURITY_SLACK}")
    eb_exact, eb_approx = exact.get("edge_build_s"), approx.get("edge_build_s")
    _check(failures,
           eb_exact is not None and eb_approx is not None
           and eb_approx < eb_exact,
           f"knn-approx edge_build_s {eb_approx} < knn {eb_exact}")


def validate_robustness(report: dict, failures: list) -> None:
    _check(failures,
           report.get("schema_version") == ROBUSTNESS_SCHEMA_VERSION,
           f"robustness schema_version == {ROBUSTNESS_SCHEMA_VERSION} "
           f"(got {report.get('schema_version')})")
    rows = report.get("rows") or []
    _check(failures, bool(rows), "robustness report has rows")
    for i, row in enumerate(rows):
        missing = ROBUSTNESS_ROW_KEYS - set(row)
        _check(failures, not missing,
               f"robustness row {i} has required keys"
               + (f"; missing {sorted(missing)}" if missing else ""))


def validate_serving(report: dict, failures: list) -> None:
    """Schema 1 of the RouteServer loadgen report: full row schema,
    >= 2 closed-loop concurrency points whose batched rows beat their
    per-request twins, an ingest-while-serving row proving the
    double-buffered refinalize ran under route traffic, and zero
    dropped requests anywhere."""
    _check(failures,
           report.get("schema_version") == SERVING_SCHEMA_VERSION,
           f"serving schema_version == {SERVING_SCHEMA_VERSION} "
           f"(got {report.get('schema_version')})")
    rows = report.get("rows") or []
    _check(failures, bool(rows), "serving report has rows")
    for i, row in enumerate(rows):
        missing = SERVING_ROW_KEYS - set(row)
        _check(failures, not missing,
               f"serving row {i} ({row.get('mode')}/"
               f"batched={row.get('batched')}/callers={row.get('callers')})"
               f" has required keys" + (f"; missing {sorted(missing)}"
                                        if missing else ""))
        if not missing:
            _check(failures, row["drops"] == 0 and row["n_errors"] == 0,
                   f"serving row {i} drops == 0 and n_errors == 0 "
                   f"(got {row['drops']}/{row['n_errors']})")
    crit = report.get("criterion") or {}
    _check(failures, len(crit) >= SERVING_MIN_CLOSED_POINTS,
           f"serving criterion has >= {SERVING_MIN_CLOSED_POINTS} "
           f"closed-loop concurrency points (got {len(crit)})")
    for point, c in crit.items():
        _check(failures, bool(c.get("pass")),
               f"serving criterion {point}: batched "
               f"{c.get('batched_qps', 0):.0f}/s beats per-request "
               f"{c.get('direct_qps', 0):.0f}/s")
    under = [r for r in rows if r.get("ingest_waves")]
    ok = bool(under) and all(r["refinalize_under_load_ms"] is not None
                             for r in under)
    _check(failures, ok,
           "serving report has an ingest-while-serving row with a "
           "measured refinalize_under_load_ms")


def _row_key(row: dict):
    return (row["algorithm"], row.get("edges") or "complete",
            row["clients"], row.get("shards", 1))


def quick_check(baseline: dict, failures: list) -> None:
    """Re-run the C=256 kmeans-device row and compare against baseline."""
    from benchmarks.bench_engine_scale import run

    sweeps = (("kmeans-device", (256,),
               {"finalize_repeats": 5, "route_probes": 256,
                "reupload_frac": 0.25, "churn": 64,
                "refinalize_threshold": 1.5}),)
    with tempfile.TemporaryDirectory() as td:
        report = run(sweeps=sweeps, out=os.path.join(td, "quick.json"))
    row = report["rows"][0]
    base_rows = {_row_key(r): r for r in baseline.get("rows", [])}
    base = base_rows.get(_row_key(row))
    if base is None:
        _check(failures, False,
               f"baseline row {_row_key(row)} present in BENCH_engine.json")
        return

    _check(failures, row["purity"] >= base["purity"] - PURITY_SLACK,
           f"purity {row['purity']:.3f} >= "
           f"{base['purity']:.3f} - {PURITY_SLACK}")
    _check(failures,
           row["n_clusters_recovered"] == base["n_clusters_recovered"],
           f"n_clusters_recovered {row['n_clusters_recovered']} == "
           f"{base['n_clusters_recovered']}")
    _check(failures, row["comm_bytes"] == base["comm_bytes"],
           f"comm_bytes {row['comm_bytes']:g} == {base['comm_bytes']:g}")
    for phase in ("aggregate_s", "total_s"):
        cap = base["phases"][phase] * TIME_MULT + TIME_SLACK_S
        _check(failures, row["phases"][phase] <= cap,
               f"{phase} {row['phases'][phase]:.2f}s <= {cap:.2f}s "
               f"(baseline {base['phases'][phase]:.2f}s)")
    if base.get("device_peak_bytes"):
        cap = base["device_peak_bytes"] * MEM_MULT + MEM_SLACK_B
        _check(failures, row["device_peak_bytes"] <= cap,
               f"device_peak_bytes {row['device_peak_bytes']} <= {cap:.0f}")
    if base.get("route_p50_ms"):
        cap = base["route_p50_ms"] * ROUTE_MULT + ROUTE_SLACK_MS
        _check(failures, row["route_p50_ms"] <= cap,
               f"route_p50_ms {row['route_p50_ms']:.3f} <= {cap:.3f}")
    if base.get("refinalize_warm_p50_ms"):
        cap = base["refinalize_warm_p50_ms"] * ROUTE_MULT + ROUTE_SLACK_MS
        _check(failures,
               row.get("refinalize_warm_p50_ms") is not None
               and row["refinalize_warm_p50_ms"] <= cap,
               f"refinalize_warm_p50_ms {row.get('refinalize_warm_p50_ms')} "
               f"<= {cap:.3f}")
    if base.get("route_batch_ms"):
        cap = base["route_batch_ms"] * ROUTE_MULT + ROUTE_SLACK_MS
        _check(failures,
               row.get("route_batch_ms") is not None
               and row["route_batch_ms"] <= cap,
               f"route_batch_ms {row.get('route_batch_ms')} <= {cap:.3f}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="re-run the kmeans-device C=256 row and compare "
                         "against the committed baseline")
    ap.add_argument("--validate-only", action="store_true",
                    help="schema validation only (explicit alias of the "
                         "no-flag default)")
    ap.add_argument("--engine-json", default=ENGINE_JSON)
    ap.add_argument("--robustness-json", default=ROBUSTNESS_JSON)
    ap.add_argument("--serving-json", default=SERVING_JSON)
    args = ap.parse_args(argv)

    failures: list = []
    engine = _load(args.engine_json)
    validate_engine(engine, failures)
    validate_robustness(_load(args.robustness_json), failures)
    validate_serving(_load(args.serving_json), failures)
    if args.quick and not args.validate_only:
        quick_check(engine, failures)

    if failures:
        print(f"[bench-gate] {len(failures)} check(s) failed")
        return 1
    print("[bench-gate] all checks passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
