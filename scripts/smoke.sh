#!/usr/bin/env bash
# Tier-1 smoke: the repo's own test suite + an import-level check of the
# benchmark driver (catches dispatch/API breakage without the multi-minute
# full benchmark run).
set -euo pipefail
cd "$(dirname "$0")/.."

# Deselected: failures already present at the seed commit (c788f4d) —
# kept visible here so a future fix can re-enable them.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    --deselect tests/test_dryrun_integration.py::test_dryrun_single_combo \
    --deselect tests/test_federated.py::test_one_shot_aggregate_recovers_clusters \
    --deselect tests/test_federated.py::test_aggregation_improves_or_matches_local \
    --deselect tests/test_theory_and_baselines.py::test_ifca_needs_many_rounds_where_odcl_needs_one

PYTHONPATH=src python - <<'PY'
import benchmarks.run  # imports every benchmark module
from repro.core import ODCL, get_algorithm, list_algorithms, list_methods
from repro.core.clustering import is_device_algorithm

assert len(list_algorithms()) >= 6, list_algorithms()
assert "odcl" in list_methods()
get_algorithm("kmeans++")
assert is_device_algorithm(get_algorithm("kmeans-device"))
print("benchmark driver imports OK;",
      f"{len(list_algorithms())} clustering algorithms,",
      f"{len(list_methods())} federated methods registered")
PY

# reduced large-C simulation: the device aggregation engine end-to-end
# (wave-batched client gen + local ERMs -> sketch -> kmeans-device ->
# cluster mean, one jitted program)
PYTHONPATH=src python -m repro.launch.simulate \
    --clients 512 --clusters 8 --wave 256 --samples 32 --init spectral
