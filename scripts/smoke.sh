#!/usr/bin/env bash
# Tier-1 smoke: the repo's own test suite + an import-level check of the
# benchmark driver (catches dispatch/API breakage without the multi-minute
# full benchmark run).
set -euo pipefail
cd "$(dirname "$0")/.."

# Streaming-session + edge-set + device convex + hierarchy + serving +
# runtime gates: the newest engine paths fail fast and loudly before the
# multi-minute full suite below.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "not slow" \
    --durations=20 \
    tests/test_session.py tests/test_edges.py tests/test_device_convex.py \
    tests/test_hierarchy.py tests/test_serving.py tests/test_runtime.py

# The fast gate must not silently shrink: @slow markings, marker typos
# and bad deselects all surface as a collected-count drift here.
# Update the expected count when tests are added/removed on purpose.
EXPECTED_FAST_GATE_TESTS=425
collected=$(PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q \
    -m "not slow" --collect-only 2>/dev/null | tail -1 | grep -oE '[0-9]+' | head -1)
if [ "$collected" != "$EXPECTED_FAST_GATE_TESTS" ]; then
    echo "fast gate collected $collected tests, expected" \
         "$EXPECTED_FAST_GATE_TESTS (update scripts/smoke.sh if intended)" >&2
    exit 1
fi

# Fast gate first: the full suite minus the @slow large-C engine runs.
# Deselected: failures already present at the seed commit (c788f4d) —
# kept visible here so a future fix can re-enable them.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "not slow" \
    --durations=20 \
    --deselect tests/test_dryrun_integration.py::test_dryrun_single_combo \
    --deselect tests/test_federated.py::test_one_shot_aggregate_recovers_clusters \
    --deselect tests/test_federated.py::test_aggregation_improves_or_matches_local \
    --deselect tests/test_theory_and_baselines.py::test_ifca_needs_many_rounds_where_odcl_needs_one

PYTHONPATH=src python - <<'PY'
import benchmarks.run  # imports every benchmark module
from repro.core import ODCL, get_algorithm, list_algorithms, list_methods
from repro.core.clustering import is_device_algorithm
from repro.core.engine import AggregationSession, HierarchicalSession
from repro.core.engine import list_aggregators, list_edge_sets, make_aggregator
from repro.core.federated_methods import list_federated_methods
from repro.scenarios import build_scenario, list_scenarios
from repro.serving import BackpressureError, RouteServer, RouteTimeout
from repro import runtime

assert len(list_algorithms()) >= 8, list_algorithms()
assert "odcl" in list_methods()
get_algorithm("kmeans++")
assert is_device_algorithm(get_algorithm("kmeans-device"))
assert is_device_algorithm(get_algorithm("convex-device"))
assert is_device_algorithm(get_algorithm("clusterpath-device"))
assert is_device_algorithm(get_algorithm("gradient-device"))
assert {"complete", "knn", "knn-approx"} <= set(list_edge_sets())
assert callable(AggregationSession)
assert callable(HierarchicalSession)
assert {"odcl", "ifca", "fedavg", "local-only"} <= set(list_federated_methods())
assert {"mean", "trimmed_mean", "median",
        "geometric_median"} <= set(list_aggregators())
assert make_aggregator("trimmed_mean", beta=0.2).beta == 0.2
assert make_aggregator("geometric_median").breakdown == 0.5
assert callable(RouteServer) and issubclass(RouteTimeout, Exception)
assert issubclass(BackpressureError, Exception)
assert callable(runtime.apply_env_presets)
assert {"drift", "longtail", "byzantine", "dp"} <= set(list_scenarios())
assert build_scenario("longtail+byzantine", frac=0.1).transforms_sketches is False
print("benchmark driver imports OK;",
      f"{len(list_algorithms())} clustering algorithms,",
      f"{len(list_methods())} federated methods,",
      f"{len(list_federated_methods())} LM-scale federated methods,",
      f"{len(list_edge_sets())} edge sets,",
      f"{len(list_aggregators())} aggregators,",
      f"{len(list_scenarios())} scenarios registered")
PY

# reduced large-C simulation: the device aggregation engine end-to-end
# (wave-batched client gen + local ERMs -> sketch -> kmeans-device ->
# cluster mean, one jitted program)
PYTHONPATH=src python -m repro.launch.simulate \
    --clients 512 --clusters 8 --wave 256 --samples 32 --init spectral

# the same federation through the two-level hierarchical round (4 shard
# sessions, then the shard centers clustered at the top level)
PYTHONPATH=src python -m repro.launch.simulate \
    --clients 512 --clusters 8 --wave 128 --samples 32 --shards 4

# adversity gate: 10% sign-flip Byzantine clients survived by the
# trimmed-mean aggregator (robust center update + step-3 reduction +
# trimmed-objective restart selection, all inside the jitted round)
PYTHONPATH=src python -m repro.launch.simulate \
    --clients 256 --clusters 4 --wave 128 --samples 32 \
    --init random --restarts 4 \
    --scenario byzantine --byzantine-frac 0.1 \
    --aggregator trimmed_mean --trim-beta 0.25

# mutable serving: keyed drifted re-uploads + churned-in joiners under
# the sliding-window staleness policy, drift-triggered warm re-finalize
# and the one-program batched route
PYTHONPATH=src python -m repro.launch.simulate \
    --clients 256 --clusters 4 --wave 128 --samples 32 \
    --route-probes 32 --finalize-repeats 3 \
    --reupload-frac 0.25 --churn 32 --max-age 2 --refinalize-threshold 1.5

# same federation through the iterative baseline (sketch-assign rounds)
PYTHONPATH=src python -m repro.launch.simulate \
    --clients 256 --clusters 4 --wave 128 --samples 32 --init spectral \
    --method ifca --rounds 3

# the convex family on the same federation (K-free exact-lambda ODCL-CC
# through the device AMA + fusion-graph components, one jitted round)
PYTHONPATH=src python -m repro.launch.simulate \
    --clients 128 --clusters 4 --wave 64 --samples 32 \
    --algorithm convex --sketch-dim 32

# the same convex round over the sparse mutual-kNN fusion graph (the
# EdgeSet registry path that scales ODCL-CC past the C=4k edge wall)
PYTHONPATH=src python -m repro.launch.simulate \
    --clients 128 --clusters 4 --wave 64 --samples 32 \
    --algorithm convex-device --edges knn --knn-k 6 --sketch-dim 32

# reduced deep-model drivers through the FederatedMethod registry:
# the one-shot round on the device engine, and IFCA's round loop
PYTHONPATH=src python -m repro.launch.train --reduced --clients 4 \
    --clusters 2 --local-steps 4 --post-steps 0 --batch 2 --seq-len 16 \
    --method odcl --engine device --sketch-dim 32

# same reduced train run, but clustered by the device convex family
PYTHONPATH=src python -m repro.launch.train --reduced --clients 4 \
    --clusters 2 --local-steps 4 --post-steps 0 --batch 2 --seq-len 16 \
    --method odcl --engine device --algo convex --sketch-dim 32
PYTHONPATH=src python -m repro.launch.train --reduced --clients 4 \
    --clusters 2 --local-steps 3 --batch 2 --seq-len 16 \
    --method ifca --rounds 2 --warmup-steps 3 --sketch-dim 32 \
    --ifca-carry-opt

# sketch-routed serving: train a reduced federation to a checkpoint,
# then serve the cluster model the client's sketch routes to (the
# AggregationSession rebuilt from the stacked checkpoint)
SMOKE_CKPT="$(mktemp -d)"
trap 'rm -rf "$SMOKE_CKPT"' EXIT
PYTHONPATH=src python -m repro.launch.train --reduced --clients 4 \
    --clusters 2 --local-steps 4 --post-steps 0 --batch 2 --seq-len 16 \
    --method odcl --engine device --sketch-dim 32 --ckpt-dir "$SMOKE_CKPT"
PYTHONPATH=src python -m repro.launch.serve --reduced --batch 2 \
    --prompt-len 8 --gen 4 --ckpt-dir "$SMOKE_CKPT" --route-by-sketch \
    --clusters 2 --client 3 --route-sketch-dim 32

# concurrent serving gate: tiny closed-loop load generation through the
# RouteServer (cross-caller batching, bounded queue, request timeouts)
# with a floor on sustained route throughput.  No --require-criterion:
# at 2 callers there is not enough concurrency for batching to win; the
# full-size criterion lives in the committed BENCH_serving.json and is
# validated by the check_bench_regression gate at the bottom.
PYTHONPATH=src python -m repro.serving.loadgen \
    --clients 256 --clusters 4 --sketch-dim 32 --callers 2 --duration 2 \
    --max-batch 16 --no-ingest --floor-qps 50 \
    --out "$SMOKE_CKPT/BENCH_serving.json"

# reduced robustness bench: Byzantine x aggregator + DP-epsilon sweeps
# end-to-end, written to a throwaway path (the committed
# BENCH_robustness.json comes from the full-size run)
PYTHONPATH=src python -m benchmarks.bench_robustness --reduced \
    --out "$SMOKE_CKPT/BENCH_robustness.json"
python - "$SMOKE_CKPT/BENCH_robustness.json" <<'PY'
import json, sys
report = json.load(open(sys.argv[1]))
assert report["bench"] == "robustness" and report["rows"]
for row in report["rows"]:
    assert {"scenario", "aggregator", "purity"} <= set(row), sorted(row)
print(f"bench_robustness --reduced OK ({len(report['rows'])} rows)")
PY

# benchmark regression gate: BENCH_*.json schema validation + a re-run
# of the cheapest engine row compared against the committed baseline
PYTHONPATH=src python scripts/check_bench_regression.py --quick
