"""Theory quantities (Table 1 / Theorem 1) and the IFCA baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IFCAConfig, ifca, ifca_init_annulus, theory
from repro.core.erm import batched_ridge_erm
from repro.core.odcl import odcl
from repro.data import make_linear_regression_federation


def test_constant_M_positive_and_monotone_in_d():
    c1 = theory.ProblemConstants(L=1, mu_F=0.5, R=10, d=5, G_F=1.0)
    c2 = theory.ProblemConstants(L=1, mu_F=0.5, R=10, d=50, G_F=1.0)
    assert 0 < theory.constant_M(c1) < theory.constant_M(c2)


def test_sample_threshold_solves_inequality():
    n = theory.sample_threshold(M=10.0, alpha=4.0, D=2.0, gamma=0.5)
    assert n / np.log(n) > 4 * 10 * 16 / 1.0
    # slightly smaller n must violate
    m = n * 0.9
    assert m / np.log(m) <= 4 * 10 * 16 / 1.0 * 1.001


def test_cc_threshold_above_km_threshold_small_clusters():
    # |C_(K)| <= sqrt(m): CC pays ~m-factor more samples (Section 4.2)
    km = theory.threshold_odcl_km(M=1.0, m=100, c_min=5, D=4.0, gamma=0.5)
    cc = theory.threshold_odcl_cc(M=1.0, m=100, c_min=5, D=4.0, gamma=0.5)
    assert cc > km


def test_ifca_comm_rounds_formula():
    t = theory.ifca_comm_rounds(kappa=10, p=0.1, D=1.0, eps=0.01)
    assert t == pytest.approx(800 * np.log(200))
    # ODCL uses exactly 1 round: saving factor = t
    assert t > 1000


def test_merge_condition_appendix_f():
    # equal sample sizes: eps < 1/(2n)
    assert theory.merge_condition(100, 100) == pytest.approx(1 / 200)
    assert theory.merge_condition(50, 200) < theory.merge_condition(100, 100)


def test_ifca_converges_with_good_init():
    fed = make_linear_regression_federation(seed=3, m=40, K=4, n=100)

    def loss_fn(t, x, y):
        r = x @ t - y
        return jnp.mean(r * r)

    grad_fn = jax.grad(loss_fn)
    key = jax.random.PRNGKey(0)
    theta0 = ifca_init_annulus(key, jnp.asarray(fed.optima), fed.D)
    cfg = IFCAConfig(k=4, rounds=120, step_size=0.1)
    thetaT, labels, hist = ifca(theta0, jnp.asarray(fed.xs),
                                jnp.asarray(fed.ys), loss_fn, grad_fn, cfg)
    err = float(jnp.mean(jnp.sum(
        (thetaT - jnp.asarray(fed.optima)) ** 2, -1)))
    err0 = float(jnp.mean(jnp.sum(
        (theta0 - jnp.asarray(fed.optima)) ** 2, -1)))
    assert err < 0.1 * err0
    # users assigned to the matching model
    from collections import Counter

    labels = np.asarray(labels)
    for c in np.unique(labels):
        assert len(Counter(fed.true_labels[labels == c])) == 1


def test_ifca_needs_many_rounds_where_odcl_needs_one():
    """Fig. 4 behaviour: at n in the order-optimal regime, one-shot ODCL
    reaches oracle MSE that IFCA needs tens of rounds to approach."""
    fed = make_linear_regression_federation(seed=4, m=40, K=4, n=200)
    local = np.asarray(batched_ridge_erm(
        jnp.asarray(fed.xs), jnp.asarray(fed.ys), 1e-8))
    res = odcl(local, algorithm="kmeans++", k=4)
    opt = fed.optima[fed.true_labels]
    odcl_err = float(np.mean(np.sum((res.user_models - opt) ** 2, 1)))

    def loss_fn(t, x, y):
        r = x @ t - y
        return jnp.mean(r * r)

    grad_fn = jax.grad(loss_fn)
    theta0 = ifca_init_annulus(jax.random.PRNGKey(1),
                               jnp.asarray(fed.optima), fed.D)
    cfg = IFCAConfig(k=4, rounds=5, step_size=0.1)
    thetaT, labels, _ = ifca(theta0, jnp.asarray(fed.xs), jnp.asarray(fed.ys),
                             loss_fn, grad_fn, cfg)
    ifca5 = float(jnp.mean(jnp.sum(
        (thetaT[np.asarray(labels)] - jnp.asarray(opt)) ** 2, -1)))
    assert odcl_err < ifca5, (odcl_err, ifca5)
