"""Serving-path consistency: decode == full forward; prefill_with_cache
== token-by-token decode; ring buffers; recurrent-state carry."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    decode_step,
    forward,
    init_decode_cache,
    init_params,
)
from repro.models.transformer import prefill_with_cache

# every decode-vs-forward case costs 8-16s (token-by-token decode loop);
# the fast gate keeps the cheapest arch as representative and the full
# sweep runs under -m slow
_FAST_DECODE_ARCH = "xlstm_125m"
DECODE_ARCHS = [
    a if a == _FAST_DECODE_ARCH else pytest.param(a, marks=pytest.mark.slow)
    for a in ARCH_IDS if a != "hubert_xlarge"]


def _tok_cfg(arch, **overrides):
    cfg = get_config(arch).reduced()
    # pure-token mode so decode and forward see identical inputs
    if cfg.input_mode != "tokens":
        cfg = dataclasses.replace(cfg, input_mode="tokens")
    if cfg.is_moe:
        # avoid capacity drops: they legitimately differ between batch sizes
        cfg = dataclasses.replace(cfg, capacity_factor=4.0)
    return dataclasses.replace(cfg, **overrides)


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = _tok_cfg(arch, serve_window=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, {"tokens": toks, "labels": toks})
    cache = init_decode_cache(cfg, b, context=s)
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(dec - full))) / scale < 1e-3


@pytest.mark.parametrize("arch", [
    pytest.param("qwen2_0_5b", marks=pytest.mark.slow), "xlstm_125m",
    pytest.param("hymba_1_5b", marks=pytest.mark.slow)])
def test_prefill_cache_matches_decode(arch):
    cfg = _tok_cfg(arch, serve_window=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s, gen = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + gen), 0,
                              cfg.vocab_size)
    logits_p, cache = prefill_with_cache(params, cfg, {"tokens": toks[:, :s]},
                                         capacity=s + gen)
    full, _ = forward(params, cfg, {"tokens": toks[:, :s], "labels": toks[:, :s]})
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full),
                               rtol=1e-4, atol=1e-4)
    outs_a = []
    for t in range(s, s + gen):
        lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1])
        outs_a.append(np.asarray(lg[:, 0]))
    cache_b = init_decode_cache(cfg, b, context=s + gen)
    for t in range(s + gen):
        lg, cache_b = decode_step(params, cfg, cache_b, toks[:, t:t + 1])
        if t >= s:
            np.testing.assert_allclose(outs_a[t - s], np.asarray(lg[:, 0]),
                                       rtol=1e-3, atol=1e-3)


@pytest.mark.slow
def test_ring_buffer_windowed_decode():
    """Sliding-window serving: cache capacity < sequence length."""
    cfg = _tok_cfg("qwen2_0_5b", serve_window=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s, gen = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + gen), 0,
                              cfg.vocab_size)
    _, cache = prefill_with_cache(params, cfg, {"tokens": toks[:, :s]},
                                  capacity=8)
    assert cache.layers["k"].shape[3] == 8
    outs_a = []
    for t in range(s, s + gen):
        lg, cache = decode_step(params, cfg, cache, toks[:, t:t + 1])
        outs_a.append(np.asarray(lg[:, 0]))
    cache_b = init_decode_cache(cfg, b, context=8)
    for t in range(s + gen):
        lg, cache_b = decode_step(params, cfg, cache_b, toks[:, t:t + 1])
        if t >= s:
            np.testing.assert_allclose(outs_a[t - s], np.asarray(lg[:, 0]),
                                       rtol=1e-3, atol=1e-3)


def test_windowed_matches_full_within_window():
    """With pos < window the windowed model equals the full model."""
    full_cfg = _tok_cfg("yi_9b", serve_window=None)
    win_cfg = dataclasses.replace(full_cfg, serve_window=64)
    params = init_params(jax.random.PRNGKey(0), full_cfg)
    b, s = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                              full_cfg.vocab_size)
    cache_f = init_decode_cache(full_cfg, b, context=64)
    cache_w = init_decode_cache(win_cfg, b, context=64)
    for t in range(s):
        lf, cache_f = decode_step(params, full_cfg, cache_f, toks[:, t:t + 1])
        lw, cache_w = decode_step(params, win_cfg, cache_w, toks[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lw),
                               rtol=1e-5, atol=1e-5)


def test_xlstm_state_is_o1():
    """Recurrent archs carry O(1) decode state (no KV growth)."""
    cfg = _tok_cfg("xlstm_125m")
    cache = init_decode_cache(cfg, batch=2, context=1_000_000)
    n_bytes = sum(np.prod(l.shape) * l.dtype.itemsize
                  for l in jax.tree_util.tree_leaves(cache.layers))
    assert n_bytes < 50e6, "xLSTM state must not scale with context"
