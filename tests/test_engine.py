"""Aggregation-engine validation: device kmeans vs host parity oracle,
fused-kernel block-boundary sweeps, the zero-host-transfer contract of
the jitted one-shot round, and the large-C simulation driver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clustering import (
    ClusteringResult,
    get_algorithm,
    is_device_algorithm,
    kmeans,
    list_algorithms,
)
from repro.core.engine import device_kmeans
from repro.core.federated import FederatedState, one_shot_aggregate
from repro.kernels import ref
from repro.kernels.kmeans_assign import kmeans_assign_pallas
from repro.launch.simulate import simulate
from repro.optim import adamw_init

from conftest import same_partition


def make_blobs(seed, k=3, per=12, d=8, sep=12.0, noise=0.3):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d))
    dists = np.linalg.norm(centers[:, None] - centers[None], axis=-1)
    np.fill_diagonal(dists, np.inf)
    centers *= sep / dists.min()
    pts = np.concatenate(
        [c + noise * rng.normal(size=(per, d)) for c in centers])
    labels = np.repeat(np.arange(k), per)
    return pts.astype(np.float32), labels


def blob_state(seed=0, k=3, per=16, d=8):
    pts, true = make_blobs(seed, k=k, per=per, d=d)
    params = {"theta": jnp.asarray(pts)}
    return FederatedState(params=params,
                          opt_state=jax.vmap(adamw_init)(params),
                          n_clients=len(pts)), true


# ------------------------------------------------------ registry plumbing

def test_kmeans_device_registered_and_device_capable():
    assert "kmeans-device" in list_algorithms()
    algo = get_algorithm("kmeans-device")
    assert is_device_algorithm(algo)
    assert not is_device_algorithm(get_algorithm("kmeans++"))
    assert not is_device_algorithm(get_algorithm("convex"))


def test_kmeans_device_host_call_returns_clustering_result():
    pts, true = make_blobs(0)
    res = get_algorithm("kmeans-device")(jax.random.PRNGKey(0), pts, k=3)
    assert isinstance(res, ClusteringResult)
    assert res.n_clusters == 3
    assert same_partition(res.labels, true)
    assert res.meta["n_iter"] >= 1


# ------------------------------------------------- device vs host parity

@pytest.mark.parametrize("init", ["kmeans++", "spectral", "random"])
def test_device_kmeans_matches_host_kmeans(init):
    pts, _ = make_blobs(1, k=4, per=10, d=6)
    key = jax.random.PRNGKey(7)
    host = kmeans(key, jnp.asarray(pts), 4, init=init)
    dev = device_kmeans(key, jnp.asarray(pts), 4, init=init)
    assert same_partition(np.asarray(host.labels), np.asarray(dev.labels))
    np.testing.assert_allclose(float(dev.inertia), float(host.inertia),
                               rtol=1e-3, atol=1e-3)
    assert int(dev.n_iter) == int(host.n_iter)


# -------------------------------------- fused kernel at block boundaries

@pytest.mark.parametrize("m,k,d,bm", [
    (13, 3, 5, 8),      # non-multiple of bm: one padded tail block
    (300, 7, 33, 128),  # multi-block grid + padded tail
    (256, 4, 16, 256),  # exact single block
    (5, 2, 4, 256),     # m smaller than bm
])
def test_kmeans_assign_pallas_block_boundaries(m, k, d, bm):
    rng = np.random.default_rng(m * 31 + k)
    pts = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    cts = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    lab_p, sum_p, cnt_p = kmeans_assign_pallas(pts, cts, bm=bm,
                                               interpret=True)
    lab_r, sum_r, cnt_r = ref.kmeans_assign(pts, cts)
    np.testing.assert_array_equal(np.asarray(lab_p), np.asarray(lab_r))
    np.testing.assert_allclose(np.asarray(sum_p), np.asarray(sum_r),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cnt_p), np.asarray(cnt_r))


# ------------------------------------------- one-shot round on the engine

def test_device_engine_matches_host_engine_labels():
    state, true = blob_state()
    kwargs = dict(algorithm="kmeans-device", k=3, sketch_dim=32, seed=3)
    _, lab_host, info_host = one_shot_aggregate(state, None, engine="host",
                                                **kwargs)
    _, lab_dev, info_dev = one_shot_aggregate(state, None, engine="device",
                                              **kwargs)
    assert info_host["engine"] == "host"
    assert info_dev["engine"] == "device"
    assert same_partition(lab_host, lab_dev)
    assert same_partition(lab_dev, true)
    assert info_dev["n_clusters"] == info_host["n_clusters"] == 3


def test_device_engine_shares_models_within_cluster():
    state, _ = blob_state()
    new_state, labels, _ = one_shot_aggregate(
        state, None, algorithm="kmeans-device", k=3, sketch_dim=32)
    theta = np.asarray(new_state.params["theta"])
    for c in np.unique(labels):
        members = np.where(labels == c)[0]
        np.testing.assert_allclose(
            theta[members], np.broadcast_to(theta[members[0]],
                                            theta[members].shape),
            rtol=1e-6, atol=1e-6)


def _arrays_of_shape(obj, shape):
    """All ndarray leaves of a nested dict matching ``shape``."""
    found = []
    if isinstance(obj, dict):
        for v in obj.values():
            found += _arrays_of_shape(v, shape)
    elif isinstance(obj, (np.ndarray, jnp.ndarray)) and obj.shape == shape:
        found.append(obj)
    return found


def test_device_engine_no_host_sketch_transfer():
    state, _ = blob_state()
    sketch_dim = 32
    full = (state.n_clients, sketch_dim)
    _, _, info = one_shot_aggregate(state, None, algorithm="kmeans-device",
                                    k=3, sketch_dim=sketch_dim)
    assert not _arrays_of_shape(info, full), \
        "one-shot info must not materialize the (C, sketch_dim) sketches"
    _, _, info = one_shot_aggregate(state, None, algorithm="kmeans-device",
                                    k=3, sketch_dim=sketch_dim,
                                    return_sketches=True)
    assert len(_arrays_of_shape(info, full)) == 1  # opt-in still works


def test_host_engine_sketches_are_opt_in_too():
    state, _ = blob_state()
    _, _, info = one_shot_aggregate(state, None, algorithm="kmeans++", k=3,
                                    sketch_dim=32)
    assert "sketches" not in info
    _, _, info = one_shot_aggregate(state, None, algorithm="kmeans++", k=3,
                                    sketch_dim=32, return_sketches=True)
    assert info["sketches"].shape == (state.n_clients, 32)


def test_cluster_seed_reaches_device_engine():
    state, true = blob_state()
    _, lab_dev, info_dev = one_shot_aggregate(
        state, None, algorithm="kmeans-device", k=3, cluster_seed=11,
        sketch_dim=32)
    _, lab_host, _ = one_shot_aggregate(
        state, None, algorithm="kmeans-device", k=3, cluster_seed=11,
        sketch_dim=32, engine="host")
    assert info_dev["engine"] == "device"
    assert same_partition(lab_dev, lab_host)
    assert same_partition(lab_dev, true)


def test_auto_engine_assert_separable_falls_back_to_host():
    state, true = blob_state()
    _, labels, info = one_shot_aggregate(
        state, None, algorithm="kmeans-device", k=3, assert_separable=True,
        sketch_dim=32)
    assert info["engine"] == "host"          # auto fell back, no raise
    assert "separability_alpha" in info["meta"]
    assert same_partition(labels, true)
    with pytest.raises(ValueError, match="assert_separable"):
        one_shot_aggregate(state, None, algorithm="kmeans-device", k=3,
                           assert_separable=True, sketch_dim=32,
                           engine="device")


def test_device_engine_rejects_host_only_algorithm():
    state, _ = blob_state()
    with pytest.raises(ValueError, match="device"):
        one_shot_aggregate(state, None, algorithm="kmeans++", k=3,
                           engine="device")


# ----------------------------------------------------- simulation driver

def test_simulate_small_federation_recovers_clusters():
    summary = simulate(clients=128, clusters=4, dim=8, samples=64, wave=64,
                       sketch_dim=32, seed=0, restarts=4)
    assert summary["purity"] == 1.0
    assert summary["n_clusters_recovered"] == 4
    assert summary["phases"]["local_erm_s"] > 0
    assert summary["phases"]["aggregate_s"] > 0


def test_simulate_logistic_task():
    summary = simulate(clients=64, clusters=2, dim=4, samples=128, wave=32,
                       task="logistic", sketch_dim=16, seed=1)
    assert summary["purity"] >= 0.9
    assert summary["n_clusters_recovered"] == 2


@pytest.mark.slow
def test_simulate_large_c():
    """C >= 4k wave-batched simulation (the engine's target regime)."""
    summary = simulate(clients=4096, clusters=8, dim=16, samples=64,
                       wave=2048, sketch_dim=64, seed=0)
    assert summary["purity"] >= 0.99
    assert summary["n_clusters_recovered"] == 8


# ---------------------------------- degenerate one-shot shapes (ISSUE 3)
# (the hypothesis-drawn shape/parity properties are in
# tests/test_engine_properties.py; these fixed degenerate cases run even
# without the optional hypothesis dependency)

@pytest.mark.parametrize("engine", ["host", "device"])
def test_one_shot_k1_collapses_to_global_mean(engine):
    pts, _ = make_blobs(3, k=1, per=13, d=5)   # C=13: not a block multiple
    params = {"theta": jnp.asarray(pts)}
    state = FederatedState(params=params,
                           opt_state=jax.vmap(adamw_init)(params),
                           n_clients=len(pts))
    new_state, labels, info = one_shot_aggregate(
        state, None, algorithm="kmeans-device", k=1, sketch_dim=8,
        engine=engine)
    assert info["n_clusters"] == 1
    assert np.all(np.asarray(labels) == 0)
    np.testing.assert_allclose(
        np.asarray(new_state.params["theta"]),
        np.broadcast_to(pts.mean(0), pts.shape), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("engine", ["host", "device"])
def test_one_shot_c_equals_k_is_identity(engine):
    pts, _ = make_blobs(4, k=5, per=1, d=6, sep=40.0, noise=0.0)
    params = {"theta": jnp.asarray(pts)}
    state = FederatedState(params=params,
                           opt_state=jax.vmap(adamw_init)(params),
                           n_clients=len(pts))
    new_state, labels, info = one_shot_aggregate(
        state, None, algorithm="kmeans-device", k=5, sketch_dim=16,
        engine=engine)
    # every client is its own cluster -> averaging changes nothing
    assert info["n_clusters"] == 5
    assert len(np.unique(labels)) == 5
    np.testing.assert_allclose(np.asarray(new_state.params["theta"]), pts,
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("engine", ["host", "device"])
def test_one_shot_duplicate_clients_share_label(engine):
    # two distinct models, each duplicated across many clients: the
    # sketch rows are duplicates within each group and the recovered
    # clustering must be exactly the two groups
    a = np.full((4,), 5.0, np.float32)
    b = np.full((4,), -5.0, np.float32)
    pts = np.stack([a] * 7 + [b] * 6)          # C=13, k=2
    true = np.array([0] * 7 + [1] * 6)
    params = {"theta": jnp.asarray(pts)}
    state = FederatedState(params=params,
                           opt_state=jax.vmap(adamw_init)(params),
                           n_clients=len(pts))
    new_state, labels, info = one_shot_aggregate(
        state, None, algorithm="kmeans-device", k=2, sketch_dim=8,
        engine=engine)
    assert info["n_clusters"] == 2
    assert same_partition(labels, true)
    np.testing.assert_allclose(np.asarray(new_state.params["theta"]), pts,
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------- uniform device meta

def test_device_meta_keys_are_the_contract():
    from repro.core.clustering.api import DEVICE_META_KEYS
    assert DEVICE_META_KEYS == ("inertia", "n_iter", "restarts",
                                "n_clusters", "lam", "restart_spread")


@pytest.mark.parametrize("name,opts", [
    ("kmeans-device", {"restarts": 2}),
    ("convex-device", {"lam": 0.5, "iters": 50}),
    ("clusterpath-device", {"n_lambdas": 4, "iters": 50}),
    ("gradient-device", {"iters": 20}),
])
def test_device_meta_uniform_schema(name, opts):
    """Every device family reports the same typed meta schema: jnp
    scalars on device, int/float/None on host, NaN sentinels for the
    fields a family has no notion of."""
    from repro.core.clustering.api import DEVICE_META_KEYS

    pts, _ = make_blobs(0, k=3, per=8, d=4)
    algo = get_algorithm(name)
    k = 3 if algo.requires_k else None
    res = algo.device_call(jax.random.PRNGKey(0), jnp.asarray(pts), k=k,
                           **opts)
    assert set(res.meta) == set(DEVICE_META_KEYS)
    for v in res.meta.values():
        assert isinstance(v, jnp.ndarray) and v.shape == ()

    host = algo(jax.random.PRNGKey(0), pts, k=k, **opts)
    assert set(host.meta) == set(DEVICE_META_KEYS)
    for key_ in ("n_iter", "restarts", "n_clusters"):
        assert isinstance(host.meta[key_], int), key_
    assert isinstance(host.meta["inertia"], float)
    assert host.meta["inertia"] >= 0.0
    assert host.meta["n_iter"] >= 1
    # n_clusters in meta agrees with the compacted host result
    assert host.meta["n_clusters"] == host.n_clusters

    if name == "kmeans-device":
        # Lloyd: restart diagnostics real, lambda not a concept -> None
        assert host.meta["lam"] is None
        assert host.meta["restarts"] == 2
        assert isinstance(host.meta["restart_spread"], float)
    if name == "kmeans-device" or name == "gradient-device":
        assert host.meta["lam"] is None
    if name == "convex-device":
        # convex: lambda real, restart machinery not a concept -> None
        assert host.meta["lam"] == pytest.approx(0.5)
        assert host.meta["restart_spread"] is None
        assert host.meta["restarts"] == 1
