"""The jittable one-shot aggregation step (launch.steps.make_aggregate_step):
single-device correctness — cluster recovery + exact cluster means."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_aggregate_step
from repro.models import init_params


def test_aggregate_step_recovers_and_averages():
    cfg = get_config("qwen2_0_5b").reduced(max_d_model=64, max_vocab=64)
    base = init_params(jax.random.PRNGKey(0), cfg)
    # 6 clients in 2 synthetic clusters: cluster B offset by a large delta
    def offset(p, delta):
        return jax.tree_util.tree_map(lambda l: l + delta, p)

    clients = [offset(base, 0.01 * i) for i in range(3)] + \
              [offset(base, 5.0 + 0.01 * i) for i in range(3)]
    stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *clients)

    step = jax.jit(make_aggregate_step(cfg, k=2, sketch_dim=128))
    new_params, labels = step(stacked, jax.random.PRNGKey(1))
    labels = np.asarray(labels)
    assert set(labels[:3]) != set(labels[3:]) or len(set(labels)) == 2
    assert len(set(labels[:3])) == 1 and len(set(labels[3:])) == 1

    # every client's new params equal its cluster's mean
    emb = np.asarray(stacked["embed"], np.float32)
    new_emb = np.asarray(new_params["embed"], np.float32)
    for c in set(labels):
        members = np.where(labels == c)[0]
        want = emb[members].mean(axis=0)
        for m in members:
            np.testing.assert_allclose(new_emb[m], want, rtol=1e-4, atol=1e-4)
