"""Integration: the multi-pod dry-run entry point end-to-end (subprocess,
because dryrun.py must own the 512-device XLA flag before jax init)."""
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=480)


def test_dryrun_single_combo(tmp_path):
    out = tmp_path / "d.jsonl"
    r = _run(["--arch", "xlstm_125m", "--shape", "long_500k",
              "--json", str(out)])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["status"] == "OK"
    assert rec["chips"] == 256
    assert rec["mesh"] == "16x16"
    assert rec["peak_bytes_per_device"] < 2 ** 30   # O(1) recurrent state
    assert "roofline" in rec and rec["roofline"]["bottleneck"] in (
        "compute", "memory", "collective")


def test_dryrun_skip_rule(tmp_path):
    out = tmp_path / "d.jsonl"
    r = _run(["--arch", "hubert_xlarge", "--shape", "decode_32k",
              "--json", str(out)])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["status"] == "SKIP"
    assert "encoder-only" in rec["reason"]
