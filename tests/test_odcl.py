"""End-to-end ODCL behaviour on the paper's synthetic setups (Section 5):
order-optimality vs oracles, superiority over naive/local baselines,
phase transition in the sample size."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import batched_ridge_erm, odcl, oracles
from repro.core.erm import ridge_erm
from repro.data import make_linear_regression_federation


def nmse(models, fed):
    opt = fed.optima[fed.true_labels]
    return float(np.mean(np.sum((models - opt) ** 2, 1) / np.sum(opt ** 2, 1)))


@pytest.fixture(scope="module")
def fed():
    return make_linear_regression_federation(seed=0, n=200)


@pytest.fixture(scope="module")
def local_models(fed):
    return np.asarray(batched_ridge_erm(
        jnp.asarray(fed.xs), jnp.asarray(fed.ys), 1e-8))


def test_odcl_km_matches_oracle_averaging(fed, local_models):
    res = odcl(local_models, algorithm="kmeans++", k=10)
    oa = oracles.oracle_averaging(local_models, fed.true_labels)
    assert res.n_clusters == 10
    assert nmse(res.user_models, fed) == pytest.approx(nmse(oa, fed), rel=1e-5)


def test_odcl_cc_matches_oracle_averaging(fed, local_models):
    res = odcl(local_models, algorithm="clusterpath", n_lambdas=8,
               iters=300)
    oa = oracles.oracle_averaging(local_models, fed.true_labels)
    assert res.n_clusters == 10
    assert nmse(res.user_models, fed) == pytest.approx(nmse(oa, fed), rel=1e-5)


def test_odcl_beats_local_and_naive(fed, local_models):
    res = odcl(local_models, algorithm="kmeans++", k=10)
    assert nmse(res.user_models, fed) < 0.5 * nmse(
        oracles.local_erm(local_models), fed)
    assert nmse(res.user_models, fed) < 0.01 * nmse(
        oracles.naive_averaging(local_models), fed)


def test_cluster_oracle_is_best(fed, local_models):
    co = oracles.cluster_oracle(lambda x, y: ridge_erm(
        jnp.asarray(x), jnp.asarray(y), 1e-8), fed.xs, fed.ys, fed.true_labels)
    res = odcl(local_models, algorithm="kmeans++", k=10)
    # ODCL approaches but does not beat pooled-data training
    assert nmse(co, fed) <= nmse(res.user_models, fed) * 1.5


def test_gradient_clustering_variant(fed, local_models):
    res = odcl(local_models, algorithm="gradient", k=10)
    oa = oracles.oracle_averaging(local_models, fed.true_labels)
    assert nmse(res.user_models, fed) == pytest.approx(nmse(oa, fed), rel=1e-4)


def test_sample_size_phase_transition():
    """MSE(n) must (a) decay with n and (b) reach the oracle regime."""
    errs, oracle_errs = [], []
    for n in (25, 100, 400):
        fed = make_linear_regression_federation(seed=1, n=n)
        local = np.asarray(batched_ridge_erm(
            jnp.asarray(fed.xs), jnp.asarray(fed.ys), 1e-8))
        res = odcl(local, algorithm="kmeans++", k=10)
        errs.append(nmse(res.user_models, fed))
        oracle_errs.append(nmse(
            oracles.oracle_averaging(local, fed.true_labels), fed))
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] == pytest.approx(oracle_errs[2], rel=1e-3)


def test_odcl_perfect_recovery_labels(fed, local_models):
    from collections import Counter

    res = odcl(local_models, algorithm="kmeans++", k=10)
    for c in range(res.n_clusters):
        members = fed.true_labels[res.labels == c]
        assert len(Counter(members)) == 1, "recovered clusters must be pure"
