"""Sharding-rule unit tests (no multi-device requirement): specs mirror
the parameter tree, respect divisibility, and never shard ring capacity."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import inputs as inp
from repro.models import transformer as tr
from repro.sharding import ShardingRules, batch_spec, cache_specs, param_specs


class FakeMesh:
    """Just enough of a Mesh for the spec builders."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.zeros(shape)


MESH = FakeMesh((16, 16), ("data", "model"))
RULES = ShardingRules(data_axes=("data",))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_structure_and_divisibility(arch):
    cfg = get_config(arch)
    params = tr.abstract_params(cfg)
    specs = param_specs(cfg, params, RULES, MESH)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    sizes = {"data": 16, "model": 16}
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= leaf.ndim
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert leaf.shape[dim] % total == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "grok_1_314b", "xlstm_125m",
                                  "hymba_1_5b"])
def test_cache_specs_never_shard_capacity(arch):
    cfg = get_config(arch)
    shape = inp.INPUT_SHAPES["decode_32k"]
    cache_sds, _ = inp.decode_input_specs(cfg, shape)
    specs = cache_specs(cfg, cache_sds, RULES, MESH)

    def check(path, spec):
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        if name.endswith("/k") or name.endswith("/v"):
            # dims: (L, b, hkv, cap, dh); cap (index 3) must be None
            assert len(spec) < 4 or spec[3] is None, (name, spec)

    jax.tree_util.tree_map_with_path(check, specs,
                                     is_leaf=lambda x: isinstance(x, P))


def test_batch_spec_skips_indivisible_batch():
    cfg = get_config("qwen2_0_5b")
    fn = batch_spec(cfg, RULES, MESH)
    big = jax.ShapeDtypeStruct((256, 128), np.int32)
    tiny = jax.ShapeDtypeStruct((1, 1), np.int32)
    assert fn(big)[0] == "data"
    assert fn(tiny)[0] is None


def test_moe_expert_sharding_strategies():
    """64 experts -> expert-parallel; 8 experts -> hidden-dim TP."""
    ds = get_config("deepseek_moe_16b")
    gk = get_config("grok_1_314b")
    ds_specs = param_specs(ds, tr.abstract_params(ds), RULES, MESH)
    gk_specs = param_specs(gk, tr.abstract_params(gk), RULES, MESH)
    # (L, E, D, F) layout: index 1 is the expert dim
    assert ds_specs["layers"]["moe"]["w_in"][1] == "model"
    assert gk_specs["layers"]["moe"]["w_in"][1] is None
    assert gk_specs["layers"]["moe"]["w_in"][3] == "model"


def test_client_axis_prepends():
    cfg = get_config("qwen2_0_5b")
    rules = ShardingRules(data_axes=("data",), client_axis="data", fsdp=False)
    params = tr.abstract_params(cfg)
    stacked = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((16,) + l.shape, l.dtype), params)
    specs = param_specs(cfg, stacked, rules, MESH)
    for spec in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)):
        assert spec[0] == "data"


def test_input_specs_cover_all_shapes():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for name, shape in inp.INPUT_SHAPES.items():
            ok, _ = inp.shape_supported(cfg, shape)
            if not ok:
                continue
            specs = inp.input_specs(cfg, shape)
            assert isinstance(specs, dict) and specs


def test_serve_config_decode32k_keeps_full_cache():
    cfg = get_config("yi_9b")
    scfg = inp.serve_config(cfg, inp.INPUT_SHAPES["decode_32k"])
    assert scfg.serve_window is None
    lcfg = inp.serve_config(cfg, inp.INPUT_SHAPES["long_500k"])
    assert lcfg.serve_window == 4096
