"""The LM-scale FederatedMethod registry (core/federated_methods.py).

Covers the four contracts ISSUE 3 pins down: registry round-trip,
ODCLFederated reproducing the pre-refactor train.py flow bit-exactly on
a reduced arch, IFCAFederated recovering a planted 2-cluster federation,
and comm-cost accounting (one-shot = 1 round, IFCA = R rounds).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.federated import (
    FederatedState,
    init_federation,
    local_training,
    one_shot_aggregate,
)
from repro.core.federated_methods import (
    FederatedMethod,
    FederatedMethodResult,
    IFCAFederated,
    LocalOnlyFederated,
    ODCLFederated,
    FedAvgGlobal,
    build_federated_method,
    cluster_agreement,
    get_federated_method,
    list_federated_methods,
    params_bytes_per_client,
    register_federated_method,
    unregister_federated_method,
)
from repro.data import ClusteredTokenStream, make_lm_batch_iterator
from repro.optim import AdamWConfig, adamw_init

from conftest import same_partition


N_CLIENTS, K, BATCH, SEQ = 4, 2, 2, 16


def tiny_cfg():
    return get_config("qwen2_0_5b").reduced(n_layers=1, max_d_model=64,
                                            max_vocab=64)


def make_stream(cfg, seed=0):
    return ClusteredTokenStream(n_clients=N_CLIENTS, n_clusters=K,
                                vocab_size=cfg.vocab_size, seed=seed,
                                branching=4)


def make_iter(stream):
    raw = make_lm_batch_iterator(
        stream, clients_per_batch=list(range(N_CLIENTS)),
        per_client_batch=BATCH, seq_len=SEQ)
    return ({"tokens": t, "labels": l} for t, l in raw)


def blob_state(seed=0, k=3, per=5, d=6, sep=25.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d))
    dists = np.linalg.norm(centers[:, None] - centers[None], axis=-1)
    np.fill_diagonal(dists, np.inf)
    centers *= sep / dists.min()
    pts = np.concatenate(
        [c + 0.2 * rng.normal(size=(per, d)) for c in centers]
    ).astype(np.float32)
    params = {"theta": jnp.asarray(pts)}
    state = FederatedState(params=params,
                           opt_state=jax.vmap(adamw_init)(params),
                           n_clients=len(pts))
    return state, np.repeat(np.arange(k), per)


# ------------------------------------------------------------- registry

def test_registry_prepopulated():
    names = list_federated_methods()
    assert {"odcl", "ifca", "fedavg", "local-only"} <= set(names)
    assert get_federated_method("odcl") is ODCLFederated
    assert get_federated_method("ifca") is IFCAFederated
    assert get_federated_method("fedavg") is FedAvgGlobal
    assert get_federated_method("local-only") is LocalOnlyFederated


def test_registry_round_trip_and_build():
    @dataclasses.dataclass
    class Dummy:
        local_steps: int = 0
        name: str = "dummy-fm"

        def run(self, key, state, cfg, batches=None, *, mesh=None):
            return FederatedMethodResult(
                state=state, labels=np.zeros(state.n_clients, np.int32),
                n_clusters=1, comm_rounds=0, comm_bytes=0,
                round_metrics=[], meta={})

    try:
        register_federated_method(Dummy, name="dummy-fm")
        assert "dummy-fm" in list_federated_methods()
        assert get_federated_method("dummy-fm") is Dummy
        with pytest.raises(ValueError, match="already registered"):
            register_federated_method(Dummy, name="dummy-fm")
        # build_federated_method keeps declared fields, drops the rest
        m = build_federated_method("dummy-fm", local_steps=3,
                                   rounds=7, engine="device")
        assert isinstance(m, Dummy) and m.local_steps == 3
        assert isinstance(m, FederatedMethod)   # protocol conformance
        state, _ = blob_state()
        res = m.run(jax.random.PRNGKey(0), state, None)
        assert isinstance(res, FederatedMethodResult)
    finally:
        unregister_federated_method("dummy-fm")
    with pytest.raises(KeyError, match="dummy-fm"):
        get_federated_method("dummy-fm")


def test_prepopulated_methods_are_protocol_instances():
    for name in ("odcl", "ifca", "fedavg", "local-only"):
        assert isinstance(get_federated_method(name)(), FederatedMethod)


# -------------------------------------- ODCL ≡ legacy train.py flow

def test_odcl_federated_matches_legacy_train_flow_bit_exact():
    """The exact pre-refactor launch/train.py sequence — local_training
    then one_shot_aggregate(algorithm=...) — must be reproduced
    bit-for-bit by ODCLFederated.run on the same batch stream."""
    cfg = tiny_cfg()
    opt = AdamWConfig(lr=1e-3, weight_decay=0.0)
    steps = 6

    # legacy flow (what train.py hardcoded before the registry)
    stream = make_stream(cfg)
    it = make_iter(stream)
    state = init_federation(jax.random.PRNGKey(0), cfg, N_CLIENTS)
    state, _ = local_training(state, cfg, it, steps, opt)
    legacy_state, legacy_labels, _ = one_shot_aggregate(
        state, cfg, algorithm="kmeans++", k=K, sketch_dim=32, seed=0)

    # registry flow
    stream2 = make_stream(cfg)
    method = ODCLFederated(algorithm="kmeans++", k=K, sketch_dim=32,
                           local_steps=steps, opt=opt, seed=0)
    res = method.run(jax.random.PRNGKey(0),
                     init_federation(jax.random.PRNGKey(0), cfg, N_CLIENTS),
                     cfg, make_iter(stream2))

    assert res.comm_rounds == 1
    np.testing.assert_array_equal(res.labels, legacy_labels)
    legacy_leaves = jax.tree_util.tree_leaves(legacy_state.params)
    new_leaves = jax.tree_util.tree_leaves(res.state.params)
    assert len(legacy_leaves) == len(new_leaves)
    for a, b in zip(legacy_leaves, new_leaves):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------- IFCA planted clusters

# sketch-space assignment compares client parameters directly, which
# need the same ~120-step separation the one-shot sketch clustering
# does (see tests/test_federated.py); loss assignment separates sooner
@pytest.mark.parametrize("assign,warmup", [("loss", 40), ("sketch", 120)])
def test_ifca_federated_recovers_planted_clusters(assign, warmup):
    cfg = tiny_cfg()
    stream = make_stream(cfg)
    it = make_iter(stream)
    state = init_federation(jax.random.PRNGKey(0), cfg, N_CLIENTS)
    method = IFCAFederated(k=K, rounds=2, local_steps=5, warmup_steps=warmup,
                           init="clients", assign=assign, sketch_dim=32,
                           opt=AdamWConfig(lr=1e-3, weight_decay=0.0))
    res = method.run(jax.random.PRNGKey(0), state, cfg, it)
    assert res.comm_rounds == 2.0
    assert res.n_clusters == K
    assert same_partition(res.labels, stream.true_labels)
    assert cluster_agreement(res.labels, stream.true_labels) == 1.0
    # personalized models: clients in the same round-final cluster hold
    # models refined from the same broadcast model
    assert len(res.round_metrics) == 2
    assert res.round_metrics[-1]["assign_churn"] <= 0.5


def test_ifca_sketch_rounds_on_shallow_state():
    """cfg=None path (simulate.py): pure sketch-assign/re-average rounds
    still recover planted blob clusters."""
    state, true = blob_state(seed=1, k=3, per=5)
    method = IFCAFederated(k=3, rounds=3, local_steps=0, assign="sketch",
                           init="clients", sketch_dim=16)
    res = method.run(jax.random.PRNGKey(0), state, None, None)
    assert same_partition(res.labels, true)
    pts = np.asarray(state.params["theta"])
    theta = np.asarray(res.state.params["theta"])
    for c in np.unique(res.labels):
        members = np.where(res.labels == c)[0]
        # every member holds the cluster model, and that model is the
        # MEAN of the members' own uploaded ERMs (not a seed client's
        # raw model — the re-average must actually aggregate)
        np.testing.assert_allclose(
            theta[members],
            np.broadcast_to(pts[members].mean(0), theta[members].shape),
            rtol=1e-5, atol=1e-5)


# ------------------------------------------------- comm accounting

def test_comm_accounting_one_shot_vs_iterative():
    state, _ = blob_state(seed=2, k=2, per=4, d=8)
    bytes_per = params_bytes_per_client(state)
    assert bytes_per == 8 * 4                      # d float32 per client

    odcl = ODCLFederated(algorithm="kmeans++", k=2, sketch_dim=16)
    r = odcl.run(jax.random.PRNGKey(0), blob_state(seed=2, k=2, per=4, d=8)[0],
                 None)
    assert r.comm_rounds == 1.0
    # uplink sketch + model, downlink cluster model — once
    assert r.comm_bytes == state.n_clients * (16 * 4 + 2 * bytes_per)

    rounds = 4
    ifca = IFCAFederated(k=2, rounds=rounds, local_steps=0, assign="sketch",
                         init="clients", sketch_dim=16)
    r2 = ifca.run(jax.random.PRNGKey(0),
                  blob_state(seed=2, k=2, per=4, d=8)[0], None)
    assert r2.comm_rounds == float(rounds)
    assert r2.comm_bytes == rounds * state.n_clients * (16 * 4 + 2 * bytes_per)
    assert r2.comm_bytes > r.comm_bytes            # Fig-4 at the byte level

    local = LocalOnlyFederated().run(jax.random.PRNGKey(0),
                                     blob_state(seed=2, k=2, per=4, d=8)[0],
                                     None)
    assert local.comm_rounds == 0.0 and local.comm_bytes == 0.0
    assert local.n_clusters == state.n_clients

    fedavg = FedAvgGlobal(rounds=3, local_steps=0)
    r3 = fedavg.run(jax.random.PRNGKey(0),
                    blob_state(seed=2, k=2, per=4, d=8)[0], None)
    assert r3.comm_rounds == 3.0 and r3.n_clusters == 1
    theta = np.asarray(r3.state.params["theta"])
    np.testing.assert_allclose(theta, np.broadcast_to(theta[0], theta.shape),
                               rtol=1e-6, atol=1e-6)


def test_ifca_sketch_assign_fused_kernel_matches_plain_argmin():
    """The assign='sketch' rule now runs the engine's fused
    kernels/kmeans_assign dispatch; it must agree with the old plain-jnp
    argmin over the (C, k, sketch_dim) difference block."""
    from repro.core.sketch import sketch_tree

    state, _ = blob_state(seed=3, k=3, per=6, d=8)
    method = IFCAFederated(k=3, assign="sketch", sketch_dim=16, seed=0)
    assign_fn = method._make_assign(None, None)
    theta = method._theta0(jax.random.PRNGKey(0), state)

    new = np.asarray(assign_fn(theta, state.params, None))

    skey = jax.random.PRNGKey(0)
    sk = jax.vmap(lambda p: sketch_tree(skey, p, 16))
    s_c, s_k = sk(state.params), sk(theta)
    d2 = jnp.sum((s_c[:, None] - s_k[None]) ** 2, axis=-1)
    old = np.asarray(jnp.argmin(d2, axis=1).astype(jnp.int32))
    np.testing.assert_array_equal(new, old)


def test_ifca_carry_opt_state_changes_trajectory_not_contract():
    """carry_opt_state=True must carry per-cluster Adam moments across
    rounds: same contract (labels/rounds/bytes), different parameter
    trajectory after round 2 (fresh zeros vs carried moments)."""
    cfg = tiny_cfg()
    opt = AdamWConfig(lr=1e-3, weight_decay=0.0)

    def run(carry):
        stream = make_stream(cfg)
        state = init_federation(jax.random.PRNGKey(0), cfg, N_CLIENTS)
        method = IFCAFederated(k=K, rounds=2, local_steps=3, warmup_steps=0,
                               init="clients", assign="sketch",
                               sketch_dim=32, opt=opt,
                               carry_opt_state=carry)
        return method.run(jax.random.PRNGKey(0), state, cfg,
                          make_iter(stream))

    plain, carried = run(False), run(True)
    assert carried.meta["carry_opt_state"] is True
    assert carried.comm_rounds == plain.comm_rounds
    assert carried.comm_bytes == plain.comm_bytes
    np.testing.assert_array_equal(carried.labels, plain.labels)
    # the carried moments actually change round-2 optimization
    diffs = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
             for a, b in zip(jax.tree_util.tree_leaves(plain.state.params),
                             jax.tree_util.tree_leaves(carried.state.params))]
    assert max(diffs) > 0.0
    # determinism: the carried variant reproduces itself bit-for-bit
    again = run(True)
    for a, b in zip(jax.tree_util.tree_leaves(carried.state.params),
                    jax.tree_util.tree_leaves(again.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_build_federated_method_threads_carry_opt():
    m = build_federated_method("ifca", carry_opt_state=True, rounds=3)
    assert m.carry_opt_state is True and m.rounds == 3
    assert build_federated_method("ifca").carry_opt_state is False


def test_training_methods_require_cfg_and_batches():
    state, _ = blob_state()
    with pytest.raises(ValueError, match="local steps"):
        ODCLFederated(local_steps=5).run(jax.random.PRNGKey(0), state, None)
    with pytest.raises(ValueError, match="assign='loss'"):
        IFCAFederated(assign="loss").run(jax.random.PRNGKey(0), state, None)
    with pytest.raises(ValueError, match="local steps"):
        FedAvgGlobal(local_steps=2).run(jax.random.PRNGKey(0), state, None)
