"""End-to-end behaviour tests for the paper's system (Algorithm 1 run
through the public API on both paper-scale and LM-scale workloads)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched_ridge_erm, odcl, oracles
from repro.data import make_linear_regression_federation


def test_full_paper_pipeline_one_shot():
    """The complete Section-5 pipeline: local ERMs -> one-shot server
    round -> order-optimal per-user models, in ONE communication round."""
    fed = make_linear_regression_federation(seed=42, n=300)
    # step 1: every user solves its local ERM (one batched call)
    local = np.asarray(batched_ridge_erm(
        jnp.asarray(fed.xs), jnp.asarray(fed.ys), 1e-8))
    # steps 2-4: the server's single round
    result = odcl(local, algorithm="kmeans++", k=fed.K)

    opt = fed.optima[fed.true_labels]
    def mse(models):
        return float(np.mean(np.sum((models - opt) ** 2, 1)))

    # communication: exactly one uplink (m models) + one downlink
    assert result.user_models.shape == local.shape
    # quality: matches oracle averaging, close to the cluster oracle
    oa = oracles.oracle_averaging(local, fed.true_labels)
    assert mse(result.user_models) <= mse(oa) * 1.0001
    assert mse(result.user_models) < 0.2 * mse(local)
