"""Mutable-serving contracts of the AggregationSession.

Pins the keyed-slot / staleness / warm-re-finalize semantics:

  * keyed re-uploads replace in place and finalize bit-exact with a
    fresh session holding only the surviving values (the hypothesis
    property drives arbitrary re-upload/evict interleavings);
  * the staleness policies (sliding-window eviction, exp-decay
    weighting) and their effect on finalize;
  * warm-started re-finalize: device Lloyd from the previous centers
    and AMA from its previous dual reach the same fixed point in fewer
    iterations, with cold fallback when the family (or a changed client
    count, for the convex dual) cannot warm-start;
  * the drift gauge (degenerate zero-inertia fallback included) and the
    ``maybe_refinalize`` trigger;
  * the engine='host' resolution of explicit device names, the
    rejected-wave atomicity guarantee, and ``cluster_model`` bounds.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clustering.api import (
    DeviceClusteringResult,
    register_algorithm,
    resolve_host_request,
    unregister_algorithm,
)
from repro.core.engine import (
    AggregationSession,
    ExpDecay,
    NoStaleness,
    SlidingWindow,
    make_staleness_policy,
)
from repro.core.engine.device_convex import device_convex_cluster
from repro.core.engine.device_kmeans import device_kmeans

from test_session import make_blobs


def keyed_session(pts, ids, sketch_dim=16, seed=0, **kw):
    sess = AggregationSession(len(pts), sketch_dim=sketch_dim, seed=seed,
                              **kw)
    sess.ingest({"theta": jnp.asarray(pts)}, client_ids=ids)
    return sess


# ------------------------------------------------- keyed slots / re-upload

def test_reupload_replaces_in_place():
    pts, _ = make_blobs(0, [6, 6], 5)
    sess = keyed_session(pts, list(range(len(pts))))
    assert sess.count == len(pts)
    rows = sess.ingest({"theta": jnp.asarray(pts[:3] + 1.0)},
                       client_ids=[0, 1, 2])
    np.testing.assert_array_equal(rows, [0, 1, 2])
    assert sess.count == len(pts)          # replaced, not appended
    st = sess.state()
    np.testing.assert_allclose(np.asarray(st.params["theta"][:3]),
                               pts[:3] + 1.0, rtol=1e-6)


def test_reupload_finalize_bit_exact_with_fresh_session():
    pts, _ = make_blobs(3, [8, 8], 6)
    sess = keyed_session(pts, list(range(len(pts))), sketch_dim=24, seed=5)
    moved = pts[4:10] + 0.5
    sess.ingest({"theta": jnp.asarray(moved)}, client_ids=list(range(4, 10)))

    final = pts.copy()
    final[4:10] = moved
    ref = keyed_session(final, list(range(len(pts))), sketch_dim=24, seed=5)

    state, labels, _ = sess.finalize(algorithm="kmeans-device", k=2)
    ref_state, ref_labels, _ = ref.finalize(algorithm="kmeans-device", k=2)
    np.testing.assert_array_equal(labels, ref_labels)
    np.testing.assert_array_equal(np.asarray(state.params["theta"]),
                                  np.asarray(ref_state.params["theta"]))


def test_duplicate_ids_within_wave_rejected():
    pts, _ = make_blobs(1, [4], 5)
    sess = AggregationSession(8, sketch_dim=16)
    with pytest.raises(ValueError, match="duplicate client ids"):
        sess.ingest({"theta": jnp.asarray(pts)}, client_ids=[0, 1, 1, 2])
    assert sess.count == 0                 # nothing committed


def test_new_ids_reuse_evicted_rows_before_growing():
    pts, _ = make_blobs(2, [4], 5)
    sess = AggregationSession(4, sketch_dim=16,
                              staleness=SlidingWindow(max_age=1))
    sess.ingest({"theta": jnp.asarray(pts)}, client_ids=["a", "b", "c", "d"])
    sess.ingest({"theta": jnp.asarray(pts[:1])}, client_ids=["a"])
    sess.ingest({"theta": jnp.asarray(pts[:1])}, client_ids=["a"])
    # b/c/d aged out; their rows are free again, so new joiners fit in a
    # capacity-4 buffer even though 4 distinct ids already passed through
    assert sess.count == 1
    rows = sess.ingest({"theta": jnp.asarray(pts[:2])},
                       client_ids=["e", "f"])
    assert set(int(r) for r in rows) <= {1, 2, 3}
    assert sess.count == 3


# ------------------------------------------------------------- staleness

def test_make_staleness_policy_parses_cli_spellings():
    assert isinstance(make_staleness_policy("none"), NoStaleness)
    assert make_staleness_policy("max_age=3") == SlidingWindow(3)
    assert make_staleness_policy("exp_decay=2.0") == ExpDecay(2.0)
    p = SlidingWindow(7)
    assert make_staleness_policy(p) is p
    with pytest.raises(ValueError, match="unknown staleness policy"):
        make_staleness_policy("lru")


def test_sliding_window_evicts_and_finalize_matches_survivors():
    pts, _ = make_blobs(4, [6, 6], 6)
    sess = AggregationSession(len(pts), sketch_dim=24, seed=7,
                              staleness="max_age=1")
    sess.ingest({"theta": jnp.asarray(pts[:6])},
                client_ids=list(range(6)))
    sess.ingest({"theta": jnp.asarray(pts[6:])},
                client_ids=list(range(6, 12)))
    sess.ingest({"theta": jnp.asarray(pts[6:])},
                client_ids=list(range(6, 12)))
    # first wave is now age 2 > max_age=1 -> evicted
    assert sess.count == 6
    assert set(sess.clients) == set(range(6, 12))

    state, labels, info = sess.finalize(algorithm="kmeans-device", k=2)
    assert info["count"] == 6
    assert labels.shape == (6,)
    # the eviction left holes (rows 0..5 dead) — finalize must see the
    # same federation as a fresh session of just the survivors
    ref = keyed_session(pts[6:], list(range(6)), sketch_dim=24, seed=7)
    ref_state, ref_labels, _ = ref.finalize(algorithm="kmeans-device", k=2)
    np.testing.assert_array_equal(labels, ref_labels)
    np.testing.assert_array_equal(np.asarray(state.params["theta"]),
                                  np.asarray(ref_state.params["theta"]))


def test_exp_decay_weights_fade_stale_rows():
    # two clients per cluster: one fresh at the optimum, one stale and
    # offset.  With NoStaleness the cluster mean sits midway; with a
    # sharp ExpDecay the stale row's weight vanishes and the mean hugs
    # the fresh upload.
    base = np.array([[10.0, 0.0], [-10.0, 0.0]], np.float32)
    stale = base + np.array([2.0, 0.0], np.float32)
    sess = AggregationSession(4, sketch_dim=8, seed=0,
                              staleness=ExpDecay(half_life=0.1))
    sess.ingest({"theta": jnp.asarray(stale)}, client_ids=["s0", "s1"])
    for _ in range(8):                      # age the stale pair
        sess.ingest({"theta": jnp.asarray(base)}, client_ids=["f0", "f1"])
    state, _, info = sess.finalize(algorithm="kmeans-device", k=2)
    assert info["n_clusters"] == 2
    served = np.asarray(state.params["theta"])
    fresh_rows = served[2:]                 # f0/f1 ingested after s0/s1
    np.testing.assert_allclose(fresh_rows, base, atol=1e-2)


def test_exp_decay_requires_mean_aggregator():
    pts, _ = make_blobs(5, [4, 4], 5)
    sess = AggregationSession(len(pts), sketch_dim=16,
                              staleness=ExpDecay(half_life=1.0))
    sess.ingest({"theta": jnp.asarray(pts)},
                client_ids=list(range(len(pts))))
    with pytest.raises(ValueError, match="mean"):
        sess.finalize(algorithm="kmeans-device", k=2,
                      aggregator="trimmed_mean")


# ------------------------------------------------- warm-start re-finalize

def test_device_kmeans_warm_matches_cold_in_fewer_iters():
    pts, _ = make_blobs(6, [20, 20, 20], 8)
    key = jax.random.PRNGKey(0)
    cold = device_kmeans(key, jnp.asarray(pts), k=3, init="kmeans++",
                         iters=50)
    warm = device_kmeans(key, jnp.asarray(pts), k=3, init="warm",
                         init_centers=cold.centers, iters=50)
    np.testing.assert_array_equal(np.asarray(warm.labels),
                                  np.asarray(cold.labels))
    np.testing.assert_allclose(np.asarray(warm.centers),
                               np.asarray(cold.centers), atol=1e-5)
    assert int(warm.n_iter) <= int(cold.n_iter)
    assert int(warm.n_iter) <= 2           # restart at the fixed point


def test_device_kmeans_warm_requires_centers():
    with pytest.raises(ValueError, match="init_centers"):
        device_kmeans(jax.random.PRNGKey(0), jnp.zeros((4, 3)), k=2,
                      init="warm")


def test_device_convex_warm_dual_converges_faster():
    pts, _ = make_blobs(7, [6, 6], 4, sep=40.0, noise=0.05)
    a = jnp.asarray(pts)
    key = jax.random.PRNGKey(0)
    cold = device_convex_cluster(key, a, lam=5e-3, iters=200)
    assert cold.nu is not None
    warm = device_convex_cluster(key, a, lam=5e-3, iters=200,
                                 warm_nu=cold.nu)
    np.testing.assert_array_equal(np.asarray(warm.labels),
                                  np.asarray(cold.labels))
    assert int(warm.n_iter) < int(cold.n_iter)


def test_session_refinalize_warm_agrees_with_cold():
    pts, _ = make_blobs(8, [10, 10], 8)
    sess = keyed_session(pts, list(range(len(pts))), sketch_dim=24, seed=3)
    _, labels0, info0 = sess.finalize(algorithm="kmeans-device", k=2)
    assert info0["refinalize"] is None     # a plain finalize is not warm
    _, labels1, info1 = sess.refinalize()
    assert info1["refinalize"] == "warm"
    np.testing.assert_array_equal(labels1, labels0)
    assert info1["meta"]["n_iter"] <= info0["meta"]["n_iter"]


def test_session_refinalize_needs_prior_finalize():
    pts, _ = make_blobs(9, [4], 5)
    sess = keyed_session(pts, list(range(len(pts))))
    with pytest.raises(ValueError, match="prior finalize"):
        sess.refinalize()


def test_convex_warm_falls_back_cold_when_count_changes():
    pts, _ = make_blobs(10, [5, 5], 4, sep=40.0, noise=0.05)
    sess = AggregationSession(len(pts) + 1, sketch_dim=8, seed=1)
    sess.ingest({"theta": jnp.asarray(pts)},
                client_ids=list(range(len(pts))))
    sess.finalize(algorithm="convex-device",
                  algo_options={"lam": 5e-3, "iters": 150})
    _, _, info_same = sess.refinalize()
    assert info_same["refinalize"] == "warm"
    # the AMA dual is per-edge: a changed client count invalidates it
    sess.ingest({"theta": jnp.asarray(pts[:1] + 9.0)}, client_ids=["new"])
    _, _, info = sess.refinalize()
    assert info["refinalize"] == "cold"    # same-count guard tripped


# ------------------------------------------------- drift / maybe_refinalize

def test_maybe_refinalize_triggers_on_drift():
    pts, _ = make_blobs(11, [12, 12], 8)
    sess = keyed_session(pts, list(range(len(pts))), sketch_dim=24, seed=2)
    sess.finalize(algorithm="kmeans-device", k=2)
    # routing the whole clustered federation back pins drift at ~1.0
    sess.route(sess.sketch_params({"theta": jnp.asarray(pts)}))
    assert sess.drift is not None and sess.drift < 1.5
    assert sess.maybe_refinalize(threshold=1.5) is None

    far = {"theta": jnp.asarray(pts[:6] + 80.0)}
    sess.route(sess.sketch_params(far))    # drifted request batch
    assert sess.drift > 1.5
    out = sess.maybe_refinalize(threshold=1.5)
    assert out is not None
    _, _, info = out
    assert info["refinalize"] == "warm"
    assert sess.drift is None              # gauge re-anchored


def test_drift_degenerate_zero_inertia_uses_scale_fallback():
    # every client identical -> finalized inertia is exactly 0.  The
    # old /1e-12 denominator exploded the gauge to ~1e12 for any routed
    # request; the fallback normalizes by the absolute sketch scale so
    # near-identical traffic still reads as no drift.
    pts = np.ones((6, 5), np.float32) * 3.0
    sess = AggregationSession(6, sketch_dim=8, seed=0)
    sess.ingest({"theta": jnp.asarray(pts)})
    sess.finalize(algorithm="kmeans-device", k=1)
    sess.route(params={"theta": jnp.asarray(pts[0])})
    assert sess.drift is not None
    assert sess.drift < 10.0               # was ~1e12 before the fix


# ------------------------------------------------- host-engine resolution

def test_finalize_host_downgrades_device_name():
    pts, _ = make_blobs(12, [8, 8], 6)
    sess = keyed_session(pts, list(range(len(pts))), sketch_dim=16, seed=4)
    _, labels, info = sess.finalize(algorithm="kmeans-device", k=2,
                                    algo_options={"init": "kmeans++"},
                                    engine="host")
    assert info["engine"] == "host"
    assert labels.shape == (len(pts),)


def test_resolve_host_request_rejects_twinless_device_algo():
    class FakeDeviceAlgo:
        name = "fakeonly-device"
        requires_k = True

        def __call__(self, key, points, k=None, **options):
            raise AssertionError("host path must not run the device loop")

        def device_call(self, key, points, *, k=None, **options):
            raise AssertionError("engine='host' must not reach device_call")

    register_algorithm(FakeDeviceAlgo(), overwrite=True)
    try:
        with pytest.raises(ValueError, match="no\\s+registered host base"):
            resolve_host_request("fakeonly-device")
        pts, _ = make_blobs(13, [4], 5)
        sess = keyed_session(pts, list(range(len(pts))))
        with pytest.raises(ValueError, match="fakeonly-device"):
            sess.finalize(algorithm="fakeonly-device", k=1, engine="host")
    finally:
        unregister_algorithm("fakeonly-device")


def test_resolve_host_request_rejects_warm_init():
    with pytest.raises(ValueError, match="init='warm'"):
        resolve_host_request("kmeans-device", {"init": "warm"})


# ------------------------------------------------- atomicity / bounds

def test_rejected_wave_leaves_state_untouched():
    pts, _ = make_blobs(14, [6, 6], 5)
    sess = keyed_session(pts, list(range(len(pts))), sketch_dim=16, seed=6)
    sess.finalize(algorithm="kmeans-device", k=2)
    clients_before = sess.clients
    with pytest.raises(ValueError, match="does not match the session's"):
        sess.ingest({"theta": jnp.zeros((3, 99))}, client_ids=[0, 1, 2])
    assert sess.count == len(pts)
    assert sess.clients == clients_before
    # the finalized round survived too: the rejected wave never touched
    # the buffers, so serving continues uninvalidated
    cid = sess.route(params={"theta": jnp.asarray(pts[0])})
    assert 0 <= cid < sess.n_clusters


def test_cluster_model_bounds_check():
    pts, _ = make_blobs(15, [6, 6], 5)
    sess = keyed_session(pts, list(range(len(pts))), sketch_dim=16, seed=0)
    sess.finalize(algorithm="kmeans-device", k=2)
    sess.cluster_model(0)
    sess.cluster_model(sess.n_clusters - 1)
    with pytest.raises(IndexError, match="out of range"):
        sess.cluster_model(-1)             # wrapped silently before
    with pytest.raises(IndexError, match="out of range"):
        sess.cluster_model(sess.n_clusters)


# ------------------------------------------------- hypothesis property

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                        # pragma: no cover - baked image
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @st.composite
    def mutation_scripts(draw):
        """An initial keyed federation plus a random script of keyed
        re-upload waves (subsets of the ids, shifted values)."""
        n = draw(st.integers(4, 10))
        n_waves = draw(st.integers(1, 4))
        waves = []
        for w in range(n_waves):
            size = draw(st.integers(1, n))
            ids = draw(st.lists(st.integers(0, n - 1), min_size=size,
                                max_size=size, unique=True))
            shift = draw(st.floats(-4.0, 4.0, allow_nan=False))
            waves.append((sorted(ids), shift))
        return n, waves

    @settings(max_examples=15, deadline=None)
    @given(mutation_scripts())
    def test_arbitrary_reuploads_match_fresh_session(script):
        n, waves = script
        pts, _ = make_blobs(42, [n - n // 2, n // 2], 6)
        sess = keyed_session(pts, list(range(n)), sketch_dim=16, seed=9)
        final = pts.copy()
        for ids, shift in waves:
            vals = pts[ids] + np.float32(shift)
            sess.ingest({"theta": jnp.asarray(vals)}, client_ids=ids)
            final[ids] = vals
        assert sess.count == n
        ref = keyed_session(final, list(range(n)), sketch_dim=16, seed=9)
        state, labels, _ = sess.finalize(algorithm="kmeans-device", k=2)
        ref_state, ref_labels, _ = ref.finalize(algorithm="kmeans-device",
                                                k=2)
        np.testing.assert_array_equal(labels, ref_labels)
        np.testing.assert_array_equal(
            np.asarray(state.params["theta"]),
            np.asarray(ref_state.params["theta"]))


@pytest.mark.parametrize("spec", ["max_age=3.5", "max_age=x", "max_age=0",
                                  "max_age=-2"])
def test_make_staleness_policy_rejects_bad_max_age(spec):
    """Malformed CLI spellings must raise one ValueError echoing the
    spec string, not a raw int() traceback or a silent no-op policy."""
    with pytest.raises(ValueError, match="invalid staleness spec") as ei:
        make_staleness_policy(spec)
    assert spec in str(ei.value)
    assert "max_age" in str(ei.value)


@pytest.mark.parametrize("spec", ["exp_decay=x", "exp_decay=0",
                                  "exp_decay=-1.5"])
def test_make_staleness_policy_rejects_bad_half_life(spec):
    with pytest.raises(ValueError, match="invalid staleness spec") as ei:
        make_staleness_policy(spec)
    assert spec in str(ei.value)


# ------------------------------------------------- route host-sync budget

def test_route_batch_is_a_single_host_sync(monkeypatch):
    """The batched route path must cross the host boundary exactly once
    per batch — labels and the drift accumulator ride one device_get."""
    pts, _ = make_blobs(0, [8, 8], 6)
    sess = keyed_session(pts, list(range(len(pts))), sketch_dim=16)
    sess.finalize(k=2)
    sk = sess.sketch_params({"theta": jnp.asarray(pts)})

    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(1) or real(x))
    sess.route(np.asarray(sk))          # batch of 16
    assert calls == [1]
    calls.clear()
    sess.route(np.asarray(sk)[0])       # single probe: same budget
    assert calls == [1]
    assert sess.drift is not None
