"""Property-based tests for the device aggregation engine.

Hypothesis draws federation shapes (C, k, d, sketch_dim — including
sizes that are not multiples of any kernel block) and checks the two
engine contracts the PR-2 tests only spot-checked:

  * device/host kmeans parity: ``engine.device_kmeans`` and the host
    oracle ``clustering.kmeans`` produce the same partition and inertia
    for identical (key, points, k, init);
  * one-shot round agreement: ``one_shot_aggregate`` through
    ``engine='host'`` and ``engine='device'`` recover the same labels
    and the same per-cluster parameter means.

Degenerate cases (k=1, C==k, duplicate client sketches) get explicit
non-drawn tests below.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

from repro.core.clustering import kmeans
from repro.core.engine import device_kmeans
from repro.core.federated import FederatedState, one_shot_aggregate
from repro.optim import adamw_init

from conftest import same_partition


def make_blobs(seed, sizes, d, sep=25.0, noise=0.25):
    """Well-separated blobs with per-cluster sizes ``sizes`` (so the
    total point count is arbitrary, not a multiple of any block)."""
    rng = np.random.default_rng(seed)
    k = len(sizes)
    centers = rng.normal(size=(k, d))
    if k > 1:
        dists = np.linalg.norm(centers[:, None] - centers[None], axis=-1)
        np.fill_diagonal(dists, np.inf)
        centers *= sep / dists.min()
    pts = np.concatenate([
        c + noise * rng.normal(size=(n, d)) for c, n in zip(centers, sizes)])
    labels = np.repeat(np.arange(k), sizes)
    return pts.astype(np.float32), labels


def blob_state(pts):
    params = {"theta": jnp.asarray(pts)}
    return FederatedState(params=params,
                          opt_state=jax.vmap(adamw_init)(params),
                          n_clients=len(pts))


sizes_st = st.lists(st.integers(2, 9), min_size=1, max_size=4)


# ------------------------------------------------- device vs host kmeans

@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), sizes=sizes_st, d=st.integers(2, 9),
       init=st.sampled_from(["kmeans++", "spectral", "random"]))
def test_device_host_kmeans_parity(seed, sizes, d, init):
    pts, _ = make_blobs(seed, sizes, d)
    k = len(sizes)
    key = jax.random.PRNGKey(seed)
    host = kmeans(key, jnp.asarray(pts), k, iters=30, init=init)
    dev = device_kmeans(key, jnp.asarray(pts), k, iters=30, init=init)
    assert same_partition(np.asarray(host.labels), np.asarray(dev.labels))
    np.testing.assert_allclose(float(dev.inertia), float(host.inertia),
                               rtol=1e-3, atol=1e-3)
    assert int(dev.n_iter) == int(host.n_iter)


# ------------------------------------- one-shot round: host ≡ device

@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), sizes=st.lists(st.integers(2, 7),
                                                   min_size=2, max_size=4),
       d=st.integers(2, 8), sketch_dim=st.sampled_from([8, 16, 24]))
def test_one_shot_engines_agree(seed, sizes, d, sketch_dim):
    pts, true = make_blobs(seed, sizes, d)
    k = len(sizes)
    kwargs = dict(algorithm="kmeans-device", k=k, sketch_dim=sketch_dim,
                  seed=seed % 97)
    st_h, lab_h, info_h = one_shot_aggregate(blob_state(pts), None,
                                             engine="host", **kwargs)
    st_d, lab_d, info_d = one_shot_aggregate(blob_state(pts), None,
                                             engine="device", **kwargs)
    assert same_partition(lab_h, lab_d)
    assert info_h["n_clusters"] == info_d["n_clusters"]
    np.testing.assert_allclose(np.asarray(st_h.params["theta"]),
                               np.asarray(st_d.params["theta"]),
                               rtol=1e-5, atol=1e-5)
    # the recovered per-cluster means are the true cluster means of theta
    theta = np.asarray(st_d.params["theta"])
    for c in np.unique(lab_d):
        members = np.where(lab_d == c)[0]
        np.testing.assert_allclose(
            theta[members],
            np.broadcast_to(pts[members].mean(0), theta[members].shape),
            rtol=1e-4, atol=1e-4)


# The degenerate non-drawn cases (k=1, C==k, duplicate client sketches)
# live in tests/test_engine.py so they run even without hypothesis.

# -------------------------------------- multi-restart / minibatch Lloyd

@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), sizes=sizes_st, d=st.integers(2, 8),
       restarts=st.integers(2, 5),
       init=st.sampled_from(["kmeans++", "random"]))
def test_multi_restart_inertia_monotone(seed, sizes, d, restarts, init):
    """restarts=r keeps the best of r inits INCLUDING the caller's key,
    so its inertia can never exceed the single-restart run."""
    pts, _ = make_blobs(seed, sizes, d)
    k = len(sizes)
    key = jax.random.PRNGKey(seed)
    one = device_kmeans(key, jnp.asarray(pts), k, iters=25, init=init)
    multi = device_kmeans(key, jnp.asarray(pts), k, iters=25, init=init,
                          restarts=restarts)
    assert float(multi.inertia) <= float(one.inertia) + 1e-4


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), sizes=sizes_st, d=st.integers(2, 8),
       init=st.sampled_from(["kmeans++", "spectral", "random"]))
def test_minibatch_full_batch_is_bitexact(seed, sizes, d, init):
    """batch_m >= m reduces to the full-Lloyd path bit-for-bit."""
    pts, _ = make_blobs(seed, sizes, d)
    m, k = len(pts), len(sizes)
    key = jax.random.PRNGKey(seed)
    full = device_kmeans(key, jnp.asarray(pts), k, iters=25, init=init)
    mb = device_kmeans(key, jnp.asarray(pts), k, iters=25, init=init,
                       batch_m=m)
    np.testing.assert_array_equal(np.asarray(full.labels),
                                  np.asarray(mb.labels))
    np.testing.assert_array_equal(np.asarray(full.centers),
                                  np.asarray(mb.centers))
    np.testing.assert_array_equal(np.asarray(full.inertia),
                                  np.asarray(mb.inertia))
    assert int(full.n_iter) == int(mb.n_iter)


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), sizes=st.lists(st.integers(4, 9),
                                                   min_size=2, max_size=4),
       d=st.integers(2, 6))
def test_minibatch_lloyd_is_valid_clustering(seed, sizes, d):
    """Sub-m minibatches still return a full-data labeling with finite
    inertia >= the full-Lloyd inertia minus tolerance is NOT guaranteed,
    but the result contract (shapes, label range, final full-data
    inertia consistency) must hold."""
    pts, _ = make_blobs(seed, sizes, d)
    m, k = len(pts), len(sizes)
    res = device_kmeans(jax.random.PRNGKey(seed), jnp.asarray(pts), k,
                        iters=25, batch_m=max(2, m // 2))
    labels = np.asarray(res.labels)
    assert labels.shape == (m,)
    assert labels.min() >= 0 and labels.max() < k
    # reported inertia is the full-data objective of the final centers
    centers = np.asarray(res.centers)
    d2 = ((pts[:, None] - centers[None]) ** 2).sum(-1)
    np.testing.assert_allclose(float(res.inertia), d2.min(1).sum(),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=4, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), d=st.integers(2, 6))
def test_device_kmeans_k1_inertia_is_total_variance(seed, d):
    pts, _ = make_blobs(seed, [11], d)
    res = device_kmeans(jax.random.PRNGKey(seed), jnp.asarray(pts), 1,
                        iters=10, init="random")
    expected = float(np.sum((pts - pts.mean(0)) ** 2))
    np.testing.assert_allclose(float(res.inertia), expected,
                               rtol=1e-4, atol=1e-4)
