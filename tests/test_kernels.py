"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
with hypothesis sweeps over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.group_prox import group_ball_proj_pallas
from repro.kernels.kmeans_assign import kmeans_assign_pallas
from repro.kernels.pairwise_l2 import pairwise_sqdist_pallas

SETTINGS = dict(max_examples=8, deadline=None)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 40),
    d=st.integers(1, 300),
    dtype=st.sampled_from([np.float32, np.float16]),
)
def test_pairwise_sqdist_matches_ref(m, k, d, dtype):
    rng = np.random.default_rng(m * 1000 + k * 10 + d)
    a = jnp.asarray(rng.normal(size=(m, d)).astype(dtype))
    b = jnp.asarray(rng.normal(size=(k, d)).astype(dtype))
    got = pairwise_sqdist_pallas(a, b, interpret=True)
    want = ref.pairwise_sqdist(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == np.float16 else 1e-4,
                               atol=1e-2 if dtype == np.float16 else 1e-3)


@settings(**SETTINGS)
@given(m=st.integers(2, 150), k=st.integers(1, 16), d=st.integers(2, 100))
def test_kmeans_assign_matches_ref(m, k, d):
    rng = np.random.default_rng(m + k + d)
    pts = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    cts = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    lab_p, sum_p, cnt_p = kmeans_assign_pallas(pts, cts, interpret=True)
    lab_r, sum_r, cnt_r = ref.kmeans_assign(pts, cts)
    np.testing.assert_array_equal(np.asarray(lab_p), np.asarray(lab_r))
    np.testing.assert_allclose(np.asarray(sum_p), np.asarray(sum_r),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cnt_p), np.asarray(cnt_r))


@settings(**SETTINGS)
@given(e=st.integers(1, 300), d=st.integers(1, 128),
       radius=st.floats(0.01, 10.0))
def test_group_ball_proj_matches_ref(e, d, radius):
    rng = np.random.default_rng(e * 7 + d)
    v = jnp.asarray((rng.normal(size=(e, d)) * 3).astype(np.float32))
    got = group_ball_proj_pallas(v, radius, interpret=True)
    want = ref.group_ball_proj(v, radius)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # invariant: projected rows never exceed the radius
    norms = np.linalg.norm(np.asarray(got), axis=1)
    assert (norms <= radius * (1 + 1e-5)).all()


@settings(max_examples=6, deadline=None)
@given(
    b=st.integers(1, 3),
    hkv=st.sampled_from([1, 2, 4]),
    rep=st.sampled_from([1, 2, 7]),
    sq=st.integers(1, 80),
    extra_kv=st.integers(0, 60),
    dh=st.sampled_from([8, 16, 64]),
    window=st.sampled_from([None, 5, 32]),
    causal=st.booleans(),
)
def test_flash_attention_matches_ref(b, hkv, rep, sq, extra_kv, dh, window,
                                     causal):
    h = hkv * rep
    skv = sq + extra_kv
    rng = np.random.default_rng(b + h + sq + skv + dh)
    q = jnp.asarray(rng.normal(size=(b, h, sq, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, dh)).astype(np.float32))
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_large_block_shapes():
    """One MXU-aligned large case (block-boundary exactness)."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 384, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 384, 64)).astype(np.float32))
    got = flash_attention_pallas(q, k, v, causal=True, bq=128, bk=128,
                                 interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ops_dispatch_cpu_fallback():
    from repro.kernels import ops

    a = jnp.ones((4, 8))
    b = jnp.zeros((3, 8))
    d = ops.pairwise_sqdist(a, b)
    assert d.shape == (4, 3)
    np.testing.assert_allclose(np.asarray(d), 8.0)
