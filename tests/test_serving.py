"""The concurrent serving subsystem (``repro/serving/``).

What this file pins down:

* queue/batching mechanics without a session (backpressure, caller- and
  server-side timeouts, drain vs drop shutdown);
* the RouteServer front-end (batched answers bit-equal to direct
  ``session.route``, the params route path, lifecycle guards);
* the double-buffered ingest-while-finalize contract — a round computed
  on a snapshot while ingest keeps mutating the live buffer serves
  EXACTLY what a serialized replay (same keyed waves in clock order,
  finalize right after the snapshot's clock) would serve;
* the full threaded stress: N ingest threads + M route callers +
  drift-triggered background refinalizes, with zero dropped or
  duplicated requests and a bit-exact serialized replay of the final
  served round.
"""
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core.engine import AggregationSession
from repro.serving import (
    BackpressureError,
    RequestQueue,
    RouteFuture,
    RouteServer,
    RouteTimeout,
    ServerClosed,
)
from repro.serving.batching import _Request
from repro.serving.loadgen import make_population

DIM = 16
K = 4


def _population(clients=256, seed=0):
    rows, _, _ = make_population(clients=clients, clusters=K,
                                 sketch_dim=DIM, seed=seed)
    return rows


def _served_session(rows, *, capacity=None, wave=64, seed=0):
    """Keyed ingest in waves + cold finalize; returns (session, log)
    where log holds (clock, ids, wave_rows) — the replay source."""
    session = AggregationSession(capacity or len(rows), sketch_dim=DIM,
                                 seed=seed)
    log = []
    for lo in range(0, len(rows), wave):
        chunk = rows[lo:lo + wave]
        ids = list(range(lo, lo + len(chunk)))
        session.ingest(sketches=chunk, client_ids=ids)
        log.append((session.clock, ids, chunk))
    session.finalize(algorithm="kmeans-device", k=K)
    return session, log


def _replay(log, round_clocks, *, capacity, seed=0):
    """The serialized-equivalence oracle: a fresh session, the SAME
    keyed waves in clock order, and a finalize (then warm refinalizes)
    right after each recorded snapshot clock."""
    replay = AggregationSession(capacity, sketch_dim=DIM, seed=seed)
    waves = sorted(log)
    clocks = [c for c, _, _ in waves]
    assert len(set(clocks)) == len(clocks), "duplicated wave commit"
    applied = 0

    def ingest_upto(clk):
        nonlocal applied
        while applied < len(waves) and waves[applied][0] <= clk:
            c, ids, chunk = waves[applied]
            replay.ingest(sketches=chunk, client_ids=ids)
            assert replay.clock == c
            applied += 1

    for i, clk in enumerate(round_clocks):
        ingest_upto(clk)
        if i == 0:
            replay.finalize(algorithm="kmeans-device", k=K)
        else:
            replay.refinalize()
    return replay


def _assert_same_round(live, rep):
    assert live.clock == rep.clock
    assert live.n_clusters == rep.n_clusters
    np.testing.assert_array_equal(np.asarray(live.centers),
                                  np.asarray(rep.centers))
    np.testing.assert_array_equal(np.asarray(live.first_idx),
                                  np.asarray(rep.first_idx))
    np.testing.assert_array_equal(np.asarray(live.out[1]),
                                  np.asarray(rep.out[1]))
    assert live.finalized_d2 == rep.finalized_d2


# ------------------------------------------------- queue mechanics (no jax)

def _req(deadline=None):
    return _Request(np.zeros(DIM, np.float32), RouteFuture(),
                    time.monotonic(), deadline)


def test_queue_backpressure_nonblocking_and_timed():
    q = RequestQueue(2)
    q.put(_req()), q.put(_req())
    with pytest.raises(BackpressureError, match="full"):
        q.put(_req(), block=False)
    t0 = time.monotonic()
    with pytest.raises(BackpressureError, match="full"):
        q.put(_req(), block=True, timeout=0.05)
    assert time.monotonic() - t0 >= 0.04
    with pytest.raises(ValueError, match=">= 1"):
        RequestQueue(0)


def test_queue_next_batch_coalesces_and_respects_max_batch():
    q = RequestQueue(16)
    for _ in range(5):
        q.put(_req())
    batch = q.next_batch(3, 0.0)
    assert len(batch) == 3
    assert len(q.next_batch(8, 0.0)) == 2


def test_queue_stop_drop_returns_backlog_and_rejects_puts():
    q = RequestQueue(8)
    q.put(_req()), q.put(_req())
    dropped = q.stop(drop=True)
    assert len(dropped) == 2 and len(q) == 0
    assert q.next_batch(4, 0.0) is None
    with pytest.raises(ServerClosed):
        q.put(_req())


def test_future_caller_side_timeout_and_single_use():
    fut = RouteFuture()
    with pytest.raises(RouteTimeout, match="no route result"):
        fut.result(0.01)
    fut.set_result(3)
    assert fut.result(0.01) == 3 and fut.done()
    assert fut.done_at is not None


# ------------------------------------------------------- server basic routes

def test_server_batched_routes_match_direct():
    rows = _population()
    session, _ = _served_session(rows)
    expect = np.asarray(session.route(rows[:32]))
    with RouteServer(session, max_batch=8, max_wait_ms=1.0) as srv:
        futs = [srv.submit(r) for r in rows[:32]]
        got = np.asarray([f.result(30.0) for f in futs])
        single = srv.route(rows[7], timeout=30.0)
    np.testing.assert_array_equal(got, expect)
    assert single == expect[7]
    assert srv.route_direct(rows[7]) == expect[7]


def test_server_params_route_path():
    rng = np.random.default_rng(0)
    theta = np.concatenate([
        j * 30.0 + rng.standard_normal((16, 8)).astype(np.float32)
        for j in range(2)])
    session = AggregationSession(32, sketch_dim=DIM, seed=0)
    session.ingest({"theta": theta})
    session.finalize(algorithm="kmeans-device", k=2)
    with RouteServer(session) as srv:
        probe = {"theta": theta[3]}
        got = srv.route(params=probe, timeout=30.0)
    assert got == int(session.route(params=probe))


def test_server_submit_validation_and_lifecycle():
    rows = _population(64)
    session, _ = _served_session(rows, wave=64)
    srv = RouteServer(session)
    srv.start(), srv.start()                      # idempotent
    with pytest.raises(ValueError, match="exactly one"):
        srv.submit(rows[0], params={"theta": rows[0]})
    with pytest.raises(ValueError, match="exactly one"):
        srv.submit()
    with pytest.raises(ValueError, match=r"\(16,\)"):
        srv.submit(rows[:2])
    srv.stop()
    with pytest.raises(ServerClosed):
        srv.submit(rows[0])
    with pytest.raises(ServerClosed):
        srv.start()
    with pytest.raises(ValueError, match="max_batch"):
        RouteServer(session, max_batch=0)


def test_server_side_deadline_expires_requests():
    rows = _population(64)
    session, _ = _served_session(rows, wave=64)
    obs.reset()
    # a long micro-batch window, so the request's own 1ms deadline has
    # long passed when the flush finally examines it
    with RouteServer(session, max_wait_ms=200.0) as srv:
        fut = srv.submit(rows[0], timeout=0.001)
        with pytest.raises(RouteTimeout, match="expired"):
            fut.result(10.0)
    assert obs.snapshot()["counters"].get("serving.timeouts") == 1


def test_server_backpressure_and_drop_shutdown():
    rows = _population(64)
    session, _ = _served_session(rows, wave=64)
    srv = RouteServer(session, queue_depth=2, block_on_full=False)
    # batcher not started: the queue only fills
    futs = [srv.submit(rows[0]), srv.submit(rows[1])]
    with pytest.raises(BackpressureError):
        srv.submit(rows[2])
    srv.stop(drain=False)
    for fut in futs:
        with pytest.raises(ServerClosed):
            fut.result(1.0)


def test_server_drain_serves_backlog_on_stop():
    rows = _population(64)
    session, _ = _served_session(rows, wave=64)
    srv = RouteServer(session, max_batch=4, max_wait_ms=50.0)
    futs = [srv.submit(r) for r in rows[:8]]      # queued, no batcher yet
    srv.start()
    srv.stop(drain=True)
    got = np.asarray([f.result(30.0) for f in futs])
    np.testing.assert_array_equal(got, np.asarray(session.route(rows[:8])))


# ------------------------------------- ingest-while-finalize double buffering

def test_ingest_during_finalize_serves_snapshot_bit_exact():
    """finalize(background=True) snapshots atomically BEFORE returning;
    a wave ingested while the round computes leaves the served round on
    the snapshot — bit-exact with the serialized replay that stops
    ingesting at the snapshot's clock."""
    rows = _population(256)
    session, log = _served_session(rows, capacity=512)
    extra = _population(64, seed=9)
    with RouteServer(session) as srv:
        fut = srv.finalize(background=True, algorithm="kmeans-device", k=K)
        snap_clock = session.clock
        _, clk = srv.ingest(sketches=extra,
                            client_ids=list(range(256, 320)))
        log.append((clk, list(range(256, 320)), extra))
        assert clk == snap_clock + 1
        out = fut.result(120.0)
    assert out[2]["snapshot_clock"] == snap_clock
    served = session.served_round
    assert served.clock == snap_clock          # known-stale by one wave
    assert session.clock == snap_clock + 1
    replay = _replay(log, [snap_clock], capacity=512)
    _assert_same_round(served, replay.served_round)


def test_sync_finalize_through_server_matches_session():
    rows = _population(128)
    session, log = _served_session(rows)
    with RouteServer(session) as srv:
        out = srv.finalize(algorithm="kmeans-device", k=K)
    assert out[2]["snapshot_clock"] == session.clock
    replay = _replay(log, [session.clock], capacity=128)
    _assert_same_round(session.served_round, replay.served_round)


def test_refinalize_requires_prior_finalize():
    session = AggregationSession(64, sketch_dim=DIM, seed=0)
    session.ingest(sketches=_population(64)[:32],
                   client_ids=list(range(32)))
    with RouteServer(session) as srv:
        with pytest.raises(ValueError, match="prior finalize"):
            srv.refinalize()
        assert srv.maybe_refinalize() is None      # no drift, no config


# ----------------------------------------------------------- threaded stress

def test_stress_threads_and_serialized_replay():
    """3 ingest threads re-uploading keyed waves, 4 route callers, and
    drift-triggered background warm refinalizes — all concurrent.  Every
    submitted request resolves exactly once (completions == submissions,
    no errors), and the final served round is bit-exact with the
    serialized replay of the logged waves + round snapshots."""
    clients, n_ingesters, n_callers = 384, 3, 4
    rows = _population(clients)
    session, log = _served_session(rows, capacity=512, wave=128)
    info0 = session.served_round
    round_clocks = [info0.clock]
    log_lock = threading.Lock()
    stop_routing = threading.Event()
    counts = [None] * n_callers
    obs.reset()

    srv = RouteServer(session, max_batch=16, max_wait_ms=1.0,
                      queue_depth=256)
    srv.start()

    def ingester(tid):
        rng = np.random.default_rng(100 + tid)
        for _ in range(5):
            ids = rng.choice(clients, size=64, replace=False)
            chunk = (rows[ids] + 0.2 * rng.standard_normal(
                (len(ids), DIM)).astype(np.float32))
            _, clk = srv.ingest(sketches=chunk,
                                client_ids=[int(i) for i in ids])
            with log_lock:
                log.append((clk, [int(i) for i in ids], chunk))
            time.sleep(0.003)

    def caller(tid):
        rng = np.random.default_rng(200 + tid)
        n_sub = n_done = n_to = 0
        while not stop_routing.is_set():
            sk = rows[rng.integers(0, clients)]
            n_sub += 1
            try:
                srv.route(sk, timeout=30.0)
                n_done += 1
            except RouteTimeout:
                n_to += 1
        counts[tid] = (n_sub, n_done, n_to)

    ingesters = [threading.Thread(target=ingester, args=(t,), daemon=True)
                 for t in range(n_ingesters)]
    callers = [threading.Thread(target=caller, args=(t,), daemon=True)
               for t in range(n_callers)]
    rounds = []
    for t in ingesters + callers:
        t.start()
    while any(t.is_alive() for t in ingesters):
        fut = srv.maybe_refinalize(threshold=-1.0, background=True)
        if fut is not None:
            rounds.append(fut)
        time.sleep(0.02)
    for t in ingesters:
        t.join()
    if not rounds:
        # loaded machine: no drift-triggered round landed inside the
        # ingest window — force one under live route traffic so the
        # replay still covers a mid-stream round
        rounds.append(srv.refinalize(background=True))
    # one last round over a quiet buffer, so the served round is final
    rounds.append(srv.refinalize(background=True))
    results = [f.result(120.0) for f in rounds]
    stop_routing.set()
    for t in callers:
        t.join(60.0)
    srv.stop()

    # zero dropped / duplicated requests
    assert all(c is not None for c in counts), "a caller thread hung"
    n_sub = sum(c[0] for c in counts)
    n_done = sum(c[1] for c in counts)
    n_to = sum(c[2] for c in counts)
    assert n_done + n_to == n_sub and n_to == 0
    snap = obs.snapshot()["counters"]
    assert snap.get("serving.requests", 0) == n_sub
    assert snap.get("serving.flush_errors", 0) == 0
    assert n_done > 0 and len(results) >= 2

    # serialized-replay equivalence of the final served round
    round_clocks += [r[2]["snapshot_clock"] for r in results]
    assert round_clocks == sorted(round_clocks)
    served = session.served_round
    assert served.clock == round_clocks[-1] == session.clock
    replay = _replay(log, round_clocks, capacity=512)
    _assert_same_round(served, replay.served_round)


# -------------------------------------------------------------- loadgen smoke

def test_loadgen_smoke_report_schema():
    from repro.serving import loadgen

    report = loadgen.run(clients=128, clusters=K, sketch_dim=DIM,
                         callers=(2,), duration_s=0.4, max_batch=16,
                         queue_depth=64, open_rate=None, ingest=True)
    assert report["bench"] == "serving"
    assert report["schema_version"] == loadgen.SCHEMA_VERSION
    assert "callers=2" in report["criterion"]
    assert len(report["rows"]) == 3            # direct, batched, ingest
    for row in report["rows"]:
        for key in ("mode", "batched", "qps", "n_requests", "n_errors",
                    "timeouts", "drops", "flush_size_p50",
                    "backpressure", "ingest_waves",
                    "refinalize_under_load_ms", "clients"):
            assert key in row
        assert row["n_errors"] == 0 and row["drops"] == 0
    under = report["rows"][-1]
    assert under["ingest_waves"] > 0
    assert under["refinalize_under_load_ms"] is not None
