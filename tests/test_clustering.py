"""Clustering-algorithm correctness: recovery on separable data,
admissibility constants, lambda-interval logic, clusterpath heuristic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

from repro.core.clustering import (
    alpha_convex_clustering,
    alpha_kmeans,
    clusterpath,
    convex_clustering,
    gradient_clustering,
    is_separable,
    kmeans,
    lambda_interval,
    separability_alpha,
    spectral_init,
)


def make_blobs(seed, k=3, per=10, d=5, sep=10.0, noise=0.3):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d))
    centers *= sep / np.maximum(
        np.linalg.norm(centers[:, None] - centers[None], axis=-1).max(), 1e-9)
    # re-scale so that min pairwise distance is >= sep
    dists = np.linalg.norm(centers[:, None] - centers[None], axis=-1)
    np.fill_diagonal(dists, np.inf)
    centers *= sep / dists.min()
    pts = np.concatenate(
        [c + noise * rng.normal(size=(per, d)) for c in centers])
    labels = np.repeat(np.arange(k), per)
    return pts.astype(np.float32), labels


def purity(pred, true):
    from collections import Counter

    total = 0
    for c in np.unique(pred):
        total += Counter(true[pred == c]).most_common(1)[0][1]
    return total / len(true)


@pytest.mark.parametrize("init", ["kmeans++", "spectral", "random"])
def test_kmeans_recovers_blobs(init):
    pts, true = make_blobs(0)
    res = kmeans(jax.random.PRNGKey(0), jnp.asarray(pts), 3, init=init)
    assert purity(np.asarray(res.labels), true) == 1.0
    assert int(res.n_iter) <= 20


def test_kmeans_inertia_decreases_vs_random_centers():
    pts, _ = make_blobs(1)
    res = kmeans(jax.random.PRNGKey(0), jnp.asarray(pts), 3)
    rand_centers = jnp.asarray(pts[:3]) + 50.0
    from repro.kernels import ops

    d2 = ops.pairwise_sqdist(jnp.asarray(pts), rand_centers)
    assert float(res.inertia) < float(jnp.sum(jnp.min(d2, axis=1)))


def test_convex_clustering_recovers_with_interval_lambda():
    pts, true = make_blobs(2, k=3, per=8, sep=20.0, noise=0.2)
    lo, hi = lambda_interval(pts, true)
    assert lo < hi, "recovery interval must be non-empty for separated blobs"
    res = convex_clustering(pts, (lo + hi) / 2, iters=500)
    assert res.n_clusters == 3
    assert purity(res.labels, true) == 1.0


def test_convex_clustering_lambda_extremes():
    pts, _ = make_blobs(3, k=2, per=6, sep=15.0)
    tiny = convex_clustering(pts, 1e-6, iters=200)
    assert tiny.n_clusters == len(pts)          # all singletons
    huge = convex_clustering(pts, 1e3, iters=500)
    assert huge.n_clusters == 1                 # single fused cluster


def test_clusterpath_finds_true_k():
    pts, true = make_blobs(4, k=3, per=8, sep=25.0, noise=0.2)
    best, sweep = clusterpath(pts, n_lambdas=8, iters=300)
    assert best.n_clusters == 3
    assert purity(best.labels, true) == 1.0
    assert len(sweep) == 8


def test_gradient_clustering_recovers_blobs():
    pts, true = make_blobs(5)
    res = gradient_clustering(jax.random.PRNGKey(1), jnp.asarray(pts), 3,
                              iters=150)
    assert purity(np.asarray(res.labels), true) == 1.0


def test_separability_alpha_monotone_in_separation():
    pts1, t1 = make_blobs(6, sep=5.0)
    pts2, t2 = make_blobs(6, sep=50.0)
    assert separability_alpha(pts2, t2) > separability_alpha(pts1, t1)


def test_admissibility_constants():
    # Lemma 1 / Lemma 2 formulas
    assert alpha_convex_clustering(m=100, c_min=10) == pytest.approx(36.0)
    assert alpha_kmeans(m=100, c_min=10, c=1.0) == pytest.approx(4.0)
    # CC needs more separation than KM when clusters are small
    assert alpha_convex_clustering(100, 5) > alpha_kmeans(100, 5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 4),
       per=st.integers(4, 10))
def test_kmeans_label_invariants(seed, k, per):
    pts, _ = make_blobs(seed, k=k, per=per, sep=30.0, noise=0.1)
    res = kmeans(jax.random.PRNGKey(seed), jnp.asarray(pts), k)
    labels = np.asarray(res.labels)
    assert labels.min() >= 0 and labels.max() < k
    # well-separated blobs with tiny noise: exactly k non-empty clusters
    assert len(np.unique(labels)) == k


def test_separable_condition_matches_definition():
    pts, true = make_blobs(7, sep=40.0, noise=0.1)
    alpha = separability_alpha(pts, true)
    assert is_separable(pts, true, alpha * 0.9)
    assert not is_separable(pts, true, alpha * 1.1)


def test_spectral_init_returns_points_from_distinct_clusters():
    pts, true = make_blobs(8, k=3, per=10, sep=30.0, noise=0.1)
    seeds = np.asarray(spectral_init(jnp.asarray(pts), 3))
    # each seed should be close to a distinct blob center
    d = np.linalg.norm(seeds[:, None] - seeds[None], axis=-1)
    np.fill_diagonal(d, np.inf)
    assert d.min() > 10.0
