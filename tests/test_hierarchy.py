"""The two-level hierarchical round (core/engine/hierarchy.py).

Pins the composition contracts: shards=1 is BIT-EXACT with the flat
fused ``one_shot_aggregate(engine="device")`` round (hypothesis
property — delegation, not a 1-shard two-level pass), sharded rounds
recover the planted clusters with exact global per-cluster means, the
per-level communication accounting shrinks at the top, and the guard
rails (anonymous-only ingest, capacity, empty finalize) hold.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import HierarchicalSession, hierarchical_one_shot_aggregate
from repro.core.federated import one_shot_aggregate

from conftest import same_partition
from test_session import blob_state, make_blobs


def hier_ingest(sess, pts, pattern=(7, 12)):
    off, i = 0, 0
    while off < len(pts):
        w = min(pattern[i % len(pattern)], len(pts) - off)
        sess.ingest({"theta": jnp.asarray(pts[off:off + w])})
        off += w
        i += 1
    return sess


# ----------------------------------------------- S=1 bit-exact delegation

@pytest.mark.parametrize("seed,sizes,d", [
    (0, [9, 7, 11], 8), (3, [5, 5], 4), (11, [8, 3, 6, 7], 12)])
def test_shards_1_bit_exact_with_fused_round(seed, sizes, d):
    pts, _ = make_blobs(seed, sizes, d)
    k = len(sizes)
    ref_state, ref_labels, _ = one_shot_aggregate(
        blob_state(pts), None, algorithm="kmeans-device", k=k,
        sketch_dim=32, seed=3, engine="device")
    state, labels, info = hierarchical_one_shot_aggregate(
        blob_state(pts), shards=1, k=k, sketch_dim=32, seed=3)
    np.testing.assert_array_equal(labels, ref_labels)
    np.testing.assert_array_equal(np.asarray(state.params["theta"]),
                                  np.asarray(ref_state.params["theta"]))
    assert info["shards"] == 1


def test_shards_1_bit_exact_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16),
           sizes=st.lists(st.integers(2, 9), min_size=2, max_size=4),
           d=st.integers(4, 12))
    def prop(seed, sizes, d):
        pts, _ = make_blobs(seed, sizes, d)
        k = len(sizes)
        ref_state, ref_labels, _ = one_shot_aggregate(
            blob_state(pts), None, algorithm="kmeans-device", k=k,
            sketch_dim=16, seed=seed % 97, engine="device")
        state, labels, _ = hierarchical_one_shot_aggregate(
            blob_state(pts), shards=1, k=k, sketch_dim=16, seed=seed % 97)
        np.testing.assert_array_equal(labels, ref_labels)
        np.testing.assert_array_equal(np.asarray(state.params["theta"]),
                                      np.asarray(ref_state.params["theta"]))

    prop()


# -------------------------------------------------- sharded composition

def test_sharded_round_recovers_planted_clusters():
    pts, true = make_blobs(1, [40, 40, 40], 8)
    rng = np.random.default_rng(1)
    perm = rng.permutation(len(pts))
    state, labels, info = hierarchical_one_shot_aggregate(
        blob_state(pts[perm]), shards=4, k=3, sketch_dim=32, seed=0)
    assert info["shards"] == 4
    assert info["n_clusters"] == 3
    assert same_partition(labels, true[perm])


def test_sharded_models_are_exact_global_cluster_means():
    # the weighted top-level composition must equal the global
    # per-cluster mean: flat-round parity on well-separated blobs where
    # both levels recover the truth exactly
    pts, true = make_blobs(2, [30, 25, 35], 6, sep=40.0, noise=0.05)
    state, labels, _ = hierarchical_one_shot_aggregate(
        blob_state(pts), shards=3, k=3, sketch_dim=24, seed=0)
    assert same_partition(labels, true)
    served = np.asarray(state.params["theta"])
    for c in np.unique(labels):
        got = served[labels == c]
        want = np.broadcast_to(pts[labels == c].mean(axis=0), got.shape)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sharded_ingest_split_matches_single_wave():
    # the same clients, chunked differently across ingest waves, land in
    # the same shards (contiguous fill) -> identical composed round
    pts, _ = make_blobs(3, [20, 20], 5)
    a = HierarchicalSession(len(pts), shards=2, sketch_dim=16, seed=0)
    b = HierarchicalSession(len(pts), shards=2, sketch_dim=16, seed=0)
    a.ingest({"theta": jnp.asarray(pts)})
    hier_ingest(b, pts, pattern=(3, 11, 6))
    _, lab_a, _ = a.finalize(k=2)
    _, lab_b, _ = b.finalize(k=2)
    np.testing.assert_array_equal(lab_a, lab_b)


def test_per_level_comm_accounting():
    pts, _ = make_blobs(4, [30, 30, 30], 6)
    sess = HierarchicalSession(len(pts), shards=3, sketch_dim=16, seed=0)
    sess.ingest({"theta": jnp.asarray(pts)})
    _, _, info = sess.finalize(k=3)
    clb = info["comm_level_bytes"]
    assert clb["level0"] == len(pts) * 16 * 4
    # top level moves one row per shard-cluster (plus its count), far
    # below the flat round's per-client uploads
    m_top = sum(info["per_shard_clusters"])
    assert clb["level1"] == m_top * (16 + 1) * 4
    assert clb["level1"] < clb["level0"]


def test_sketch_only_hierarchical_round_routes():
    pts, true = make_blobs(5, [25, 25], 6)
    flat = HierarchicalSession(len(pts), shards=1, sketch_dim=16, seed=0)
    sk = flat._sessions[0].sketch_params({"theta": jnp.asarray(pts)})
    sess = HierarchicalSession(len(pts), shards=2, sketch_dim=16, seed=0)
    sess.ingest(sketches=sk)
    state, labels, info = sess.finalize(k=2)
    assert state is None
    assert same_partition(labels, true)
    routed = sess.route(sk)
    np.testing.assert_array_equal(routed, labels)
    with pytest.raises(ValueError, match="no parameters"):
        sess.cluster_model(0)


def test_route_and_cluster_model_compose():
    pts, _ = make_blobs(6, [30, 30, 30], 8)
    sess = HierarchicalSession(len(pts), shards=3, sketch_dim=32, seed=0)
    sess.ingest({"theta": jnp.asarray(pts)})
    state, labels, _ = sess.finalize(k=3)
    assert sess.n_clusters == 3
    # every ingested client routes to its own composed cluster
    sk = sess._sessions[0].sketch_params({"theta": jnp.asarray(pts)})
    np.testing.assert_array_equal(sess.route(sk), labels)
    # the served model is the client's own averaged row
    cid = int(labels[0])
    np.testing.assert_allclose(
        np.asarray(sess.cluster_model(cid)["theta"]),
        np.asarray(state.params["theta"][0]), rtol=1e-6)


def test_convex_family_streams_through_hierarchy():
    pts, true = make_blobs(7, [14, 12, 13], 6, sep=30.0, noise=0.1)
    sess = HierarchicalSession(len(pts), shards=2, sketch_dim=24, seed=1)
    sess.ingest({"theta": jnp.asarray(pts)})
    _, labels, info = sess.finalize(
        algorithm="clusterpath-device",
        algo_options={"edges": "knn", "knn_k": 5, "iters": 300})
    assert info["n_clusters"] == 3
    assert same_partition(labels, true)


# ------------------------------------------------------------ guard rails

def test_keyed_ingest_rejected():
    sess = HierarchicalSession(8, shards=2, sketch_dim=8)
    with pytest.raises(ValueError, match="anonymous-only"):
        sess.ingest({"theta": jnp.zeros((2, 4))}, client_ids=[0, 1])


def test_capacity_and_empty_guards():
    sess = HierarchicalSession(8, shards=2, sketch_dim=8)
    with pytest.raises(ValueError, match="nothing ingested"):
        sess.finalize(k=2)
    with pytest.raises(ValueError, match="capacity exceeded"):
        sess.ingest({"theta": jnp.zeros((9, 4))})
    with pytest.raises(ValueError, match="shards"):
        HierarchicalSession(4, shards=0)
    with pytest.raises(ValueError, match="capacity"):
        HierarchicalSession(2, shards=4)


def test_simulate_guards_shards_against_mutation():
    from repro.launch.simulate import simulate
    with pytest.raises(ValueError, match="shards"):
        simulate(clients=64, clusters=2, shards=2, churn=4)
    with pytest.raises(ValueError, match="shards"):
        simulate(clients=64, clusters=2, shards=2, method="ifca")
