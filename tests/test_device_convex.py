"""Device convex-clustering validation: host/device AMA parity on
planted-cluster sketches, batched group-prox kernel block boundaries,
the K-free device clusterpath, engine dispatch for the convex names,
and the zero-host-sketch-transfer contract of the jitted convex round."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clustering import (
    convex_clustering,
    device_twin,
    get_algorithm,
    is_device_algorithm,
    lambda_interval,
    list_algorithms,
)
from repro.core.engine import device_clusterpath, device_convex_cluster
from repro.core.federated import FederatedState, one_shot_aggregate
from repro.kernels import ref
from repro.kernels.group_prox import group_ball_proj_batched_pallas
from repro.launch.simulate import simulate
from repro.optim import adamw_init

from conftest import same_partition


def make_blobs(seed, k=3, per=10, d=6, sep=30.0, noise=0.1):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d))
    dists = np.linalg.norm(centers[:, None] - centers[None], axis=-1)
    np.fill_diagonal(dists, np.inf)
    centers *= sep / dists.min()
    pts = np.concatenate(
        [c + noise * rng.normal(size=(per, d)) for c in centers])
    labels = np.repeat(np.arange(k), per)
    return pts.astype(np.float32), labels


def interval_lambda(pts, labels):
    lo, hi = lambda_interval(pts, labels)
    assert lo < hi
    return 0.5 * (lo + hi)


def blob_state(seed=0, k=3, per=12, d=8):
    pts, true = make_blobs(seed, k=k, per=per, d=d, sep=15.0, noise=0.3)
    params = {"theta": jnp.asarray(pts)}
    return FederatedState(params=params,
                          opt_state=jax.vmap(adamw_init)(params),
                          n_clients=len(pts)), true


# ------------------------------------------------------ registry plumbing

def test_convex_device_registered_and_device_capable():
    assert {"convex-device", "clusterpath-device"} <= set(list_algorithms())
    for name in ("convex-device", "clusterpath-device"):
        algo = get_algorithm(name)
        assert is_device_algorithm(algo)
        assert not algo.requires_k
    # the host names stay host-only but expose their device twins
    assert device_twin(get_algorithm("convex")).name == "convex-device"
    assert device_twin(get_algorithm("clusterpath")).name == \
        "clusterpath-device"
    assert device_twin(get_algorithm("kmeans++")) is None
    assert device_twin(get_algorithm("kmeans-device")) is None


# ------------------------------------------------- device vs host parity

@pytest.mark.parametrize("seed,k", [(0, 3), (1, 2), (2, 4)])
def test_device_convex_matches_host_convex(seed, k):
    pts, true = make_blobs(seed, k=k)
    lam = interval_lambda(pts, true)
    host = convex_clustering(jnp.asarray(pts), lam, iters=400)
    dev = device_convex_cluster(jax.random.PRNGKey(0), jnp.asarray(pts),
                                lam=lam, iters=400)
    # same fusion graph -> same partition and cluster count
    assert int(dev.n_clusters) == host.n_clusters == k
    assert same_partition(np.asarray(host.labels), np.asarray(dev.labels))
    assert same_partition(np.asarray(dev.labels), true)
    # cluster means agree within AMA tolerance: align device's
    # root-indexed centers to the host's compact ids
    dev_labels = np.asarray(dev.labels)
    dev_centers = np.asarray(dev.centers)[np.unique(dev_labels)]
    host_order = [np.asarray(host.labels)[dev_labels == r][0]
                  for r in np.unique(dev_labels)]
    np.testing.assert_allclose(dev_centers, host.centers[host_order],
                               rtol=1e-3, atol=1e-3)


def test_device_convex_default_lambda_matches_host():
    pts, _ = make_blobs(4)
    host = get_algorithm("convex")(jax.random.PRNGKey(0), pts)
    dev = device_convex_cluster(jax.random.PRNGKey(0), jnp.asarray(pts))
    assert int(dev.n_clusters) == host.n_clusters
    assert same_partition(host.labels, np.asarray(dev.labels))


@pytest.mark.parametrize("seed,k", [(0, 3), (1, 2), (2, 4)])
def test_device_clusterpath_recovers_planted_k(seed, k):
    pts, true = make_blobs(seed, k=k)
    res = device_clusterpath(jax.random.PRNGKey(0), jnp.asarray(pts),
                             n_lambdas=10, iters=300)
    assert int(res.n_clusters) == k
    assert same_partition(np.asarray(res.labels), true)


def test_device_convex_lambda_extremes():
    pts, _ = make_blobs(3, k=3, per=8)
    m = len(pts)
    tiny = device_convex_cluster(jax.random.PRNGKey(0), jnp.asarray(pts),
                                 lam=1e-7, iters=100)
    assert int(tiny.n_clusters) == m          # no fusion: all singletons
    huge = device_convex_cluster(jax.random.PRNGKey(0), jnp.asarray(pts),
                                 lam=1e3, iters=400)
    assert int(huge.n_clusters) == 1          # everything fuses


def test_device_convex_single_client():
    pts = np.ones((1, 4), np.float32)
    res = device_convex_cluster(jax.random.PRNGKey(0), jnp.asarray(pts))
    assert int(res.n_clusters) == 1
    assert np.asarray(res.labels).tolist() == [0]


# -------------------------------------- fused kernel at block boundaries

@pytest.mark.parametrize("b,e,d,be", [
    (3, 13, 5, 8),      # E not a multiple of be: one padded tail block
    (2, 300, 33, 128),  # multi-block edge grid + padded tail
    (1, 256, 16, 256),  # exact single block
    (4, 5, 4, 256),     # E smaller than be
])
def test_group_prox_batched_pallas_block_boundaries(b, e, d, be):
    rng = np.random.default_rng(e * 7 + b)
    v = jnp.asarray(rng.normal(size=(b, e, d)).astype(np.float32))
    r = jnp.asarray(rng.uniform(0.1, 2.0, size=(b, e)).astype(np.float32))
    out_p = group_ball_proj_batched_pallas(v, r, be=be, interpret=True)
    out_r = ref.group_ball_proj_batched(v, r)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)
    # rows inside the radius pass through untouched
    inside = np.linalg.norm(np.asarray(v), axis=2) <= np.asarray(r)
    np.testing.assert_array_equal(np.asarray(out_p)[inside],
                                  np.asarray(v)[inside])


# ------------------------------------------- one-shot round on the engine

def test_convex_auto_engine_dispatches_to_device_and_agrees_with_host():
    state, true = blob_state()
    kwargs = dict(algorithm="convex", sketch_dim=32, seed=3)
    _, lab_host, info_host = one_shot_aggregate(state, None, engine="host",
                                                **kwargs)
    _, lab_auto, info_auto = one_shot_aggregate(state, None, engine="auto",
                                                **kwargs)
    _, lab_dev, info_dev = one_shot_aggregate(state, None, engine="device",
                                              **kwargs)
    assert info_host["engine"] == "host"
    assert info_auto["engine"] == "device"
    assert info_dev["engine"] == "device"
    assert same_partition(lab_host, lab_auto)
    assert same_partition(lab_auto, lab_dev)
    assert info_auto["n_clusters"] == info_host["n_clusters"]


def test_clusterpath_auto_engine_recovers_planted_clusters():
    state, true = blob_state()
    new_state, labels, info = one_shot_aggregate(
        state, None, algorithm="clusterpath", engine="auto", sketch_dim=32,
        seed=3)
    assert info["engine"] == "device"
    assert info["n_clusters"] == 3
    assert same_partition(labels, true)
    # clients in one recovered cluster share the averaged model
    theta = np.asarray(new_state.params["theta"])
    for c in np.unique(labels):
        members = np.where(labels == c)[0]
        np.testing.assert_allclose(
            theta[members], np.broadcast_to(theta[members[0]],
                                            theta[members].shape),
            rtol=1e-6, atol=1e-6)


def test_convex_engines_agree_on_averaged_params_with_interval_lambda():
    state, true = blob_state()
    # oracle lambda in sketch space: pull the sketches once host-side
    # (debug path) to compute the recovery interval, then run both
    # engines at that lambda
    _, _, info = one_shot_aggregate(state, None, algorithm="convex",
                                    engine="host", sketch_dim=32, seed=3,
                                    return_sketches=True)
    lam = interval_lambda(info["sketches"], true)
    kwargs = dict(algorithm="convex", algo_options={"lam": lam},
                  sketch_dim=32, seed=3)
    st_h, lab_h, info_h = one_shot_aggregate(state, None, engine="host",
                                             **kwargs)
    st_d, lab_d, info_d = one_shot_aggregate(state, None, engine="auto",
                                             **kwargs)
    assert info_d["engine"] == "device"
    assert info_h["n_clusters"] == info_d["n_clusters"] == 3
    assert same_partition(lab_h, lab_d)
    assert same_partition(lab_d, true)
    np.testing.assert_allclose(np.asarray(st_h.params["theta"]),
                               np.asarray(st_d.params["theta"]),
                               rtol=1e-4, atol=1e-4)


def _arrays_of_shape(obj, shape):
    """All ndarray leaves of a nested dict matching ``shape``."""
    found = []
    if isinstance(obj, dict):
        for v in obj.values():
            found += _arrays_of_shape(v, shape)
    elif isinstance(obj, (np.ndarray, jnp.ndarray)) and obj.shape == shape:
        found.append(obj)
    return found


def test_convex_device_engine_no_host_sketch_transfer():
    state, _ = blob_state()
    sketch_dim = 32
    full = (state.n_clients, sketch_dim)
    _, _, info = one_shot_aggregate(state, None, algorithm="convex",
                                    engine="auto", sketch_dim=sketch_dim)
    assert info["engine"] == "device"
    assert not _arrays_of_shape(info, full), \
        "one-shot info must not materialize the (C, sketch_dim) sketches"
    assert all(np.asarray(v).ndim == 0 for v in info["meta"].values())
    _, _, info = one_shot_aggregate(state, None, algorithm="convex",
                                    engine="auto", sketch_dim=sketch_dim,
                                    return_sketches=True)
    assert len(_arrays_of_shape(info, full)) == 1  # opt-in still works


# ----------------------------------------------------- simulation driver

def test_simulate_convex_exact_lambda_recovers_clusters():
    summary = simulate(clients=96, clusters=4, dim=8, samples=64, wave=48,
                       sketch_dim=32, seed=0, algorithm="convex",
                       cc_iters=300)
    assert summary["algorithm"] == "convex"
    assert summary["purity"] == 1.0
    assert summary["n_clusters_recovered"] == 4
    assert summary["meta"]["engine"] == "device"


@pytest.mark.slow
def test_simulate_convex_large_c():
    """C >= 4096 convex sweep (the complete-graph AMA at bench scale)."""
    summary = simulate(clients=4096, clusters=8, dim=16, samples=64,
                       wave=2048, sketch_dim=32, seed=0,
                       algorithm="convex-device", cc_iters=200)
    assert summary["purity"] >= 0.99
    assert summary["n_clusters_recovered"] == 8


# ------------------------------------------- degenerate edge-set sweep

@pytest.mark.parametrize("edges", ["complete", "knn", "knn-approx"])
@pytest.mark.parametrize("m", [1, 2, 3])
def test_solver_survives_degenerate_sizes(edges, m):
    """m in {1, 2, 3} with knn_k >= m and tile > m must solve, not
    crash (E=0 at m=1 hits the empty-dual AMA and kernel guards)."""
    rng = np.random.default_rng(m)
    pts = jnp.asarray(rng.normal(size=(m, 4)), jnp.float32)
    res = device_convex_cluster(jax.random.PRNGKey(0), pts, lam=1e-3,
                                iters=50, edges=edges, knn_k=8)
    labels = np.asarray(res.labels)
    assert labels.shape == (m,)
    assert 1 <= int(res.n_clusters) <= m
    assert np.isfinite(np.asarray(res.u)).all()


@pytest.mark.parametrize("edges", ["knn", "knn-approx"])
@pytest.mark.parametrize("m", [2, 3])
def test_clusterpath_survives_degenerate_sizes(edges, m):
    rng = np.random.default_rng(m + 10)
    pts = jnp.asarray(rng.normal(size=(m, 3)), jnp.float32)
    res = device_clusterpath(jax.random.PRNGKey(0), pts, n_lambdas=4,
                             iters=50, edges=edges, knn_k=8)
    assert np.asarray(res.labels).shape == (m,)


def test_ama_empty_edge_set_returns_input():
    """E=0 (a single client's fusion graph): the fixed point is the
    input itself, zero iterations, an empty dual."""
    from repro.core.engine.device_convex import _ama_fixed_point
    from repro.core.engine.edges import Edges

    a = jnp.asarray([[1.0, -2.0, 3.0]], jnp.float32)
    empty = Edges(i_idx=jnp.zeros((0,), jnp.int32),
                  j_idx=jnp.zeros((0,), jnp.int32),
                  weights=jnp.zeros((0,), jnp.float32),
                  inv_eta=1.0)
    u, nu, n_iter = _ama_fixed_point(a, jnp.asarray([0.5, 1.0]), empty,
                                     iters=100, tol=1e-7)
    assert u.shape == (2, 1, 3)
    np.testing.assert_array_equal(np.asarray(u[0]), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(u[1]), np.asarray(a))
    assert nu.shape == (2, 0, 3)
    assert int(n_iter) == 0


def test_group_prox_kernels_handle_zero_edges():
    from repro.kernels.group_prox import group_ball_proj_pallas

    flat = group_ball_proj_pallas(jnp.zeros((0, 4)), jnp.zeros((0,)))
    assert flat.shape == (0, 4)
    batched = group_ball_proj_batched_pallas(jnp.zeros((3, 0, 4)),
                                             jnp.zeros((3, 0)))
    assert batched.shape == (3, 0, 4)
