"""The adversity-scenario subsystem (``repro.scenarios``): registry
round-trips, '+'-composition, hook invariants (wave-partition
invariance, DP clipping), data-layer wiring, and the
``BENCH_robustness.json`` schema the robustness bench emits."""
import dataclasses
import json
import pathlib
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import make_linear_regression_federation
from repro.scenarios import (
    ByzantineScenario,
    ComposedScenario,
    DPScenario,
    DriftScenario,
    LongtailScenario,
    Scenario,
    build_scenario,
    get_scenario,
    list_scenarios,
    register_scenario,
    unregister_scenario,
)


# ------------------------------------------------------------------ registry

def test_registry_round_trip():
    assert set(list_scenarios()) >= {"none", "drift", "longtail",
                                     "byzantine", "dp"}
    probe = ByzantineScenario(name="probe-scen", frac=0.3)
    register_scenario(probe)
    try:
        assert get_scenario("probe-scen") is probe
        with pytest.raises(ValueError, match="already registered"):
            register_scenario(ByzantineScenario(name="probe-scen"))
    finally:
        unregister_scenario("probe-scen")
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("probe-scen")


def test_build_scenario_specializes_and_composes():
    s = build_scenario("byzantine", frac=0.25, attack="noise", epsilon=4.0)
    assert isinstance(s, ByzantineScenario)
    assert (s.frac, s.attack) == (0.25, "noise")   # epsilon ignored
    assert build_scenario(None).name == "none"
    inst = DPScenario(epsilon=2.0)
    assert build_scenario(inst) is inst            # instances pass through

    comp = build_scenario("longtail+byzantine+dp", frac=0.2, epsilon=8.0,
                          zipf_a=1.5)
    assert isinstance(comp, ComposedScenario)
    lt, byz, dp = comp.members
    assert isinstance(lt, LongtailScenario) and lt.zipf_a == 1.5
    assert isinstance(byz, ByzantineScenario) and byz.frac == 0.2
    assert isinstance(dp, DPScenario) and dp.epsilon == 8.0
    # each flat option lands only on the member that declares the field
    assert comp.transforms_sketches            # dp noises the sketch rows
    mask = comp.honest_mask(jax.random.PRNGKey(0), 64)
    assert mask.dtype == jnp.bool_ and not bool(jnp.all(mask))


def test_scenarios_are_frozen_and_hashable():
    """Scenario instances key jitted-program caches: must be hashable."""
    for s in (Scenario(), DriftScenario(), LongtailScenario(),
              ByzantineScenario(), DPScenario()):
        assert dataclasses.is_dataclass(s)
        assert hash(s) == hash(dataclasses.replace(s))


def test_identity_scenario_hooks_are_noops():
    key = jax.random.PRNGKey(0)
    s = build_scenario(None)
    labels = s.population(key, 12, 4)
    np.testing.assert_array_equal(np.asarray(labels),
                                  np.arange(12) % 4)
    theta = jnp.ones((6, 3))
    assert s.corrupt_uploads(key, theta, labels[:6], 0, 12) is theta
    assert s.sketch_transform(key, theta, 0) is theta
    assert not s.transforms_sketches
    assert bool(jnp.all(s.honest_mask(key, 12)))


# ----------------------------------------------------------------- byzantine

def test_byzantine_wave_partition_invariance():
    """Corrupting the full population in one call == corrupting it wave
    by wave: the Bernoulli role coin is keyed on the GLOBAL client
    index, not the wave-local row."""
    key = jax.random.PRNGKey(7)
    s = ByzantineScenario(frac=0.3)
    theta = jax.random.normal(jax.random.fold_in(key, 1), (64, 5))
    full = s.corrupt_uploads(key, theta, None, 0, 64)
    waved = jnp.concatenate([
        s.corrupt_uploads(key, theta[:24], None, 0, 64),
        s.corrupt_uploads(key, theta[24:], None, 24, 64)])
    np.testing.assert_array_equal(np.asarray(full), np.asarray(waved))
    # the honest mask names exactly the sign-flipped rows
    mask = np.asarray(s.honest_mask(key, 64))
    flipped = ~np.all(np.asarray(full) == np.asarray(theta), axis=1)
    np.testing.assert_array_equal(~mask, flipped)
    assert 0.0 < flipped.mean() < 0.6


def test_byzantine_spoof_forges_sketch_channel_only():
    key = jax.random.PRNGKey(3)
    s = ByzantineScenario(frac=0.4, attack="spoof")
    assert s.transforms_sketches
    theta = jnp.ones((32, 5))
    assert s.corrupt_uploads(key, theta, None, 0, 32) is theta
    sk = jax.random.normal(key, (32, 8))
    out = np.asarray(s.sketch_transform(key, sk, 0))
    bad = ~np.asarray(s.honest_mask(key, 32))
    assert bad.any()
    # every attacker uploads the SAME forged row (a fake cluster)
    assert np.ptp(out[bad], axis=0).max() == 0.0
    np.testing.assert_array_equal(out[~bad], np.asarray(sk)[~bad])


# ------------------------------------------------------------------------ dp

def test_dp_sketch_transform_clips_then_noises():
    key = jax.random.PRNGKey(5)
    sk = 50.0 * jax.random.normal(key, (128, 16))
    # eps -> huge: sigma -> 0, so the output is just the L2 clip
    out = np.asarray(DPScenario(epsilon=1e9, clip=1.0).sketch_transform(
        key, sk, 0))
    norms = np.linalg.norm(out, axis=1)
    assert np.all(norms <= 1.0 + 1e-4)
    # clipping preserves direction
    cos = np.sum(out * np.asarray(sk), axis=1) / np.maximum(
        norms * np.linalg.norm(np.asarray(sk), axis=1), 1e-12)
    assert np.all(cos > 1.0 - 1e-5)
    # tighter budget -> more noise (monotone in 1/eps)
    def spread(eps):
        o = np.asarray(DPScenario(epsilon=eps, clip=1.0).sketch_transform(
            key, jnp.zeros((128, 16)), 0))
        return np.std(o)
    assert spread(1.0) > 4.0 * spread(16.0)


# ---------------------------------------------------------- drift / longtail

def test_drift_shifts_only_late_stream_clients():
    key = jax.random.PRNGKey(2)
    s = DriftScenario(drift_frac=1.0, drift_at=0.5, shift=2)
    labels = jnp.arange(64, dtype=jnp.int32) % 4
    out = np.asarray(s.wave_labels(key, labels, 0, 64, 4))
    np.testing.assert_array_equal(out[:32], np.asarray(labels)[:32])
    np.testing.assert_array_equal(out[32:], (np.asarray(labels)[32:] + 2) % 4)


def test_longtail_population_is_zipf_occupancy():
    s = LongtailScenario(zipf_a=1.2)
    labels = np.asarray(s.population(jax.random.PRNGKey(0), 100, 8))
    counts = np.bincount(labels, minlength=8)
    assert counts.sum() == 100
    assert counts.min() >= 1                  # admissibility needs c_min >= 1
    assert np.all(np.diff(counts) <= 0)       # head-heavy
    assert counts[0] > counts[-1]
    with pytest.raises(ValueError, match="clients >= clusters"):
        s.population(jax.random.PRNGKey(0), 4, 8)


# ------------------------------------------------------------- data wiring

def test_synthetic_federation_applies_scenario():
    fed = make_linear_regression_federation(
        seed=0, m=40, K=4, n=8, d=6,
        scenario=ByzantineScenario(frac=0.25))
    assert fed.honest is not None and fed.honest.shape == (40,)
    assert 0 < (~fed.honest).sum() < 40
    assert make_linear_regression_federation(
        seed=0, m=40, K=4, n=8, d=6).honest is None
    # same draw under the identity scenario (same round-robin population,
    # nobody corrupted): the sign-flip lands as exactly -y on attackers —
    # the ridge ERM is linear in y
    clean = make_linear_regression_federation(seed=0, m=40, K=4, n=8, d=6,
                                              scenario="none")
    assert clean.honest is not None and clean.honest.all()
    np.testing.assert_array_equal(fed.true_labels, clean.true_labels)
    np.testing.assert_allclose(fed.ys[~fed.honest],
                               -clean.ys[~fed.honest], rtol=1e-6)
    np.testing.assert_allclose(fed.ys[fed.honest],
                               clean.ys[fed.honest], rtol=1e-6)


# --------------------------------------------------------- bench schema gate

def test_bench_robustness_schema(tmp_path):
    """Every BENCH_robustness.json row carries the pinned schema keys
    (``scenario`` / ``aggregator`` / ``purity``) in both sweeps."""
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks import bench_robustness

    out = tmp_path / "BENCH_robustness.json"
    report = bench_robustness.run(
        base=dict(clients=128, wave=128, samples=32),
        byz=dict(restarts=2), robust=dict(restarts=2),
        aggregators=("mean", "trimmed_mean"),
        robust_aggregators=("trimmed_mean", "geometric_median"),
        byz_fracs=(0.1,), breakdown_fracs=(0.3,), spoof_fracs=(0.1,),
        seeds=(0,), dp_epsilons=(32.0,),
        out=str(out))
    on_disk = json.loads(out.read_text())
    assert on_disk["bench"] == "robustness"
    # 2 byzantine + 2 breakdown + 2 spoof + 2 dp (eps=32 + inf baseline)
    assert len(on_disk["rows"]) == len(report["rows"]) == 8
    for row in on_disk["rows"]:
        for key in ("sweep", "scenario", "aggregator", "purity", "mse"):
            assert key in row, f"row missing {key!r}: {sorted(row)}"
        assert 0.0 <= row["purity"] <= 1.0
    byz = [r for r in on_disk["rows"] if r["sweep"] == "byzantine"]
    assert {r["aggregator"] for r in byz} == {"mean", "trimmed_mean"}
    assert all(r["scenario"] == "byzantine" for r in byz)
    for sweep in ("breakdown", "spoof"):
        part = [r for r in on_disk["rows"] if r["sweep"] == sweep]
        assert {r["aggregator"] for r in part} == {"trimmed_mean",
                                                   "geometric_median"}
        assert all(r["scenario"] == "byzantine" for r in part)
    dp = [r for r in on_disk["rows"] if r["sweep"] == "dp"]
    assert all(r["scenario"] == "dp" for r in dp)
    assert all("achieved_alpha" in r and "predicted_alpha" in r for r in dp)
