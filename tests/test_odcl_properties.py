"""Property-based tests of Algorithm 1's system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

import functools

from repro.core import aggregate, odcl
from repro.core.clustering import convex_clustering, knn_weights


def blobs(seed, k=3, per=6, d=4, sep=25.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d))
    dists = np.linalg.norm(centers[:, None] - centers[None], axis=-1)
    np.fill_diagonal(dists, np.inf)
    centers *= sep / dists.min()
    pts = np.concatenate([c + 0.2 * rng.normal(size=(per, d)) for c in centers])
    return pts.astype(np.float32), np.repeat(np.arange(k), per)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_permutation_equivariance(seed):
    """Shuffling the clients permutes the outputs identically."""
    pts, _ = blobs(seed)
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(len(pts))
    run = functools.partial(odcl, algorithm="kmeans++", k=3, seed=0)
    r1 = run(pts)
    r2 = run(pts[perm])
    np.testing.assert_allclose(r2.user_models, r1.user_models[perm],
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_aggregation_idempotent(seed):
    """Aggregating the aggregated models changes nothing."""
    pts, _ = blobs(seed)
    run = functools.partial(odcl, algorithm="kmeans++", k=3, seed=0)
    r1 = run(pts)
    r2 = run(r1.user_models)
    np.testing.assert_allclose(r2.user_models, r1.user_models,
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(0.1, 10.0))
def test_scale_equivariance(seed, scale):
    """odcl(c*models) == c*odcl(models) for K-means variants."""
    pts, _ = blobs(seed)
    run = functools.partial(odcl, algorithm="kmeans++", k=3, seed=0)
    r1 = run(pts)
    r2 = run(pts * scale)
    np.testing.assert_allclose(r2.user_models, r1.user_models * scale,
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_aggregate_preserves_mean_per_cluster(seed):
    """Cluster-wise averaging conserves each cluster's mass."""
    pts, labels = blobs(seed)
    cluster_avg, user_models = aggregate(pts, labels)
    for c in np.unique(labels):
        np.testing.assert_allclose(cluster_avg[c],
                                   pts[labels == c].mean(axis=0),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(user_models.mean(axis=0), pts.mean(axis=0),
                               rtol=1e-4, atol=1e-5)


def test_weighted_convex_clustering_recovers():
    """Remark 13: kNN-weighted CC also recovers well-separated blobs."""
    pts, true = blobs(0, k=3, per=8)
    w = knn_weights(pts, k=5)
    # weighted edges shrink the penalty mass -> larger lambda range works
    res = convex_clustering(pts, lam=2.0, iters=400, weights=w)
    from collections import Counter

    assert res.n_clusters >= 3
    for c in range(res.n_clusters):
        members = true[res.labels == c]
        if len(members):
            assert len(Counter(members)) == 1
