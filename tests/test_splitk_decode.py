"""Split-K decode (cache-length sharding + grouped-head GQA einsums)
must be numerically identical to the baseline decode path."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import decode_step, forward, init_decode_cache, init_params


# each case replays the full token-by-token decode twice (~10s); the
# fast gate keeps one GQA representative, the rest run under -m slow
@pytest.mark.parametrize("arch", [
    pytest.param("qwen2_0_5b", marks=pytest.mark.slow), "yi_9b",
    pytest.param("gemma_2b", marks=pytest.mark.slow)])
def test_splitk_matches_baseline(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(), serve_window=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, {"tokens": toks, "labels": toks})
    for sk in (False, True):
        cfg2 = dataclasses.replace(cfg, splitk_decode=sk)
        cache = init_decode_cache(cfg2, b, context=s)
        outs = []
        for t in range(s):
            lg, cache = decode_step(params, cfg2, cache, toks[:, t:t + 1])
            outs.append(lg[:, 0])
        err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - full)))
        assert err < 1e-3 * float(jnp.max(jnp.abs(full))), (sk, err)
