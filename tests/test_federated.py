"""The multi-pod integration at toy scale: federated ODCL over clustered
LM clients — local phase learns cluster-specific bigram stats, the
one-shot aggregate recovers the client clustering and improves loss."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.federated import (
    evaluate_per_client,
    init_federation,
    local_training,
    one_shot_aggregate,
)
from repro.data import ClusteredTokenStream, make_lm_batch_iterator
from repro.optim import AdamWConfig
import jax


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2_0_5b").reduced(n_layers=2, max_d_model=64,
                                           max_vocab=64)
    n_clients, k = 8, 2
    stream = ClusteredTokenStream(n_clients=n_clients, n_clusters=k,
                                  vocab_size=cfg.vocab_size, seed=0,
                                  branching=4)
    batches = make_lm_batch_iterator(
        stream, clients_per_batch=list(range(n_clients)),
        per_client_batch=4, seq_len=32)

    def batch_fn():
        toks, labels = next(batches)
        return {"tokens": toks, "labels": labels}

    state = init_federation(jax.random.PRNGKey(0), cfg, n_clients)

    def batch_iter():
        while True:
            yield batch_fn()

    # enough local steps for the clients' models to separate by cluster
    # (the deep-net analogue of the paper's sample-size threshold)
    state, losses = local_training(
        state, cfg, batch_iter(), steps=120,
        opt_cfg=AdamWConfig(lr=1e-3, weight_decay=0.0))
    return cfg, stream, state, losses, batch_fn


def test_local_training_reduces_loss(setup):
    _, _, _, losses, _ = setup
    assert losses[-1].mean() < losses[0].mean()


def test_one_shot_aggregate_recovers_clusters(setup):
    cfg, stream, state, _, _ = setup
    new_state, labels, info = one_shot_aggregate(
        state, cfg, algorithm="kmeans++", k=2, sketch_dim=64)
    # recovered clusters must match the hidden client clustering exactly
    from collections import Counter

    for c in np.unique(labels):
        members = stream.true_labels[labels == c]
        assert len(Counter(members)) == 1
    assert info["n_clusters"] == 2


def test_aggregation_improves_or_matches_local(setup):
    cfg, stream, state, _, batch_fn = setup
    new_state, labels, _ = one_shot_aggregate(
        state, cfg, algorithm="kmeans++", k=2, sketch_dim=64)
    eval_batch = batch_fn()
    local_losses = evaluate_per_client(state, cfg, eval_batch)
    agg_losses = evaluate_per_client(new_state, cfg, eval_batch)
    # cluster-averaged models should not be worse on average (they pool
    # 4x the data of a single client)
    assert agg_losses.mean() <= local_losses.mean() * 1.05


def test_clients_in_same_cluster_share_model(setup):
    cfg, stream, state, _, _ = setup
    new_state, labels, _ = one_shot_aggregate(
        state, cfg, algorithm="kmeans++", k=2, sketch_dim=64)
    embed = np.asarray(new_state.params["embed"], np.float32)
    for c in np.unique(labels):
        members = np.where(labels == c)[0]
        for m in members[1:]:
            np.testing.assert_allclose(embed[members[0]], embed[m],
                                       rtol=1e-5, atol=1e-6)


def test_different_clusters_differ(setup):
    cfg, stream, state, _, _ = setup
    new_state, labels, _ = one_shot_aggregate(
        state, cfg, algorithm="kmeans++", k=2, sketch_dim=64)
    embed = np.asarray(new_state.params["embed"], np.float32)
    a = np.where(labels == 0)[0][0]
    b = np.where(labels == 1)[0][0]
    assert np.abs(embed[a] - embed[b]).max() > 1e-6
