"""The pluggable-clustering registry and the unified Method API:
round-trip registration, ClusteringResult invariants for every seed
algorithm, function-API parity, and drop-in use of a new algorithm."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GlobalERM,
    LocalOnly,
    ODCL,
    OracleAveraging,
    batched_ridge_erm,
    get_algorithm,
    get_method,
    list_algorithms,
    list_methods,
    odcl,
    oracles,
    register_algorithm,
    unregister_algorithm,
)
from repro.core.clustering import ClusteringResult, separability_of
from repro.data import make_linear_regression_federation

SEED_ALGORITHMS = ("kmeans", "kmeans++", "spectral", "gradient", "convex",
                   "clusterpath")


def blobs(seed=0, k=3, per=8, d=5, sep=40.0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d))
    dists = np.linalg.norm(centers[:, None] - centers[None], axis=-1)
    np.fill_diagonal(dists, np.inf)
    centers *= sep / dists.min()
    pts = np.concatenate([c + 0.1 * rng.normal(size=(per, d))
                          for c in centers])
    return pts.astype(np.float32), np.repeat(np.arange(k), per)


def purity(pred, true):
    from collections import Counter

    total = 0
    for c in np.unique(pred):
        total += Counter(true[pred == c]).most_common(1)[0][1]
    return total / len(true)


@dataclasses.dataclass(frozen=True)
class TrueKSplit:
    """Toy plugin: splits points by sign of their first coordinate."""
    name: str = "first-coord-sign"
    requires_k: bool = False

    def __call__(self, key, points, *, k=None, **options):
        labels = (np.asarray(points)[:, 0] > 0).astype(np.int32)
        labels = labels - labels.min()        # contiguous ids from 0
        centers = np.stack([np.asarray(points)[labels == c].mean(axis=0)
                            for c in range(int(labels.max()) + 1)])
        return ClusteringResult(labels=labels, centers=centers,
                                n_clusters=int(labels.max()) + 1, meta={})

    def admissibility_alpha(self, m, c_min):
        return 1.0


# ------------------------------------------------------------- registry

def test_all_seed_algorithms_registered():
    assert set(SEED_ALGORITHMS) <= set(list_algorithms())


def test_get_unknown_algorithm_raises_with_known_names():
    with pytest.raises(KeyError, match="kmeans"):
        get_algorithm("definitely-not-registered")


def test_register_round_trip_and_duplicate_guard():
    algo = TrueKSplit(name="round-trip-probe")
    try:
        register_algorithm(algo)
        assert get_algorithm("round-trip-probe") is algo
        assert "round-trip-probe" in list_algorithms()
        with pytest.raises(ValueError, match="already registered"):
            register_algorithm(TrueKSplit(name="round-trip-probe"))
        replacement = TrueKSplit(name="round-trip-probe")
        register_algorithm(replacement, overwrite=True)
        assert get_algorithm("round-trip-probe") is replacement
    finally:
        unregister_algorithm("round-trip-probe")
    assert "round-trip-probe" not in list_algorithms()


@pytest.mark.parametrize("name", SEED_ALGORITHMS)
def test_clustering_result_invariants(name):
    pts, true = blobs()
    algo = get_algorithm(name)
    res = algo(jax.random.PRNGKey(0), jnp.asarray(pts),
               k=3 if algo.requires_k else None)
    assert isinstance(res, ClusteringResult)
    assert res.labels.shape == (len(pts),)
    assert res.labels.dtype.kind in "iu"
    assert res.labels.min() >= 0
    assert res.n_clusters == int(res.labels.max()) + 1
    assert res.centers.ndim == 2 and res.centers.shape[1] == pts.shape[1]
    assert res.centers.shape[0] >= res.n_clusters
    assert np.all(np.isfinite(res.centers[np.unique(res.labels)]))
    assert isinstance(res.meta, dict)
    assert float(algo.admissibility_alpha(len(pts), 8)) > 0
    if name != "kmeans":   # random init may hit a bad local optimum
        assert purity(res.labels, true) == 1.0
        assert separability_of(pts, res) > 1.0


# ------------------------------------------------------------- methods

@pytest.fixture(scope="module")
def fed():
    return make_linear_regression_federation(seed=0, n=200)


def ridge_solver(xs, ys):
    return batched_ridge_erm(jnp.asarray(xs), jnp.asarray(ys), 1e-8)


def test_method_registry_lists_core_methods():
    assert {"odcl", "ifca", "local-only", "global-erm"} <= set(list_methods())
    assert get_method("odcl") is ODCL
    with pytest.raises(KeyError):
        get_method("nope")


def test_odcl_method_matches_function_api_bit_for_bit(fed):
    local = np.asarray(ridge_solver(fed.xs, fed.ys))
    legacy = odcl(local, algorithm="kmeans++", k=10, seed=0)
    res = ODCL(algorithm="kmeans++", k=10).fit(
        jax.random.PRNGKey(0), fed.xs, fed.ys, ridge_solver)
    assert np.array_equal(res.labels, legacy.labels)
    assert np.array_equal(res.user_models, legacy.user_models)
    assert np.array_equal(res.cluster_models, legacy.cluster_models)
    assert res.n_clusters == legacy.n_clusters
    assert res.comm_rounds == 1


def test_baseline_methods_match_oracle_functions(fed):
    key = jax.random.PRNGKey(0)
    local = np.asarray(ridge_solver(fed.xs, fed.ys))
    oa = OracleAveraging(true_labels=fed.true_labels).fit(
        key, fed.xs, fed.ys, ridge_solver)
    np.testing.assert_array_equal(
        oa.user_models, oracles.oracle_averaging(local, fed.true_labels))
    lo = LocalOnly().fit(key, fed.xs, fed.ys, ridge_solver)
    np.testing.assert_array_equal(lo.user_models, local)
    assert lo.comm_rounds == 0
    ge = GlobalERM().fit(key, fed.xs, fed.ys, ridge_solver)
    np.testing.assert_array_equal(ge.user_models,
                                  oracles.naive_averaging(local))
    assert ge.n_clusters == 1
    # accessor sanity: ODCL should sit at the oracle, far below naive
    assert oa.nmse(fed.optima, fed.true_labels) < \
        ge.nmse(fed.optima, fed.true_labels)


def test_new_algorithm_usable_via_method_and_function_api():
    pts, _ = blobs(seed=1, k=2, per=10, d=4, sep=30.0)
    # center the first coordinate so the sign split is the 2-cluster truth
    pts[:, 0] -= pts[:, 0].mean()
    try:
        register_algorithm(TrueKSplit())
        via_method = ODCL(algorithm="first-coord-sign").fit(
            jax.random.PRNGKey(0), None, None, erm=lambda xs, ys: pts)
        via_fn = odcl(pts, algorithm="first-coord-sign")
        assert via_method.n_clusters == via_fn.n_clusters == 2
        np.testing.assert_array_equal(via_method.labels, via_fn.labels)
        np.testing.assert_array_equal(via_method.user_models,
                                      via_fn.user_models)
        assert "separability_alpha" in via_fn.meta
    finally:
        unregister_algorithm("first-coord-sign")


def test_odcl_function_convex_family_matches_method_api():
    """The function API's convex-family option passthrough (lam / iters /
    n_lambdas forwarded as ``**options``) must agree with ``Method.fit``
    driving the same registered algorithm."""
    pts, true = blobs(seed=2, k=3, per=8, d=5, sep=40.0)
    from repro.core.clustering import lambda_interval

    lo, hi = lambda_interval(pts, true)
    lam = 0.5 * (lo + hi)
    key = jax.random.PRNGKey(0)
    erm = lambda xs, ys: pts    # noqa: E731 - the "local models" stack

    via_fn = odcl(pts, algorithm="convex", lam=lam, iters=250)
    via_method = ODCL(algorithm="convex",
                      options={"lam": lam, "iters": 250}).fit(
        key, None, None, erm)
    np.testing.assert_array_equal(via_fn.labels, via_method.labels)
    np.testing.assert_array_equal(via_fn.user_models, via_method.user_models)
    assert via_fn.n_clusters == via_method.n_clusters == 3

    via_fn_cp = odcl(pts, algorithm="clusterpath", n_lambdas=6, iters=200)
    via_method_cp = ODCL(algorithm="clusterpath",
                         options={"n_lambdas": 6, "iters": 200}).fit(
        key, None, None, erm)
    np.testing.assert_array_equal(via_fn_cp.labels, via_method_cp.labels)
    assert via_fn_cp.n_clusters == via_method_cp.n_clusters


def test_resolve_device_request_lloyd_mapping_outranks_twin():
    """The shared device resolver must map host Lloyd names onto
    kmeans-device with the HOST algorithm's init — in particular
    'kmeans' (which also has a registered twin) keeps init='random'
    rather than silently upgrading to the twin's kmeans++ default."""
    from repro.core.clustering.api import resolve_device_request

    assert resolve_device_request("kmeans") == \
        ("kmeans-device", {"init": "random"})
    assert resolve_device_request("kmeans++", {"iters": 5}) == \
        ("kmeans-device", {"init": "kmeans++", "iters": 5})
    assert resolve_device_request("spectral") == \
        ("kmeans-device", {"init": "spectral"})
    # device-capable names and twin-upgradable names pass through —
    # including "gradient", whose gradient-device twin makes engine=auto
    # cover the whole registry
    assert resolve_device_request("kmeans-device") == ("kmeans-device", None)
    assert resolve_device_request("convex", {"lam": 0.1}) == \
        ("convex", {"lam": 0.1})
    assert resolve_device_request("gradient") == ("gradient", None)
    # caller options override the mapped init
    assert resolve_device_request("kmeans", {"init": "spectral"}) == \
        ("kmeans-device", {"init": "spectral"})
    # truly host-only plugins (no twin, not Lloyd) still raise loudly
    try:
        register_algorithm(TrueKSplit(name="host-only-probe"))
        with pytest.raises(ValueError, match="device-capable"):
            resolve_device_request("host-only-probe")
        assert resolve_device_request("host-only-probe", strict=False) == \
            ("host-only-probe", None)
    finally:
        unregister_algorithm("host-only-probe")


def test_odcl_config_shim_is_gone():
    """The deprecated ``ODCLConfig`` shim was removed: the name must not
    resurface in the public core namespace (migrators use ``odcl(...)``
    keyword arguments or ``Method.fit``)."""
    import repro.core
    import repro.core.odcl

    assert not hasattr(repro.core, "ODCLConfig")
    assert not hasattr(repro.core.odcl, "ODCLConfig")
    assert "ODCLConfig" not in getattr(repro.core, "__all__", ())


def test_assert_separable_flags_bad_clustering():
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(20, 4)).astype(np.float32)   # no cluster structure
    with pytest.raises(ValueError, match="not separable"):
        ODCL(algorithm="kmeans++", k=4, assert_separable=True).fit(
            jax.random.PRNGKey(0), None, None, erm=lambda xs, ys: pts)
