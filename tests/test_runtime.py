"""Backend/environment configuration (``repro/runtime.py``): XLA flag
merging, REPRO_* env presets, and the post-import degradation paths.

These tests run in a process where jax IS already imported (pytest
loads it via conftest), so the import-time-only setters must take the
warn-and-fallback branch — the before-import behavior is pinned through
the env-var values they write, which is all a fresh process would read.
"""
import os
import warnings

import pytest

from repro import runtime


@pytest.fixture
def clean_env(monkeypatch):
    for var in ("XLA_FLAGS", "JAX_PLATFORMS", "JAX_ENABLE_X64",
                "REPRO_PLATFORM", "REPRO_X64", "REPRO_CPU_THREADS",
                "REPRO_HOST_DEVICES", "REPRO_XLA_FLAGS",
                "OMP_NUM_THREADS", "MKL_NUM_THREADS"):
        monkeypatch.delenv(var, raising=False)
    return monkeypatch


def test_merge_xla_flags_dedupes_by_name_last_wins():
    out = runtime.merge_xla_flags(
        "--xla_a=1 --xla_b=2", "--xla_a=9 --xla_c", "")
    assert out.split() == ["--xla_b=2", "--xla_a=9", "--xla_c"]
    assert runtime.merge_xla_flags("", None if False else "") == ""


def test_add_xla_flags_merges_into_environment(clean_env):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        runtime.add_xla_flags("--xla_foo=1")
        value = runtime.add_xla_flags("--xla_foo=2 --xla_bar=3")
    assert value == os.environ["XLA_FLAGS"]
    assert value.split() == ["--xla_foo=2", "--xla_bar=3"]


def test_set_platform_validates_and_sets_env(clean_env):
    runtime.set_platform("cpu")
    assert os.environ["JAX_PLATFORMS"] == "cpu"
    with pytest.raises(ValueError, match="cpu|gpu|tpu"):
        runtime.set_platform("quantum")


def test_enable_x64_round_trip(clean_env):
    import jax

    try:
        runtime.enable_x64(True)
        assert os.environ["JAX_ENABLE_X64"] == "1"
        assert jax.config.jax_enable_x64 is True
    finally:
        runtime.enable_x64(False)
    assert os.environ["JAX_ENABLE_X64"] == "0"
    assert jax.config.jax_enable_x64 is False


def test_pin_cpu_threads_sets_pools_and_eigen_flag(clean_env):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        runtime.pin_cpu_threads(1)
    assert os.environ["OMP_NUM_THREADS"] == "1"
    assert os.environ["MKL_NUM_THREADS"] == "1"
    assert "--xla_cpu_multi_thread_eigen=false" in os.environ["XLA_FLAGS"]
    with pytest.raises(ValueError, match=">= 1"):
        runtime.pin_cpu_threads(0)


def test_import_time_setters_warn_after_jax_import(clean_env):
    assert runtime.jax_imported()      # conftest already imported it
    with pytest.warns(RuntimeWarning, match="after jax was imported"):
        runtime.add_xla_flags("--xla_probe=1")
    with pytest.warns(RuntimeWarning, match="fresh process"):
        runtime.set_host_device_count(2)


def test_apply_env_presets_reads_overrides(clean_env):
    clean_env.setenv("REPRO_PLATFORM", "cpu")
    clean_env.setenv("REPRO_CPU_THREADS", "1")
    clean_env.setenv("REPRO_XLA_FLAGS", "--xla_custom=7")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        applied = runtime.apply_env_presets()
    assert applied == {"platform": "cpu", "cpu_threads": 1,
                       "xla_flags": "--xla_custom=7"}
    assert os.environ["JAX_PLATFORMS"] == "cpu"
    assert "--xla_custom=7" in os.environ["XLA_FLAGS"]


def test_apply_env_presets_no_overrides_is_noop(clean_env):
    assert runtime.apply_env_presets() == {}
    assert "XLA_FLAGS" not in os.environ


def test_runtime_module_does_not_import_jax():
    """The whole point of the module: importing it must not pull jax in
    (checked via a fresh interpreter, since this process has jax)."""
    import subprocess
    import sys

    code = ("import sys; from repro import runtime; "
            "sys.exit(1 if 'jax' in sys.modules else 0)")
    proc = subprocess.run([sys.executable, "-c", code],
                          env={**os.environ,
                               "PYTHONPATH": os.pathsep.join(sys.path)},
                          capture_output=True)
    assert proc.returncode == 0, proc.stderr.decode()
