"""Golden regression tests for core/theory.py and the per-algorithm
admissibility constants.

The Theorem-1 / Table-1 formulas are transcriptions of the paper's
constants; nothing else in the suite pins their VALUES, so a silently
dropped factor would pass every behavioural test.  Each golden below is
hand-derived from the printed formula (derivation in the comment) and
locked tightly — drift of any coefficient fails here first.
"""
import numpy as np
import pytest

from repro.core import theory
from repro.core.clustering import get_algorithm

LOG2 = np.log(2.0)

# ProblemConstants used throughout: L=2, mu_F=1, R=1, d=2, G_F=3,
# N=1, F_star=1/2, beta=2 — chosen so every term of M is non-zero.
C = theory.ProblemConstants(L=2.0, mu_F=1.0, R=1.0, d=2, G_F=3.0,
                            N=1.0, F_star=0.5, beta=2.0)


def test_constant_M_golden():
    # t1 = 16*2*(1/2)*(log2+2)/1^2            = 16 (log2 + 2)
    # t2 = 64*1*2*(log2 + 2 log6 + 3*2)/1     = 128 (log2 + 2 log6 + 6)
    # t3 = 16*1*1*(log2+2)/1                  = 16 (log2 + 2)
    # t4 = 2*3 + 16*1*2*(1 + log2 + 2 log6 + 6)
    by_hand = (16 * (LOG2 + 2)
               + 128 * (LOG2 + 2 * np.log(6) + 6)
               + 16 * (LOG2 + 2)
               + 6 + 32 * (7 + LOG2 + 2 * np.log(6)))
    assert theory.constant_M(C) == pytest.approx(by_hand, rel=1e-12)
    assert theory.constant_M(C) == pytest.approx(1768.4472888204873,
                                                 rel=1e-10)


def test_constant_M_seed_constants_golden():
    # the constants the pre-existing monotonicity test uses — locked
    c2 = theory.ProblemConstants(L=1.0, mu_F=0.5, R=10.0, d=5, G_F=1.0)
    assert theory.constant_M(c2) == pytest.approx(436308.9013884954,
                                                  rel=1e-10)


def test_sample_threshold_golden():
    # rhs = 4 * 10 * 4^2 / (2 - 2*0.5)^2 = 640; n/log n = 640 at n ~ 5513.6
    n = theory.sample_threshold(M=10.0, alpha=4.0, D=2.0, gamma=0.5)
    assert n == pytest.approx(5513.580484337553, rel=1e-9)
    assert n / np.log(n) == pytest.approx(640.0, rel=1e-6)


def test_threshold_odcl_cc_golden():
    # alpha = 4 (100-5)/5 = 76; rhs = 4 M alpha^2 / (D-2g)^2 = 4*76^2/9
    n = theory.threshold_odcl_cc(M=1.0, m=100, c_min=5, D=4.0, gamma=0.5)
    assert n == pytest.approx(26107.459284824385, rel=1e-9)
    assert n / np.log(n) == pytest.approx(4 * 76.0 ** 2 / 9.0, rel=1e-6)


def test_threshold_odcl_km_golden():
    # alpha = 2 + 2 sqrt(100)/5 = 6; rhs = 4*36/9 = 16
    n = theory.threshold_odcl_km(M=1.0, m=100, c_min=5, D=4.0, gamma=0.5)
    assert n == pytest.approx(67.36107796577377, rel=1e-9)
    assert n / np.log(n) == pytest.approx(16.0, rel=1e-6)


def test_ifca_comm_rounds_golden():
    # 8 * 10 / 0.1 * log(2*1/0.01) = 800 log(200)
    t = theory.ifca_comm_rounds(kappa=10, p=0.1, D=1.0, eps=0.01)
    assert t == pytest.approx(800.0 * np.log(200.0), rel=1e-12)
    assert t == pytest.approx(4238.653893238429, rel=1e-10)
    assert theory.communication_saving(10, 0.1, 1.0, 0.01) == pytest.approx(t)


def test_all_for_all_comm_rounds_golden():
    # x = 100*50/5 = 1000 -> 1000 log 1000
    t = theory.all_for_all_comm_rounds(100, 50, 5)
    assert t == pytest.approx(1000.0 * np.log(1000.0), rel=1e-12)
    assert t == pytest.approx(6907.755278982137, rel=1e-10)


def test_mse_bound_theorem1_golden():
    # t1 = 2 E_k/(n c_k) = 2*2/5000 = 8e-4
    # t2 = 8*4*3*R^2/(500*5*0.5^2) = 96/625 = 0.1536
    # t3 = 8*40*R^2/500^2 = 0.00128
    b = theory.mse_bound_theorem1(C, n=500, K=4, c_k=10, c_min=5,
                                  E_k=2.0, E_tilde=3.0, gamma=0.5, m=40)
    assert b == pytest.approx(8e-4 + 0.1536 + 0.00128, rel=1e-12)
    assert b == pytest.approx(0.15568, rel=1e-10)


def test_merge_condition_golden():
    assert theory.merge_condition(100, 100) == pytest.approx(0.005, rel=1e-12)
    assert theory.merge_condition(50, 200) == pytest.approx(0.001, rel=1e-12)


# ------------------------------------------ Lemma-1/2 admissibility alphas

KMEANS_FAMILY = ("kmeans", "kmeans++", "spectral", "kmeans-device",
                 "gradient")
CONVEX_FAMILY = ("convex", "clusterpath", "convex-device",
                 "clusterpath-device")


@pytest.mark.parametrize("name", KMEANS_FAMILY)
def test_lemma2_alpha_kmeans_family(name):
    # Lemma 2: alpha = 2 + 2 c sqrt(m) / |C_(K)|, c = 1
    algo = get_algorithm(name)
    assert algo.admissibility_alpha(100, 5) == pytest.approx(6.0, rel=1e-12)
    assert algo.admissibility_alpha(64, 4) == pytest.approx(6.0, rel=1e-12)
    assert algo.admissibility_alpha(400, 10) == pytest.approx(6.0, rel=1e-12)
    assert algo.admissibility_alpha(900, 10) == pytest.approx(8.0, rel=1e-12)


@pytest.mark.parametrize("name", CONVEX_FAMILY)
def test_lemma1_alpha_convex_family(name):
    # Lemma 1: alpha = 4 (m - |C_(K)|) / |C_(K)|
    algo = get_algorithm(name)
    assert algo.admissibility_alpha(100, 5) == pytest.approx(76.0, rel=1e-12)
    assert algo.admissibility_alpha(10, 5) == pytest.approx(4.0, rel=1e-12)
    assert algo.admissibility_alpha(6, 2) == pytest.approx(8.0, rel=1e-12)
