"""Per-architecture smoke tests (assignment requirement): reduced
same-family variant (2 layers, d_model<=512, <=4 experts), one forward +
one train step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import make_train_step
from repro.models import forward, init_params, train_loss
from repro.optim import adamw_init


def make_batch(cfg, b=2, s=32, seed=1):
    key = jax.random.PRNGKey(seed)
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}
    if cfg.input_mode == "embeddings":
        return {
            "frames": jax.random.normal(key, (b, s, 512), jnp.float32) * 0.1,
            "mask": jnp.arange(s)[None].repeat(b, 0) % 5 == 0,
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "patch_embeds": jax.random.normal(key, (b, 4, 1024), jnp.float32) * 0.1,
        "patch_positions": jnp.arange(4)[None].repeat(b, 0),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_invariants(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    assert cfg.n_heads % cfg.n_kv_heads == 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, aux = forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


# the two heaviest train-step cases (>7s compiles) ride the slow lane;
# the rest of the arch sweep stays in the fast gate
@pytest.mark.parametrize("arch", [
    pytest.param(a, marks=pytest.mark.slow)
    if a in ("grok_1_314b", "hymba_1_5b") else a for a in ARCH_IDS])
def test_train_step_no_nan(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params)
    batch = make_batch(cfg)
    step = jax.jit(make_train_step(cfg, remat="none"))
    loss, new_params, new_state = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss))
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "xlstm_125m",
                                  pytest.param("hymba_1_5b",
                                               marks=pytest.mark.slow),
                                  "deepseek_moe_16b"])
def test_loss_decreases_under_training(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params)
    batch = make_batch(cfg)
    step = jax.jit(make_train_step(cfg, remat="none"))
    losses = []
    for _ in range(8):
        loss, params, opt_state = step(params, opt_state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_remat_matches_no_remat():
    cfg = get_config("qwen2_0_5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    l1 = train_loss(params, cfg, batch, remat="none")
    l2 = train_loss(params, cfg, batch, remat="full")
    g1 = jax.grad(lambda p: train_loss(p, cfg, batch, remat="none"))(params)
    g2 = jax.grad(lambda p: train_loss(p, cfg, batch, remat="full"))(params)
    assert float(jnp.abs(l1 - l2)) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_unroll_matches_scan():
    cfg = get_config("gemma_2b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    l1 = train_loss(params, cfg, batch, unroll=False)
    l2 = train_loss(params, cfg, batch, unroll=True)
    assert float(jnp.abs(l1 - l2)) < 1e-5


@pytest.mark.slow
def test_chunked_attention_matches_direct():
    import dataclasses

    from repro.models.attention import attention

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 4, 4096, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 2, 4096, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 2, 4096, 16)).astype(np.float32))
    direct = attention(q, k, v, causal=True, chunk=0)
    chunked = attention(q, k, v, causal=True, chunk=1024)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                               rtol=1e-4, atol=1e-4)
    windowed_d = attention(q, k, v, causal=True, window=100, chunk=0)
    windowed_c = attention(q, k, v, causal=True, window=100, chunk=1024)
    np.testing.assert_allclose(np.asarray(windowed_c), np.asarray(windowed_d),
                               rtol=1e-4, atol=1e-4)


def test_moe_aux_loss_positive_and_bounded():
    cfg = get_config("deepseek_moe_16b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    _, aux = forward(params, cfg, batch)
    assert 0.0 <= float(aux) < 1.0
