"""The streaming AggregationSession (core/engine/session.py).

Pins down the server-API redesign's contracts: wave-partition
invariance (finalize is bit-exact with the fused
``one_shot_aggregate(engine="device")`` round no matter how the same
clients were chunked into ingest waves), sketch-routed serving
(``route`` sends every ingested client to its own recovered cluster and
``cluster_model`` hands back that cluster's averaged model), the
sketch-only ingest mode, and the buffer/mode guard rails.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import AggregationSession
from repro.core.federated import FederatedState, one_shot_aggregate
from repro.optim import adamw_init

from conftest import same_partition


def make_blobs(seed, sizes, d, sep=25.0, noise=0.25):
    rng = np.random.default_rng(seed)
    k = len(sizes)
    centers = rng.normal(size=(k, d))
    if k > 1:
        dists = np.linalg.norm(centers[:, None] - centers[None], axis=-1)
        np.fill_diagonal(dists, np.inf)
        centers *= sep / dists.min()
    pts = np.concatenate([
        c + noise * rng.normal(size=(n, d)) for c, n in zip(centers, sizes)])
    labels = np.repeat(np.arange(k), sizes)
    return pts.astype(np.float32), labels


def blob_state(pts):
    params = {"theta": jnp.asarray(pts)}
    return FederatedState(params=params,
                          opt_state=jax.vmap(adamw_init)(params),
                          n_clients=len(pts))


def ingest_in_waves(session, pts, pattern):
    """Chunk the client stack into waves by cycling ``pattern``."""
    off, i = 0, 0
    while off < len(pts):
        w = min(pattern[i % len(pattern)], len(pts) - off)
        session.ingest({"theta": jnp.asarray(pts[off:off + w])})
        off += w
        i += 1
    return session


# ------------------------------------- streaming ≡ fused one-shot round

def test_session_finalize_bit_exact_with_fused_round():
    pts, true = make_blobs(0, [9, 7, 11], 8)
    ref_state, ref_labels, ref_info = one_shot_aggregate(
        blob_state(pts), None, algorithm="kmeans-device", k=3,
        sketch_dim=32, seed=3, engine="device")

    sess = AggregationSession(len(pts), sketch_dim=32, seed=3)
    ingest_in_waves(sess, pts, [5, 9, 2])
    new_state, labels, info = sess.finalize(algorithm="kmeans-device", k=3)

    np.testing.assert_array_equal(labels, ref_labels)
    np.testing.assert_array_equal(np.asarray(new_state.params["theta"]),
                                  np.asarray(ref_state.params["theta"]))
    assert info["n_clusters"] == ref_info["n_clusters"]
    assert info["engine"] == "device"
    assert same_partition(labels, true)


def test_session_finalize_convex_family_with_knn_edges():
    pts, true = make_blobs(1, [10, 8, 9], 6, sep=30.0, noise=0.1)
    sess = AggregationSession(len(pts), sketch_dim=24, seed=1)
    ingest_in_waves(sess, pts, [6])
    _, labels, info = sess.finalize(
        algorithm="clusterpath-device",
        algo_options={"edges": "knn", "knn_k": 5, "iters": 300})
    assert info["n_clusters"] == 3
    assert same_partition(labels, true)


def test_session_resolves_lloyd_host_names():
    pts, true = make_blobs(2, [8, 8], 5)
    sess = AggregationSession(len(pts), sketch_dim=16, seed=0)
    sess.ingest({"theta": jnp.asarray(pts)})
    _, labels, info = sess.finalize(algorithm="kmeans++", k=2,
                                    engine="device")
    assert info["engine"] == "device"
    assert same_partition(labels, true)


def test_session_host_finalize():
    pts, true = make_blobs(3, [7, 9], 5)
    sess = AggregationSession(len(pts), sketch_dim=16, seed=0)
    sess.ingest({"theta": jnp.asarray(pts)})
    new_state, labels, info = sess.finalize(algorithm="kmeans++", k=2,
                                            engine="host")
    assert info["engine"] == "host"
    assert same_partition(labels, true)
    theta = np.asarray(new_state.params["theta"])
    for c in np.unique(labels):
        members = np.where(labels == c)[0]
        np.testing.assert_allclose(
            theta[members],
            np.broadcast_to(pts[members].mean(0), theta[members].shape),
            rtol=1e-5, atol=1e-5)


# --------------------------------------------------- sketch-routed serving

def test_route_self_consistency_and_cluster_model():
    pts, _ = make_blobs(4, [8, 6, 7], 8)
    sess = AggregationSession(len(pts), sketch_dim=32, seed=5)
    ingest_in_waves(sess, pts, [4, 7])
    new_state, labels, _ = sess.finalize(algorithm="kmeans-device", k=3)
    # every ingested client routes to its own recovered cluster
    routed = sess.route(sess.sketches)
    np.testing.assert_array_equal(routed, labels)
    # single-sketch route returns a plain int
    cid = sess.route(sess.sketches[0])
    assert cid == int(labels[0])
    # routing raw parameters sketches them with the session's projection
    cid_p = sess.route(params={"theta": jnp.asarray(pts[0])})
    assert cid_p == int(labels[0])
    # the served cluster model is the routed cluster's averaged model
    model = sess.cluster_model(cid)
    np.testing.assert_array_equal(np.asarray(model["theta"]),
                                  np.asarray(new_state.params["theta"][0]))


def test_route_unseen_client_goes_to_nearest_cluster():
    pts, true = make_blobs(5, [10, 10], 6, sep=30.0, noise=0.2)
    # hold out the last client of each cluster
    seen = np.ones(len(pts), bool)
    seen[[9, 19]] = False
    sess = AggregationSession(int(seen.sum()), sketch_dim=24, seed=7)
    sess.ingest({"theta": jnp.asarray(pts[seen])})
    _, labels, _ = sess.finalize(algorithm="kmeans-device", k=2)
    for held in (9, 19):
        cid = sess.route(params={"theta": jnp.asarray(pts[held])})
        neighbours = labels[true[seen] == true[held]]
        assert cid == neighbours[0]          # routed with its own blob


# ------------------------------------------------ modes and guard rails

def test_sketch_only_session_clusters_and_routes_but_has_no_models():
    pts, true = make_blobs(6, [8, 9], 5)
    full = AggregationSession(len(pts), sketch_dim=16, seed=0)
    full.ingest({"theta": jnp.asarray(pts)})
    sk = np.asarray(full.sketches)

    sess = AggregationSession(len(pts), sketch_dim=16, seed=0)
    sess.ingest(sketches=sk[:5])
    sess.ingest(sketches=sk[5:])
    state, labels, info = sess.finalize(algorithm="kmeans-device", k=2)
    assert state is None
    assert same_partition(labels, true)
    np.testing.assert_array_equal(sess.route(sess.sketches), labels)
    with pytest.raises(ValueError, match="sketch-only"):
        sess.cluster_model(0)
    with pytest.raises(ValueError, match="parameter waves"):
        sess.state()


def test_session_guard_rails():
    sess = AggregationSession(8, sketch_dim=16)
    with pytest.raises(ValueError, match="nothing ingested"):
        sess.finalize()
    with pytest.raises(ValueError, match="finalize"):
        sess.route(np.zeros(16, np.float32))
    with pytest.raises(ValueError, match="exactly one"):
        sess.ingest()
    sess.ingest({"theta": jnp.zeros((3, 4))})
    with pytest.raises(ValueError, match="cannot mix"):
        sess.ingest(sketches=np.zeros((2, 16), np.float32))
    with pytest.raises(ValueError, match="capacity exceeded"):
        sess.ingest({"theta": jnp.zeros((6, 4))})
    with pytest.raises(ValueError, match=r"\(w, 16\)"):
        AggregationSession(8, sketch_dim=16).ingest(
            sketches=np.zeros((2, 8), np.float32))
    assert sess.count == 3
    assert sess.sketches.shape == (3, 16)


def test_empty_batch_guards():
    """Zero-row waves and probes fail loudly instead of tracing a
    zero-size program (or silently serving nothing)."""
    pts, _ = make_blobs(2, [6, 6], 5)
    sess = AggregationSession(len(pts), sketch_dim=16, seed=0)
    sess.ingest({"theta": jnp.asarray(pts)})
    sess.finalize(algorithm="kmeans-device", k=2)
    with pytest.raises(ValueError, match="at least one probe"):
        sess.route(np.zeros((0, 16), np.float32))
    with pytest.raises(ValueError, match="at least one client row"):
        sess.sketch_params({"theta": jnp.zeros((0, 5))})
    with pytest.raises(ValueError, match="empty parameter wave"):
        sess.sketch_params({})


def test_snapshot_compute_install_composes_to_finalize():
    """The split server API (snapshot -> compute_round -> install_round)
    is exactly finalize() taken apart: same round bit-for-bit, and the
    snapshot is immune to ingests that land between compute and
    install."""
    pts, _ = make_blobs(9, [10, 8, 9], 6)
    sess = AggregationSession(32, sketch_dim=16, seed=0)
    sess.ingest({"theta": jnp.asarray(pts[:20])})

    ref = AggregationSession(32, sketch_dim=16, seed=0)
    ref.ingest({"theta": jnp.asarray(pts[:20])})
    ref_out = ref.finalize(algorithm="kmeans-device", k=3)

    snap = sess.snapshot()
    assert snap.count == 20 and snap.clock == sess.clock
    out, served = sess.compute_round(snap, algorithm="kmeans-device", k=3)
    # the live buffer moves on BEFORE install: the round stays the
    # snapshot's, and the session knows it is stale (clock mismatch)
    sess.ingest({"theta": jnp.asarray(pts[20:])})
    sess.install_round(out, served)
    np.testing.assert_array_equal(np.asarray(out[1]),
                                  np.asarray(ref_out[1]))
    np.testing.assert_array_equal(np.asarray(sess.served_round.centers),
                                  np.asarray(ref.served_round.centers))
    assert out[2]["snapshot_clock"] == served.clock < sess.clock
    assert sess.served_round.count == 20
    # finalize_config was captured by compute_round: refinalize covers
    # the grown buffer with the same algorithm/k
    _, labels, info = sess.refinalize()
    assert labels.shape == (len(pts),)
    assert info["snapshot_clock"] == sess.clock


def test_snapshot_requires_data_and_clock_ticks_per_wave():
    sess = AggregationSession(8, sketch_dim=16)
    with pytest.raises(ValueError, match="nothing ingested"):
        sess.snapshot()
    assert sess.clock == 0
    sess.ingest(sketches=np.zeros((2, 16), np.float32))
    sess.ingest(sketches=np.ones((3, 16), np.float32))
    assert sess.clock == 2
    snap = sess.snapshot()
    assert snap.count == 5 and snap.clock == 2
    assert snap.params is None                  # sketch-only session
    np.testing.assert_array_equal(np.asarray(snap.sketches)[:2], 0.0)


def test_rejected_wave_does_not_lock_ingest_mode():
    """A wave that fails validation must leave the session untouched —
    in particular an invalid sketch wave on a fresh session must not
    lock out parameter ingestion (and vice versa)."""
    sess = AggregationSession(8, sketch_dim=16)
    with pytest.raises(ValueError, match=r"\(w, 16\)"):
        sess.ingest(sketches=np.zeros((2, 4), np.float32))
    sess.ingest({"theta": jnp.zeros((2, 4))})      # still allowed
    assert sess.count == 2

    sess2 = AggregationSession(8, sketch_dim=16)
    with pytest.raises(ValueError, match="empty parameter wave"):
        sess2.ingest({})
    sess2.ingest(sketches=np.zeros((2, 16), np.float32))   # still allowed
    assert sess2.count == 2


def test_ingest_after_finalize_serves_stale_round():
    """A mutable server keeps serving the last finalized round while the
    buffer moves on (stale-serving); the next finalize covers the full
    buffer.  Routing before ANY finalize still raises."""
    pts, _ = make_blobs(7, [6, 6], 5)
    sess = AggregationSession(len(pts), sketch_dim=16, seed=0)
    sess.ingest({"theta": jnp.asarray(pts[:8])})
    with pytest.raises(ValueError, match="finalize"):
        sess.route(np.zeros(16, np.float32))
    sess.finalize(algorithm="kmeans-device", k=2)
    k_before = sess.n_clusters
    sess.ingest({"theta": jnp.asarray(pts[8:])})
    cid = sess.route(params={"theta": jnp.asarray(pts[0])})
    assert 0 <= cid < k_before                  # stale round still serves
    _, labels, _ = sess.finalize(algorithm="kmeans-device", k=2)
    assert labels.shape == (len(pts),)


def test_session_state_round_trips_into_one_shot():
    """session.state() is the exact stacked federation — feeding it to
    the fused round matches finalize (the simulate.py iterative path)."""
    pts, _ = make_blobs(8, [7, 9], 6)
    sess = AggregationSession(len(pts), sketch_dim=16, seed=2)
    ingest_in_waves(sess, pts, [3, 5])
    st = sess.state()
    assert st.n_clients == len(pts)
    np.testing.assert_array_equal(np.asarray(st.params["theta"]), pts)
    ref_state, ref_labels, _ = one_shot_aggregate(
        st, None, algorithm="kmeans-device", k=2, sketch_dim=16, seed=2,
        engine="device")
    new_state, labels, _ = sess.finalize(algorithm="kmeans-device", k=2)
    np.testing.assert_array_equal(labels, ref_labels)
    np.testing.assert_array_equal(np.asarray(new_state.params["theta"]),
                                  np.asarray(ref_state.params["theta"]))


# ------------------------------------------- hypothesis wave partitions

try:
    import hypothesis  # noqa: F401
    _HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - env-dependent
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 10_000),
           sizes=st.lists(st.integers(2, 7), min_size=2, max_size=4),
           d=st.integers(2, 8),
           sketch_dim=st.sampled_from([8, 16, 24]),
           pattern=st.lists(st.integers(1, 7), min_size=1, max_size=5))
    def test_any_wave_partition_is_bit_exact_with_fused_round(
            seed, sizes, d, sketch_dim, pattern):
        """The acceptance property: ANY wave partition of the same
        clients makes finalize() bit-exact with the fused device round —
        same labels, same averaged parameters, bit for bit."""
        pts, _ = make_blobs(seed, sizes, d)
        k = len(sizes)
        ref_state, ref_labels, ref_info = one_shot_aggregate(
            blob_state(pts), None, algorithm="kmeans-device", k=k,
            sketch_dim=sketch_dim, seed=seed % 97, engine="device")

        sess = AggregationSession(len(pts), sketch_dim=sketch_dim,
                                  seed=seed % 97)
        ingest_in_waves(sess, pts, pattern)
        assert sess.count == len(pts)
        new_state, labels, info = sess.finalize(algorithm="kmeans-device",
                                                k=k)
        np.testing.assert_array_equal(labels, ref_labels)
        assert info["n_clusters"] == ref_info["n_clusters"]
        np.testing.assert_array_equal(
            np.asarray(new_state.params["theta"]),
            np.asarray(ref_state.params["theta"]))
        # route() self-consistency rides along on every drawn federation
        np.testing.assert_array_equal(sess.route(sess.sketches), labels)


# ------------------------------------------------------------ obs / drift

def test_session_drift_gauge_and_route_histogram():
    """The drift gauge anchors at finalize and tracks routed traffic:
    routing the session's own members gives drift ~= 1; routing points
    far from every center inflates it.  Route latencies land in the
    ``session.route.ms`` histogram."""
    from repro import obs

    obs.reset()
    pts, _ = make_blobs(3, (12, 12, 12), 8)
    sess = AggregationSession(len(pts), sketch_dim=16, seed=0)
    sess.ingest({"theta": jnp.asarray(pts)})
    assert sess.drift is None                  # nothing finalized yet
    sess.finalize(algorithm="kmeans-device", k=3)
    assert sess.drift is None                  # nothing routed yet

    sess.route(sess.sketches)                  # members of the clustering
    assert sess.drift == pytest.approx(1.0, rel=1e-4)

    far = jnp.asarray(np.full((4, 16), 1e3, np.float32))
    sess.route(far)
    assert sess.drift > 1.0

    snap = obs.snapshot()
    h = snap["histograms"]["session.route.ms"]
    assert h["count"] == 2
    assert snap["gauges"]["session.drift"] == pytest.approx(sess.drift)
    assert snap["histograms"]["session.finalize.ms"]["count"] == 1

    # a re-finalize re-anchors: the routed accumulator starts over
    sess.finalize(algorithm="kmeans-device", k=3)
    assert sess.drift is None
