"""The telemetry spine (``repro.obs``): spans, histograms, sinks.

Pins the contracts the instrumented engine relies on: span nesting and
timing land in the right places, histogram percentiles match numpy's
default convention exactly, the JSONL sink round-trips events, and
counter/histogram merges are order-independent (so per-worker
registries can be folded together in any order).
"""
from __future__ import annotations

import math
import time

import numpy as np
import pytest

from repro import obs
from repro.obs.core import Histogram, Registry


# ------------------------------------------------------------------ spans

def test_span_records_duration_and_histogram():
    reg = Registry()
    with reg.span("work") as info:
        time.sleep(0.01)
    assert info["ms"] >= 10.0 * 0.5          # coarse clocks: half slack
    h = reg.histograms["work.ms"]
    assert h.count == 1
    assert h.values[0] == info["ms"]


def test_span_nesting_parent_depth_and_monotone_timing():
    reg = Registry()
    events = reg.add_sink(obs.ListSink())
    with reg.span("outer") as outer:
        with reg.span("inner") as inner:
            time.sleep(0.005)
    spans = {e["name"]: e for e in events.events if e["event"] == "span"}
    assert spans["inner"]["parent"] == "outer"
    assert spans["inner"]["depth"] == 1
    assert "parent" not in spans["outer"]
    assert spans["outer"]["depth"] == 0
    # an enclosing span can never be shorter than what it encloses
    assert outer["ms"] >= inner["ms"]


def test_span_survives_exceptions_and_pops_stack():
    reg = Registry()
    with pytest.raises(RuntimeError):
        with reg.span("boom"):
            raise RuntimeError("x")
    assert reg.histograms["boom.ms"].count == 1
    with reg.span("after") as info:
        pass
    assert "parent" not in info              # stack was popped on the error


# -------------------------------------------------------------- histograms

@pytest.mark.parametrize("n", [1, 2, 5, 17, 100])
@pytest.mark.parametrize("p", [0.0, 50.0, 95.0, 99.0, 100.0])
def test_histogram_percentiles_match_numpy(n, p):
    rng = np.random.default_rng(n)
    vals = rng.normal(size=n) * 10.0
    h = Histogram(vals.tolist())
    assert h.percentile(p) == pytest.approx(float(np.percentile(vals, p)),
                                            rel=1e-12, abs=1e-12)


def test_histogram_summary_fields():
    h = Histogram([3.0, 1.0, 2.0])
    s = h.summary()
    assert s["count"] == 3 and s["min"] == 1.0 and s["max"] == 3.0
    assert s["mean"] == pytest.approx(2.0) and s["p50"] == 2.0
    assert Histogram().summary() == {"count": 0}
    assert math.isnan(Histogram().percentile(50.0))


# ------------------------------------------------------------------- sinks

def test_jsonl_sink_round_trip(tmp_path):
    path = tmp_path / "trace.jsonl"
    reg = Registry()
    sink = reg.add_sink(obs.JsonlSink(str(path)))
    reg.event("fed.round", method="odcl", round=0, bytes=128.0)
    with reg.span("phase", wave=4):
        pass
    reg.close_sinks()
    events = obs.read_jsonl(str(path))
    assert [e["event"] for e in events] == ["fed.round", "span"]
    assert events[0]["method"] == "odcl" and events[0]["bytes"] == 128.0
    assert events[1]["name"] == "phase" and events[1]["wave"] == 4
    assert events[1]["ms"] >= 0.0


def test_snapshot_shape_and_reset_keeps_sinks():
    reg = Registry()
    sink = reg.add_sink(obs.ListSink())
    reg.count("c", 2.0)
    reg.count("c", 3.0)
    reg.gauge("g", 7.0)
    reg.observe("h", 1.5)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 5.0
    assert snap["gauges"]["g"] == 7.0
    assert snap["histograms"]["h"]["count"] == 1
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}
    reg.event("still-here")
    assert sink.events[-1]["event"] == "still-here"


# ------------------------------------------------------------------- merge

def _apply(reg: Registry, op):
    kind, name, value = op
    if kind == "count":
        reg.count(name, value)
    else:
        reg.observe(name, value)


def test_counter_merge_order_independent_smoke():
    ops = [("count", "a", 1.0), ("count", "b", 2.5), ("obs", "h", 3.0),
           ("count", "a", -4.0), ("obs", "h", 1.0)]
    r1, r2 = Registry(), Registry()
    for op in ops:
        _apply(r1, op)
    for op in reversed(ops):
        _apply(r2, op)
    s1, s2 = r1.snapshot(), r2.snapshot()
    assert s1["counters"] == s2["counters"]
    assert s1["histograms"] == s2["histograms"]


def test_merge_order_independent_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    op = st.tuples(st.sampled_from(["count", "obs"]),
                   st.sampled_from(["a", "b", "c"]),
                   st.floats(-100, 100, allow_nan=False))

    @settings(max_examples=50, deadline=None)
    @given(st.lists(op, max_size=30), st.lists(op, max_size=30))
    def check(ops1, ops2):
        def build(ops):
            r = Registry()
            for o in ops:
                _apply(r, o)
            return r

        ab, ba = Registry(), Registry()
        ab.merge(build(ops1))
        ab.merge(build(ops2))
        ba.merge(build(ops2))
        ba.merge(build(ops1))
        sa, sb = ab.snapshot(), ba.snapshot()
        assert set(sa["counters"]) == set(sb["counters"])
        for k in sa["counters"]:
            assert sa["counters"][k] == pytest.approx(sb["counters"][k],
                                                      abs=1e-9)
        # histogram value multisets are identical -> equal summaries
        for k in set(sa["histograms"]) | set(sb["histograms"]):
            ha, hb = sa["histograms"][k], sb["histograms"][k]
            assert ha["count"] == hb["count"]
            for f in ("min", "max", "p50", "p95", "p99"):
                assert ha[f] == pytest.approx(hb[f], abs=1e-9)

    check()
