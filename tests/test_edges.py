"""The pluggable fusion-graph registry (core/engine/edges.py) and the
edge-set-generic device convex solver: complete-graph parity with the
PR-4 behaviour, the tiled-top-k mutual-kNN builder against a dense
NumPy oracle, degree-normalized weights, and cluster recovery through
the sparse graph at fixed lambda and along the clusterpath ladder.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.clustering import lambda_interval
from repro.core.engine.edges import COMPLETE_EDGES_MAX_M
from repro.core.engine import (
    ApproxKnnEdges,
    CompleteEdges,
    Edges,
    KnnEdges,
    device_clusterpath,
    device_convex_cluster,
    get_edge_set,
    list_edge_sets,
    register_edge_set,
    unregister_edge_set,
)

from conftest import same_partition


def make_blobs(seed, k=3, per=10, d=6, sep=30.0, noise=0.1):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d))
    dists = np.linalg.norm(centers[:, None] - centers[None], axis=-1)
    np.fill_diagonal(dists, np.inf)
    centers *= sep / dists.min()
    pts = np.concatenate(
        [c + noise * rng.normal(size=(per, d)) for c in centers])
    return pts.astype(np.float32), np.repeat(np.arange(k), per)


def active_pairs(e: Edges):
    i = np.asarray(e.i_idx)
    j = np.asarray(e.j_idx)
    w = np.asarray(e.weights)
    return {(int(a), int(b)) for a, b, ww in zip(i, j, w) if ww > 0}


# ------------------------------------------------------------- registry

def test_registry_prepopulated_and_round_trip():
    assert {"complete", "knn"} <= set(list_edge_sets())
    assert isinstance(get_edge_set("complete"), CompleteEdges)
    assert isinstance(get_edge_set("knn"), KnnEdges)
    with pytest.raises(KeyError, match="complete"):
        get_edge_set("not-a-graph")

    @dataclasses.dataclass(frozen=True)
    class Probe:
        name: str = "probe-edges"

        def __call__(self, points, **options):
            return CompleteEdges()(points)

    try:
        register_edge_set(Probe())
        assert "probe-edges" in list_edge_sets()
        with pytest.raises(ValueError, match="already registered"):
            register_edge_set(Probe())
    finally:
        unregister_edge_set("probe-edges")
    assert "probe-edges" not in list_edge_sets()


# --------------------------------------------------------- the builders

def test_complete_edges_match_triu():
    pts = jnp.asarray(np.random.default_rng(0).normal(size=(7, 3)),
                      jnp.float32)
    e = get_edge_set("complete")(pts)
    iu, ju = np.triu_indices(7, k=1)
    np.testing.assert_array_equal(np.asarray(e.i_idx), iu)
    np.testing.assert_array_equal(np.asarray(e.j_idx), ju)
    np.testing.assert_array_equal(np.asarray(e.weights), np.ones(len(iu)))
    assert float(e.inv_eta) == 7.0


def test_knn_edges_match_dense_oracle():
    """Active slots must be exactly the union kNN graph the host
    ``knn_weights`` builds (j in kNN(i) or i in kNN(j)), each unordered
    pair once."""
    rng = np.random.default_rng(3)
    pts = rng.normal(size=(23, 5)).astype(np.float32)
    k = 4
    e = jax.jit(lambda p: get_edge_set("knn")(p, knn_k=k))(jnp.asarray(pts))

    d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    knn_idx = np.argsort(d2, axis=1)[:, :k]
    mask = np.zeros((23, 23), bool)
    rows = np.repeat(np.arange(23), k)
    mask[rows, knn_idx.ravel()] = True
    mask |= mask.T
    iu, ju = np.triu_indices(23, k=1)
    expected = {(int(a), int(b)) for a, b in zip(iu, ju) if mask[a, b]}

    got = active_pairs(e)
    assert got == expected
    # every slot is canonicalized i < j and slot count is m*k
    assert np.all(np.asarray(e.i_idx) < np.asarray(e.j_idx))
    assert e.n_edges == 23 * k
    # min_dist is the exact nearest-neighbour distance
    np.testing.assert_allclose(float(e.min_dist),
                               float(np.sqrt(d2.min())), rtol=1e-5)


def test_knn_weights_are_degree_normalized():
    rng = np.random.default_rng(4)
    pts = rng.normal(size=(17, 4)).astype(np.float32)
    e = jax.jit(lambda p: get_edge_set("knn")(p, knn_k=3))(jnp.asarray(pts))
    w = np.asarray(e.weights)
    active = w[w > 0]
    n_active = len(active)
    # uniform normalized value: (m-1) / avg_degree, avg_degree = 2E/m
    expected = (17 - 1) / (2.0 * n_active / 17)
    np.testing.assert_allclose(active, expected, rtol=1e-5)
    # inv_eta = 2 * max unweighted degree
    deg = np.zeros(17)
    for a, b in active_pairs(e):
        deg[a] += 1
        deg[b] += 1
    np.testing.assert_allclose(float(e.inv_eta), 2.0 * deg.max(), rtol=1e-6)


def test_knn_k_clamps_to_m_minus_one():
    pts = jnp.asarray(np.random.default_rng(5).normal(size=(5, 3)),
                      jnp.float32)
    e = jax.jit(lambda p: get_edge_set("knn")(p, knn_k=64))(pts)
    # k clamps to m-1: the graph is complete, every pair active once
    assert active_pairs(e) == {(int(a), int(b))
                               for a, b in zip(*np.triu_indices(5, k=1))}


# -------------------------------------------- solver through the edges

def test_complete_edges_keep_pr4_solution_bit_exact():
    """edges='complete' (the default) must reproduce the pre-EdgeSet
    solver exactly — same labels, same fused representatives."""
    pts, true = make_blobs(0)
    lo, hi = lambda_interval(pts, true)
    lam = 0.5 * (lo + hi)
    res = device_convex_cluster(jax.random.PRNGKey(0), jnp.asarray(pts),
                                lam=lam, iters=400)
    res2 = device_convex_cluster(jax.random.PRNGKey(0), jnp.asarray(pts),
                                 lam=lam, iters=400, edges="complete")
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(res2.labels))
    np.testing.assert_array_equal(np.asarray(res.u), np.asarray(res2.u))
    assert int(res.n_clusters) == 3


@pytest.mark.parametrize("seed,k", [(0, 3), (1, 2), (2, 4)])
def test_knn_edges_recover_planted_clusters_at_interval_lambda(seed, k):
    """Degree-normalized weights keep the complete-graph recovery
    interval's lambda meaningful on the sparse graph."""
    pts, true = make_blobs(seed, k=k)
    lo, hi = lambda_interval(pts, true)
    lam = 0.5 * (lo + hi)
    res = device_convex_cluster(jax.random.PRNGKey(0), jnp.asarray(pts),
                                lam=lam, iters=400, edges="knn", knn_k=5)
    assert int(res.n_clusters) == k
    assert same_partition(np.asarray(res.labels), true)


@pytest.mark.parametrize("seed,k", [(0, 3), (2, 4)])
def test_knn_clusterpath_recovers_planted_k(seed, k):
    pts, true = make_blobs(seed, k=k)
    res = device_clusterpath(jax.random.PRNGKey(0), jnp.asarray(pts),
                             n_lambdas=10, iters=300, edges="knn", knn_k=5)
    assert int(res.n_clusters) == k
    assert same_partition(np.asarray(res.labels), true)


def test_knn_rejects_explicit_weights():
    pts, _ = make_blobs(1)
    with pytest.raises(ValueError, match="complete"):
        device_convex_cluster(jax.random.PRNGKey(0), jnp.asarray(pts),
                              lam=0.1, weights=jnp.ones((5,)), edges="knn")


def test_edge_components_match_dense_on_complete_graph():
    """Min-label propagation over the complete edge list must find the
    same components as the dense (m, m) propagation."""
    from repro.core.engine.device_convex import (
        _fusion_components_dense,
        _fusion_components_edges,
    )

    rng = np.random.default_rng(6)
    # three tight groups of fused u's plus one outlier
    u = np.concatenate([np.full((4, 3), 0.0), np.full((3, 3), 5.0),
                        np.full((2, 3), -4.0), [[9.0, 9.0, 9.0]]])
    u = jnp.asarray(u + 1e-5 * rng.normal(size=u.shape), jnp.float32)
    iu, ju = np.triu_indices(10, k=1)
    dense = _fusion_components_dense(u, jnp.float32(0.1))
    via_edges = _fusion_components_edges(u, jnp.asarray(iu, jnp.int32),
                                         jnp.asarray(ju, jnp.int32),
                                         jnp.float32(0.1))
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(via_edges))
    assert len(np.unique(np.asarray(dense))) == 4


# ------------------------------------------------- approximate kNN (LSH)

def knn_oracle(pts, k):
    """Dense NumPy per-row k nearest neighbours (index sets)."""
    d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    return np.argsort(d2, axis=1)[:, :k]


def test_knn_approx_registered():
    assert "knn-approx" in list_edge_sets()
    assert isinstance(get_edge_set("knn-approx"), ApproxKnnEdges)


def test_knn_approx_small_m_falls_back_to_exact_bit_for_bit():
    # m <= 3*bucket: the candidate window spans every point, so the
    # approximate builder must run the exact tiled top-k instead
    pts = jnp.asarray(make_blobs(0, k=3, per=10)[0])
    exact = KnnEdges()(pts, knn_k=5)
    approx = ApproxKnnEdges()(pts, knn_k=5)
    np.testing.assert_array_equal(np.asarray(exact.i_idx),
                                  np.asarray(approx.i_idx))
    np.testing.assert_array_equal(np.asarray(exact.j_idx),
                                  np.asarray(approx.j_idx))
    np.testing.assert_array_equal(np.asarray(exact.weights),
                                  np.asarray(approx.weights))
    np.testing.assert_array_equal(np.asarray(exact.inv_eta),
                                  np.asarray(approx.inv_eta))


def test_knn_approx_recall_against_dense_oracle():
    # large enough to force the LSH candidate stage (m > 3*bucket)
    pts, _ = make_blobs(4, k=3, per=100, d=6)
    k, bucket = 5, 32
    assert len(pts) > 3 * bucket
    edges = ApproxKnnEdges()(jnp.asarray(pts), knn_k=k, bucket=bucket)
    oracle = knn_oracle(pts, k)
    truth = {(min(i, int(j)), max(i, int(j)))
             for i, row in enumerate(oracle) for j in row}
    found = active_pairs(edges)
    recall = len(found & truth) / len(truth)
    assert recall >= 0.9, f"LSH recall {recall:.3f} below 0.9"


def test_knn_approx_recovers_planted_clusters_at_interval_lambda():
    pts, true = make_blobs(0, k=3, per=80, d=6)
    lo, hi = lambda_interval(pts, true)
    # 240 points > 3 * the default bucket (64): the LSH path engages
    res = device_convex_cluster(jax.random.PRNGKey(0), jnp.asarray(pts),
                                lam=0.5 * (lo + hi), iters=400,
                                edges="knn-approx", knn_k=5)
    assert int(res.n_clusters) == 3
    assert same_partition(np.asarray(res.labels), true)


# ------------------------------------------------- degenerate sizes

@pytest.mark.parametrize("name", ["complete", "knn", "knn-approx"])
@pytest.mark.parametrize("m", [1, 2, 3])
def test_degenerate_sizes_build_valid_edges(name, m):
    # knn_k >= m and tile > m: the builders must clamp, not crash
    pts = jnp.asarray(np.random.default_rng(m).normal(size=(m, 4)),
                      jnp.float32)
    edges = get_edge_set(name)(pts, knn_k=8, tile=1024, bucket=64)
    if m == 1:
        assert int(edges.n_edges) == 0
    i = np.asarray(edges.i_idx)
    j = np.asarray(edges.j_idx)
    assert ((0 <= i) & (i < max(m, 1))).all()
    assert ((0 <= j) & (j < max(m, 1))).all()
    if m >= 2:
        # every unordered pair of a 2-3 point set is a nearest
        # neighbour, so all builders agree on the active pair set
        assert active_pairs(edges) == {(a, b) for a in range(m)
                                       for b in range(a + 1, m)}


# ------------------------------------------------------------ OOM guard

def test_complete_edges_guard_refuses_quadratic_blowup():
    pts = jnp.zeros((COMPLETE_EDGES_MAX_M + 1, 2), jnp.float32)
    with pytest.raises(ValueError, match="knn-approx"):
        CompleteEdges()(pts)
    with pytest.raises(ValueError, match="max_m"):
        CompleteEdges()(jnp.zeros((64, 2)), max_m=32)


def test_complete_edges_guard_override():
    edges = CompleteEdges()(jnp.zeros((64, 2)), max_m=64)
    assert int(edges.n_edges) == 64 * 63 // 2
