"""Substrate tests: data generators (hypothesis), optimizer, checkpoint,
sketching (JL property), ERM solvers, schedules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dependency")
from hypothesis import given, settings, strategies as st

from repro.core.erm import logistic_erm, ridge_erm, sgd_erm
from repro.core.sketch import sketch_tree, sketch_vector
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import (
    ClusteredTokenStream,
    make_linear_regression_federation,
    make_logistic_federation,
    make_mnist_like_federation,
)
from repro.optim import adamw_init, adamw_update, AdamWConfig, cosine_schedule, sgd_init, sgd_update


# ------------------------------------------------------------------ data

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(5, 50))
def test_linear_federation_properties(seed, n):
    fed = make_linear_regression_federation(seed=seed, m=20, K=10, n=n)
    assert fed.xs.shape == (20, n, 20)
    assert fed.D > 0
    counts = np.bincount(fed.true_labels)
    assert (counts == 2).all()                     # balanced
    # per-row sparsity: exactly 5 nonzero covariate components
    nnz = (fed.xs != 0).sum(axis=-1)
    assert (nnz <= 5).all()


def test_logistic_federation_labels_pm1():
    fed = make_logistic_federation(seed=0, m=8, K=4, n=50)
    assert set(np.unique(fed.ys)) <= {-1.0, 1.0}


def test_mnist_like_flips_labels_across_clusters():
    fed = make_mnist_like_federation(seed=0, m=10, n=4)
    # same covariate distribution, opposite labels: check test sets of a
    # pair of users from different clusters have opposite label means
    y0 = fed.ys_test[fed.true_labels == 0].mean()
    y1 = fed.ys_test[fed.true_labels == 1].mean()
    assert abs(y0 + y1) < 0.2


def test_token_stream_cluster_specific_statistics():
    stream = ClusteredTokenStream(n_clients=4, n_clusters=2, vocab_size=32,
                                  seed=0)
    a = stream.sample(0, batch=8, seq_len=64, step=0)   # cluster 0
    b = stream.sample(1, batch=8, seq_len=64, step=0)   # cluster 0
    c = stream.sample(2, batch=8, seq_len=64, step=0)   # cluster 1
    assert a.shape == (8, 65)

    def bigram(t):
        h = np.zeros((32, 32))
        for row in t:
            for x, y in zip(row[:-1], row[1:]):
                h[x, y] += 1
        return h / h.sum()

    d_ab = np.abs(bigram(a) - bigram(b)).sum()
    d_ac = np.abs(bigram(a) - bigram(c)).sum()
    assert d_ac > d_ab, "cross-cluster bigram stats must differ more"


# ------------------------------------------------------------------ erm

def test_ridge_erm_solves_normal_equations():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(200, 5)).astype(np.float32)
    w = rng.normal(size=5).astype(np.float32)
    y = x @ w
    w_hat = np.asarray(ridge_erm(jnp.asarray(x), jnp.asarray(y), 1e-8))
    np.testing.assert_allclose(w_hat, w, rtol=1e-3, atol=1e-4)


def test_logistic_erm_newton_recovers_direction():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(2000, 2)).astype(np.float32)
    w = np.array([2.0, -1.0], np.float32)
    p = 1 / (1 + np.exp(-(x @ w)))
    y = (2 * (rng.uniform(size=2000) < p) - 1).astype(np.float32)
    theta = np.asarray(logistic_erm(jnp.asarray(x), jnp.asarray(y), 1e-4))
    w_hat = theta[:2]
    cos = w_hat @ w / (np.linalg.norm(w_hat) * np.linalg.norm(w))
    assert cos > 0.98


def test_sgd_erm_appendix_d_approaches_exact():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(500, 4)).astype(np.float32)
    w = rng.normal(size=4).astype(np.float32)
    y = (x @ w + 0.01 * rng.normal(size=500)).astype(np.float32)
    exact = np.asarray(ridge_erm(jnp.asarray(x), jnp.asarray(y), 1e-6))

    def loss(theta, batch):
        xx, yy = batch
        r = xx @ theta - yy
        return 0.5 * jnp.mean(r * r)

    approx = sgd_erm(jax.random.PRNGKey(0), jnp.zeros(4),
                     (jnp.asarray(x), jnp.asarray(y)), loss,
                     steps=2000, batch=32, mu=1.0, radius=100.0)
    assert np.linalg.norm(np.asarray(approx) - exact) < 0.3


# ---------------------------------------------------------------- optim

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.ones((8,)) * 5.0}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": params["w"]}
        params, state = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_sgd_projection_keeps_radius():
    params = {"w": jnp.ones((4,)) * 10.0}
    state = sgd_init(params)
    params, state = sgd_update(params, {"w": jnp.zeros(4)}, state, lr=0.1,
                               radius=1.0)
    assert float(jnp.linalg.norm(params["w"])) <= 1.0 + 1e-5


def test_cosine_schedule_endpoints():
    assert float(cosine_schedule(0, 100, warmup_steps=10)) < 0.2
    assert float(cosine_schedule(50, 100, 10)) > float(cosine_schedule(99, 100, 10))


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    back = restore_checkpoint(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == np.dtype(jnp.bfloat16)


# ----------------------------------------------------------------- sketch

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100))
def test_sketch_preserves_relative_distances(seed):
    """JL property: sketched distances within ~40% of true (s=512)."""
    rng = np.random.default_rng(seed)
    vs = [jnp.asarray(rng.normal(size=4000).astype(np.float32))
          for _ in range(4)]
    key = jax.random.PRNGKey(0)
    sk = [np.asarray(sketch_vector(key, v, 512)) for v in vs]
    for i in range(4):
        for j in range(i + 1, 4):
            true_d = float(jnp.linalg.norm(vs[i] - vs[j]))
            sk_d = float(np.linalg.norm(sk[i] - sk[j]))
            assert abs(sk_d - true_d) / true_d < 0.4


def test_sketch_tree_filter_excludes_leaves():
    tree = {"moe": {"w_in": jnp.ones((4, 8)), "router": jnp.ones((8,))},
            "dense": jnp.ones((16,))}
    key = jax.random.PRNGKey(0)
    full = sketch_tree(key, tree, 32)
    filt = sketch_tree(key, tree, 32,
                       leaf_filter=lambda p, l: "w_in" not in
                       "/".join(str(getattr(q, 'key', q)) for q in p))
    assert not np.allclose(np.asarray(full), np.asarray(filt))
