"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device; only launch/dryrun.py forces 512 host devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def same_partition(a, b) -> bool:
    """Label vectors agree up to renaming of cluster ids (shared by the
    engine, engine-property, and federated-method tests)."""
    a, b = np.asarray(a), np.asarray(b)
    fwd, bwd = {}, {}
    for x, y in zip(a, b):
        if fwd.setdefault(x, y) != y or bwd.setdefault(y, x) != x:
            return False
    return True
