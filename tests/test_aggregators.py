"""Properties of the robust per-cluster aggregator registry
(``core/engine/aggregators.py``): bit-exactness at zero trim, breakdown
boundedness, degenerate clusters, and registry plumbing."""
import dataclasses
import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    # the properties still run without the optional dev dependency:
    # sweep a fixed sample grid (bounds + interior) per strategy
    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(lo, hi):
            return [lo, hi, (lo + hi) // 2, lo + 31]

        @staticmethod
        def floats(lo, hi):
            return [lo, lo + 0.999 * (hi - lo), 0.5 * (lo + hi)]

    def settings(**_kw):
        return lambda fn: fn

    def given(**params):
        names = sorted(params)
        combos = list(itertools.product(*(params[n] for n in names)))
        return pytest.mark.parametrize(",".join(names), combos)

import jax
import jax.numpy as jnp

from repro.core.engine import (
    GeometricMedianAggregator,
    MeanAggregator,
    MedianAggregator,
    TrimmedMeanAggregator,
    cluster_aggregate_tree,
    device_kmeans,
    get_aggregator,
    list_aggregators,
    make_aggregator,
    register_aggregator,
    unregister_aggregator,
)


def _inputs(flat, labels, k):
    labels = jnp.asarray(labels, jnp.int32)
    onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    return jnp.asarray(flat, jnp.float32), labels, onehot, counts


def _random_problem(seed, c=24, n=5, k=4):
    rng = np.random.default_rng(seed)
    flat = rng.normal(size=(c, n)).astype(np.float32)
    labels = rng.integers(0, k, size=c).astype(np.int32)
    return _inputs(flat, labels, k)


# ------------------------------------------------------------ bit-exactness

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_trimmed_beta0_bit_exact_with_mean(seed):
    """beta=0 keeps every row: the trimmed reduction IS the mean."""
    flat, labels, onehot, counts = _random_problem(seed)
    ref = MeanAggregator()(flat, labels, onehot, counts)
    out = TrimmedMeanAggregator(beta=0.0)(flat, labels, onehot, counts)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_median_matches_numpy_per_cluster(seed):
    flat, labels, onehot, counts = _random_problem(seed)
    out = np.asarray(MedianAggregator()(flat, labels, onehot, counts))
    flat_np, labels_np = np.asarray(flat), np.asarray(labels)
    for j in range(onehot.shape[1]):
        rows = flat_np[labels_np == j]
        if rows.size == 0:
            np.testing.assert_array_equal(out[j], 0.0)
        else:
            np.testing.assert_allclose(out[j], np.median(rows, axis=0),
                                       rtol=1e-6, atol=1e-6)


# ----------------------------------------------------- breakdown boundedness

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       beta=st.floats(0.1, 0.45),
       spike=st.floats(1e3, 1e8))
def test_trimmed_mean_breakdown_boundedness(seed, beta, spike):
    """With <= floor(beta * cnt) corrupted rows per cluster, the trimmed
    mean stays inside the honest rows' per-coordinate [min, max] hull —
    the corrupted values cannot leak into the output at all."""
    rng = np.random.default_rng(seed)
    k, per = 3, 12
    flat = rng.normal(size=(k * per, 4)).astype(np.float32)
    labels = np.repeat(np.arange(k), per).astype(np.int32)
    honest = np.ones(k * per, bool)
    t = int(np.floor(beta * per))
    for j in range(k):
        idx = np.where(labels == j)[0][:t]
        flat[idx] = spike * rng.choice([-1.0, 1.0], size=(t, 4))
        honest[idx] = False
    out = np.asarray(TrimmedMeanAggregator(beta=beta)(
        *_inputs(flat, labels, k)))
    for j in range(k):
        rows = flat[(labels == j) & honest]
        assert np.all(out[j] >= rows.min(axis=0) - 1e-5)
        assert np.all(out[j] <= rows.max(axis=0) + 1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), spike=st.floats(1e3, 1e8))
def test_median_breakdown_boundedness(seed, spike):
    """Corrupting a minority of each cluster leaves the coordinate-wise
    median inside the honest hull (breakdown point 1/2)."""
    rng = np.random.default_rng(seed)
    k, per, bad = 3, 11, 5                    # bad < per / 2
    flat = rng.normal(size=(k * per, 4)).astype(np.float32)
    labels = np.repeat(np.arange(k), per).astype(np.int32)
    honest = np.ones(k * per, bool)
    for j in range(k):
        idx = np.where(labels == j)[0][:bad]
        flat[idx] = spike * rng.choice([-1.0, 1.0], size=(bad, 4))
        honest[idx] = False
    out = np.asarray(MedianAggregator()(*_inputs(flat, labels, k)))
    for j in range(k):
        rows = flat[(labels == j) & honest]
        assert np.all(out[j] >= rows.min(axis=0) - 1e-5)
        assert np.all(out[j] <= rows.max(axis=0) + 1e-5)


def test_mean_has_no_breakdown():
    """One spiked row moves the mean arbitrarily far — breakdown 0."""
    flat = np.zeros((8, 3), np.float32)
    flat[0] = 1e6
    labels = np.zeros(8, np.int32)
    out = np.asarray(MeanAggregator()(*_inputs(flat, labels, 1)))
    assert out[0, 0] == pytest.approx(1e6 / 8)


# ---------------------------------------------------------------- degenerate

def test_degenerate_clusters_survive_trimming():
    """Size-1 / size-2 clusters clamp the trim window: at least one
    value survives and the output is the plain mean of the segment."""
    flat = np.array([[5.0], [1.0], [3.0], [100.0], [0.0], [2.0], [4.0]],
                    np.float32)
    labels = np.array([0, 1, 1, 2, 2, 2, 2], np.int32)
    out = np.asarray(TrimmedMeanAggregator(beta=0.4)(
        *_inputs(flat, labels, 4)))
    assert out[0, 0] == pytest.approx(5.0)            # size 1: the row
    assert out[1, 0] == pytest.approx(2.0)            # size 2: t=0 mean
    # size 4, t = min(floor(0.4*4), 1) = 1: drop 100 and 0, keep {2, 4}
    assert out[2, 0] == pytest.approx(3.0)
    assert out[3, 0] == 0.0                           # empty cluster -> 0


def test_median_small_clusters_match_mean():
    flat = np.array([[7.0], [1.0], [3.0]], np.float32)
    labels = np.array([0, 1, 1], np.int32)
    out = np.asarray(MedianAggregator()(*_inputs(flat, labels, 2)))
    assert out[0, 0] == pytest.approx(7.0)
    assert out[1, 0] == pytest.approx(2.0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_empty_clusters_aggregate_to_zero(seed):
    """The masked-matmul convention: clusters nobody joined emit 0."""
    flat, labels, onehot, counts = _random_problem(seed, c=10, k=6)
    for agg in (MeanAggregator(), TrimmedMeanAggregator(beta=0.2),
                MedianAggregator()):
        out = np.asarray(agg(flat, labels, onehot, counts))
        empty = np.asarray(counts) == 0
        if empty.any():
            np.testing.assert_array_equal(out[empty], 0.0)


# ------------------------------------------------------------------ registry

def test_registry_round_trip():
    assert set(list_aggregators()) >= {"mean", "trimmed_mean", "median"}
    probe = MeanAggregator(name="probe-agg")
    register_aggregator(probe)
    try:
        assert get_aggregator("probe-agg") is probe
        assert "probe-agg" in list_aggregators()
        with pytest.raises(ValueError, match="already registered"):
            register_aggregator(MeanAggregator(name="probe-agg"))
    finally:
        unregister_aggregator("probe-agg")
    assert "probe-agg" not in list_aggregators()
    with pytest.raises(KeyError, match="unknown aggregator"):
        get_aggregator("probe-agg")


def test_make_aggregator_specializes_fields():
    agg = make_aggregator("trimmed_mean", beta=0.25, frac=0.3, eps=None)
    assert isinstance(agg, TrimmedMeanAggregator)
    assert agg.beta == 0.25                   # unknown keys ignored
    assert make_aggregator("mean") is get_aggregator("mean")
    inst = TrimmedMeanAggregator(beta=0.3)
    assert make_aggregator(inst) is inst      # instances pass through


def test_breakdown_attributes():
    assert MeanAggregator().breakdown == 0.0
    assert TrimmedMeanAggregator(beta=0.2).breakdown == 0.2
    assert MedianAggregator().breakdown == 0.5
    with pytest.raises(ValueError, match="beta"):
        TrimmedMeanAggregator(beta=0.5)


def test_aggregators_are_hashable_jit_keys():
    """Frozen dataclasses: usable as static jit arguments."""
    assert hash(TrimmedMeanAggregator(beta=0.2)) == hash(
        TrimmedMeanAggregator(beta=0.2))
    assert dataclasses.is_dataclass(MedianAggregator())


# ----------------------------------------------------------- jit + device use

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_aggregators_jit_traceable(seed):
    """Every registered reduction runs inside jit, bit-equal to eager."""
    flat, labels, onehot, counts = _random_problem(seed)
    for name in ("mean", "trimmed_mean", "median"):
        agg = get_aggregator(name)
        eager = agg(flat, labels, onehot, counts)
        jitted = jax.jit(agg)(flat, labels, onehot, counts)
        np.testing.assert_array_equal(np.asarray(jitted), np.asarray(eager))


def test_cluster_aggregate_tree_mean_matches_manual():
    flat, labels, onehot, counts = _random_problem(3, c=12, n=4, k=3)
    tree = {"w": flat.reshape(12, 2, 2)}
    out = cluster_aggregate_tree(tree, labels, onehot, counts, "mean")
    means = np.asarray(MeanAggregator()(flat, labels, onehot, counts))
    expect = means[np.asarray(labels)].reshape(12, 2, 2)
    np.testing.assert_allclose(np.asarray(out["w"]), expect,
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------- geometric median

def test_geometric_median_rejects_colluding_blob():
    """30% of one cluster's rows collude at a distant shared point —
    past the per-coordinate trim budget, so the trimmed mean is dragged,
    while the Weiszfeld geometric median (breakdown 0.5) stays at the
    honest mode."""
    rng = np.random.default_rng(0)
    honest = 5.0 + 0.2 * rng.normal(size=(70, 6)).astype(np.float32)
    colluders = np.full((30, 6), 120.0, np.float32)
    flat = np.concatenate([honest, colluders])
    labels = np.zeros(100, np.int32)
    args = _inputs(flat, labels, 1)
    err = {name: float(np.linalg.norm(
        np.asarray(make_aggregator(name, beta=0.1)(*args))[0] - 5.0))
        for name in ("mean", "trimmed_mean", "geometric_median")}
    assert err["geometric_median"] < 2.0
    assert err["geometric_median"] < 0.1 * err["trimmed_mean"]
    assert err["geometric_median"] < 0.1 * err["mean"]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_geometric_median_near_mean_on_clean_data(seed):
    """On symmetric clean clusters the geometric median lands near the
    mean (it is not a different estimand, just a robust one)."""
    flat, labels, onehot, counts = _random_problem(seed, c=48, k=3)
    gm = np.asarray(GeometricMedianAggregator(iters=32)(
        flat, labels, onehot, counts))
    mean = np.asarray(MeanAggregator()(flat, labels, onehot, counts))
    live = np.asarray(counts) > 0
    assert np.linalg.norm(gm[live] - mean[live], axis=1).max() < 1.0


def test_geometric_median_degenerate_clusters():
    """Size-1 cluster -> its member exactly; empty cluster -> 0."""
    flat = np.array([[7.0, -3.0], [1.0, 1.0], [3.0, 3.0]], np.float32)
    labels = np.array([0, 1, 1], np.int32)
    out = np.asarray(GeometricMedianAggregator()(*_inputs(flat, labels, 3)))
    np.testing.assert_allclose(out[0], [7.0, -3.0], atol=1e-4)
    np.testing.assert_allclose(out[1], [2.0, 2.0], atol=1e-3)
    np.testing.assert_array_equal(out[2], 0.0)


def test_geometric_median_registry_and_jit():
    assert "geometric_median" in list_aggregators()
    assert GeometricMedianAggregator().breakdown == 0.5
    agg = make_aggregator("geometric_median", iters=8)
    assert isinstance(agg, GeometricMedianAggregator)
    assert agg.iters == 8
    with pytest.raises(ValueError, match="iters"):
        GeometricMedianAggregator(iters=0)
    flat, labels, onehot, counts = _random_problem(11)
    eager = agg(flat, labels, onehot, counts)
    jitted = jax.jit(agg)(flat, labels, onehot, counts)
    np.testing.assert_array_equal(np.asarray(jitted), np.asarray(eager))


def test_device_kmeans_trimmed_restart_selection_objective():
    """A robust aggregator makes restart *selection* robust too: the
    reported inertia is the trimmed k-means objective — the sum of the
    m - floor(breakdown * m) smallest squared row distances."""
    rng = np.random.default_rng(0)
    pts = np.concatenate([
        rng.normal(size=(40, 4)).astype(np.float32) + 20.0 * np.eye(4)[j]
        for j in range(3)])
    agg = make_aggregator("trimmed_mean", beta=0.2)
    res = device_kmeans(jax.random.PRNGKey(0), jnp.asarray(pts), 3,
                        restarts=3, init="random", aggregator=agg)
    labels = np.asarray(res.labels)
    centers = np.asarray(res.centers)
    d2 = np.sum((pts - centers[labels]) ** 2, axis=1)
    t = int(0.2 * len(pts))
    expect = np.sort(d2)[: len(pts) - t].sum()
    assert float(res.inertia) == pytest.approx(expect, rel=1e-4)
    # beta=0 keeps the accumulator-identity (untrimmed) inertia path
    res0 = device_kmeans(jax.random.PRNGKey(0), jnp.asarray(pts), 3,
                         aggregator=make_aggregator("trimmed_mean",
                                                    beta=0.0))
    ref = device_kmeans(jax.random.PRNGKey(0), jnp.asarray(pts), 3,
                        aggregator=make_aggregator("mean"))
    np.testing.assert_array_equal(np.asarray(res0.labels),
                                  np.asarray(ref.labels))
    np.testing.assert_array_equal(np.asarray(res0.centers),
                                  np.asarray(ref.centers))
