"""Table 2: test accuracy on the MNIST stand-in (binary digits with
flipped labels across the two clusters; m=100, n=4/user).

Offline container => MNIST replaced by a matched synthetic two-class
problem (DESIGN.md §7).  Methods: ODCL-KM++, Local ERM, Cluster Oracle,
IFCA-1 / IFCA-2 (oracle-init + noise), IFCA-R (random init) — all run
through the unified ``Method.fit`` interface."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, memoized_solver, timed
from repro.core import (
    IFCA,
    LocalOnly,
    ODCL,
    ClusterOracle,
    batched_logistic_erm,
    ifca_init_near_optima,
)
from repro.core.erm import logistic_erm
from repro.data import make_mnist_like_federation

RUNS = 3


def accuracy(models, fed):
    """models (m, d+1) with intercept slot; evaluate per-user test acc."""
    accs = []
    for i in range(fed.m):
        w, b = models[i, :-1], models[i, -1]
        pred = np.sign(fed.xs_test[i] @ w + b)
        accs.append((pred == fed.ys_test[i]).mean())
    return float(np.mean(accs))


def _loss(theta, x, y):
    w, b = theta[:-1], theta[-1]
    z = x @ w + b
    return jnp.mean(jnp.logaddexp(0.0, -y * z)) + 5e-6 * jnp.sum(w * w)


def logistic_solver(xs, ys):
    return batched_logistic_erm(jnp.asarray(xs), jnp.asarray(ys), 1e-4, 25)


def run():
    rows: dict[str, list] = {}
    us = 0.0
    grad_fn = jax.grad(_loss)
    for seed in range(RUNS):
        fed = make_mnist_like_federation(seed=seed, m=100, n=4)
        key = jax.random.PRNGKey(0)

        def pooled(x, y):
            return logistic_erm(jnp.asarray(x), jnp.asarray(y), 1e-4, 25)

        solver = memoized_solver(logistic_solver)   # one ERM pass per fed
        odcl_method = ODCL(algorithm="kmeans++", k=2)
        res, us = timed(odcl_method.fit, key, fed.xs, fed.ys,
                        solver, iters=1)
        rows.setdefault("odcl_km++", []).append(accuracy(res.user_models, fed))
        local = LocalOnly().fit(key, fed.xs, fed.ys, solver)
        rows.setdefault("local_erm", []).append(
            accuracy(local.user_models, fed))
        oracle = ClusterOracle(solve_fn=pooled,
                               true_labels=fed.true_labels).fit(
            key, fed.xs, fed.ys)
        rows.setdefault("cluster_oracle", []).append(
            accuracy(oracle.user_models, fed))

        opt = jnp.asarray(fed.optima.astype(np.float32))
        for name, init in (
            ("ifca_1", ifca_init_near_optima(jax.random.PRNGKey(seed), opt, 1.0)),
            ("ifca_2", ifca_init_near_optima(jax.random.PRNGKey(seed), opt, 2.0)),
            ("ifca_r", jax.random.normal(jax.random.PRNGKey(seed + 7),
                                         opt.shape)),
        ):
            method = IFCA(k=2, loss_fn=_loss, grad_fn=grad_fn, init=init,
                          rounds=200, step_size=0.1)
            r = method.fit(key, fed.xs, fed.ys)
            rows.setdefault(name, []).append(accuracy(r.user_models, fed))

    for method, vals in rows.items():
        emit(f"table2/{method}", us, f"acc={np.mean(vals):.4f}")
    return {k: float(np.mean(v)) for k, v in rows.items()}


def main():
    run()


if __name__ == "__main__":
    main()
