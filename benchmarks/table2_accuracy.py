"""Table 2: test accuracy on the MNIST stand-in (binary digits with
flipped labels across the two clusters; m=100, n=4/user).

Offline container => MNIST replaced by a matched synthetic two-class
problem (DESIGN.md §7).  Methods: ODCL-KM++, Local ERM, Cluster Oracle,
IFCA-1 / IFCA-2 (oracle-init + noise), IFCA-R (random init)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import (
    IFCAConfig,
    ODCLConfig,
    batched_logistic_erm,
    ifca,
    ifca_init_near_optima,
    odcl,
)
from repro.core.erm import logistic_erm
from repro.data import make_mnist_like_federation

RUNS = 3


def accuracy(models, fed):
    """models (m, d+1) with intercept slot; evaluate per-user test acc."""
    accs = []
    for i in range(fed.m):
        w, b = models[i, :-1], models[i, -1]
        pred = np.sign(fed.xs_test[i] @ w + b)
        accs.append((pred == fed.ys_test[i]).mean())
    return float(np.mean(accs))


def _loss(theta, x, y):
    w, b = theta[:-1], theta[-1]
    z = x @ w + b
    return jnp.mean(jnp.logaddexp(0.0, -y * z)) + 5e-6 * jnp.sum(w * w)


def run():
    rows: dict[str, list] = {}
    us = 0.0
    for seed in range(RUNS):
        fed = make_mnist_like_federation(seed=seed, m=100, n=4)
        local = np.asarray(batched_logistic_erm(
            jnp.asarray(fed.xs), jnp.asarray(fed.ys), 1e-4, 25))
        res, us = timed(odcl, local, ODCLConfig(algo="kmeans++", k=2), iters=1)
        rows.setdefault("odcl_km++", []).append(accuracy(res.user_models, fed))
        rows.setdefault("local_erm", []).append(accuracy(local, fed))
        # cluster oracle: pool each true cluster's data
        pooled = []
        for k in range(2):
            sel = fed.true_labels == k
            x = fed.xs[sel].reshape(-1, fed.xs.shape[-1])
            y = fed.ys[sel].reshape(-1)
            pooled.append(np.asarray(logistic_erm(
                jnp.asarray(x), jnp.asarray(y), 1e-4, 25)))
        oracle_models = np.stack([pooled[k] for k in fed.true_labels])
        rows.setdefault("cluster_oracle", []).append(
            accuracy(oracle_models, fed))

        grad_fn = jax.grad(_loss)
        opt = jnp.asarray(fed.optima.astype(np.float32))
        for name, init in (
            ("ifca_1", ifca_init_near_optima(jax.random.PRNGKey(seed), opt, 1.0)),
            ("ifca_2", ifca_init_near_optima(jax.random.PRNGKey(seed), opt, 2.0)),
            ("ifca_r", jax.random.normal(jax.random.PRNGKey(seed + 7),
                                         opt.shape)),
        ):
            cfg = IFCAConfig(k=2, rounds=200, step_size=0.1)
            thetaT, labels, _ = ifca(init, jnp.asarray(fed.xs),
                                     jnp.asarray(fed.ys), _loss, grad_fn, cfg)
            user_models = np.asarray(thetaT)[np.asarray(labels)]
            rows.setdefault(name, []).append(accuracy(user_models, fed))

    for method, vals in rows.items():
        emit(f"table2/{method}", us, f"acc={np.mean(vals):.4f}")
    return {k: float(np.mean(v)) for k, v in rows.items()}


def main():
    run()


if __name__ == "__main__":
    main()
