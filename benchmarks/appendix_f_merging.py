"""Appendix F (Lemma 9): when is merging two clusters beneficial?

Empirically verifies the merge condition D^2 <= ~1/(2n): two linear
regression clusters at varying separation eps are trained (a) separately
and (b) merged; the crossover point of which is better tracks 1/(2n).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core.erm import ridge_erm
from repro.core.theory import merge_condition

N = 200          # samples per user
D_DIM = 10
USERS_PER = 4
RUNS = 5


def run():
    rng = np.random.default_rng(0)
    bound = merge_condition(N * USERS_PER, N * USERS_PER)
    rows = []
    us = 0.0
    for eps2 in (bound * 0.04, bound * 0.5, bound * 25, bound * 2500):
        sep_err, merged_err = [], []
        for run_i in range(RUNS):
            theta_i = rng.normal(size=D_DIM)
            delta = rng.normal(size=D_DIM)
            delta *= np.sqrt(eps2) / np.linalg.norm(delta)
            theta_j = theta_i + delta
            xs_i = rng.normal(size=(USERS_PER * N, D_DIM)).astype(np.float32)
            xs_j = rng.normal(size=(USERS_PER * N, D_DIM)).astype(np.float32)
            y_i = xs_i @ theta_i + rng.normal(size=len(xs_i))
            y_j = xs_j @ theta_j + rng.normal(size=len(xs_j))
            th_i, us = timed(ridge_erm, jnp.asarray(xs_i),
                             jnp.asarray(y_i.astype(np.float32)), 1e-8,
                             iters=1)
            th_j = ridge_erm(jnp.asarray(xs_j),
                             jnp.asarray(y_j.astype(np.float32)), 1e-8)
            x_all = np.concatenate([xs_i, xs_j])
            y_all = np.concatenate([y_i, y_j]).astype(np.float32)
            th_m = ridge_erm(jnp.asarray(x_all), jnp.asarray(y_all), 1e-8)
            sep = 0.5 * (np.sum((np.asarray(th_i) - theta_i) ** 2)
                         + np.sum((np.asarray(th_j) - theta_j) ** 2))
            mer = 0.5 * (np.sum((np.asarray(th_m) - theta_i) ** 2)
                         + np.sum((np.asarray(th_m) - theta_j) ** 2))
            sep_err.append(sep)
            merged_err.append(mer)
        rows.append((eps2 / bound, float(np.mean(merged_err))
                     / float(np.mean(sep_err))))
    emit("appendix_f/merge_vs_separate_mse_ratio", us,
         ";".join(f"D2_over_bound={r:.2g}:{v:.3f}" for r, v in rows))
    # merging should win (<1) below the bound and lose (>1) far above it
    emit("appendix_f/verdict", us,
         f"below_bound={rows[0][1]:.3f}(<1 good);far_above={rows[-1][1]:.3f}(>1 good)")


def main():
    run()


if __name__ == "__main__":
    main()
