"""Aggregation-engine scaling: per-algorithm C-sweep of the streaming
one-shot round.

For each (algorithm, edge set, federation size C) cell the full pipeline
of ``launch/simulate.py`` runs — wave-batched local ERMs streamed into
an ``AggregationSession`` (``ingest`` sketches each wave on device into
the fixed-capacity buffer), then ``finalize`` (registered clustering +
cluster mean, one jitted program) — and the per-phase wall clock plus
peak memory are recorded to ``BENCH_engine.json``: the perf trajectory
the next optimization PRs measure against.  The phases are disjoint:
``ingest_s`` is the streaming-upload dispatch inside the wave loop,
``local_erm_s`` the wave ERMs without it (comparable with pre-session
rows), ``aggregate_s`` the finalize round.

Schema_version 3 adds the mutable-serving columns to the kmeans rows:
the sweep re-runs each federation with keyed drifted re-uploads +
churned-in joiners (``reupload_frac`` / ``churn``), measures the
drift-triggered warm re-finalize (``refinalize_warm_p50_ms`` — the
number to compare against the cold ``finalize_p50_ms``) and the
one-program batched route (``route_batch_ms`` / ``batched_routes_per_s``
over the drifted probe batch), and records the eviction/live-slot
accounting.  The convex rows keep these columns null (the complete-graph
rows are too slow to re-run mutated, and the warm AMA dual only applies
at unchanged client count).

Each row also carries (since schema_version 2):

  * serving columns — ``route_p50_ms`` / ``route_p99_ms`` /
    ``routes_per_s`` from 256 fresh probe clients routed through the
    session, ``finalize_p50_ms`` / ``finalize_p99_ms`` from warm
    re-finalizes, and the session ``drift`` gauge.  The serving
    exercise runs OUTSIDE the phase timings, so ``total_s`` stays
    comparable with schema-1 rows.
  * ``kernels`` — achieved-vs-peak roofline rows
    (``roofline.engine_costs``): ``programs`` pairs each AOT program's
    XLA cost analysis with its measured warm p50 (captured by the obs
    layer at the run's own compiles, zero extra compiles); ``probes``
    AOT-times the per-iteration kernel at the row's shapes.
  * ``device_peak_bytes`` — the backend allocator's peak when it
    reports one (TPU/GPU ``memory_stats``), else the peak-RSS delta
    over the bench's start (the CPU backend allocates from RSS);
    ``device_peak_bytes_source`` says which.  The RSS delta is a
    process-wide high-water mark, so later rows upper-bound earlier
    peaks rather than resetting per row.

The kmeans family sweeps to C=16k flat, then rides the two-level
hierarchical round (``shards=`` -> ``engine/hierarchy.py``) to
C=100k-1M.  The convex family's complete fusion graph is E = C(C-1)/2
edges (the AMA state is O(E * sketch_dim)), which walls at C=4k — the
``edges=knn`` rows swap in the sparse mutual-kNN graph (E = C*k via
the tiled top-k over the ``pairwise_l2`` kernel) and carry the family
to C=16k, and the ``edges=knn-approx`` row replaces even that build's
O(C^2) distance sweep with the projection-LSH candidate stage.

Schema_version 4 adds the scale columns: ``shards`` (1 = the flat
session) and ``comm_level_bytes`` (per-level upload bytes of the
hierarchical round, null for flat rows) on every row, and
``edge_build_s`` on the convex rows — the standalone warm wall-clock
of the registered edge builder at the row's (C, sketch_dim), the
number the ``knn`` vs ``knn-approx`` comparison reads.
"""
from __future__ import annotations

import json
import resource
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.engine.edges import get_edge_set
from repro.launch.simulate import simulate
from repro.roofline.engine_costs import (
    detect_hardware,
    engine_kernel_report,
    hardware_info,
    program_rows_from_snapshot,
)

CLUSTERS = 8
OUT = "BENCH_engine.json"
SCHEMA_VERSION = 4
# (algorithm, C grid, simulate overrides).  The kmeans rows carry the
# mutation knobs, so each row ALSO measures the mutable-serving path
# (keyed drifted re-uploads + churn, warm re-finalize, batched route)
# after the scored run; the row key (algorithm, edges, C, shards) is
# unchanged.
SWEEPS = (
    ("kmeans-device", (256, 1024, 4096, 16384),
     {"finalize_repeats": 5, "route_probes": 256,
      "reupload_frac": 0.25, "churn": 64, "refinalize_threshold": 1.5}),
    # two-level hierarchical rounds: the million-client path (S shards
    # of the fused round, then the S*k shard centers at the top level)
    ("kmeans-device", (102400,),
     {"shards": 8, "wave": 8192, "route_probes": 256}),
    ("kmeans-device", (1048576,),
     {"shards": 32, "wave": 8192, "route_probes": 256}),
    ("convex-device", (256, 1024),
     {"sketch_dim": 32, "cc_iters": 200,
      "finalize_repeats": 3, "route_probes": 256}),
    # the complete-graph wall row: one finalize is already ~15 min, so
    # its finalize histogram is the single (compile-heavy) run
    ("convex-device", (4096,),
     {"sketch_dim": 32, "cc_iters": 200,
      "finalize_repeats": 1, "route_probes": 256}),
    # sparse kNN fusion graph: past the complete-graph C=4k edge wall
    ("convex-device", (4096, 16384),
     {"sketch_dim": 32, "cc_iters": 200, "edges": "knn", "knn_k": 8,
      "finalize_repeats": 2, "route_probes": 256}),
    # approximate kNN: the LSH candidate stage drops the edge build's
    # O(C^2) distance sweep (compare edge_build_s with the knn row)
    ("convex-device", (16384,),
     {"sketch_dim": 32, "cc_iters": 200, "edges": "knn-approx", "knn_k": 8,
      "finalize_repeats": 2, "route_probes": 256}),
)


def edge_build_seconds(c: int, sketch_dim: int, edges: str, knn_k: int,
                       repeats: int = 3) -> float:
    """Standalone warm wall-clock of the registered edge builder at the
    row's shapes — isolates the fusion-graph build from the AMA solve so
    the exact-vs-approximate kNN comparison is apples to apples."""
    pts = jax.random.normal(jax.random.PRNGKey(0), (c, sketch_dim),
                            jnp.float32)
    builder = get_edge_set(edges)
    fn = jax.jit(lambda p: builder(p, knn_k=knn_k))
    jax.block_until_ready(fn(pts))                  # compile + warm
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(pts))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _peak_bytes(rss_baseline: int) -> dict:
    """Device allocator peak when the backend reports it (TPU/GPU), else
    the peak-RSS delta over the bench baseline; the source is recorded
    so consumers know which estimate they are reading."""
    stats = {}
    try:
        stats = jax.local_devices()[0].memory_stats() or {}
    except Exception:  # noqa: BLE001 - CPU backends may not implement it
        pass
    peak_rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
    dev = stats.get("peak_bytes_in_use")
    source = "memory_stats"
    if dev is None:
        dev = max(peak_rss - rss_baseline, 0)
        source = "rss_delta"
    return {"device_peak_bytes": int(dev),
            "device_peak_bytes_source": source,
            "peak_rss_bytes": peak_rss}


def run(sweeps=SWEEPS, out: str = OUT):
    hw = detect_hardware()
    rss_baseline = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
    rows = []
    for algorithm, c_grid, overrides in sweeps:
        tag = algorithm
        if overrides.get("edges", "complete") != "complete":
            tag = f"{algorithm}+{overrides['edges']}"
        if overrides.get("shards", 1) > 1:
            tag = f"{tag}@S{overrides['shards']}"
        for c in c_grid:
            summary = simulate(clients=c, clusters=CLUSTERS,
                               algorithm=algorithm,
                               **{"wave": 4096, **overrides})
            snap = summary.pop("obs")
            serving = summary.pop("serving") or {}
            # hierarchical rows probe at the per-shard level-0 shapes —
            # that is the program the round actually compiles
            probe_c = -(-c // summary.get("shards", 1))
            probes = engine_kernel_report(
                probe_c, summary["sketch_dim"], CLUSTERS, algorithm,
                edges=summary.get("edges") or "complete",
                knn_k=summary.get("knn_k") or 8, hw=hw)
            edge_build_s = None
            if summary.get("edges") is not None:
                edge_build_s = edge_build_seconds(
                    c, summary["sketch_dim"], summary["edges"],
                    summary.get("knn_k") or 8)
            row = {**summary, **serving, **_peak_bytes(rss_baseline),
                   "edge_build_s": edge_build_s,
                   "kernels": {
                       "programs": program_rows_from_snapshot(snap, hw),
                       "probes": probes}}
            rows.append(row)
            ph = summary["phases"]
            emit(f"bench_engine/{tag}/C{c}", ph["aggregate_s"] * 1e6,
                 f"erm_s={ph['local_erm_s']:.2f};"
                 f"ingest_s={ph['ingest_s']:.2f};"
                 f"purity={summary['purity']:.3f};"
                 f"route_p50_ms={serving.get('route_p50_ms')};"
                 f"refinalize_warm_p50_ms={serving.get('refinalize_warm_p50_ms')};"
                 f"route_batch_ms={serving.get('route_batch_ms')};"
                 f"rss={row['peak_rss_bytes']}")
    report = {"bench": "engine_scale", "schema_version": SCHEMA_VERSION,
              "backend": jax.default_backend(), "clusters": CLUSTERS,
              "hw": hardware_info(hw), "rows": rows}
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    emit("bench_engine/report", 0.0, out)
    return report


def main():
    run()


if __name__ == "__main__":
    main()
