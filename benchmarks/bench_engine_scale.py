"""Aggregation-engine scaling: per-algorithm C-sweep of the streaming
one-shot round.

For each (algorithm, edge set, federation size C) cell the full pipeline
of ``launch/simulate.py`` runs — wave-batched local ERMs streamed into
an ``AggregationSession`` (``ingest`` sketches each wave on device into
the fixed-capacity buffer), then ``finalize`` (registered clustering +
cluster mean, one jitted program) — and the per-phase wall clock plus
peak memory are recorded to ``BENCH_engine.json``: the perf trajectory
the next optimization PRs measure against.  The phases are disjoint:
``ingest_s`` is the streaming-upload dispatch inside the wave loop,
``local_erm_s`` the wave ERMs without it (comparable with pre-session
rows), ``aggregate_s`` the finalize round.

The kmeans family sweeps to C=16k.  The convex family's complete fusion
graph is E = C(C-1)/2 edges (the AMA state is O(E * sketch_dim)), which
walls at C=4k — the ``edges=knn`` rows swap in the sparse mutual-kNN
graph (E = C*k via the tiled top-k over the ``pairwise_l2`` kernel) and
carry the family to C=16k.
"""
from __future__ import annotations

import json
import resource

import jax

from benchmarks.common import emit
from repro.launch.simulate import simulate

CLUSTERS = 8
OUT = "BENCH_engine.json"
# (algorithm, C grid, simulate overrides)
SWEEPS = (
    ("kmeans-device", (256, 1024, 4096, 16384), {}),
    ("convex-device", (256, 1024, 4096),
     {"sketch_dim": 32, "cc_iters": 200}),
    # sparse kNN fusion graph: past the complete-graph C=4k edge wall
    ("convex-device", (4096, 16384),
     {"sketch_dim": 32, "cc_iters": 200, "edges": "knn", "knn_k": 8}),
)


def _peak_bytes() -> dict:
    """Device allocator peak when the backend reports it (TPU/GPU), else
    None; host peak RSS always (the CPU backend allocates from RSS)."""
    stats = {}
    try:
        stats = jax.local_devices()[0].memory_stats() or {}
    except Exception:  # noqa: BLE001 - CPU backends may not implement it
        pass
    return {
        "device_peak_bytes": stats.get("peak_bytes_in_use"),
        "peak_rss_bytes": resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024,
    }


def run(sweeps=SWEEPS, out: str = OUT):
    rows = []
    for algorithm, c_grid, overrides in sweeps:
        tag = algorithm
        if overrides.get("edges", "complete") != "complete":
            tag = f"{algorithm}+{overrides['edges']}"
        for c in c_grid:
            summary = simulate(clients=c, clusters=CLUSTERS, wave=4096,
                               algorithm=algorithm, **overrides)
            row = {**summary, **_peak_bytes()}
            rows.append(row)
            ph = summary["phases"]
            emit(f"bench_engine/{tag}/C{c}", ph["aggregate_s"] * 1e6,
                 f"erm_s={ph['local_erm_s']:.2f};"
                 f"ingest_s={ph['ingest_s']:.2f};"
                 f"purity={summary['purity']:.3f};"
                 f"rss={row['peak_rss_bytes']}")
    report = {"bench": "engine_scale", "backend": jax.default_backend(),
              "clusters": CLUSTERS, "rows": rows}
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    emit("bench_engine/report", 0.0, out)
    return report


def main():
    run()


if __name__ == "__main__":
    main()
