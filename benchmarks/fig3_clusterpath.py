"""Figure 3 (Appendix E.3): exact-lambda ODCL-CC vs the practical
clusterpath variant — MSE and cluster counts vs n (linear regression,
K=4).  Drives the unified ``Method.fit`` API (``methods.ODCL``); the
keyword-argument function API (``odcl(...)``) keeps its own coverage in
``tests/test_registry_and_methods.py``."""
from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core import ODCL, batched_ridge_erm
from repro.core.clustering import lambda_interval
from repro.data import make_linear_regression_federation

N_GRID = (50, 200, 800)
RUNS = 2
M_USERS = 100


def run():
    us = 0.0
    exact_curve, path_curve, exact_k, path_k = [], [], [], []
    for n in N_GRID:
        ee, pe, ek, pk = [], [], [], []
        for seed in range(RUNS):
            fed = make_linear_regression_federation(seed=seed, m=M_USERS, K=4, n=n)
            local = np.asarray(batched_ridge_erm(
                jnp.asarray(fed.xs), jnp.asarray(fed.ys), 1e-8))
            erm = lambda xs, ys: local    # noqa: E731 - precomputed ERMs
            key = jax.random.PRNGKey(seed)
            # paper E.1 selection: bounds (17) on the true clustering;
            # uniform-in-interval when non-empty else the upper bound
            lo, hi = lambda_interval(local, fed.true_labels)
            lam = 0.5 * (lo + hi) if lo < hi else lo
            exact = ODCL(algorithm="convex",
                         options={"lam": lam, "iters": 250}).fit(
                key, fed.xs, fed.ys, erm)
            path, us = timed(
                ODCL(algorithm="clusterpath",
                     options={"n_lambdas": 8, "iters": 250}).fit,
                key, fed.xs, fed.ys, erm, iters=1)
            ee.append(exact.nmse(fed.optima, fed.true_labels))
            pe.append(path.nmse(fed.optima, fed.true_labels))
            ek.append(exact.n_clusters)
            pk.append(path.n_clusters)
        exact_curve.append(float(np.mean(ee)))
        path_curve.append(float(np.mean(pe)))
        exact_k.append(float(np.mean(ek)))
        path_k.append(float(np.mean(pk)))

    emit("fig3/exact_cc_mse", us,
         ";".join(f"n={n}:{v:.2e}" for n, v in zip(N_GRID, exact_curve)))
    emit("fig3/clusterpath_mse", us,
         ";".join(f"n={n}:{v:.2e}" for n, v in zip(N_GRID, path_curve)))
    emit("fig3/exact_k", us,
         ";".join(f"n={n}:{v:.1f}" for n, v in zip(N_GRID, exact_k)))
    emit("fig3/clusterpath_k", us,
         ";".join(f"n={n}:{v:.1f}" for n, v in zip(N_GRID, path_k)))


def main():
    run()


if __name__ == "__main__":
    main()
