# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import traceback

from benchmarks import (
    appendix_d_inexact,
    appendix_f_merging,
    bench_engine_scale,
    bench_robustness,
    fig1_mse_vs_n,
    fig2_logistic,
    fig3_clusterpath,
    fig4_ifca_comm,
    fig_separability,
    kernels_bench,
    roofline_report,
    table1_comparison,
    table2_accuracy,
)

BENCHES = [
    ("table1", table1_comparison.run),
    ("fig1", fig1_mse_vs_n.run),
    ("table2", table2_accuracy.run),
    ("fig2", fig2_logistic.run),
    ("fig3", fig3_clusterpath.run),
    ("fig4", fig4_ifca_comm.run),
    ("fig4_lm", fig4_ifca_comm.run_lm),
    ("appendix_f", appendix_f_merging.run),
    ("appendix_d", appendix_d_inexact.run),
    ("fig_sep", fig_separability.run),
    ("bench_engine", bench_engine_scale.run),
    ("bench_robustness", bench_robustness.run),
    ("kernels", kernels_bench.run),
    ("roofline", roofline_report.run),
]


def main() -> None:
    print("name,us_per_call,derived")
    failed = []
    for name, fn in BENCHES:
        try:
            fn()
        except Exception:  # noqa: BLE001 - report all benches
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
