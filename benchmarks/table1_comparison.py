"""Table 1: method comparison — communication rounds and sample
requirements for ODCL-KM / ODCL-CC / IFCA / ALL-for-ALL, evaluated from
the paper's explicit formulas (core.theory) at a reference problem."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import theory

REF = dict(m=100, K=10, c_min=10, D=4.0, gamma=0.5, n=600,
           kappa=10.0, eps=1e-3)


def run():
    c = theory.ProblemConstants(L=1.0, mu_F=0.1, R=20.0, d=20, G_F=1.0)
    M, us = timed(theory.constant_M, c, iters=10)
    p = REF["c_min"] / REF["m"]

    km = theory.threshold_odcl_km(M, REF["m"], REF["c_min"], REF["D"],
                                  REF["gamma"])
    cc = theory.threshold_odcl_cc(M, REF["m"], REF["c_min"], REF["D"],
                                  REF["gamma"])
    t_ifca = theory.ifca_comm_rounds(REF["kappa"], p, REF["D"], REF["eps"])
    t_a4a = theory.all_for_all_comm_rounds(REF["n"], REF["m"], REF["K"])

    emit("table1/odcl_km", us, f"rounds=1;sample_req={km:.3e}")
    emit("table1/odcl_cc", us, f"rounds=1;sample_req={cc:.3e}")
    emit("table1/ifca", us, f"rounds={t_ifca:.1f};needs_init=True;needs_K=True")
    emit("table1/all_for_all", us, f"rounds={t_a4a:.3e};needs_clusters=True")
    emit("table1/comm_saving_vs_ifca", us, f"{t_ifca:.1f}x")
    # ODCL-KM beats IFCA's sample req when D < |C_(K)| sqrt(K)/(|C_(K)|+sqrt(m))
    d_star = REF["c_min"] * np.sqrt(REF["K"]) / (REF["c_min"] + np.sqrt(REF["m"]))
    emit("table1/km_beats_ifca_regime", us, f"D<{d_star:.2f}")


def main():
    run()


if __name__ == "__main__":
    main()
