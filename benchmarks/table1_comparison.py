"""Table 1: method comparison — communication rounds and sample
requirements for ODCL-KM / ODCL-CC / IFCA / ALL-for-ALL.

Sample thresholds are evaluated from the paper's explicit formulas
(core.theory) using each *registered* clustering algorithm's
Lemma-1/Lemma-2 admissibility margin, and one-shot round counts come
from the unified method layer — so a newly registered algorithm can be
rowed into this table without touching dispatch code."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import ODCL, get_algorithm, theory

REF = dict(m=100, K=10, c_min=10, D=4.0, gamma=0.5, n=600,
           kappa=10.0, eps=1e-3)

# Table rows: (row name, registered algorithm carrying the Lemma alpha)
ODCL_ROWS = (("odcl_km", "kmeans"), ("odcl_cc", "convex"))


def odcl_sample_requirement(M: float, algo_name: str) -> float:
    """Theorem 1 threshold with the algorithm's own admissible alpha."""
    alpha = get_algorithm(algo_name).admissibility_alpha(REF["m"],
                                                         REF["c_min"])
    return theory.sample_threshold(M, alpha, REF["D"], REF["gamma"])


def run():
    c = theory.ProblemConstants(L=1.0, mu_F=0.1, R=20.0, d=20, G_F=1.0)
    M, us = timed(theory.constant_M, c, iters=10)
    p = REF["c_min"] / REF["m"]

    # every ODCL instance is one-shot regardless of the algorithm plugged in
    one_shot_rounds = ODCL.COMM_ROUNDS

    t_ifca = theory.ifca_comm_rounds(REF["kappa"], p, REF["D"], REF["eps"])
    t_a4a = theory.all_for_all_comm_rounds(REF["n"], REF["m"], REF["K"])

    for row, algo_name in ODCL_ROWS:
        req = odcl_sample_requirement(M, algo_name)
        emit(f"table1/{row}", us,
             f"rounds={one_shot_rounds};sample_req={req:.3e}")
    emit("table1/ifca", us, f"rounds={t_ifca:.1f};needs_init=True;needs_K=True")
    emit("table1/all_for_all", us, f"rounds={t_a4a:.3e};needs_clusters=True")
    emit("table1/comm_saving_vs_ifca", us, f"{t_ifca:.1f}x")
    # ODCL-KM beats IFCA's sample req when D < |C_(K)| sqrt(K)/(|C_(K)|+sqrt(m))
    d_star = REF["c_min"] * np.sqrt(REF["K"]) / (REF["c_min"] + np.sqrt(REF["m"]))
    emit("table1/km_beats_ifca_regime", us, f"D<{d_star:.2f}")


def main():
    run()


if __name__ == "__main__":
    main()
