"""Kernel micro-benchmarks: the ODCL server-step hot spots through the
public ops wrappers (CPU runs the jnp oracle path; on TPU these dispatch
to the Pallas kernels — same call sites)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)
    m, k, d = 1024, 16, 4096
    pts = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    cts = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))

    pd = jax.jit(ops.pairwise_sqdist)
    _, us = timed(pd, pts, cts, warmup=2, iters=5)
    emit("kernels/pairwise_sqdist_1024x16x4096", us,
         f"gflops={2 * m * k * d / us / 1e3:.2f}")

    ka = jax.jit(ops.kmeans_assign)
    _, us = timed(ka, pts, cts, warmup=2, iters=5)
    emit("kernels/kmeans_assign_1024x16x4096", us,
         f"gflops={4 * m * k * d / us / 1e3:.2f}")

    e = 4950
    v = jnp.asarray(rng.normal(size=(e, 256)).astype(np.float32))
    gp = jax.jit(lambda x: ops.group_ball_proj(x, 1.0))
    _, us = timed(gp, v, warmup=2, iters=5)
    emit("kernels/group_ball_proj_4950x256", us,
         f"gbps={2 * e * 256 * 4 / us / 1e3:.2f}")

    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)).astype(np.float32))
    kk = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)).astype(np.float32))
    fa = jax.jit(lambda a, b, c: ops.flash_attention(a, b, c, causal=True))
    _, us = timed(fa, q, kk, kk, warmup=2, iters=3)
    emit("kernels/attention_1x8x1024x64", us,
         f"gflops={4 * 8 * 1024 * 1024 * 64 / us / 1e3:.2f}")


def main():
    run()


if __name__ == "__main__":
    main()
