"""Figure 4 (Appendix E.4): MSE vs communication rounds — ODCL (one
round, flat line) vs IFCA with annulus initialization, at n=400 (phase
transition) and n=600 (order-optimal regime). Both methods run through
the unified ``Method.fit`` interface."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, memoized_solver, timed
from repro.core import IFCA, ODCL, batched_ridge_erm, ifca_init_annulus
from repro.data import make_linear_regression_federation

ROUND_GRID = (1, 5, 20, 80, 200)


def ridge_solver(xs, ys):
    return batched_ridge_erm(jnp.asarray(xs), jnp.asarray(ys), 1e-8)


def _loss(t, x, y):
    r = x @ t - y
    return jnp.mean(r * r)


def run():
    key = jax.random.PRNGKey(0)
    for n in (400, 600):
        fed = make_linear_regression_federation(seed=0, m=40, K=4, n=n)
        solver = memoized_solver(ridge_solver)       # one ERM pass per fed
        method = ODCL(algorithm="kmeans++", k=4)
        res, us = timed(method.fit, key, fed.xs, fed.ys, solver,
                        iters=1)
        odcl_err = res.nmse(fed.optima, fed.true_labels)
        emit(f"fig4/odcl@n{n}", us,
             f"rounds={int(res.comm_rounds)}:{odcl_err:.2e}")

        grad_fn = jax.grad(_loss)
        theta0 = ifca_init_annulus(jax.random.PRNGKey(0),
                                   jnp.asarray(fed.optima), fed.D)
        pts = []
        for rounds in ROUND_GRID:
            ifca_method = IFCA(k=4, loss_fn=_loss, grad_fn=grad_fn,
                               init=theta0, rounds=rounds, step_size=0.05)
            r = ifca_method.fit(key, fed.xs, fed.ys)
            pts.append((rounds, r.nmse(fed.optima, fed.true_labels)))
        emit(f"fig4/ifca@n{n}", us,
             ";".join(f"rounds={r}:{v:.2e}" for r, v in pts))


def main():
    run()


if __name__ == "__main__":
    main()
