"""Figure 4 (Appendix E.4): MSE vs communication rounds — ODCL (one
round, flat line) vs IFCA with annulus initialization, at n=400 (phase
transition) and n=600 (order-optimal regime). Both methods run through
the unified ``Method.fit`` interface.

``run_lm`` is the deep-model variant of the same trade-off: the
one-shot ``ODCLFederated`` round against ``IFCAFederated`` at growing
round counts on a reduced clustered-LM federation, reporting protocol
bytes moved and achieved per-client eval loss — the paper's
communication-saving contribution at ``FederatedState`` scale."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, memoized_solver, timed
from repro.core import IFCA, ODCL, batched_ridge_erm, ifca_init_annulus
from repro.data import make_linear_regression_federation

ROUND_GRID = (1, 5, 20, 80, 200)
LM_ROUND_GRID = (1, 2, 4)


def ridge_solver(xs, ys):
    return batched_ridge_erm(jnp.asarray(xs), jnp.asarray(ys), 1e-8)


def _loss(t, x, y):
    r = x @ t - y
    return jnp.mean(r * r)


def run():
    key = jax.random.PRNGKey(0)
    for n in (400, 600):
        fed = make_linear_regression_federation(seed=0, m=40, K=4, n=n)
        solver = memoized_solver(ridge_solver)       # one ERM pass per fed
        method = ODCL(algorithm="kmeans++", k=4)
        res, us = timed(method.fit, key, fed.xs, fed.ys, solver,
                        iters=1)
        odcl_err = res.nmse(fed.optima, fed.true_labels)
        emit(f"fig4/odcl@n{n}", us,
             f"rounds={int(res.comm_rounds)}:{odcl_err:.2e}")

        grad_fn = jax.grad(_loss)
        theta0 = ifca_init_annulus(jax.random.PRNGKey(0),
                                   jnp.asarray(fed.optima), fed.D)
        pts = []
        for rounds in ROUND_GRID:
            ifca_method = IFCA(k=4, loss_fn=_loss, grad_fn=grad_fn,
                               init=theta0, rounds=rounds, step_size=0.05)
            r = ifca_method.fit(key, fed.xs, fed.ys)
            pts.append((rounds, r.nmse(fed.optima, fed.true_labels)))
        emit(f"fig4/ifca@n{n}", us,
             ";".join(f"rounds={r}:{v:.2e}" for r, v in pts))


def run_lm():
    """One-shot vs iterative at deep-model scale (reduced arch)."""
    import numpy as np

    from repro.configs import get_config
    from repro.core.federated import evaluate_per_client, init_federation
    from repro.core.federated_methods import (
        IFCAFederated,
        ODCLFederated,
        cluster_agreement,
    )
    from repro.data import ClusteredTokenStream, make_lm_batch_iterator
    from repro.launch.steps import make_eval_batch
    from repro.optim import AdamWConfig

    cfg = get_config("qwen2_0_5b").reduced(n_layers=1, max_d_model=64,
                                           max_vocab=64)
    n_clients, k, batch, seq_len = 8, 2, 2, 16
    opt = AdamWConfig(lr=1e-3, weight_decay=0.0)

    def fresh_run(method):
        stream = ClusteredTokenStream(n_clients=n_clients, n_clusters=k,
                                      vocab_size=cfg.vocab_size, seed=0,
                                      branching=4)
        raw = make_lm_batch_iterator(
            stream, clients_per_batch=list(range(n_clients)),
            per_client_batch=batch, seq_len=seq_len)
        it = ({"tokens": t, "labels": l} for t, l in raw)
        state = init_federation(jax.random.PRNGKey(0), cfg, n_clients)
        res = method.run(jax.random.PRNGKey(0), state, cfg, it)
        eval_batch = make_eval_batch(stream, n_clients=n_clients,
                                     batch=batch, seq_len=seq_len)
        loss = float(np.mean(evaluate_per_client(res.state, cfg, eval_batch)))
        purity = cluster_agreement(res.labels, stream.true_labels)
        return res, loss, purity

    # 120 local steps put the clients past the sketch-separability
    # threshold (the n/log n > ... regime of Theorem 1 in step-count
    # terms); below it the one-shot clustering degrades — that IS the
    # phase transition fig4 plots at the shallow scale
    res, loss, purity = fresh_run(ODCLFederated(
        algorithm="kmeans++", k=k, sketch_dim=32, local_steps=120, opt=opt))
    emit("fig4lm/odcl", 0.0,
         f"rounds={res.comm_rounds:g}:bytes={res.comm_bytes:.3g}:"
         f"loss={loss:.4f}:purity={purity:.2f}")

    for rounds in LM_ROUND_GRID:
        # equal total compute (120 optimizer steps per client) across
        # every point, so the emitted gap isolates communication;
        # carry=True is the FedOpt-style variant (per-cluster Adam
        # moments averaged server-side and carried across rounds)
        for carry in (False, True):
            res, loss, purity = fresh_run(IFCAFederated(
                k=k, rounds=rounds, local_steps=10,
                warmup_steps=120 - rounds * 10,
                init="clients", sketch_dim=32, opt=opt,
                carry_opt_state=carry))
            tag = "ifca-carry" if carry else "ifca"
            emit(f"fig4lm/{tag}@r{rounds}", 0.0,
                 f"rounds={res.comm_rounds:g}:bytes={res.comm_bytes:.3g}:"
                 f"loss={loss:.4f}:purity={purity:.2f}")


def main():
    run()
    run_lm()


if __name__ == "__main__":
    main()
