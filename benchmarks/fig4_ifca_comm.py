"""Figure 4 (Appendix E.4): MSE vs communication rounds — ODCL (one
round, flat line) vs IFCA with annulus initialization, at n=400 (phase
transition) and n=600 (order-optimal regime)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import IFCAConfig, ODCLConfig, batched_ridge_erm, ifca, \
    ifca_init_annulus, odcl
from repro.data import make_linear_regression_federation

ROUND_GRID = (1, 5, 20, 80, 200)


def nmse_models(user_models, fed):
    opt = fed.optima[fed.true_labels]
    return float(np.mean(np.sum((user_models - opt) ** 2, 1)
                         / np.sum(opt ** 2, 1)))


def _loss(t, x, y):
    r = x @ t - y
    return jnp.mean(r * r)


def run():
    for n in (400, 600):
        fed = make_linear_regression_federation(seed=0, m=40, K=4, n=n)
        local = np.asarray(batched_ridge_erm(
            jnp.asarray(fed.xs), jnp.asarray(fed.ys), 1e-8))
        res, us = timed(odcl, local, ODCLConfig(algo="kmeans++", k=4), iters=1)
        odcl_err = nmse_models(res.user_models, fed)
        emit(f"fig4/odcl@n{n}", us, f"rounds=1:{odcl_err:.2e}")

        grad_fn = jax.grad(_loss)
        theta0 = ifca_init_annulus(jax.random.PRNGKey(0),
                                   jnp.asarray(fed.optima), fed.D)
        pts = []
        for rounds in ROUND_GRID:
            cfg = IFCAConfig(k=4, rounds=rounds, step_size=0.05)
            thetaT, labels, _ = ifca(theta0, jnp.asarray(fed.xs),
                                     jnp.asarray(fed.ys), _loss, grad_fn, cfg)
            um = np.asarray(thetaT)[np.asarray(labels)]
            pts.append((rounds, nmse_models(um, fed)))
        emit(f"fig4/ifca@n{n}", us,
             ";".join(f"rounds={r}:{v:.2e}" for r, v in pts))


def main():
    run()


if __name__ == "__main__":
    main()
