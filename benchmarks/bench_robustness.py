"""Robustness benchmark: adversity scenarios x robust aggregators over
the streaming one-shot round.

Two sweeps through the full ``launch/simulate.py`` pipeline (wave ERMs
-> session ingest -> one jitted clustering + aggregation round), written
to ``BENCH_robustness.json``:

  * **Byzantine sweep** — sign-flip attackers at fraction f in
    {0, .05, .1, .15, .2} of C = 1024 clients, for every registered
    aggregator (mean / trimmed_mean / median) driving BOTH the device
    Lloyd center update and the restart selection (trimmed k-means
    objective) and the step-3 reduction.  The story the rows tell:
    the mean's served models degrade by ~3 orders of magnitude in MSE
    already at f = 0.05 (center drag toward the coherent mirror blob),
    and its partition purity collapses by f = 0.15-0.2 (plain inertia
    rewards the restart whose center was captured by the attacker
    blob); the robust aggregators hold purity at 1.0 and near-clean
    MSE through f = 0.2 breakdown territory.  Lloyd runs from random
    data seeds with multi-restart — kmeans++ D^2 seeding plants a
    center ON the far attacker blob in every restart, which no robust
    center update can undo (a seeding pathology, not an aggregation
    one).

  * **DP sweep** — the (eps, delta)-Gaussian sketch release at clip 1
    for eps in {2..64}: purity/MSE vs privacy budget, overlaid against
    the paper's separability threshold in the style of
    ``fig_separability`` — per eps the achieved Definition-1 margin of
    the TRUE clustering on the noised sketches vs the algorithm's
    Lemma-1 admissibility requirement; the empirical recovery
    threshold (eps between 8 and 32 at C = 1024) is exactly where the
    achieved margin crosses the predicted one.

Every row carries ``scenario`` / ``aggregator`` / ``purity`` (the
schema the smoke tests pin) plus the full ``simulate`` summary.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.clustering import get_algorithm, separability_alpha
from repro.core.sketch import sketch_tree
from repro.launch.simulate import _wave_erm, simulate, staggered_optima
from repro.scenarios import build_scenario

OUT = "BENCH_robustness.json"
SCHEMA_VERSION = 1

BYZ_FRACS = (0.0, 0.05, 0.1, 0.15, 0.2)
AGGREGATORS = ("mean", "trimmed_mean", "median")
SEEDS = (0, 1)
DP_EPSILONS = (2.0, 4.0, 8.0, 16.0, 32.0, 64.0)

# the acceptance geometry: C >= 1024 shallow ridge clients, 8 clusters
BASE = dict(clients=1024, clusters=8, dim=16, samples=64, wave=512,
            sketch_dim=32)
# Byzantine rows: random-seed multi-restart Lloyd (see module docstring)
# with the trim budget above the attacked fraction
BYZ = dict(init="random", restarts=8, trim_beta=0.25)
# DP rows: no attacker blobs -> kmeans++ seeding is the reliable choice
DP = dict(init="kmeans++", restarts=4, aggregator="mean")


def _dp_separability(eps: float, *, clients, clusters, dim, samples,
                     sketch_dim, seed, **_):
    """fig_separability-style overlay for one DP budget: the achieved
    Definition-1 margin of the TRUE labels on the (eps, delta)-noised
    sketch rows vs the Lloyd family's Lemma-1 admissibility threshold
    (recovery is predicted exactly when achieved > predicted)."""
    key = jax.random.PRNGKey(seed)
    k_opt, k_data = jax.random.split(key)
    optima = staggered_optima(k_opt, clusters, dim)
    labels = jnp.arange(clients, dtype=jnp.int32) % clusters
    theta = _wave_erm(jax.random.fold_in(k_data, 0), optima, labels,
                      wave=clients, n=samples, d=dim, task="ridge")
    sk = jax.vmap(lambda p: sketch_tree(jax.random.PRNGKey(seed), p,
                                        sketch_dim))({"theta": theta})
    if eps is not None:
        scen = build_scenario("dp", epsilon=eps, clip=1.0)
        sk = scen.sketch_transform(jax.random.fold_in(key, 0x5ce0), sk, 0)
    achieved = float(separability_alpha(np.asarray(sk), np.asarray(labels)))
    predicted = float(get_algorithm("kmeans-device").admissibility_alpha(
        clients, clients // clusters))
    return achieved, predicted


def run(*, base=None, byz=None, dp=None, byz_fracs=BYZ_FRACS,
        aggregators=AGGREGATORS, seeds=SEEDS, dp_epsilons=DP_EPSILONS,
        out: str = OUT):
    base = {**BASE, **(base or {})}
    byz = {**BYZ, **(byz or {})}
    dp = {**DP, **(dp or {})}
    rows = []

    for f in byz_fracs:
        for seed in seeds:
            for agg in aggregators:
                s = simulate(**base, **byz, seed=seed, aggregator=agg,
                             scenario="byzantine",
                             scenario_options={"frac": f,
                                               "attack": "sign_flip"})
                # the per-run obs snapshot / serving block are engine-
                # bench concerns; robustness rows track quality only
                s.pop("obs", None), s.pop("serving", None)
                rows.append({"sweep": "byzantine", "frac": f, **s})
                emit(f"bench_rob/byz/f{f:g}/s{seed}/{agg}", 0.0,
                     f"purity={s['purity']:.3f}:mse={s['mse']:.3g}")

    for eps in (*dp_epsilons, None):     # None = the eps->inf baseline
        opts = ({"epsilon": eps, "clip": 1.0} if eps is not None else None)
        s = simulate(**base, **dp, seed=seeds[0],
                     scenario="dp" if eps is not None else None,
                     scenario_options=opts)
        s.pop("obs", None), s.pop("serving", None)
        ach, pred = _dp_separability(eps, seed=seeds[0], **base)
        row = {"sweep": "dp", "epsilon": eps, **s,
               "achieved_alpha": ach, "predicted_alpha": pred,
               "recovery_predicted": ach > pred}
        if eps is None:
            # the clean baseline is a dp-sweep row even though no
            # scenario ran: keep the schema uniform for plotting
            row["scenario"] = "dp"
        rows.append(row)
        emit(f"bench_rob/dp/eps{eps if eps is not None else 'inf'}", 0.0,
             f"purity={s['purity']:.3f}:mse={s['mse']:.3g}:"
             f"alpha={ach:.3g}/{pred:.3g}")

    # the headline numbers the PR's acceptance pins: at 10% sign-flip
    # attackers the robust rows hold purity while the mean's served
    # models have degraded by orders of magnitude vs its clean rows
    def _sel(frac, agg):
        return [r for r in rows if r["sweep"] == "byzantine"
                and r["frac"] == frac and r["aggregator"] == agg]

    crit = None
    if 0.1 in byz_fracs and 0.0 in byz_fracs:
        clean_mse = float(np.mean([r["mse"] for r in _sel(0.0, "mean")]))
        mean_mse = float(np.mean([r["mse"] for r in _sel(0.1, "mean")]))
        crit = {
            "frac": 0.1,
            "trimmed_purity_min": min(r["purity"]
                                      for r in _sel(0.1, "trimmed_mean")),
            "mean_purity_min": min(r["purity"] for r in _sel(0.1, "mean")),
            "mean_mse_degradation_x": mean_mse / max(clean_mse, 1e-12),
            "clean_mean_mse": clean_mse,
            "byzantine_mean_mse": mean_mse,
        }
        emit("bench_rob/criterion", 0.0,
             f"trim_purity={crit['trimmed_purity_min']:.3f}:"
             f"mean_mse_x={crit['mean_mse_degradation_x']:.3g}")

    report = {"bench": "robustness", "schema_version": SCHEMA_VERSION,
              "backend": jax.default_backend(),
              "config": {"base": base, "byzantine": byz, "dp": dp,
                         "seeds": list(seeds)},
              "criterion": crit, "rows": rows}
    with open(out, "w") as fh:
        json.dump(report, fh, indent=2)
    emit("bench_rob/report", 0.0, out)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="small grid / small federation (smoke-sized)")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args(argv)
    if args.reduced:
        return run(base=dict(clients=256, wave=128),
                   byz=dict(restarts=4),
                   byz_fracs=(0.0, 0.1), seeds=(0,),
                   dp_epsilons=(8.0, 32.0), out=args.out)
    return run(out=args.out)


if __name__ == "__main__":
    main()
