"""Robustness benchmark: adversity scenarios x robust aggregators over
the streaming one-shot round.

Two sweeps through the full ``launch/simulate.py`` pipeline (wave ERMs
-> session ingest -> one jitted clustering + aggregation round), written
to ``BENCH_robustness.json``:

  * **Byzantine sweep** — sign-flip attackers at fraction f in
    {0, .05, .1, .15, .2} of C = 1024 clients, for every registered
    aggregator (mean / trimmed_mean / median) driving BOTH the device
    Lloyd center update and the restart selection (trimmed k-means
    objective) and the step-3 reduction.  The story the rows tell:
    the mean's served models degrade by ~3 orders of magnitude in MSE
    already at f = 0.05 (center drag toward the coherent mirror blob),
    and its partition purity collapses by f = 0.15-0.2 (plain inertia
    rewards the restart whose center was captured by the attacker
    blob); the robust aggregators hold purity at 1.0 and near-clean
    MSE through f = 0.2 breakdown territory.  Lloyd runs from random
    data seeds with multi-restart — kmeans++ D^2 seeding plants a
    center ON the far attacker blob in every restart, which no robust
    center update can undo (a seeding pathology, not an aggregation
    one).

  * **Breakdown sweep** — coordinated sign-flip at fractions PAST the
    trim budget (f in {0.25, 0.3, 0.35} with ``trim_beta = 0.1``, so the
    per-coordinate trim discards at most 20% while up to 35% of uploads
    collude).  This is where the aggregators' breakdown points separate:
    ``trimmed_mean`` behaves like the mean once the colluding mass
    survives the trim (purity collapses to 0.64 at f = 0.3 on the worst
    seed, MSE 2-3x the clean rows), while ``geometric_median``
    (Weiszfeld, breakdown 0.5) holds purity and the best MSE through
    f = 0.35.

  * **Spoof sweep** — colluding sketch-channel forgery
    (``attack='spoof'``): every attacker uploads ONE shared crafted
    sketch row, a zero-variance fake cluster planted inside the data
    cloud (scale 2).  Forged rows co-assign with an honest cluster, so
    the in-cluster colluding share (28-62% for f = 0.05-0.2) exceeds
    the trim budget from f = 0.05 on: the mean/trimmed served models
    are dragged toward the forgery while the geometric median rejects
    the colluders outright (MSE 2e-4 vs 1e-2 at f = 0.05) whenever the
    partition is recovered.  The sweep also documents the geometric
    median's one genuine pathology: an exact zero-variance point mass
    below breakdown can still capture a Weiszfeld center (the GM of
    "44% identical + 56% spread" snaps onto the identical mass), so
    its PURITY under spoof is seeding-dominated — robust aggregation
    fixes the served models, not a partition the seeding already gave
    away (the same lesson as the kmeans++ note above).

  * **DP sweep** — the (eps, delta)-Gaussian sketch release at clip 1
    for eps in {2..64}: purity/MSE vs privacy budget, overlaid against
    the paper's separability threshold in the style of
    ``fig_separability`` — per eps the achieved Definition-1 margin of
    the TRUE clustering on the noised sketches vs the algorithm's
    Lemma-1 admissibility requirement; the empirical recovery
    threshold (eps between 8 and 32 at C = 1024) is exactly where the
    achieved margin crosses the predicted one.

Every row carries ``scenario`` / ``aggregator`` / ``purity`` (the
schema the smoke tests pin) plus the full ``simulate`` summary.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.clustering import get_algorithm, separability_alpha
from repro.core.sketch import sketch_tree
from repro.launch.simulate import _wave_erm, simulate, staggered_optima
from repro.scenarios import build_scenario

OUT = "BENCH_robustness.json"
SCHEMA_VERSION = 1

BYZ_FRACS = (0.0, 0.05, 0.1, 0.15, 0.2)
AGGREGATORS = ("mean", "trimmed_mean", "median")
BREAKDOWN_FRACS = (0.25, 0.3, 0.35)
SPOOF_FRACS = (0.05, 0.1, 0.15, 0.2)
ROBUST_AGGREGATORS = ("mean", "trimmed_mean", "geometric_median")
SEEDS = (0, 1)
DP_EPSILONS = (2.0, 4.0, 8.0, 16.0, 32.0, 64.0)

# the acceptance geometry: C >= 1024 shallow ridge clients, 8 clusters
BASE = dict(clients=1024, clusters=8, dim=16, samples=64, wave=512,
            sketch_dim=32)
# Byzantine rows: random-seed multi-restart Lloyd (see module docstring)
# with the trim budget above the attacked fraction
BYZ = dict(init="random", restarts=8, trim_beta=0.25)
# breakdown/spoof rows: the trim budget deliberately BELOW the attacked
# fraction — the regime that separates trimmed_mean from the geometric
# median's 0.5 breakdown
ROBUST = dict(init="random", restarts=8, trim_beta=0.1)
SPOOF_SCALE = 2.0    # forged row inside the data cloud (far blobs just
                     # steal a center cleanly for every aggregator)
# DP rows: no attacker blobs -> kmeans++ seeding is the reliable choice
DP = dict(init="kmeans++", restarts=4, aggregator="mean")


def _dp_separability(eps: float, *, clients, clusters, dim, samples,
                     sketch_dim, seed, **_):
    """fig_separability-style overlay for one DP budget: the achieved
    Definition-1 margin of the TRUE labels on the (eps, delta)-noised
    sketch rows vs the Lloyd family's Lemma-1 admissibility threshold
    (recovery is predicted exactly when achieved > predicted)."""
    key = jax.random.PRNGKey(seed)
    k_opt, k_data = jax.random.split(key)
    optima = staggered_optima(k_opt, clusters, dim)
    labels = jnp.arange(clients, dtype=jnp.int32) % clusters
    theta = _wave_erm(jax.random.fold_in(k_data, 0), optima, labels,
                      wave=clients, n=samples, d=dim, task="ridge")
    sk = jax.vmap(lambda p: sketch_tree(jax.random.PRNGKey(seed), p,
                                        sketch_dim))({"theta": theta})
    if eps is not None:
        scen = build_scenario("dp", epsilon=eps, clip=1.0)
        sk = scen.sketch_transform(jax.random.fold_in(key, 0x5ce0), sk, 0)
    achieved = float(separability_alpha(np.asarray(sk), np.asarray(labels)))
    predicted = float(get_algorithm("kmeans-device").admissibility_alpha(
        clients, clients // clusters))
    return achieved, predicted


def run(*, base=None, byz=None, robust=None, dp=None, byz_fracs=BYZ_FRACS,
        aggregators=AGGREGATORS, breakdown_fracs=BREAKDOWN_FRACS,
        spoof_fracs=SPOOF_FRACS, robust_aggregators=ROBUST_AGGREGATORS,
        seeds=SEEDS, dp_epsilons=DP_EPSILONS, out: str = OUT):
    base = {**BASE, **(base or {})}
    byz = {**BYZ, **(byz or {})}
    robust = {**ROBUST, **(robust or {})}
    dp = {**DP, **(dp or {})}
    rows = []

    def _quality_row(sweep, frac, **kw):
        s = simulate(**base, seed=kw.pop("seed"),
                     scenario="byzantine", **kw)
        # the per-run obs snapshot / serving blocks are engine-bench
        # concerns; robustness rows track quality only
        s.pop("obs", None), s.pop("serving", None), s.pop("qps_server", None)
        rows.append({"sweep": sweep, "frac": frac, **s})
        return s

    for f in byz_fracs:
        for seed in seeds:
            for agg in aggregators:
                s = _quality_row(
                    "byzantine", f, **byz, seed=seed, aggregator=agg,
                    scenario_options={"frac": f, "attack": "sign_flip"})
                emit(f"bench_rob/byz/f{f:g}/s{seed}/{agg}", 0.0,
                     f"purity={s['purity']:.3f}:mse={s['mse']:.3g}")

    # past the trim budget: 2*trim_beta < f <= geometric median breakdown
    for f in breakdown_fracs:
        for seed in seeds:
            for agg in robust_aggregators:
                s = _quality_row(
                    "breakdown", f, **robust, seed=seed, aggregator=agg,
                    scenario_options={"frac": f, "attack": "sign_flip"})
                emit(f"bench_rob/brk/f{f:g}/s{seed}/{agg}", 0.0,
                     f"purity={s['purity']:.3f}:mse={s['mse']:.3g}")

    # colluding sketch-channel forgery inside the data cloud
    for f in spoof_fracs:
        for seed in seeds:
            for agg in robust_aggregators:
                s = _quality_row(
                    "spoof", f, **robust, seed=seed, aggregator=agg,
                    scenario_options={"frac": f, "attack": "spoof",
                                      "scale": SPOOF_SCALE})
                emit(f"bench_rob/spoof/f{f:g}/s{seed}/{agg}", 0.0,
                     f"purity={s['purity']:.3f}:mse={s['mse']:.3g}")

    for eps in (*dp_epsilons, None):     # None = the eps->inf baseline
        opts = ({"epsilon": eps, "clip": 1.0} if eps is not None else None)
        s = simulate(**base, **dp, seed=seeds[0],
                     scenario="dp" if eps is not None else None,
                     scenario_options=opts)
        s.pop("obs", None), s.pop("serving", None), s.pop("qps_server", None)
        ach, pred = _dp_separability(eps, seed=seeds[0], **base)
        row = {"sweep": "dp", "epsilon": eps, **s,
               "achieved_alpha": ach, "predicted_alpha": pred,
               "recovery_predicted": ach > pred}
        if eps is None:
            # the clean baseline is a dp-sweep row even though no
            # scenario ran: keep the schema uniform for plotting
            row["scenario"] = "dp"
        rows.append(row)
        emit(f"bench_rob/dp/eps{eps if eps is not None else 'inf'}", 0.0,
             f"purity={s['purity']:.3f}:mse={s['mse']:.3g}:"
             f"alpha={ach:.3g}/{pred:.3g}")

    # the headline numbers the PR's acceptance pins: at 10% sign-flip
    # attackers the robust rows hold purity while the mean's served
    # models have degraded by orders of magnitude vs its clean rows
    def _sel(frac, agg, sweep="byzantine"):
        return [r for r in rows if r["sweep"] == sweep
                and r["frac"] == frac and r["aggregator"] == agg]

    crit = None
    if 0.1 in byz_fracs and 0.0 in byz_fracs:
        clean_mse = float(np.mean([r["mse"] for r in _sel(0.0, "mean")]))
        mean_mse = float(np.mean([r["mse"] for r in _sel(0.1, "mean")]))
        crit = {
            "frac": 0.1,
            "trimmed_purity_min": min(r["purity"]
                                      for r in _sel(0.1, "trimmed_mean")),
            "mean_purity_min": min(r["purity"] for r in _sel(0.1, "mean")),
            "mean_mse_degradation_x": mean_mse / max(clean_mse, 1e-12),
            "clean_mean_mse": clean_mse,
            "byzantine_mean_mse": mean_mse,
        }
        emit("bench_rob/criterion", 0.0,
             f"trim_purity={crit['trimmed_purity_min']:.3f}:"
             f"mean_mse_x={crit['mean_mse_degradation_x']:.3g}")

    # past-breakdown headline: at f = 0.3 > 2*trim_beta the geometric
    # median holds purity and the best MSE where trimmed_mean degrades
    crit_breakdown = None
    if 0.3 in breakdown_fracs:
        gm, tm = _sel(0.3, "geometric_median", "breakdown"), \
                 _sel(0.3, "trimmed_mean", "breakdown")
        crit_breakdown = {
            "frac": 0.3,
            "trim_beta": robust["trim_beta"],
            "geomed_purity_min": min(r["purity"] for r in gm),
            "trimmed_purity_min": min(r["purity"] for r in tm),
            "geomed_mse_max": max(r["mse"] for r in gm),
            "trimmed_mse_max": max(r["mse"] for r in tm),
        }
        emit("bench_rob/criterion_breakdown", 0.0,
             f"geomed_purity={crit_breakdown['geomed_purity_min']:.3f}:"
             f"trim_purity={crit_breakdown['trimmed_purity_min']:.3f}")

    report = {"bench": "robustness", "schema_version": SCHEMA_VERSION,
              "backend": jax.default_backend(),
              "config": {"base": base, "byzantine": byz, "robust": robust,
                         "spoof_scale": SPOOF_SCALE, "dp": dp,
                         "seeds": list(seeds)},
              "criterion": crit, "criterion_breakdown": crit_breakdown,
              "rows": rows}
    with open(out, "w") as fh:
        json.dump(report, fh, indent=2)
    emit("bench_rob/report", 0.0, out)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="small grid / small federation (smoke-sized)")
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args(argv)
    if args.reduced:
        return run(base=dict(clients=256, wave=128),
                   byz=dict(restarts=4), robust=dict(restarts=4),
                   byz_fracs=(0.0, 0.1), breakdown_fracs=(0.3,),
                   spoof_fracs=(0.1,), seeds=(0,),
                   dp_epsilons=(8.0, 32.0), out=args.out)
    return run(out=args.out)


if __name__ == "__main__":
    main()
