"""Figure 1: normalized MSE vs samples-per-user, synthetic linear
regression (K=10, d=20, m=100). ODCL-KM++ / ODCL-CC vs Oracle Averaging,
Cluster Oracle, Local ERMs, Naive Averaging — every method driven
through the unified ``Method.fit`` interface."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, memoized_solver, timed
from repro.core import (
    ClusterOracle,
    GlobalERM,
    LocalOnly,
    ODCL,
    OracleAveraging,
    batched_ridge_erm,
)
from repro.core.erm import ridge_erm
from repro.data import make_linear_regression_federation

N_GRID = (25, 50, 100, 200, 400)
RUNS = 3


def ridge_solver(xs, ys):
    return batched_ridge_erm(jnp.asarray(xs), jnp.asarray(ys), 1e-8)


def methods_for(fed):
    """The figure's cast, rebuilt per federation (oracles need labels)."""
    def pooled(x, y):
        return ridge_erm(jnp.asarray(x), jnp.asarray(y), 1e-8)

    return {
        "odcl_km++": ODCL(algorithm="kmeans++", k=10),
        "odcl_cc": ODCL(algorithm="clusterpath",
                        options=dict(n_lambdas=6, iters=200)),
        "oracle_avg": OracleAveraging(true_labels=fed.true_labels),
        "cluster_oracle": ClusterOracle(solve_fn=pooled,
                                        true_labels=fed.true_labels),
        "local_erm": LocalOnly(),
        "naive_avg": GlobalERM(),
    }


def run():
    curves: dict[str, list] = {}
    us_odcl = 0.0
    key = jax.random.PRNGKey(0)
    for n in N_GRID:
        accum: dict[str, list] = {}
        for seed in range(RUNS):
            fed = make_linear_regression_federation(seed=seed, n=n)
            solver = memoized_solver(ridge_solver)   # one ERM pass per fed
            for name, method in methods_for(fed).items():
                if name == "odcl_km++":
                    res, us_odcl = timed(method.fit, key, fed.xs, fed.ys,
                                         solver, iters=1)
                else:
                    res = method.fit(key, fed.xs, fed.ys, solver)
                accum.setdefault(name, []).append(
                    res.nmse(fed.optima, fed.true_labels))
        for k, v in accum.items():
            curves.setdefault(k, []).append(float(np.mean(v)))

    for method, vals in curves.items():
        pts = ";".join(f"n={n}:{v:.2e}" for n, v in zip(N_GRID, vals))
        emit(f"fig1/{method}", us_odcl, pts)
    # headline: ODCL matches oracle averaging at the largest n
    ratio = curves["odcl_km++"][-1] / max(curves["oracle_avg"][-1], 1e-30)
    emit("fig1/km_vs_oracle_ratio@n400", us_odcl, f"{ratio:.4f}")
    return curves


def main():
    run()


if __name__ == "__main__":
    main()
