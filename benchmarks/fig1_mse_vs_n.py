"""Figure 1: normalized MSE vs samples-per-user, synthetic linear
regression (K=10, d=20, m=100). ODCL-KM++ / ODCL-CC vs Oracle Averaging,
Cluster Oracle, Local ERMs, Naive Averaging."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core import ODCLConfig, batched_ridge_erm, odcl, oracles
from repro.core.erm import ridge_erm
from repro.data import make_linear_regression_federation

N_GRID = (25, 50, 100, 200, 400)
RUNS = 3


def nmse(models, fed):
    opt = fed.optima[fed.true_labels]
    return float(np.mean(np.sum((models - opt) ** 2, 1) / np.sum(opt ** 2, 1)))


def run():
    curves: dict[str, list] = {}
    us_odcl = 0.0
    for n in N_GRID:
        accum: dict[str, list] = {}
        for seed in range(RUNS):
            fed = make_linear_regression_federation(seed=seed, n=n)
            local = np.asarray(batched_ridge_erm(
                jnp.asarray(fed.xs), jnp.asarray(fed.ys), 1e-8))
            res_km, us = timed(odcl, local, ODCLConfig(algo="kmeans++", k=10),
                               iters=1)
            us_odcl = us
            res_cc = odcl(local, ODCLConfig(algo="clusterpath", n_lambdas=6,
                                            cc_iters=200))
            rows = {
                "odcl_km++": nmse(res_km.user_models, fed),
                "odcl_cc": nmse(res_cc.user_models, fed),
                "oracle_avg": nmse(oracles.oracle_averaging(
                    local, fed.true_labels), fed),
                "cluster_oracle": nmse(oracles.cluster_oracle(
                    lambda x, y: ridge_erm(jnp.asarray(x), jnp.asarray(y),
                                           1e-8),
                    fed.xs, fed.ys, fed.true_labels), fed),
                "local_erm": nmse(oracles.local_erm(local), fed),
                "naive_avg": nmse(oracles.naive_averaging(local), fed),
            }
            for k, v in rows.items():
                accum.setdefault(k, []).append(v)
        for k, v in accum.items():
            curves.setdefault(k, []).append(float(np.mean(v)))

    for method, vals in curves.items():
        pts = ";".join(f"n={n}:{v:.2e}" for n, v in zip(N_GRID, vals))
        emit(f"fig1/{method}", us_odcl, pts)
    # headline: ODCL matches oracle averaging at the largest n
    ratio = curves["odcl_km++"][-1] / max(curves["oracle_avg"][-1], 1e-30)
    emit("fig1/km_vs_oracle_ratio@n400", us_odcl, f"{ratio:.4f}")
    return curves


def main():
    run()


if __name__ == "__main__":
    main()
