"""Appendix D (Theorem 2): inexact local ERMs.

ODCL with SGD-solved local problems at varying local-iteration budgets T:
the MSE should recover Theorem 1's rate once the solver precision eps
crosses the threshold (32), i.e. more local steps -> exact-ERM MSE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import batched_ridge_erm, odcl, sgd_erm
from repro.data import make_linear_regression_federation

T_GRID = (20, 100, 500, 2500)


def nmse(models, fed):
    opt = fed.optima[fed.true_labels]
    return float(np.mean(np.sum((models - opt) ** 2, 1) / np.sum(opt ** 2, 1)))


def run():
    fed = make_linear_regression_federation(seed=0, m=40, K=4, n=200)
    exact = np.asarray(batched_ridge_erm(
        jnp.asarray(fed.xs), jnp.asarray(fed.ys), 1e-8))
    exact_res = odcl(exact, algorithm="kmeans++", k=4)
    exact_err = nmse(exact_res.user_models, fed)

    def loss(theta, batch):
        x, y = batch
        r = x @ theta - y
        return 0.5 * jnp.mean(r * r)

    us = 0.0
    pts = []
    for t_steps in T_GRID:
        def solve_one(key, x, y):
            return sgd_erm(key, jnp.zeros(x.shape[-1]), (x, y), loss,
                           steps=t_steps, batch=16, mu=0.5, radius=100.0)

        keys = jax.random.split(jax.random.PRNGKey(0), fed.m)
        solver = jax.jit(jax.vmap(solve_one))
        local, us = timed(solver, keys, jnp.asarray(fed.xs),
                          jnp.asarray(fed.ys), iters=1)
        res = odcl(np.asarray(local), algorithm="kmeans++", k=4)
        pts.append((t_steps, nmse(res.user_models, fed), res.n_clusters))

    emit("appendix_d/exact_erm", us, f"nmse={exact_err:.2e}")
    emit("appendix_d/inexact_sgd", us,
         ";".join(f"T={t}:{v:.2e}(K'={k})" for t, v, k in pts))
    emit("appendix_d/converged_to_exact", us,
         f"{pts[-1][1] / max(exact_err, 1e-30):.2f}x_exact")


def main():
    run()


if __name__ == "__main__":
    main()
