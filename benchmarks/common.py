"""Benchmark helpers: timing + CSV row emission."""
from __future__ import annotations

import time

import jax


def timed(fn, *args, warmup: int = 1, iters: int = 3, **kwargs):
    """Returns (result, us_per_call)."""
    result = fn(*args, **kwargs)
    jax.block_until_ready(jax.tree_util.tree_leaves(result))
    for _ in range(max(0, warmup - 1)):
        jax.block_until_ready(jax.tree_util.tree_leaves(fn(*args, **kwargs)))
    t0 = time.perf_counter()
    for _ in range(iters):
        result = fn(*args, **kwargs)
        jax.block_until_ready(jax.tree_util.tree_leaves(result))
    us = (time.perf_counter() - t0) / iters * 1e6
    return result, us


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def memoized_solver(solver):
    """Cache a batched ERM solver on input identity.

    ``Method.fit`` takes the solver so every method is self-contained,
    but within one federation all methods share the same local ERMs —
    memoizing keeps the benchmark loop (and ``timed`` around ``fit``)
    measuring the server step rather than repeated local solves.
    """
    store: dict = {}

    def f(xs, ys):
        key = (id(xs), id(ys))
        if key not in store:
            store[key] = solver(xs, ys)
        return store[key]

    return f
