"""Predicted vs achieved separability per algorithm (ROADMAP item).

For each admissible algorithm, plot the *achieved* Definition-1 margin
of the recovered clustering (``separability_alpha`` in ``result.meta``)
against the algorithm's *predicted* admissibility requirement
(Lemma-1/Lemma-2 ``admissible_alpha``) as the per-user sample count n
grows.  The crossing point — where achieved exceeds predicted — is the
sample-size threshold at which the paper's exact-recovery guarantee
kicks in for that algorithm.

Emits one CSV row per (algorithm, n) and writes the curves to
``FIG_separability.json`` for external plotting.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, memoized_solver
from repro.core import ODCL, batched_ridge_erm
from repro.data import make_linear_regression_federation

N_GRID = (25, 50, 100, 200, 400)
RUNS = 3
OUT = "FIG_separability.json"

ALGOS = {
    "kmeans++": dict(algorithm="kmeans++", k=10),
    "spectral": dict(algorithm="spectral", k=10),
    "kmeans-device": dict(algorithm="kmeans-device", k=10),
    "gradient": dict(algorithm="gradient", k=10),
    "clusterpath": dict(algorithm="clusterpath",
                        options=dict(n_lambdas=6, iters=200)),
}


def ridge_solver(xs, ys):
    return batched_ridge_erm(jnp.asarray(xs), jnp.asarray(ys), 1e-8)


def run():
    key = jax.random.PRNGKey(0)
    curves = {name: {"n": [], "achieved": [], "predicted": []}
              for name in ALGOS}
    for n in N_GRID:
        feds = [make_linear_regression_federation(seed=s, n=n)
                for s in range(RUNS)]
        solvers = [memoized_solver(ridge_solver) for _ in feds]
        for name, spec in ALGOS.items():
            ach, pred = [], []
            for fed, solver in zip(feds, solvers):
                meta = ODCL(**spec).fit(key, fed.xs, fed.ys, solver).meta
                ach.append(meta["separability_alpha"])
                pred.append(meta["admissible_alpha"])
            a, p = float(np.mean(ach)), float(np.mean(pred))
            curves[name]["n"].append(n)
            curves[name]["achieved"].append(a)
            curves[name]["predicted"].append(p)
            emit(f"fig_sep/{name}", 0.0,
                 f"n={n}:achieved={a:.3g}:predicted={p:.3g}:"
                 f"recovered={'Y' if a > p else 'N'}")
    with open(OUT, "w") as f:
        json.dump(curves, f, indent=2)
    emit("fig_sep/report", 0.0, OUT)
    return curves


def main():
    run()


if __name__ == "__main__":
    main()
