"""Figure 2 (Appendix E.2): logistic regression, K=4, d=2 — ODCL-CC MSE
vs n (left panel) and the number of clusters convex clustering produces
(right panel)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.core import ODCLConfig, batched_logistic_erm, odcl, oracles
from repro.core.clustering import lambda_interval
from repro.data import make_logistic_federation

N_GRID = (400, 1600, 4800)
RUNS = 2


def nmse(models, fed):
    opt = fed.optima[fed.true_labels]
    return float(np.mean(
        np.sum((models - opt) ** 2, 1) / np.maximum(np.sum(opt ** 2, 1), 1e-9)))


def run():
    errs, kcounts, oracle_errs = [], [], []
    us = 0.0
    for n in N_GRID:
        e, kk, oe = [], [], []
        for seed in range(RUNS):
            fed = make_logistic_federation(seed=seed, m=40, K=4, n=n)
            local = np.asarray(batched_logistic_erm(
                jnp.asarray(fed.xs), jnp.asarray(fed.ys), 1e-5, 25))
            lo, hi = lambda_interval(local, fed.true_labels)
            lam = 0.5 * (lo + hi) if lo < hi else lo
            res, us = timed(odcl, local,
                            ODCLConfig(algo="convex", lam=lam,
                                       cc_iters=250), iters=1)
            e.append(nmse(res.user_models, fed))
            kk.append(res.n_clusters)
            oe.append(nmse(oracles.oracle_averaging(local, fed.true_labels),
                           fed))
        errs.append(float(np.mean(e)))
        kcounts.append(float(np.mean(kk)))
        oracle_errs.append(float(np.mean(oe)))

    emit("fig2/odcl_cc_mse", us,
         ";".join(f"n={n}:{v:.2e}" for n, v in zip(N_GRID, errs)))
    emit("fig2/oracle_avg_mse", us,
         ";".join(f"n={n}:{v:.2e}" for n, v in zip(N_GRID, oracle_errs)))
    emit("fig2/n_clusters", us,
         ";".join(f"n={n}:{v:.1f}" for n, v in zip(N_GRID, kcounts)))
    return errs, kcounts


def main():
    run()


if __name__ == "__main__":
    main()
