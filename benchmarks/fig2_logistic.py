"""Figure 2 (Appendix E.2): logistic regression, K=4, d=2 — ODCL-CC MSE
vs n (left panel) and the number of clusters convex clustering produces
(right panel), via the unified ``Method.fit`` interface."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, memoized_solver, timed
from repro.core import ODCL, OracleAveraging, batched_logistic_erm
from repro.core.clustering import lambda_interval
from repro.data import make_logistic_federation

N_GRID = (400, 1600, 4800)
RUNS = 2


def logistic_solver(xs, ys):
    return batched_logistic_erm(jnp.asarray(xs), jnp.asarray(ys), 1e-5, 25)


def run():
    errs, kcounts, oracle_errs = [], [], []
    us = 0.0
    key = jax.random.PRNGKey(0)
    for n in N_GRID:
        e, kk, oe = [], [], []
        for seed in range(RUNS):
            fed = make_logistic_federation(seed=seed, m=40, K=4, n=n)
            solver = memoized_solver(logistic_solver)  # one ERM pass per fed
            local = np.asarray(solver(fed.xs, fed.ys))
            lo, hi = lambda_interval(local, fed.true_labels)
            lam = 0.5 * (lo + hi) if lo < hi else lo
            method = ODCL(algorithm="convex",
                          options=dict(lam=lam, iters=250))
            res, us = timed(method.fit, key, fed.xs, fed.ys,
                            solver, iters=1)
            e.append(res.nmse(fed.optima, fed.true_labels, eps=1e-9))
            kk.append(res.n_clusters)
            oracle = OracleAveraging(true_labels=fed.true_labels).fit(
                key, fed.xs, fed.ys, solver)
            oe.append(oracle.nmse(fed.optima, fed.true_labels, eps=1e-9))
        errs.append(float(np.mean(e)))
        kcounts.append(float(np.mean(kk)))
        oracle_errs.append(float(np.mean(oe)))

    emit("fig2/odcl_cc_mse", us,
         ";".join(f"n={n}:{v:.2e}" for n, v in zip(N_GRID, errs)))
    emit("fig2/oracle_avg_mse", us,
         ";".join(f"n={n}:{v:.2e}" for n, v in zip(N_GRID, oracle_errs)))
    emit("fig2/n_clusters", us,
         ";".join(f"n={n}:{v:.1f}" for n, v in zip(N_GRID, kcounts)))
    return errs, kcounts


def main():
    run()


if __name__ == "__main__":
    main()
