"""Roofline table reader: renders §Roofline rows from the sweep JSONLs
(produced by repro.roofline.run_sweep + repro.launch.dryrun)."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

BASE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(path):
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return rows


def run():
    rows = load(os.path.join(BASE, "roofline_baseline.jsonl"))
    seen = {}
    for r in rows:
        if r.get("status") != "OK":
            continue
        seen[(r["arch"], r["shape"])] = r["roofline"]
    if not seen:
        emit("roofline/missing", 0.0,
             "run: PYTHONPATH=src python -m repro.roofline.run_sweep")
        return
    for (arch, shape), rl in sorted(seen.items()):
        emit(f"roofline/{arch}/{shape}", rl["compute_s"] * 1e6,
             f"mem_s={rl['memory_s']:.3f};coll_s={rl['collective_s']:.3f};"
             f"bottleneck={rl['bottleneck']};useful={rl['useful_flop_ratio']:.2f}")
    # dominant bottleneck histogram
    from collections import Counter

    hist = Counter(v["bottleneck"] for v in seen.values())
    emit("roofline/bottleneck_histogram", 0.0,
         ";".join(f"{k}={v}" for k, v in sorted(hist.items())))


def main():
    run()


if __name__ == "__main__":
    main()
