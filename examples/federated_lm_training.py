"""End-to-end driver: federated ODCL training of a decoder LM.

Clients sample from cluster-specific token distributions; the run does
local training (zero cross-client communication), ONE clustered
aggregation round (Algorithm 1 with parameter sketching), and continued
personalized training.

CPU demo (reduced same-family config):
    PYTHONPATH=src python examples/federated_lm_training.py

Production (full qwen2-0.5b on the 16x16 mesh, a few hundred steps):
    python -m repro.launch.train --arch qwen2-0.5b --clients 16 \
        --clusters 4 --local-steps 300 --batch 16 --seq-len 4096
"""
from repro.launch.train import main

if __name__ == "__main__":
    main([
        "--arch", "qwen2-0.5b",
        "--reduced",
        "--clients", "8",
        "--clusters", "2",
        "--local-steps", "150",
        "--post-steps", "20",
        "--seq-len", "32",
    ])
