"""Reproduce every paper figure/table in one go (CSV on stdout).

    PYTHONPATH=src python examples/paper_experiments.py
"""
from benchmarks import run as bench_run

if __name__ == "__main__":
    bench_run.main()
