"""Batched serving example: prefill + autoregressive generation through
the ring-buffer KV-cache / recurrent-state serving path.

    PYTHONPATH=src python examples/serve_clustered.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    # a dense GQA arch and a fully recurrent arch through the same API
    main(["--arch", "qwen2-0.5b", "--reduced", "--batch", "4",
          "--prompt-len", "32", "--gen", "16"])
    main(["--arch", "xlstm-125m", "--reduced", "--batch", "4",
          "--prompt-len", "32", "--gen", "16", "--temperature", "0.8"])
