"""Quickstart: the complete ODCL-C pipeline on the paper's synthetic
linear-regression federation (Section 5) in a few seconds on CPU,
driven through the unified federated-method API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    GlobalERM,
    LocalOnly,
    ODCL,
    OracleAveraging,
    batched_ridge_erm,
    list_algorithms,
)
from repro.data import make_linear_regression_federation


def ridge_solver(xs, ys):
    """Step 1 (users): every user solves its local ERM in one batched call."""
    return batched_ridge_erm(jnp.asarray(xs), jnp.asarray(ys), 1e-8)


def main():
    # m=100 users in K=10 hidden clusters, n samples each (unknown to us)
    fed = make_linear_regression_federation(seed=0, n=200)
    print(f"federation: m={fed.m} users, K={fed.K} hidden clusters, "
          f"n={fed.n} samples/user, separation D={fed.D:.2f}")
    print(f"admissible clustering registry: {', '.join(list_algorithms())}")

    key = jax.random.PRNGKey(0)

    # ---- ODCL over two registered algorithms (ONE round each) ----------
    for method in (ODCL(algorithm="kmeans++", k=10),
                   ODCL(algorithm="clusterpath",
                        options=dict(n_lambdas=8, iters=200))):
        res = method.fit(key, fed.xs, fed.ys, ridge_solver)
        print(f"{method.name:17s} K'={res.n_clusters:3d} "
              f"rounds={int(res.comm_rounds)} "
              f"nmse={res.nmse(fed.optima, fed.true_labels):.2e}")

    # ---- reference methods through the same interface ------------------
    for method, note in (
        (OracleAveraging(true_labels=fed.true_labels),
         "(knows the true clusters)"),
        (LocalOnly(), ""),
        (GlobalERM(), "(ignores heterogeneity)"),
    ):
        res = method.fit(key, fed.xs, fed.ys, ridge_solver)
        print(f"{method.name:17s} rounds={int(res.comm_rounds)}      "
              f"nmse={res.nmse(fed.optima, fed.true_labels):.2e}   {note}")


if __name__ == "__main__":
    main()
