"""Quickstart: the complete ODCL-C pipeline on the paper's synthetic
linear-regression federation (Section 5) in a few seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import ODCLConfig, batched_ridge_erm, odcl, oracles
from repro.data import make_linear_regression_federation


def nmse(models, fed):
    opt = fed.optima[fed.true_labels]
    return float(np.mean(np.sum((models - opt) ** 2, 1) / np.sum(opt ** 2, 1)))


def main():
    # m=100 users in K=10 hidden clusters, n samples each (unknown to us)
    fed = make_linear_regression_federation(seed=0, n=200)
    print(f"federation: m={fed.m} users, K={fed.K} hidden clusters, "
          f"n={fed.n} samples/user, separation D={fed.D:.2f}")

    # ---- step 1 (users): solve local ERMs, send models up (ONE round) --
    local = np.asarray(batched_ridge_erm(
        jnp.asarray(fed.xs), jnp.asarray(fed.ys), 1e-8))

    # ---- steps 2-4 (server): cluster, average, send back ---------------
    for algo, kwargs in (("kmeans++", {"k": 10}),
                         ("clusterpath", {"n_lambdas": 8, "cc_iters": 200})):
        res = odcl(local, ODCLConfig(algo=algo, **kwargs))
        print(f"ODCL-{algo:11s} K'={res.n_clusters:3d} "
              f"nmse={nmse(res.user_models, fed):.2e}")

    # ---- reference points ----------------------------------------------
    print(f"oracle averaging  nmse={nmse(oracles.oracle_averaging(local, fed.true_labels), fed):.2e}"
          "   (knows the true clusters)")
    print(f"local ERMs        nmse={nmse(oracles.local_erm(local), fed):.2e}")
    print(f"naive averaging   nmse={nmse(oracles.naive_averaging(local), fed):.2e}"
          "   (ignores heterogeneity)")


if __name__ == "__main__":
    main()
