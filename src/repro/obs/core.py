"""Dependency-free telemetry core: spans, counters, gauges, histograms.

The observability spine of the engine (ISSUE 7).  Everything here is
plain stdlib — no jax, no numpy — so the instrumented hot paths
(``core/engine/session.py``, ``core/engine/aggregate.py``,
``core/federated_methods.py``) pay dict-update + ``perf_counter`` cost
and nothing else, and the module is importable from anywhere without
cycles.

  * ``Registry`` — counters (monotonic sums), gauges (last-write
    scalars), histograms (raw-value series with numpy-convention
    percentiles), plus a thread-local span stack for nested timing.
  * ``Registry.span(name)`` — context manager: on exit the duration
    lands in the ``"<name>.ms"`` histogram AND a ``"span"`` event
    (with ``parent``/``depth`` from the nesting stack) goes to every
    attached sink.  The yielded dict carries the measured ``ms`` after
    the block, so callers can reuse the number without re-timing.
  * sinks (``obs/sinks.py``) — anything with ``emit(event: dict)``;
    ``JsonlSink`` appends events as JSON lines, ``ConsoleSink`` prints
    a summary table on close, and ``Registry.snapshot()`` is the dict
    sink the benchmarks embed into their schema-versioned JSON.

A process-global registry backs the module-level convenience functions
(``span`` / ``count`` / ``gauge`` / ``observe`` / ``event`` /
``snapshot`` / ``reset`` / ``add_sink``), which is what the engine
modules call; tests construct private ``Registry`` instances.
"""
from __future__ import annotations

import contextlib
import math
import threading
import time
from typing import Any, Callable, Iterable, Optional


class Histogram:
    """A value series with numpy-default (linear interpolation)
    percentiles — ``percentile(p)`` matches ``numpy.percentile`` on the
    same values, which ``tests/test_obs.py`` pins."""

    __slots__ = ("values",)

    def __init__(self, values: Optional[Iterable[float]] = None):
        self.values: list[float] = list(values or ())

    def observe(self, value: float) -> None:
        self.values.append(float(value))

    def merge(self, other: "Histogram") -> None:
        self.values.extend(other.values)

    @property
    def count(self) -> int:
        return len(self.values)

    def percentile(self, p: float) -> float:
        if not self.values:
            return float("nan")
        vals = sorted(self.values)
        rank = (p / 100.0) * (len(vals) - 1)
        lo = math.floor(rank)
        hi = math.ceil(rank)
        if lo == hi:
            return vals[lo]
        frac = rank - lo
        return vals[lo] * (1.0 - frac) + vals[hi] * frac

    def summary(self) -> dict:
        if not self.values:
            return {"count": 0}
        total = sum(self.values)
        return {
            "count": len(self.values),
            "sum": total,
            "mean": total / len(self.values),
            "min": min(self.values),
            "max": max(self.values),
            "p50": self.percentile(50.0),
            "p95": self.percentile(95.0),
            "p99": self.percentile(99.0),
        }


class Registry:
    """Counters + gauges + histograms + sinks + a span stack.

    Mutations are guarded by a lock (the engine is single-threaded
    today, but sinks/serving loops need not be); the span *stack* is
    thread-local so nesting is per-thread.  ``reset()`` clears the
    aggregates but keeps attached sinks — a driver that attached a
    JSONL trace keeps receiving events across ``simulate()`` runs.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._local = threading.local()
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, Histogram] = {}
        self._sinks: list[Any] = []

    # ----------------------------------------------------------- metrics

    def count(self, name: str, value: float = 1.0) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + float(value)

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            hist = self.histograms.get(name)
            if hist is None:
                hist = self.histograms[name] = Histogram()
            hist.observe(value)

    # ------------------------------------------------------------- spans

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    @contextlib.contextmanager
    def span(self, name: str, **fields: Any):
        """Time a block: duration -> ``"<name>.ms"`` histogram + a
        ``"span"`` event carrying nesting (``parent``/``depth``).  The
        yielded dict gains ``"ms"`` on exit."""
        stack = self._stack()
        info = {"name": name, **fields}
        if stack:
            info["parent"] = stack[-1]
        info["depth"] = len(stack)
        stack.append(name)
        t0 = time.perf_counter()
        try:
            yield info
        finally:
            ms = (time.perf_counter() - t0) * 1e3
            stack.pop()
            info["ms"] = ms
            self.observe(f"{name}.ms", ms)
            self.event("span", **info)

    # ------------------------------------------------------------- sinks

    def add_sink(self, sink: Any) -> Any:
        """Attach anything with ``emit(event: dict)`` (and optionally
        ``close()``).  Returns the sink."""
        with self._lock:
            self._sinks.append(sink)
        return sink

    def remove_sink(self, sink: Any) -> None:
        with self._lock:
            if sink in self._sinks:
                self._sinks.remove(sink)

    def event(self, kind: str, **fields: Any) -> dict:
        """Emit one structured event to every sink. Returns the event."""
        evt = {"event": kind, "ts": time.time(), **fields}
        with self._lock:
            sinks = list(self._sinks)
        for sink in sinks:
            sink.emit(evt)
        return evt

    def close_sinks(self) -> None:
        with self._lock:
            sinks, self._sinks = list(self._sinks), []
        for sink in sinks:
            close = getattr(sink, "close", None)
            if callable(close):
                close()

    # ---------------------------------------------------------- snapshot

    def snapshot(self) -> dict:
        """The dict sink: aggregates only (no raw event stream) — what
        the benchmarks embed per row into their schema-versioned JSON."""
        with self._lock:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {n: h.summary()
                               for n, h in self.histograms.items()},
            }

    def merge(self, other: "Registry") -> None:
        """Fold another registry's aggregates in.  Counter sums and
        histogram value multisets are order-independent under merge
        (the hypothesis property in ``tests/test_obs.py``); gauges are
        last-write-wins by definition."""
        with self._lock:
            for name, v in other.counters.items():
                self.counters[name] = self.counters.get(name, 0.0) + v
            self.gauges.update(other.gauges)
            for name, h in other.histograms.items():
                mine = self.histograms.get(name)
                if mine is None:
                    mine = self.histograms[name] = Histogram()
                mine.merge(h)

    def reset(self) -> None:
        """Drop all aggregates; attached sinks stay attached."""
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.histograms.clear()


# ------------------------------------------------- process-global registry

GLOBAL = Registry()


def span(name: str, **fields: Any):
    return GLOBAL.span(name, **fields)


def count(name: str, value: float = 1.0) -> None:
    GLOBAL.count(name, value)


def gauge(name: str, value: float) -> None:
    GLOBAL.gauge(name, value)


def observe(name: str, value: float) -> None:
    GLOBAL.observe(name, value)


def event(kind: str, **fields: Any) -> dict:
    return GLOBAL.event(kind, **fields)


def add_sink(sink: Any) -> Any:
    return GLOBAL.add_sink(sink)


def remove_sink(sink: Any) -> None:
    GLOBAL.remove_sink(sink)


def snapshot() -> dict:
    return GLOBAL.snapshot()


def reset() -> None:
    GLOBAL.reset()
