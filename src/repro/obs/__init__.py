"""repro.obs — the dependency-free telemetry spine (spans / counters /
gauges / histograms + pluggable sinks).  See ``obs/core.py``."""
from repro.obs.core import (
    GLOBAL,
    Histogram,
    Registry,
    add_sink,
    count,
    event,
    gauge,
    observe,
    remove_sink,
    reset,
    snapshot,
    span,
)
from repro.obs.sinks import ConsoleSink, JsonlSink, ListSink, read_jsonl

__all__ = [
    "GLOBAL",
    "Histogram",
    "Registry",
    "ConsoleSink",
    "JsonlSink",
    "ListSink",
    "add_sink",
    "count",
    "event",
    "gauge",
    "observe",
    "read_jsonl",
    "remove_sink",
    "reset",
    "snapshot",
    "span",
]
