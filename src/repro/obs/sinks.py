"""Pluggable sinks for the telemetry registry.

A sink is anything with ``emit(event: dict)`` and (optionally)
``close()`` — attach with ``obs.add_sink``:

  * ``JsonlSink`` — append every event as one JSON line (the
    ``--trace PATH`` flag of simulate/train/serve); ``read_jsonl``
    parses a trace back.
  * ``ConsoleSink`` — silent during the run, prints the registry's
    aggregate summary table on ``close()``.
  * ``ListSink`` — in-memory capture (tests, ad-hoc inspection).

The third sink shape — the dict snapshot — is not a class: it is
``Registry.snapshot()``, which the benchmarks embed per row.
"""
from __future__ import annotations

import json
import sys
from typing import Any, Optional


def _jsonable(value: Any):
    """Events may carry numpy/jnp scalars; coerce to plain JSON types."""
    try:
        json.dumps(value)
        return value
    except TypeError:
        item = getattr(value, "item", None)
        return item() if callable(item) else repr(value)


class JsonlSink:
    """Append events as JSON lines (line-buffered, crash-tolerant)."""

    def __init__(self, path: str, mode: str = "a"):
        self.path = path
        self._f = open(path, mode, buffering=1)

    def emit(self, event: dict) -> None:
        self._f.write(json.dumps({k: _jsonable(v) for k, v in event.items()})
                      + "\n")

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def read_jsonl(path: str) -> list[dict]:
    """Parse a JSONL trace back into a list of event dicts."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


class ListSink:
    """In-memory event capture."""

    def __init__(self):
        self.events: list[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass


class ConsoleSink:
    """Print an aggregate summary table when closed.

    Reads its registry lazily (default: the process-global one) so the
    table reflects everything recorded up to ``close()``."""

    def __init__(self, registry=None, stream=None):
        self._registry = registry
        self._stream = stream or sys.stderr
        self._events = 0

    def emit(self, event: dict) -> None:
        self._events += 1

    def close(self) -> None:
        from repro.obs import core

        reg = self._registry if self._registry is not None else core.GLOBAL
        snap = reg.snapshot()
        w = self._stream.write
        w(f"[obs] {self._events} events\n")
        for name in sorted(snap["counters"]):
            w(f"[obs] counter {name} = {snap['counters'][name]:g}\n")
        for name in sorted(snap["gauges"]):
            w(f"[obs] gauge   {name} = {snap['gauges'][name]:g}\n")
        for name in sorted(snap["histograms"]):
            h = snap["histograms"][name]
            if not h.get("count"):
                continue
            w(f"[obs] hist    {name}: n={h['count']} p50={h['p50']:.3g} "
              f"p95={h['p95']:.3g} p99={h['p99']:.3g} max={h['max']:.3g}\n")
