"""Pluggable admissible-clustering registry — the set C of ODCL-C.

The paper defines ODCL-C as a *family* of one-shot methods parametrized
by the admissible clustering algorithms C (Definition 2).  This module
makes that set first-class:

  * ``ClusteringAlgorithm`` — the protocol every member of C satisfies:
    a ``name``, a ``__call__(key, points, k=..., **options)`` returning
    a unified ``ClusteringResult``, a ``requires_k`` flag, and the
    Lemma-1/Lemma-2 admissibility margin ``admissibility_alpha(m,
    c_min)`` so the server can report (or assert) separability per
    Definition 1.
  * ``ClusteringResult`` — one result type (labels, centers,
    n_clusters, meta) replacing the ad-hoc per-algorithm tuples.
  * ``register_algorithm`` / ``get_algorithm`` / ``list_algorithms`` —
    the registry.  A newly registered algorithm is immediately usable
    by ``methods.ODCL``, the ``odcl`` entrypoint, the LM-scale
    ``federated.one_shot_aggregate`` path, and every benchmark.

The six paper algorithms (kmeans, kmeans++, spectral, gradient, convex,
clusterpath) are registered at import time below.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.core.engine.aggregators import get_aggregator
from repro.core.engine.device_convex import (
    device_clusterpath,
    device_convex_cluster,
)
from repro.core.engine.device_kmeans import device_kmeans

from repro.core.clustering.admissible import (
    alpha_convex_clustering,
    alpha_kmeans,
    separability_alpha,
)
from repro.core.clustering.convex import (
    clusterpath,
    convex_clustering,
    lambda_interval,
)
from repro.core.clustering.gradient import gradient_clustering
from repro.core.clustering.kmeans import kmeans


@dataclasses.dataclass(frozen=True)
class ClusteringResult:
    """Unified output of every admissible clustering algorithm."""
    labels: np.ndarray        # (m,) int cluster id per point (host)
    centers: np.ndarray       # (K, d) cluster representatives (host)
    n_clusters: int           # number of distinct recovered clusters
    meta: dict                # algorithm-specific diagnostics


def separability_of(points, result: "ClusteringResult") -> float:
    """Achieved margin of condition (4) for ``result`` on ``points``."""
    return separability_alpha(np.asarray(points), result.labels)


@runtime_checkable
class ClusteringAlgorithm(Protocol):
    """Protocol of the admissible set C (server step 2 of Algorithm 1)."""
    name: str
    requires_k: bool

    def __call__(self, key, points, *, k: Optional[int] = None,
                 **options: Any) -> ClusteringResult: ...

    def admissibility_alpha(self, m: int, c_min: int) -> float: ...


class DeviceClusteringResult(NamedTuple):
    """Device-resident clustering output: every field stays a jnp array
    (meta maps names to jnp scalars) so the whole result is a pytree that
    can flow out of a jitted aggregation round without a host copy."""
    labels: jnp.ndarray       # (m,) int32 cluster id per point
    centers: jnp.ndarray      # (k, d) cluster representatives
    meta: dict                # the DEVICE_META_KEYS schema, jnp scalars
    aux: Any = None           # opaque warm-start state beyond the centers
    #                           (the convex family's AMA dual); None for
    #                           families whose centers are the whole state


# the uniform device meta contract: every DeviceClusteringAlgorithm
# reports exactly these keys (jnp scalars inside the jitted round); a
# fixed dict structure keeps every algorithm's result the same pytree
# shape, and downstream consumers (benchmarks, the obs snapshot, the
# session) never branch on which family produced the round
DEVICE_META_KEYS = ("inertia", "n_iter", "restarts", "n_clusters", "lam",
                    "restart_spread")


def device_meta(*, inertia, n_iter, n_clusters, restarts=1, lam=None,
                restart_spread=None) -> dict:
    """Build the uniform device meta dict (``DEVICE_META_KEYS``).

    ``inertia`` is the family's common quality scalar (sum of squared
    distances to the assigned representative — the convex adapters
    compute it from their fusion centers so the key means the same
    thing everywhere); ``n_iter`` the iterations actually run (Lloyd
    steps, AMA fixed-point iterations-to-converge); fields a family has
    no notion of (``lam`` for Lloyd, ``restart_spread`` for the convex
    path) are NaN-valued scalars so the pytree structure stays fixed —
    ``meta_to_host`` turns them back into ``None``.
    """
    nan = jnp.asarray(jnp.nan, jnp.float32)
    return {
        "inertia": jnp.asarray(inertia, jnp.float32),
        "n_iter": jnp.asarray(n_iter, jnp.int32),
        "restarts": jnp.asarray(restarts, jnp.int32),
        "n_clusters": jnp.asarray(n_clusters, jnp.int32),
        "lam": nan if lam is None else jnp.asarray(lam, jnp.float32),
        "restart_spread": (nan if restart_spread is None
                           else jnp.asarray(restart_spread, jnp.float32)),
    }


def meta_to_host(meta: dict) -> dict:
    """Device meta -> host meta: ints for the count-valued keys, floats
    elsewhere, NaN sentinels back to ``None``.  Passes through extra
    (non-schema) keys as floats so plugin algorithms can extend."""
    out = {}
    for name, v in meta.items():
        x = np.asarray(v)
        if name in ("n_iter", "restarts", "n_clusters"):
            out[name] = int(x)
        elif name in ("lam", "restart_spread") and np.isnan(x):
            out[name] = None
        else:
            out[name] = float(x)
    return out


@runtime_checkable
class DeviceClusteringAlgorithm(ClusteringAlgorithm, Protocol):
    """Device-capable variant of the protocol (the aggregation engine).

    ``device_call`` accepts a traced (m, d) jnp array and returns a
    ``DeviceClusteringResult`` — no NumPy boundary, so the engine can
    inline it into the jitted one-shot round
    (``engine.one_shot_aggregate_device``).  Implementations still
    provide the host ``__call__`` so they remain usable by every
    host-path consumer of the registry.
    """

    def device_call(self, key, points, *, k: Optional[int] = None,
                    **options: Any) -> DeviceClusteringResult: ...


def is_device_algorithm(algo) -> bool:
    """True when ``algo`` can run inside the device aggregation engine."""
    return callable(getattr(algo, "device_call", None))


# host Lloyd-family names and the kmeans-device init that reproduces them
LLOYD_DEVICE_INIT = {"kmeans": "random", "kmeans++": "kmeans++",
                     "spectral": "spectral"}


def resolve_device_request(algorithm, options: Optional[dict] = None, *,
                           strict: bool = True):
    """Map an algorithm request onto something the device engine can run.

    Device-capable names and names with a registered ``"-device"`` twin
    pass through unchanged (``one_shot_aggregate`` / the session upgrade
    twins themselves); the host Lloyd-family names map onto
    ``kmeans-device`` with the matching ``init`` option — the legacy
    ``launch/train.py`` behaviour, now shared by ``ODCLFederated``, the
    ``AggregationSession``, and ``launch/simulate.py``.  Returns
    ``(algorithm, options)``.  Unmappable host-only names raise when
    ``strict`` (engine='device') and pass through when not (engine=
    'auto', where the caller falls back to the host path).
    """
    algo = get_algorithm(algorithm)
    if is_device_algorithm(algo):
        return algorithm, options
    name = getattr(algo, "name", algorithm)
    # the Lloyd mapping outranks the twin passthrough: "kmeans" has a
    # registered "kmeans-device" twin, but letting the twin upgrade it
    # would silently swap its random init for the twin's kmeans++
    # default — the explicit init mapping is what reproduces the host
    # algorithm
    if name in LLOYD_DEVICE_INIT:
        return "kmeans-device", {"init": LLOYD_DEVICE_INIT[name],
                                 **(options or {})}
    if device_twin(algo) is not None:
        return algorithm, options
    if strict:
        raise ValueError(
            f"engine='device' needs a device-capable algorithm "
            f"(e.g. kmeans-device), a Lloyd-family name, or a name with "
            f"a registered '-device' twin, not {name!r}")
    return algorithm, options


def resolve_host_request(algorithm, options: Optional[dict] = None):
    """Map an algorithm request onto the host clustering path.

    The mirror of ``resolve_device_request``: host names pass through
    unchanged, while explicit ``"<name>-device"`` requests downgrade to
    the host member of the same family — ``kmeans-device`` maps back
    through the inverse of ``LLOYD_DEVICE_INIT`` (its ``init`` option
    selects which host Lloyd name it reproduces), and other device
    names fall back to their registered ``"<name>"`` base.  Twin-less
    device names (and device-only options like ``init='warm'``) raise
    ``ValueError`` instead of silently running a device loop under
    ``engine='host'``.  Returns ``(algorithm, options)``.
    """
    algo = get_algorithm(algorithm)
    name = getattr(algo, "name", algorithm)
    if not (isinstance(name, str) and name.endswith("-device")):
        return algorithm, options
    opts = dict(options or {})
    if name == "kmeans-device":
        init = opts.pop("init", "kmeans++")
        host = {v: k for k, v in LLOYD_DEVICE_INIT.items()}.get(init)
        if host is None:
            raise ValueError(
                f"engine='host' cannot run kmeans-device init={init!r}; "
                f"host Lloyd inits: {sorted(LLOYD_DEVICE_INIT.values())}")
        return host, (opts or None)
    base = name[: -len("-device")]
    if base in _REGISTRY:
        return base, options
    raise ValueError(
        f"engine='host' cannot run device-only algorithm {name!r}: no "
        f"registered host base {base!r}")


def device_twin(algo) -> Optional["DeviceClusteringAlgorithm"]:
    """The registered ``"<name>-device"`` twin of a host algorithm.

    The engine auto-dispatch (``federated.one_shot_aggregate``) upgrades
    host-only names whose twin exists — ``"convex"`` runs as
    ``"convex-device"`` under ``engine='auto'|'device'`` — while names
    without a twin keep their host path.  Returns ``None`` when ``algo``
    has no device-capable twin.
    """
    name = getattr(algo, "name", None)
    if not isinstance(name, str) or name.endswith("-device"):
        return None
    twin = _REGISTRY.get(f"{name}-device")
    return twin if twin is not None and is_device_algorithm(twin) else None


# --------------------------------------------------------------- adapters

def _as_result(labels, centers, meta) -> ClusteringResult:
    # compact label ids: Lloyd's can leave empty clusters, whose skipped
    # ids would otherwise inflate n_clusters and NaN downstream averages
    uniq, labels = np.unique(np.asarray(labels), return_inverse=True)
    centers = np.asarray(centers)
    if centers.shape[0] > len(uniq):
        centers = centers[uniq]
    return ClusteringResult(
        labels=labels.astype(np.int32),
        centers=centers,
        n_clusters=len(uniq),
        meta=dict(meta),
    )


@dataclasses.dataclass(frozen=True)
class LloydFamily:
    """kmeans / kmeans++ / spectral — Lloyd's algorithm, varying init.

    Admissible per Lemma 2 (ODCL-KM): alpha = 2 + 2 c sqrt(m) / |C_(K)|.
    """
    name: str
    init: str
    requires_k: bool = True

    def __call__(self, key, points, *, k: Optional[int] = None,
                 iters: int = 100, **_: Any) -> ClusteringResult:
        if k is None:
            raise ValueError(f"{self.name!r} requires k")
        res = kmeans(key, jnp.asarray(points, jnp.float32), k,
                     iters=iters, init=self.init)
        return _as_result(res.labels, res.centers,
                          {"inertia": float(res.inertia),
                           "n_iter": int(res.n_iter)})

    def admissibility_alpha(self, m: int, c_min: int) -> float:
        return alpha_kmeans(m, c_min)


@dataclasses.dataclass(frozen=True)
class DeviceLloydFamily:
    """Device-resident Lloyd loop (``engine.device_kmeans``) — the
    aggregation engine's member of the admissible set.

    Same admissibility as the host Lloyd family (Lemma 2: K-means-type
    objective, init-agnostic bound); the init is an option rather than a
    separate registry entry (``init='kmeans++' | 'spectral' | 'random'``).
    ``restarts`` keeps the best-inertia clustering of that many vmapped
    inits; ``batch_m`` switches to minibatch Lloyd updates (values >= m
    reduce to full Lloyd bit-exactly).
    """
    name: str = "kmeans-device"
    requires_k: bool = True

    @staticmethod
    def _resolve_aggregator(aggregator):
        """None / 'mean' keep the fused-kernel accumulator path (the
        bit-exact host-parity update); anything else resolves through
        the aggregator registry to a robust center update."""
        if aggregator is None:
            return None
        agg = get_aggregator(aggregator)
        return None if agg.name == "mean" else agg

    def device_call(self, key, points, *, k: Optional[int] = None,
                    iters: int = 100, init: str = "kmeans++",
                    restarts: int = 1, batch_m: Optional[int] = None,
                    aggregator=None, init_centers=None,
                    **_: Any) -> DeviceClusteringResult:
        if k is None:
            raise ValueError(f"{self.name!r} requires k")
        res = device_kmeans(key, points, k, iters=iters, init=init,
                            restarts=restarts, batch_m=batch_m,
                            aggregator=self._resolve_aggregator(aggregator),
                            init_centers=init_centers)
        # report the EFFECTIVE restart count: full-batch spectral seeding
        # and warm starts are deterministic, so device_kmeans collapses
        # their restarts to 1
        full_batch = batch_m is None or batch_m >= points.shape[0]
        eff_restarts = (1 if (init in ("spectral", "warm") and full_batch)
                        else restarts)
        return DeviceClusteringResult(
            labels=res.labels, centers=res.centers,
            meta=device_meta(
                inertia=res.inertia, n_iter=res.n_iter,
                restarts=eff_restarts,
                n_clusters=jnp.sum(
                    jnp.bincount(res.labels, length=k) > 0),
                restart_spread=res.restart_spread))

    # ---- warm-start protocol (session incremental re-finalize) ----
    # ``warm_state(res)`` extracts what to carry across rounds;
    # ``device_warm_call(key, points, warm, ...)`` replays the family
    # from that state.  The Lloyd state is just the centers, and a warm
    # start is valid for any point count (assignment re-derives).
    warm_requires_same_count = False

    def warm_state(self, res: DeviceClusteringResult):
        return res.centers

    def device_warm_call(self, key, points, warm, *,
                         k: Optional[int] = None,
                         **options: Any) -> DeviceClusteringResult:
        options = {**options, "init": "warm", "restarts": 1}
        return self.device_call(key, points, k=k, init_centers=warm,
                                **options)

    def __call__(self, key, points, *, k: Optional[int] = None,
                 iters: int = 100, init: str = "kmeans++",
                 restarts: int = 1, batch_m: Optional[int] = None,
                 aggregator=None, **_: Any) -> ClusteringResult:
        res = self.device_call(key, jnp.asarray(points, jnp.float32), k=k,
                               iters=iters, init=init, restarts=restarts,
                               batch_m=batch_m, aggregator=aggregator)
        return _as_result(res.labels, res.centers, meta_to_host(res.meta))

    def admissibility_alpha(self, m: int, c_min: int) -> float:
        return alpha_kmeans(m, c_min)


def _device_convex_result(points, res) -> DeviceClusteringResult:
    # inertia against the fusion centers puts the convex family on the
    # same quality scalar as the Lloyd family (centers are root-indexed
    # (m, d), so the label gather works directly); n_iter is the AMA
    # fixed point's iterations-to-converge (the early-exit while_loop
    # count, not the iters budget)
    inertia = jnp.sum((points - res.centers[res.labels]) ** 2)
    return DeviceClusteringResult(
        labels=res.labels, centers=res.centers,
        meta=device_meta(inertia=inertia, n_iter=res.n_iter,
                         n_clusters=res.n_clusters, lam=res.lam),
        aux=res.nu)


@dataclasses.dataclass(frozen=True)
class DeviceConvexClustering:
    """Device twin of ``"convex"`` (``engine.device_convex``): the AMA
    fixed point, fusion-graph component extraction, and cluster means
    all stay jnp — the engine inlines it into the jitted one-shot round.
    The fusion graph is a registered ``EdgeSet`` (``engine/edges.py``):
    ``edges='complete'`` (paper default, host bit-parity) or
    ``edges='knn'`` with ``knn_k`` neighbours (the sparse graph that
    scales past the complete graph's C=4k edge wall).  Lemma 1
    admissibility is the host family's (same objective)."""
    name: str = "convex-device"
    requires_k: bool = False

    def device_call(self, key, points, *, k: Optional[int] = None,
                    lam: Optional[float] = None, iters: int = 400,
                    weights=None, merge_tol=None, edges: str = "complete",
                    knn_k: int = 8, warm_nu=None,
                    **_: Any) -> DeviceClusteringResult:
        del k
        return _device_convex_result(points, device_convex_cluster(
            key, points, lam=lam, iters=iters, weights=weights,
            merge_tol=merge_tol, edges=edges, knn_k=knn_k,
            warm_nu=warm_nu))

    # ---- warm-start protocol (session incremental re-finalize) ----
    # the convex warm state is the AMA dual, one (d,) row per fusion
    # edge — only valid when the point count (hence the edge set's
    # slot layout) is unchanged, so the session falls back to a cold
    # solve after churn changes the live-row count
    warm_requires_same_count = True

    def warm_state(self, res: DeviceClusteringResult):
        return res.aux

    def device_warm_call(self, key, points, warm, *,
                         k: Optional[int] = None,
                         **options: Any) -> DeviceClusteringResult:
        return self.device_call(key, points, k=k, warm_nu=warm, **options)

    def __call__(self, key, points, *, k: Optional[int] = None,
                 lam: Optional[float] = None, iters: int = 400,
                 weights=None, merge_tol=None, edges: str = "complete",
                 knn_k: int = 8, **_: Any) -> ClusteringResult:
        res = self.device_call(key, jnp.asarray(points, jnp.float32), k=k,
                               lam=lam, iters=iters, weights=weights,
                               merge_tol=merge_tol, edges=edges, knn_k=knn_k)
        return _as_result(res.labels, res.centers, meta_to_host(res.meta))

    def admissibility_alpha(self, m: int, c_min: int) -> float:
        return alpha_convex_clustering(m, c_min)


@dataclasses.dataclass(frozen=True)
class DeviceClusterpath:
    """Device twin of ``"clusterpath"``: the lambda ladder advances as
    one batched AMA solve (the batched group-prox kernel) and the
    plurality plateau selects the clustering — K-free, on device.
    ``edges``/``knn_k`` select the registered fusion graph, as in
    ``"convex-device"``."""
    name: str = "clusterpath-device"
    requires_k: bool = False

    def device_call(self, key, points, *, k: Optional[int] = None,
                    n_lambdas: int = 10, iters: int = 300,
                    merge_tol=None, edges: str = "complete",
                    knn_k: int = 8, **_: Any) -> DeviceClusteringResult:
        del k
        return _device_convex_result(points, device_clusterpath(
            key, points, n_lambdas=n_lambdas, iters=iters,
            merge_tol=merge_tol, edges=edges, knn_k=knn_k))

    def __call__(self, key, points, *, k: Optional[int] = None,
                 n_lambdas: int = 10, iters: int = 300,
                 merge_tol=None, edges: str = "complete",
                 knn_k: int = 8, **_: Any) -> ClusteringResult:
        res = self.device_call(key, jnp.asarray(points, jnp.float32), k=k,
                               n_lambdas=n_lambdas, iters=iters,
                               merge_tol=merge_tol, edges=edges,
                               knn_k=knn_k)
        return _as_result(res.labels, res.centers, meta_to_host(res.meta))

    def admissibility_alpha(self, m: int, c_min: int) -> float:
        return alpha_convex_clustering(m, c_min)


@dataclasses.dataclass(frozen=True)
class GradientClustering:
    """Gradient clustering [21] — K-means-type, so Lemma 2 applies."""
    name: str = "gradient"
    requires_k: bool = True

    def __call__(self, key, points, *, k: Optional[int] = None,
                 iters: int = 100, alpha: float = 0.5,
                 **_: Any) -> ClusteringResult:
        if k is None:
            raise ValueError("gradient clustering requires k")
        res = gradient_clustering(key, jnp.asarray(points, jnp.float32), k,
                                  alpha=alpha, iters=iters)
        return _as_result(res.labels, res.centers,
                          {"inertia": float(res.inertia)})

    def admissibility_alpha(self, m: int, c_min: int) -> float:
        return alpha_kmeans(m, c_min)


@dataclasses.dataclass(frozen=True)
class DeviceGradientClustering:
    """Device twin of ``"gradient"`` — the damped center update loop is
    already all-jnp (``clustering/gradient.py`` scans the fused assign),
    so the twin just exposes it through ``device_call``.  It was the
    last host-only family: with it registered, ``engine='auto'`` covers
    the whole admissible registry on device."""
    name: str = "gradient-device"
    requires_k: bool = True

    def device_call(self, key, points, *, k: Optional[int] = None,
                    iters: int = 100, alpha: float = 0.5,
                    **_: Any) -> DeviceClusteringResult:
        if k is None:
            raise ValueError("gradient clustering requires k")
        res = gradient_clustering(key, points.astype(jnp.float32), k,
                                  alpha=alpha, iters=iters)
        return DeviceClusteringResult(
            labels=res.labels, centers=res.centers,
            meta=device_meta(
                inertia=res.inertia, n_iter=res.n_iter,
                n_clusters=jnp.sum(
                    jnp.bincount(res.labels, length=k) > 0)))

    def __call__(self, key, points, *, k: Optional[int] = None,
                 iters: int = 100, alpha: float = 0.5,
                 **_: Any) -> ClusteringResult:
        res = self.device_call(key, jnp.asarray(points, jnp.float32), k=k,
                               iters=iters, alpha=alpha)
        return _as_result(res.labels, res.centers, meta_to_host(res.meta))

    def admissibility_alpha(self, m: int, c_min: int) -> float:
        return alpha_kmeans(m, c_min)


@dataclasses.dataclass(frozen=True)
class ConvexClustering:
    """Sum-of-norms clustering at a fixed lambda (ODCL-CC, Lemma 1)."""
    name: str = "convex"
    requires_k: bool = False

    def __call__(self, key, points, *, k: Optional[int] = None,
                 lam: Optional[float] = None, iters: int = 400,
                 weights=None, **_: Any) -> ClusteringResult:
        pts = jnp.asarray(points, jnp.float32)
        if lam is None:
            # paper E.1 heuristic: take the upper recovery bound of the
            # all-singletons clustering as a starting penalty
            lo, hi = lambda_interval(np.asarray(pts),
                                     np.arange(pts.shape[0]))
            lam = hi if np.isfinite(hi) else lo + 1e-3
        res = convex_clustering(pts, float(lam), iters=iters,
                                weights=weights)
        return _as_result(res.labels, res.centers,
                          {"lam": res.lam, "n_clusters": res.n_clusters})

    def admissibility_alpha(self, m: int, c_min: int) -> float:
        return alpha_convex_clustering(m, c_min)


@dataclasses.dataclass(frozen=True)
class Clusterpath:
    """Lambda-sweep convex clustering (Appendix B.3/E.3) — no k needed."""
    name: str = "clusterpath"
    requires_k: bool = False

    def __call__(self, key, points, *, k: Optional[int] = None,
                 n_lambdas: int = 10, iters: int = 400,
                 **_: Any) -> ClusteringResult:
        best, _ = clusterpath(jnp.asarray(points, jnp.float32),
                              n_lambdas=n_lambdas, iters=iters)
        return _as_result(best.labels, best.centers,
                          {"lam": best.lam, "n_clusters": best.n_clusters})

    def admissibility_alpha(self, m: int, c_min: int) -> float:
        return alpha_convex_clustering(m, c_min)


# --------------------------------------------------------------- registry

_REGISTRY: dict[str, ClusteringAlgorithm] = {}


def register_algorithm(algo: ClusteringAlgorithm, *,
                       name: Optional[str] = None,
                       overwrite: bool = False) -> ClusteringAlgorithm:
    """Add an algorithm to the admissible set C. Returns it (decorator-safe)."""
    key = name if name is not None else algo.name
    if not key:
        raise ValueError("clustering algorithm needs a non-empty name")
    if key in _REGISTRY and not overwrite:
        raise ValueError(f"clustering algorithm {key!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[key] = algo
    return algo


def unregister_algorithm(name: str) -> None:
    """Remove a registered algorithm (used by tests/plugins)."""
    _REGISTRY.pop(name, None)


def get_algorithm(name) -> ClusteringAlgorithm:
    """Resolve a name (or pass through an instance) to an algorithm."""
    if not isinstance(name, str):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown clustering algorithm {name!r}; "
            f"registered: {sorted(_REGISTRY)}") from None


def list_algorithms() -> tuple[str, ...]:
    """Names of every registered admissible clustering algorithm."""
    return tuple(sorted(_REGISTRY))


for _algo in (
    LloydFamily(name="kmeans", init="random"),
    LloydFamily(name="kmeans++", init="kmeans++"),
    LloydFamily(name="spectral", init="spectral"),
    DeviceLloydFamily(),
    GradientClustering(),
    DeviceGradientClustering(),
    ConvexClustering(),
    Clusterpath(),
    DeviceConvexClustering(),
    DeviceClusterpath(),
):
    register_algorithm(_algo)
del _algo
