"""Convex clustering (sum-of-norms clustering) — the ODCL-CC server step.

Solves the paper's problem (16):

    min_U  1/2 sum_i ||a_i - u_i||^2  +  lambda * sum_{i<j} w_ij ||u_i - u_j||

TPU adaptation (DESIGN.md §3): the paper uses CVXPY; we use the AMA
(alternating minimization algorithm) splitting of Chi & Lange (2015),
whose entire iteration is dense linear algebra + a row-wise ball
projection (the ``group_prox`` Pallas kernel) and therefore runs as a
fixed-length ``jax.lax.scan`` on device.

AMA for uniform weights over the complete graph, edges l=(i,j), i<j,
dual variables nu_l in R^d constrained to ||nu_l|| <= lambda * w_l:

    u_i      = a_i + sum_{l: i=head(l)} nu_l - sum_{l: i=tail(l)} nu_l
    nu_l    <- Proj_{||.|| <= lambda w_l} ( nu_l - eta (u_head - u_tail) )

with step eta <= 1/m for the complete graph (rho(A A^T) = m).

Cluster extraction (u_i == u_j up to tol) is a connected-components pass
done host-side with numpy union-find: it is O(m^2) on tiny data (m =
number of clients) and only runs once per one-shot aggregation.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


class ConvexClusteringResult(NamedTuple):
    labels: np.ndarray        # (m,) int cluster ids (host)
    centers: np.ndarray       # (K', d) cluster centroids of the u's
    u: jnp.ndarray            # (m, d) final fused representatives
    n_clusters: int
    lam: float


def _edges(m: int):
    iu, ju = np.triu_indices(m, k=1)
    return jnp.asarray(iu, jnp.int32), jnp.asarray(ju, jnp.int32)


@functools.partial(jax.jit, static_argnames=("iters",))
def _ama_solve(a, lam, weights, iters: int = 300):
    """Run AMA; returns final u (m,d) and duals (E,d)."""
    a = a.astype(jnp.float32)
    m, d = a.shape
    i_idx, j_idx = _edges(m)
    e = i_idx.shape[0]
    nu = jnp.zeros((e, d), jnp.float32)
    eta = 1.0 / m
    radius = lam * weights  # (e,) per-edge ball radius

    def u_of(nu):
        # u_i = a_i + sum_out nu - sum_in nu  (scatter-adds)
        delta = jnp.zeros_like(a)
        delta = delta.at[i_idx].add(nu)
        delta = delta.at[j_idx].add(-nu)
        return a + delta

    def body(nu, _):
        u = u_of(nu)
        grad = u[i_idx] - u[j_idx]                     # (e, d)
        nu = kops.group_ball_proj(nu - eta * grad, radius)
        return nu, None

    nu, _ = jax.lax.scan(body, nu, None, length=iters)
    return u_of(nu), nu


def _connected_components(adj: np.ndarray) -> np.ndarray:
    """Union-find over a boolean adjacency matrix -> labels (m,)."""
    m = adj.shape[0]
    parent = np.arange(m)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    ii, jj = np.nonzero(np.triu(adj, k=1))
    for x, y in zip(ii, jj):
        rx, ry = find(x), find(y)
        if rx != ry:
            parent[ry] = rx
    roots = np.array([find(x) for x in range(m)])
    _, labels = np.unique(roots, return_inverse=True)
    return labels.astype(np.int32)


def convex_clustering(points, lam: float, *, iters: int = 300,
                      weights=None, merge_tol: float = None) -> ConvexClusteringResult:
    """Solve (16) and extract the induced clustering.

    Args:
      points: (m, d) — for ODCL-CC these are the client model vectors.
      lam: the fusion penalty.
      iters: AMA iterations (fixed-length scan).
      weights: optional (E,) edge weights (uniform = 1, the paper's choice).
      merge_tol: fuse u_i, u_j into one cluster when ||u_i-u_j|| <= tol.
        Defaults to a scale-aware tolerance based on the data diameter.
    """
    points = jnp.asarray(points)
    m, d = points.shape
    e = m * (m - 1) // 2
    w = jnp.ones((e,), jnp.float32) if weights is None else jnp.asarray(weights, jnp.float32)
    u, _ = _ama_solve(points, jnp.float32(lam), w, iters=iters)
    u_np = np.asarray(u)
    if merge_tol is None:
        diam = float(np.max(np.linalg.norm(
            u_np - u_np.mean(0, keepdims=True), axis=1))) + 1e-12
        merge_tol = max(1e-6, 1e-3 * diam)
    d2 = np.asarray(kops.pairwise_sqdist(u, u))
    adj = d2 <= merge_tol ** 2
    labels = _connected_components(adj)
    n_clusters = int(labels.max()) + 1
    centers = np.stack([u_np[labels == c].mean(axis=0) for c in range(n_clusters)])
    return ConvexClusteringResult(labels=labels, centers=centers, u=u,
                                  n_clusters=n_clusters, lam=float(lam))


def knn_weights(points, k: int = 5, phi: float = 0.5) -> jnp.ndarray:
    """Gaussian kNN edge weights for weighted convex clustering (Remark 13).

    w_ij = exp(-phi ||a_i - a_j||^2) if j in kNN(i) or i in kNN(j) else 0.
    Returned in the same (E,) upper-triangular edge order used by the
    AMA solver.  Sparse weights shrink the effective edge set and are the
    practically recommended variant of [27]; recovery guarantees need
    cross-cluster weights nonzero, which kNN cannot promise a priori —
    hence uniform weights stay the default (paper's choice).
    """
    points = jnp.asarray(points, jnp.float32)
    m = points.shape[0]
    from repro.kernels import ops as _kops

    d2 = np.array(_kops.pairwise_sqdist(points, points))
    np.fill_diagonal(d2, np.inf)
    knn_idx = np.argsort(d2, axis=1)[:, :k]
    mask = np.zeros((m, m), bool)
    rows = np.repeat(np.arange(m), k)
    mask[rows, knn_idx.ravel()] = True
    mask |= mask.T
    iu, ju = np.triu_indices(m, k=1)
    w = np.where(mask[iu, ju], np.exp(-phi * d2[iu, ju]), 0.0)
    return jnp.asarray(w, jnp.float32)


def lambda_interval(points, labels) -> tuple[float, float]:
    """Recovery interval (17) for a *candidate* clustering.

    [ max_k diam(V_k)/|V_k| ,  min_{k!=l} ||c_k - c_l|| / (2n - |V_k| - |V_l|) )

    Returns (lo, hi); the interval is non-empty iff lo < hi.
    """
    points = np.asarray(points, np.float64)
    labels = np.asarray(labels)
    n = points.shape[0]
    ks = np.unique(labels)
    lo = 0.0
    cents, sizes = [], []
    for k in ks:
        pk = points[labels == k]
        sizes.append(len(pk))
        cents.append(pk.mean(axis=0))
        if len(pk) > 1:
            # chunked max pairwise distance: the (n_k, n_k, d) difference
            # block is ~0.5GB per cluster at C=16k — stream row chunks
            d2max = 0.0
            for s in range(0, len(pk), 256):
                blk = pk[s:s + 256]
                d2 = ((blk[:, None] - pk[None, :]) ** 2).sum(-1)
                d2max = max(d2max, float(d2.max()))
            diam = float(np.sqrt(d2max))
        else:
            diam = 0.0
        lo = max(lo, diam / len(pk))
    hi = np.inf
    for a in range(len(ks)):
        for b in range(a + 1, len(ks)):
            dist = float(np.linalg.norm(cents[a] - cents[b]))
            hi = min(hi, dist / (2 * n - sizes[a] - sizes[b]))
    if len(ks) == 1:
        hi = np.inf
    return lo, hi


def clusterpath(points, *, n_lambdas: int = 10, iters: int = 300,
                grow: float = 1.25, lam_init: float = 0.1,
                max_probe: int = 60):
    """The Appendix B.3 / E.3 clusterpath heuristic for choosing lambda.

    Probes lambda until K_{lam_1} = m (all singletons) and K_{lam_N} = 1
    (single cluster), sweeps ``n_lambdas`` equidistant values in between,
    and picks the clustering per rule (a)/(b): prefer the K' that is
    (i) produced by a lambda verifying the recovery interval (17) if any
    such lambda exists, and (ii) recovered by the largest number of
    lambdas.
    """
    points = jnp.asarray(points)
    m = points.shape[0]

    def n_clusters(lam):
        return convex_clustering(points, lam, iters=iters)

    lam_lo = lam_hi = lam_init
    r = n_clusters(lam_lo)
    probes = 0
    while r.n_clusters < m and probes < max_probe:
        lam_lo /= grow
        r = n_clusters(lam_lo)
        probes += 1
    r = n_clusters(lam_hi)
    while r.n_clusters > 1 and probes < max_probe:
        lam_hi *= grow
        r = n_clusters(lam_hi)
        probes += 1

    lams = np.linspace(lam_lo, lam_hi, n_lambdas)
    results, verified = [], []
    for lam in lams:
        res = n_clusters(float(lam))
        lo, hi = lambda_interval(np.asarray(points), res.labels)
        results.append(res)
        verified.append(lo <= lam < hi)

    # Selection (robustified variant of the paper's rule (a)/(b), see
    # DESIGN.md §7): the PLURALITY K' along the path is primary — the
    # stable plateau of lambdas recovering the same clustering is the
    # strongest signal of the true structure; the recovery-interval
    # verification (17) is the tie-break.  (The literal paper rule lets a
    # single verified *coarsening* outvote a 3x-wider unverified plateau
    # of the true clustering, because (17) is only sufficient.)
    counts: dict[int, int] = {}
    for res in results:
        counts[res.n_clusters] = counts.get(res.n_clusters, 0) + 1
    best = max(
        zip(results, verified),
        key=lambda rv: (counts[rv[0].n_clusters], rv[1], rv[0].n_clusters > 1),
    )[0]
    return best, results
