"""Gradient clustering [Armacki et al., ICML 2022] — third admissible algo.

Alternates nearest-center assignment with a *gradient* step on the
quantization objective (instead of the exact mean update of Lloyd's):

    x_k <- x_k - alpha * sum_{i in C_k} (x_k - a_i)

which for alpha = 1/|C_k| reduces to Lloyd's. Smaller alpha gives the
damped variant analysed in the paper's reference [21].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.clustering.kmeans import KMeansResult, kmeans_plus_plus_init, _assign
from repro.kernels import ops as kops


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def gradient_clustering(key, points, k: int, *, alpha: float = 0.5,
                        iters: int = 100) -> KMeansResult:
    points = points.astype(jnp.float32)
    centers0 = kmeans_plus_plus_init(key, points, k)

    def body(centers, _):
        labels, _ = _assign(points, centers)
        onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32)
        counts = jnp.sum(onehot, axis=0)                     # (k,)
        sums = onehot.T @ points                             # (k, d)
        # grad of 1/2 sum_i ||x_{c(i)} - a_i||^2 wrt x_k:
        grad = counts[:, None] * centers - sums
        step = alpha / jnp.maximum(counts, 1.0)[:, None]
        return centers - step * grad, None

    centers, _ = jax.lax.scan(body, centers0, None, length=iters)
    labels, mind = _assign(points, centers)
    return KMeansResult(labels=labels, centers=centers,
                        inertia=jnp.sum(mind), n_iter=jnp.array(iters, jnp.int32))
