"""K-means clustering (Lloyd's algorithm) with K-means++ and spectral init.

This is the server-side clustering step of ODCL-KM / ODCL-KM++ (paper
Section 3 and Appendix B.2.2).  Everything is pure JAX and jittable with
static ``k`` / ``iters`` so it can run inside the one-shot aggregation
step on-device.

The pairwise-distance hot spot is delegated to ``repro.kernels.ops``
(Pallas kernel on TPU, jnp oracle elsewhere).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


class KMeansResult(NamedTuple):
    labels: jnp.ndarray     # (m,) int32 cluster assignment
    centers: jnp.ndarray    # (k, d) cluster centers
    inertia: jnp.ndarray    # () sum of squared distances to assigned center
    n_iter: jnp.ndarray     # () iterations actually run


def _assign(points, centers):
    """Nearest-center assignment via the pairwise-distance kernel."""
    d2 = kops.pairwise_sqdist(points, centers)      # (m, k)
    labels = jnp.argmin(d2, axis=1).astype(jnp.int32)
    mind = jnp.min(d2, axis=1)
    return labels, mind


def _update_centers(points, labels, k, prev_centers):
    """Mean of assigned points; empty clusters keep their previous center."""
    onehot = jax.nn.one_hot(labels, k, dtype=points.dtype)      # (m, k)
    counts = jnp.sum(onehot, axis=0)                            # (k,)
    sums = onehot.T @ points                                    # (k, d)
    safe = jnp.maximum(counts, 1.0)[:, None]
    means = sums / safe
    return jnp.where(counts[:, None] > 0, means, prev_centers), counts


def kmeans_plus_plus_init(key, points, k: int):
    """K-means++ seeding [Arthur & Vassilvitskii 2007] (ODCL-KM++)."""
    m, d = points.shape
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, m)
    centers0 = jnp.zeros((k, d), points.dtype).at[0].set(points[first])

    def body(i, carry):
        centers, key = carry
        key, sub = jax.random.split(key)
        d2 = kops.pairwise_sqdist(points, centers)              # (m, k)
        # only the first i centers are valid
        valid = jnp.arange(k) < i
        d2 = jnp.where(valid[None, :], d2, jnp.inf)
        mind = jnp.min(d2, axis=1)
        probs = mind / jnp.maximum(jnp.sum(mind), 1e-30)
        nxt = jax.random.categorical(sub, jnp.log(probs + 1e-30))
        centers = centers.at[i].set(points[nxt])
        return centers, key

    centers, _ = jax.lax.fori_loop(1, k, body, (centers0, key))
    return centers


def spectral_init(points, k: int):
    """SVD-space initialization (Awasthi–Sheffet style, Appendix B.2.2).

    Project points onto the top-k right singular subspace and run a greedy
    farthest-point seeding there; return seeds in the original space.
    """
    m, d = points.shape
    mu = jnp.mean(points, axis=0, keepdims=True)
    x = points - mu
    # economical SVD of the (m, d) matrix
    _, _, vt = jnp.linalg.svd(x, full_matrices=False)
    proj = x @ vt[:k].T                                        # (m, k)
    # farthest-point traversal in the projected space
    start = jnp.argmax(jnp.sum(proj * proj, axis=1))
    idxs = jnp.zeros((k,), jnp.int32).at[0].set(start.astype(jnp.int32))

    def body(i, idxs):
        chosen = proj[idxs]                                    # (k, k)
        d2 = kops.pairwise_sqdist(proj, chosen)
        valid = jnp.arange(k) < i
        d2 = jnp.where(valid[None, :], d2, jnp.inf)
        mind = jnp.min(d2, axis=1)
        return idxs.at[i].set(jnp.argmax(mind).astype(jnp.int32))

    idxs = jax.lax.fori_loop(1, k, body, idxs)
    return points[idxs]


@functools.partial(jax.jit, static_argnames=("k", "iters", "init"))
def kmeans(key, points, k: int, iters: int = 50, init: str = "kmeans++", tol: float = 1e-8):
    """Lloyd's algorithm.

    Args:
      key: PRNG key (used by the ++ init).
      points: (m, d) data — for ODCL these are local model (sketch) vectors.
      k: number of clusters (static).
      iters: max Lloyd iterations (static; fixed-shape loop with early
        freeze once centers stop moving, so it is jittable).
      init: 'kmeans++' | 'spectral' | 'random'.
    """
    points = points.astype(jnp.float32)
    m, d = points.shape
    if init == "kmeans++":
        centers = kmeans_plus_plus_init(key, points, k)
    elif init == "spectral":
        centers = spectral_init(points, k)
    elif init == "random":
        sel = jax.random.choice(key, m, (k,), replace=False)
        centers = points[sel]
    else:  # pragma: no cover - guarded by static arg
        raise ValueError(f"unknown init {init!r}")

    def body(carry, _):
        centers, done, it = carry
        labels, _ = _assign(points, centers)
        new_centers, _ = _update_centers(points, labels, k, centers)
        moved = jnp.max(jnp.sum((new_centers - centers) ** 2, axis=1))
        new_done = done | (moved < tol)
        centers = jnp.where(done, centers, new_centers)
        return (centers, new_done, it + jnp.where(done, 0, 1)), None

    (centers, _, n_iter), _ = jax.lax.scan(
        body, (centers, jnp.array(False), jnp.array(0, jnp.int32)), None, length=iters
    )
    labels, mind = _assign(points, centers)
    return KMeansResult(labels=labels, centers=centers, inertia=jnp.sum(mind), n_iter=n_iter)
