from repro.core.clustering.kmeans import (
    kmeans,
    kmeans_plus_plus_init,
    spectral_init,
    KMeansResult,
)
from repro.core.clustering.convex import (
    convex_clustering,
    clusterpath,
    knn_weights,
    lambda_interval,
    ConvexClusteringResult,
)
from repro.core.clustering.gradient import gradient_clustering
from repro.core.clustering.admissible import (
    separability_alpha,
    is_separable,
    alpha_convex_clustering,
    alpha_kmeans,
)
from repro.core.clustering.api import (
    ClusteringAlgorithm,
    ClusteringResult,
    DeviceClusteringAlgorithm,
    DeviceClusteringResult,
    device_twin,
    get_algorithm,
    is_device_algorithm,
    list_algorithms,
    register_algorithm,
    separability_of,
    unregister_algorithm,
)

__all__ = [
    "kmeans",
    "kmeans_plus_plus_init",
    "spectral_init",
    "KMeansResult",
    "convex_clustering",
    "knn_weights",
    "clusterpath",
    "lambda_interval",
    "ConvexClusteringResult",
    "gradient_clustering",
    "separability_alpha",
    "is_separable",
    "alpha_convex_clustering",
    "alpha_kmeans",
    "ClusteringAlgorithm",
    "ClusteringResult",
    "DeviceClusteringAlgorithm",
    "DeviceClusteringResult",
    "device_twin",
    "get_algorithm",
    "is_device_algorithm",
    "list_algorithms",
    "register_algorithm",
    "separability_of",
    "unregister_algorithm",
]
