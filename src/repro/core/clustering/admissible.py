"""Separability condition (4) and admissibility constants (Lemmas 1-2).

Definition 1: a dataset {a_i} is separable wrt clustering {C_k} with
margin alpha if  alpha * ||mu_k - a_i|| < ||mu_k - mu_l||  for all
i in C_k, k != l.

Lemma 1 (ODCL-CC):  admissible when alpha = 4 (m - |C_(K)|) / |C_(K)|.
Lemma 2 (ODCL-KM):  admissible when alpha = 2 + 2 c sqrt(m) / |C_(K)|.
"""
from __future__ import annotations

import numpy as np


def _stats(points, labels):
    points = np.asarray(points, np.float64)
    labels = np.asarray(labels)
    ks = np.unique(labels)
    mus = np.stack([points[labels == k].mean(axis=0) for k in ks])
    radii = np.array([
        np.linalg.norm(points[labels == k] - mus[i], axis=1).max()
        for i, k in enumerate(ks)
    ])
    if len(ks) == 1:
        min_sep = np.inf
    else:
        d = np.linalg.norm(mus[:, None] - mus[None, :], axis=-1)
        np.fill_diagonal(d, np.inf)
        min_sep = d.min()
    return mus, radii, min_sep


def separability_alpha(points, labels) -> float:
    """Largest alpha for which condition (4) holds (inf if radii are 0)."""
    _, radii, min_sep = _stats(points, labels)
    rmax = radii.max()
    if rmax == 0.0:
        return np.inf
    return float(min_sep / rmax)


def is_separable(points, labels, alpha: float) -> bool:
    """Check condition (4) for a given margin alpha."""
    return separability_alpha(points, labels) > alpha


def alpha_convex_clustering(m: int, c_min: int) -> float:
    """Lemma 1 margin for convex clustering."""
    return 4.0 * (m - c_min) / c_min


def alpha_kmeans(m: int, c_min: int, c: float = 1.0) -> float:
    """Lemma 2 margin for K-means with spectral init (c = global const)."""
    return 2.0 + 2.0 * c * np.sqrt(m) / c_min
