"""Unified federated-method API at deep-model scale.

``core/methods.py`` gives the paper's Section-5 cast one interface over
flat ``(xs, ys)`` federations; this module is the same idea one level
up, over ``FederatedState`` parameter pytrees — the representation the
LM-scale drivers (``launch/train.py``, ``launch/simulate.py``) and the
device aggregation engine operate on:

  ``FederatedMethod.run(key, state, cfg, batches, *, mesh=None)
      -> FederatedMethodResult``

``state`` carries stacked per-client parameters (leading axis C);
``cfg`` is the ``ModelConfig`` driving local training (``None`` for
shallow per-client models, e.g. the wave-batched ridge clients of
``launch/simulate.py``); ``batches`` yields pytrees whose leaves have
leading axis C (``None`` when the method runs zero local steps).

Pre-registered methods:

  * ``ODCLFederated``  — Algorithm 1: local ERM phase, then the ONE
    clustered aggregation round (host or device engine), then optional
    continued personalized training.  Subsumes the previously hardcoded
    ``launch/train.py`` flow bit-exactly.
  * ``IFCAFederated``  — the iterative baseline [Ghosh et al., 2020]
    lifted from ``core/ifca.py`` onto model pytrees: R rounds of
    broadcast -> per-client cluster estimate -> local steps -> cluster
    averaging (``cluster_mean_tree``).  Assignment is either the
    classic lowest-local-loss rule or nearest-center in JL sketch
    space (``core.sketch``), which costs sketch_dim floats instead of
    k forward passes per client per round.
  * ``FedAvgGlobal``   — R rounds of heterogeneity-blind global
    averaging (the K'=1 degenerate clustering).
  * ``LocalOnlyFederated`` — pure local training, zero communication.

``register_federated_method`` / ``get_federated_method`` /
``list_federated_methods`` mirror the clustering and flat-method
registries, so new LM-scale methods are drop-in plugins — drivers
dispatch by name and never grow if/elif ladders.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterator, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.clustering.api import get_algorithm, resolve_device_request
from repro.core.engine.aggregators import cluster_reduce_tree, get_aggregator
from repro.core.federated import (
    FederatedState,
    _router_invariant_filter,
    cluster_mean_tree,
    local_training,
    one_shot_aggregate,
)
from repro.core.sketch import sketch_tree
from repro.kernels import ops as kops
from repro.optim import AdamWConfig, adamw_init


@dataclasses.dataclass
class FederatedMethodResult:
    """What every LM-scale federated method hands back to the driver."""
    state: FederatedState              # final per-client params/opt state
    labels: np.ndarray                 # (C,) cluster id per client
    n_clusters: int
    comm_rounds: float                 # server<->client round trips consumed
    comm_bytes: float                  # protocol bytes moved (up + down)
    round_metrics: list                # one dict per round (losses, churn, ...)
    meta: dict


@runtime_checkable
class FederatedMethod(Protocol):
    """A federated method runnable over a ``FederatedState``."""
    name: str

    def run(self, key, state: FederatedState, cfg, batches: Optional[Iterator],
            *, mesh=None) -> FederatedMethodResult: ...


def params_bytes_per_client(state: FederatedState) -> int:
    """Bytes of ONE client's model (the unit of comm accounting)."""
    leaves = jax.tree_util.tree_leaves(state.params)
    c = max(1, state.n_clients)
    return sum(l.size // c * l.dtype.itemsize for l in leaves)


def sketch_round_bytes(n_clients: int, sketch_dim: int,
                       bytes_per: int) -> float:
    """Protocol bytes of ONE sketch-clustered round: uplink = the JL
    sketch plus the full model (steps 3-4 average full parameters
    server-side), downlink = the cluster model.  The single accounting
    rule shared by ODCLFederated, IFCA's sketch-assign rounds, and the
    streaming-session path of ``launch/simulate.py``."""
    return float(n_clients * (sketch_dim * 4 + 2 * bytes_per))


def cluster_agreement(pred, true) -> float:
    """Purity of ``pred`` against the hidden clustering ``true`` — the
    label-agreement metric shared by train.py, simulate.py, and the
    benchmarks (each predicted cluster votes for its majority truth)."""
    from collections import Counter

    pred, true = np.asarray(pred), np.asarray(true)
    total = 0
    for c in np.unique(pred):
        total += Counter(true[pred == c]).most_common(1)[0][1]
    return total / len(true)


def _leaf_filter_for(cfg):
    return (_router_invariant_filter
            if cfg is not None and getattr(cfg, "is_moe", False) else None)


def _require_training_inputs(name: str, cfg, batches, steps: int):
    if steps > 0 and (cfg is None or batches is None):
        raise ValueError(
            f"{name} with local steps > 0 needs a ModelConfig and a batch "
            "iterator; pass local_steps=0 for shallow aggregate-only runs")


# ---------------------------------------------------------------- ODCL

@dataclasses.dataclass
class ODCLFederated:
    """Algorithm 1 end-to-end at LM scale (the one-shot tentpole).

    Phase 1: ``local_steps`` per-client optimizer steps (no cross-client
    collectives).  Phase 2: ``one_shot_aggregate`` — sketch, cluster
    through the admissible registry (``algorithm``/``k``), per-cluster
    parameter mean.  Phase 3: ``post_steps`` continued personalized
    steps.  ``engine='device'`` maps the host Lloyd-family names onto
    ``kmeans-device`` init options exactly as the legacy train.py flow
    did; any registered ``DeviceClusteringAlgorithm`` passes through.
    ``aggregator`` names the step-3 per-cluster reduction from the
    aggregator registry (``mean`` | ``trimmed_mean`` | ``median``) —
    the robust variants are the Byzantine-resilient server.
    """
    algorithm: str = "kmeans++"
    k: Optional[int] = None
    algo_options: Optional[dict] = None
    engine: str = "host"               # host | device | auto
    sketch_dim: int = 128
    local_steps: int = 0
    post_steps: int = 0
    opt: Optional[AdamWConfig] = None
    seed: int = 0
    aggregator: Any = "mean"
    name: str = "odcl"

    def _resolve(self):
        """(algorithm, options) after the legacy device-name mapping.

        The Lloyd-family host names map onto ``kmeans-device`` with the
        matching ``init`` option; names with a registered
        ``"<name>-device"`` twin (convex, clusterpath) pass through
        unchanged — ``one_shot_aggregate`` upgrades them itself.
        Shared with the streaming session
        (``clustering.api.resolve_device_request``).
        """
        if self.engine != "device":
            return self.algorithm, self.algo_options
        return resolve_device_request(self.algorithm, self.algo_options)

    def run(self, key, state: FederatedState, cfg, batches=None, *,
            mesh=None) -> FederatedMethodResult:
        _require_training_inputs(self.name, cfg, batches,
                                 self.local_steps + self.post_steps)
        rounds = []
        if self.local_steps:
            state, losses = local_training(state, cfg, batches,
                                           self.local_steps, self.opt)
            rounds.append({"phase": "local", "steps": self.local_steps,
                           "loss_first": float(np.mean(losses[0])),
                           "loss_last": float(np.mean(losses[-1]))})

        algorithm, options = self._resolve()
        k = self.k if get_algorithm(algorithm).requires_k else None
        t0 = time.perf_counter()
        state, labels, info = one_shot_aggregate(
            state, cfg, algorithm=algorithm, k=k, algo_options=options,
            engine=self.engine, sketch_dim=self.sketch_dim, seed=self.seed,
            aggregator=self.aggregator, mesh=mesh)
        round_s = time.perf_counter() - t0
        rounds.append({"phase": "aggregate", "engine": info["engine"],
                       "n_clusters": info["n_clusters"]})

        if self.post_steps:
            state, losses = local_training(state, cfg, batches,
                                           self.post_steps, self.opt)
            rounds.append({"phase": "post", "steps": self.post_steps,
                           "loss_last": float(np.mean(losses[-1]))})

        bytes_per = params_bytes_per_client(state)
        comm = sketch_round_bytes(state.n_clients, self.sketch_dim,
                                  bytes_per)
        obs.count("fed.comm_bytes", comm)
        obs.observe("fed.round.ms", round_s * 1000.0)
        obs.event("fed.round", method=self.name, round=0, seconds=round_s,
                  bytes=float(comm), clients=state.n_clients,
                  n_clusters=info["n_clusters"])
        return FederatedMethodResult(
            state=state, labels=np.asarray(labels),
            n_clusters=info["n_clusters"], comm_rounds=1.0,
            comm_bytes=float(comm), round_metrics=rounds,
            meta={"engine": info["engine"], **info["meta"]})


# ---------------------------------------------------------------- IFCA

@dataclasses.dataclass
class IFCAFederated:
    """IFCA [Ghosh et al., 2020] on model pytrees — the multi-round
    baseline the one-shot framework is measured against (Figure 4).

    Per round: the server broadcasts k cluster models; every client
    estimates its cluster (``assign='loss'``: lowest local loss of the
    k candidates, the paper's rule; ``assign='sketch'``: nearest
    cluster model to the client's current parameters in JL sketch
    space, computed by the engine's fused ``kernels/kmeans_assign``
    dispatch — one pass over the (C, sketch_dim) matrix instead of a
    materialized (C, k, sketch_dim) difference block); clients run
    ``local_steps`` optimizer steps from their cluster's model; the
    server re-averages within assigned clusters (``cluster_mean_tree``;
    empty clusters keep their model, as in ``core.ifca``).
    ``warmup_steps`` of pure local training before the loop plus
    ``init='clients'`` reproduces the paper's good-init regime;
    ``init='perturb'`` starts from the perturbed client mean.

    ``carry_opt_state=True`` is the FedOpt-style variant: per-cluster
    Adam moments are averaged server-side alongside the parameters and
    re-broadcast next round, instead of re-initializing every client's
    optimizer from zero each round (surfaced as ``launch/train.py
    --ifca-carry-opt``; benchmarked in ``fig4_ifca_comm.run_lm``).
    """
    k: int = 2
    rounds: int = 5
    local_steps: int = 5
    warmup_steps: int = 0
    assign: str = "loss"               # 'loss' | 'sketch'
    init: str = "perturb"              # 'perturb' | 'clients'
    init_scale: float = 1e-2
    sketch_dim: int = 128
    carry_opt_state: bool = False
    opt: Optional[AdamWConfig] = None
    seed: int = 0
    aggregator: Any = "mean"           # round-averaging reduction (params
    #                                    only; carried opt moments stay mean)
    name: str = "ifca"

    def _theta0(self, key, state: FederatedState):
        if self.init == "clients":
            # k clients spread across the stack (distinct under any
            # contiguous true labeling) seed the k cluster models
            idx = jnp.asarray(np.linspace(0, state.n_clients - 1, self.k)
                              .round().astype(np.int32))
            return jax.tree_util.tree_map(lambda l: l[idx], state.params)
        if self.init == "perturb":
            leaves, treedef = jax.tree_util.tree_flatten(state.params)
            subkeys = jax.random.split(key, len(leaves))
            out = []
            for sub, leaf in zip(subkeys, leaves):
                mean = jnp.mean(leaf, axis=0)
                noise = self.init_scale * jax.random.normal(
                    sub, (self.k,) + mean.shape, mean.dtype)
                out.append(mean[None] + noise)
            return jax.tree_util.tree_unflatten(treedef, out)
        raise ValueError(f"unknown init {self.init!r}")

    def _make_assign(self, cfg, leaf_filter):
        if self.assign == "loss":
            from repro.models import transformer as tr

            @jax.jit
            def assign_fn(theta, params_c, batch):
                def per_client(batch_c):
                    return jax.vmap(
                        lambda t: tr.train_loss(t, cfg, batch_c))(theta)
                losses = jax.vmap(per_client)(batch)             # (C, k)
                return jnp.argmin(losses, axis=1).astype(jnp.int32)
            return assign_fn
        if self.assign == "sketch":
            skey = jax.random.PRNGKey(self.seed)

            @jax.jit
            def assign_fn(theta, params_c, batch):
                sk = jax.vmap(lambda p: sketch_tree(
                    skey, p, self.sketch_dim, leaf_filter=leaf_filter))
                s_c, s_k = sk(params_c), sk(theta)               # (C,s),(k,s)
                # nearest-center through the engine's fused
                # assign+accumulate dispatch (Pallas kernel on TPU): no
                # (C, k, sketch_dim) difference block, so the rule
                # scales to the C >> 1k federations of simulate.py
                labels, _, _ = kops.kmeans_assign(s_c, s_k)
                return labels
            return assign_fn
        raise ValueError(f"unknown assign rule {self.assign!r}")

    def run(self, key, state: FederatedState, cfg, batches=None, *,
            mesh=None) -> FederatedMethodResult:
        if self.rounds < 1:
            raise ValueError("IFCA needs rounds >= 1 (there is no "
                             "assignment without a round)")
        if self.assign == "loss" and (cfg is None or batches is None):
            raise ValueError("assign='loss' needs a ModelConfig and batches; "
                             "use assign='sketch' for shallow states")
        _require_training_inputs(self.name, cfg, batches,
                                 self.warmup_steps + self.local_steps)
        if self.warmup_steps:
            state, _ = local_training(state, cfg, batches, self.warmup_steps,
                                      self.opt)

        theta = self._theta0(key, state)
        assign_fn = self._make_assign(cfg, _leaf_filter_for(cfg))
        local_step = None
        if self.local_steps:
            from repro.launch.steps import make_local_train_step
            # remat="none" matches local_training (the warmup/ODCL path)
            local_step = jax.jit(make_local_train_step(cfg, self.opt,
                                                       remat="none"))
        # FedOpt-style carried moments: one Adam state per cluster model,
        # averaged server-side each round exactly like the parameters
        cluster_opt = (jax.vmap(adamw_init)(theta)
                       if self.carry_opt_state and self.local_steps else None)

        # comm accounting per round, computed up front (model shapes are
        # fixed for the whole run) so every round's event can carry it
        bytes_per = params_bytes_per_client(state)
        if self.assign == "loss":
            # down: k models per client; up: one trained model per client
            per_round = state.n_clients * (self.k + 1) * bytes_per
        else:
            # up: sketch + trained model; down: the assigned model
            per_round = sketch_round_bytes(state.n_clients, self.sketch_dim,
                                           bytes_per)

        params, labels, rounds = state.params, None, []
        for r in range(self.rounds):
            t0 = time.perf_counter()
            batch = None
            if self.assign == "loss":
                batch = jax.tree_util.tree_map(jnp.asarray, next(batches))
            new_labels = assign_fn(theta, params, batch)
            churn = (float(np.mean(np.asarray(new_labels) != labels))
                     if labels is not None else 1.0)
            labels = np.asarray(new_labels)

            losses = []
            if self.local_steps:
                # clients adopt their estimated cluster's model and
                # refine it locally before uploading
                params = jax.tree_util.tree_map(lambda t: t[new_labels],
                                                theta)
                opt_state = (jax.tree_util.tree_map(
                    lambda t: t[new_labels], cluster_opt)
                    if cluster_opt is not None
                    else jax.vmap(adamw_init)(params))
                for _ in range(self.local_steps):
                    b = jax.tree_util.tree_map(jnp.asarray, next(batches))
                    loss, params, opt_state = local_step(params, opt_state, b)
                    losses.append(float(np.mean(loss)))
            # local_steps == 0: clients upload their standing models
            # (e.g. the wave-batched local ERMs of launch/simulate.py)
            # so the rounds are genuine Lloyd steps in model space —
            # averaging the broadcast copies back would be a no-op

            onehot = jax.nn.one_hot(new_labels, self.k, dtype=jnp.float32)
            counts = jnp.sum(onehot, axis=0)                       # (k,)
            means = cluster_reduce_tree(params, new_labels, onehot, counts,
                                        self.aggregator)
            hit = counts > 0

            def keep(mean, prev):
                mask = hit.reshape((self.k,) + (1,) * (mean.ndim - 1))
                return jnp.where(mask, mean, prev)

            theta = jax.tree_util.tree_map(keep, means, theta)
            if cluster_opt is not None:
                # per-cluster moment means; the integer step leaf is
                # uniform within a cluster (everyone advanced the same
                # carried state by local_steps) so its mean is exact
                opt_means = cluster_mean_tree(opt_state, onehot,
                                              jnp.maximum(counts, 1.0))
                cluster_opt = jax.tree_util.tree_map(keep, opt_means,
                                                     cluster_opt)
            round_s = time.perf_counter() - t0
            obs.count("fed.comm_bytes", per_round)
            obs.observe("fed.round.ms", round_s * 1000.0)
            obs.event("fed.round", method=self.name, round=r,
                      seconds=round_s, bytes=float(per_round),
                      clients=state.n_clients, churn=churn)
            rounds.append({"round": r, "assign_churn": churn,
                           "cluster_sizes": np.asarray(counts).tolist(),
                           "loss_last": losses[-1] if losses else None})

        if not self.local_steps:
            # each client receives its final cluster's averaged model
            # (the step-4 downlink; with local refinement the clients'
            # personalized models already ARE the deliverable)
            idx = jnp.asarray(labels)
            params = jax.tree_util.tree_map(lambda t: t[idx], theta)
        new_state = FederatedState(
            params=params, opt_state=jax.vmap(adamw_init)(params),
            n_clients=state.n_clients,
            step=state.step + self.rounds * self.local_steps)
        return FederatedMethodResult(
            state=new_state, labels=labels,
            n_clusters=int(len(np.unique(labels))),
            comm_rounds=float(self.rounds),
            comm_bytes=float(self.rounds * per_round), round_metrics=rounds,
            meta={"assign": self.assign, "k": self.k,
                  "warmup_steps": self.warmup_steps,
                  "carry_opt_state": self.carry_opt_state})


# ------------------------------------------------------------- baselines

@dataclasses.dataclass
class FedAvgGlobal:
    """R rounds of global FedAvg — the heterogeneity-blind baseline
    (every round averages ALL clients into one model, K'=1)."""
    rounds: int = 5
    local_steps: int = 5
    opt: Optional[AdamWConfig] = None
    name: str = "fedavg"

    def run(self, key, state: FederatedState, cfg, batches=None, *,
            mesh=None) -> FederatedMethodResult:
        _require_training_inputs(self.name, cfg, batches, self.local_steps)
        c = state.n_clients
        onehot = jnp.ones((c, 1), jnp.float32)
        counts = jnp.full((1,), float(c))
        per_round = c * 2 * params_bytes_per_client(state)
        rounds = []
        for r in range(self.rounds):
            t0 = time.perf_counter()
            if self.local_steps:
                state, losses = local_training(state, cfg, batches,
                                               self.local_steps, self.opt)
                rounds.append({"round": r,
                               "loss_last": float(np.mean(losses[-1]))})
            mean = cluster_mean_tree(state.params, onehot, counts)
            params = jax.tree_util.tree_map(
                lambda m: jnp.broadcast_to(m[0], (c,) + m.shape[1:]), mean)
            state = FederatedState(params=params,
                                   opt_state=jax.vmap(adamw_init)(params),
                                   n_clients=c, step=state.step)
            round_s = time.perf_counter() - t0
            obs.count("fed.comm_bytes", per_round)
            obs.observe("fed.round.ms", round_s * 1000.0)
            obs.event("fed.round", method=self.name, round=r,
                      seconds=round_s, bytes=float(per_round), clients=c)
        bytes_per = params_bytes_per_client(state)
        return FederatedMethodResult(
            state=state, labels=np.zeros(c, np.int32), n_clusters=1,
            comm_rounds=float(self.rounds),
            comm_bytes=float(self.rounds * c * 2 * bytes_per),
            round_metrics=rounds, meta={})


@dataclasses.dataclass
class LocalOnlyFederated:
    """Pure local training — every client keeps its own model (0 rounds)."""
    local_steps: int = 0
    opt: Optional[AdamWConfig] = None
    name: str = "local-only"

    def run(self, key, state: FederatedState, cfg, batches=None, *,
            mesh=None) -> FederatedMethodResult:
        rounds = []
        if self.local_steps:
            _require_training_inputs(self.name, cfg, batches, self.local_steps)
            state, losses = local_training(state, cfg, batches,
                                           self.local_steps, self.opt)
            rounds.append({"phase": "local",
                           "loss_last": float(np.mean(losses[-1]))})
        return FederatedMethodResult(
            state=state,
            labels=np.arange(state.n_clients, dtype=np.int32),
            n_clusters=state.n_clients, comm_rounds=0.0, comm_bytes=0.0,
            round_metrics=rounds, meta={})


# ------------------------------------------------------------- registry

_FEDERATED_METHODS: dict[str, type] = {}


def register_federated_method(cls: type, *, name: Optional[str] = None,
                              overwrite: bool = False) -> type:
    """Register an LM-scale method under a name. Returns it (decorator-safe)."""
    key = name if name is not None else getattr(cls, "name", None)
    if not isinstance(key, str) or not key:
        key = cls.__name__.lower()
    if key in _FEDERATED_METHODS and not overwrite:
        raise ValueError(f"federated method {key!r} already registered "
                         "(pass overwrite=True to replace)")
    _FEDERATED_METHODS[key] = cls
    return cls


def unregister_federated_method(name: str) -> None:
    """Remove a registered method (used by tests/plugins)."""
    _FEDERATED_METHODS.pop(name, None)


def get_federated_method(name: str) -> type:
    try:
        return _FEDERATED_METHODS[name]
    except KeyError:
        raise KeyError(f"unknown federated method {name!r}; "
                       f"registered: {sorted(_FEDERATED_METHODS)}") from None


def list_federated_methods() -> tuple[str, ...]:
    return tuple(sorted(_FEDERATED_METHODS))


def build_federated_method(name: str, **kwargs: Any):
    """Construct a registered method from a superset of driver kwargs.

    Drivers (train.py, simulate.py, benchmarks) collect one flat kwargs
    dict from their flags; this filters it down to the fields the named
    method actually declares — the registry stays ladder-free and new
    plugin methods pick up whichever driver flags they name.
    """
    cls = get_federated_method(name)
    if dataclasses.is_dataclass(cls):
        fields = {f.name for f in dataclasses.fields(cls) if f.init}
        kwargs = {k: v for k, v in kwargs.items()
                  if k in fields and v is not None}
    return cls(**kwargs)


for _cls, _name in ((ODCLFederated, "odcl"), (IFCAFederated, "ifca"),
                    (FedAvgGlobal, "fedavg"),
                    (LocalOnlyFederated, "local-only")):
    register_federated_method(_cls, name=_name)
del _cls, _name
