"""Explicit theory quantities from Section 4 / Table 1.

These power ``benchmarks/table1_comparison.py`` and the threshold
verification tests: given problem constants they evaluate the paper's
sample requirements and communication costs for ODCL-CC, ODCL-KM, IFCA
and ALL-for-ALL.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    """Constants appearing in M (proof of Theorem 1, Appendix B.1)."""
    L: float              # smoothness
    mu_F: float           # strong convexity of population losses
    R: float              # parameter-space radius (Assumption 2)
    d: int                # model dimension
    G_F: float            # population gradient bound
    N: float = 1.0        # Assumption 6 gradient bound at optima
    F_star: float = 0.0   # population loss value at optimum
    beta: float = 2.0     # free parameter (Remark 10)


def constant_M(c: ProblemConstants) -> float:
    """M_k of Appendix B.1 (max over the per-user constants M_ik)."""
    log2 = np.log(2.0)
    t1 = 16 * c.L * c.F_star * (log2 + c.beta) / c.mu_F ** 2
    t2 = 64 * c.R ** 2 * c.L * (log2 + c.d * np.log(6 * c.R) + (c.d + 1) * c.beta) / c.mu_F
    t3 = 16 * c.R * c.N * (log2 + c.beta) / c.mu_F
    t4 = (2 * c.G_F + 16 * c.R * c.L * (1 + log2 + c.d * np.log(6 * c.R) + (c.d + 1) * c.beta)) / c.mu_F
    return t1 + t2 + t3 + t4


def sample_threshold(M: float, alpha: float, D: float, gamma: float) -> float:
    """Theorem 1 threshold: smallest n with n/log n > 4 M alpha^2/(D-2gamma)^2."""
    rhs = 4.0 * M * alpha ** 2 / (D - 2 * gamma) ** 2
    n = max(3.0, rhs)
    # solve n / log n > rhs by doubling + bisection
    while n / np.log(n) <= rhs:
        n *= 2.0
    lo, hi = n / 2.0, n
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if mid > 3 and mid / np.log(mid) > rhs:
            hi = mid
        else:
            lo = mid
    return hi


def threshold_odcl_cc(M: float, m: int, c_min: int, D: float, gamma: float) -> float:
    """Section 4.2: n/log n > 64 M (m-|C_(K)|)^2 / (|C_(K)|^2 (D-2g)^2)."""
    alpha = 4.0 * (m - c_min) / c_min
    return sample_threshold(M, alpha, D, gamma)


def threshold_odcl_km(M: float, m: int, c_min: int, D: float, gamma: float,
                      c: float = 1.0) -> float:
    """Section 4.2: n/log n > 16 M (|C_(K)|+c sqrt m)^2/(|C_(K)|^2 (D-2g)^2)."""
    alpha = 2.0 + 2.0 * c * np.sqrt(m) / c_min
    return sample_threshold(M, alpha, D, gamma)


def ifca_comm_rounds(kappa: float, p: float, D: float, eps: float) -> float:
    """IFCA round count T = (8 kappa / p) log(2D/eps) (Section 4.3)."""
    return 8.0 * kappa / p * np.log(2.0 * D / eps)


def all_for_all_comm_rounds(n: int, m: int, K: int) -> float:
    """ALL-for-ALL: Theta((nm/K) log(nm/K)) (Table 1)."""
    x = n * m / K
    return x * np.log(x)


def communication_saving(kappa: float, p: float, D: float, eps: float) -> float:
    """ODCL saves a factor O((kappa/p) log(2D/eps)) vs IFCA (contribution 3)."""
    return ifca_comm_rounds(kappa, p, D, eps) / 1.0


def mse_bound_theorem1(c: ProblemConstants, n: int, K: int, c_k: int,
                       c_min: int, E_k: float, E_tilde: float,
                       gamma: float, m: int) -> float:
    """The dominating explicit terms of Theorem 1's MSE bound."""
    t1 = 2 * E_k / (n * c_k)
    t2 = 8 * K * E_tilde * c.R ** 2 / (n * c_min * gamma ** 2)
    t3 = 8 * m * c.R ** 2 / n ** c.beta
    return t1 + t2 + t3


def merge_condition(n_i: int, n_j: int) -> float:
    """Appendix F: merging clusters i,j is beneficial when
    D^2 <= min(n_i,n_j) / (max(n_i,n_j) (n_i+n_j)); returns the bound."""
    return min(n_i, n_j) / (max(n_i, n_j) * (n_i + n_j))
