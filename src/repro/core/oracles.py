"""Baselines of Section 5: oracle and naive references.

  * Oracle Averaging — average local ERMs within the *true* clusters
    (AVGM of [13] run per cluster; what ODCL matches when clustering
    succeeds).
  * Cluster Oracle   — centralized training on each true cluster's
    pooled data (solves (3)); order-optimal target O(1/(n |C_k|)).
  * Local ERM        — each user keeps its own local model.
  * Naive Averaging  — average all m models, oblivious to heterogeneity
    (AVGM of [13] run globally).
"""
from __future__ import annotations

import numpy as np


def oracle_averaging(local_models, true_labels):
    """(m,d) models, (m,) true labels -> per-user model (m,d)."""
    local_models = np.asarray(local_models, np.float32)
    true_labels = np.asarray(true_labels)
    out = np.empty_like(local_models)
    for k in np.unique(true_labels):
        out[true_labels == k] = local_models[true_labels == k].mean(axis=0)
    return out


def naive_averaging(local_models):
    local_models = np.asarray(local_models, np.float32)
    return np.broadcast_to(local_models.mean(axis=0), local_models.shape).copy()


def local_erm(local_models):
    return np.asarray(local_models, np.float32).copy()


def cluster_oracle(solve_fn, xs, ys, true_labels):
    """Pool each true cluster's data and solve centrally.

    solve_fn(x, y) -> theta. xs/ys are per-user arrays with leading axis m.
    Returns per-user models (m, d).
    """
    xs, ys = np.asarray(xs), np.asarray(ys)
    true_labels = np.asarray(true_labels)
    models = {}
    for k in np.unique(true_labels):
        sel = true_labels == k
        x = xs[sel].reshape(-1, xs.shape[-1])
        y = ys[sel].reshape(-1)
        models[k] = np.asarray(solve_fn(x, y))
    return np.stack([models[k] for k in true_labels])
