"""Multi-pod ODCL integration: federated clustered training of deep models.

This is Algorithm 1 elevated to the distributed-training framework:

  * clients live along the ``data`` mesh axis — parameters carry a
    leading client axis (C, ...), so the local phase
    (``launch.steps.make_local_train_step``) contains NO cross-client
    collectives (the paper's one-shot communication saving);
  * the one-shot aggregation sketches every client's parameter vector
    (JL projection, ``core.sketch``), clusters the (C, sketch_dim)
    matrix with an admissible algorithm (Section 3), and averages full
    parameters within each recovered cluster;
  * every client then holds its cluster's model — per-cluster
    personalization exactly as in the paper.

On a single host this runs via vmap (tests/examples); under a mesh the
same stacked layout shards with ``ShardingRules(client_axis="data")``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.clustering.api import (
    device_twin,
    get_algorithm,
    is_device_algorithm,
)
from repro.core.engine.aggregators import cluster_aggregate_tree
from repro.core.odcl import run_clustering
from repro.core.sketch import sketch_tree
from repro.launch.steps import make_local_train_step
from repro.models import init_params
from repro.models import transformer as tr
from repro.optim import AdamWConfig, adamw_init


@dataclasses.dataclass
class FederatedState:
    params: dict        # every leaf has leading client axis C
    opt_state: dict
    n_clients: int
    step: int = 0


def init_federation(key, cfg: ModelConfig, n_clients: int,
                    same_init: bool = True) -> FederatedState:
    """Stacked per-client parameters.

    same_init=True starts all clients from one init (the common FL
    setting); False draws independent inits (the paper's local ERMs
    have no shared-init requirement — Remark 3).
    """
    if same_init:
        p0 = init_params(key, cfg)
        params = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (n_clients,) + l.shape).copy(), p0)
    else:
        keys = jax.random.split(key, n_clients)
        params = jax.vmap(lambda k: init_params(k, cfg))(keys)
    opt_state = jax.vmap(adamw_init)(params)
    return FederatedState(params=params, opt_state=opt_state,
                          n_clients=n_clients)


def local_training(state: FederatedState, cfg: ModelConfig,
                   batches: Iterator, steps: int,
                   opt_cfg: Optional[AdamWConfig] = None,
                   remat: str = "none") -> tuple[FederatedState, list]:
    """Run the local-ERM phase: ``steps`` optimizer steps per client.

    ``batches`` yields pytrees whose leaves have leading axis C.
    """
    local_step = jax.jit(make_local_train_step(cfg, opt_cfg, remat=remat))
    losses = []
    params, opt_state = state.params, state.opt_state
    for _ in range(steps):
        batch = next(batches)
        batch = jax.tree_util.tree_map(jnp.asarray, batch)
        loss, params, opt_state = local_step(params, opt_state, batch)
        losses.append(np.asarray(loss))
    return FederatedState(params=params, opt_state=opt_state,
                          n_clients=state.n_clients,
                          step=state.step + steps), losses


def _router_invariant_filter(path, leaf) -> bool:
    """MoE permutation-robust sketch: drop per-expert tensors, keep the
    dense path + router-aggregate (DESIGN.md §4)."""
    s = "/".join(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
    return not (("moe" in s) and ("w_in" in s or "w_out" in s))


def _flat_cluster_means(leaf, onehot, counts):
    """(K', n) float32 per-cluster mean of one stacked leaf."""
    flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
    return (onehot.T @ flat) / counts[:, None]


def cluster_mean_tree(params, onehot, counts):
    """Step 3 alone: the (K', ...) per-cluster means of a stacked pytree.

    The server-side representation IFCA-style iterative methods carry
    between rounds (``core.federated_methods.IFCAFederated``); the
    one-shot path composes it with the gather-back below."""
    def mean(leaf):
        means = _flat_cluster_means(leaf, onehot, counts)
        return means.reshape((onehot.shape[1],) + leaf.shape[1:]).astype(
            leaf.dtype)

    return jax.tree_util.tree_map(mean, params)


def cluster_average_tree(params, onehot, counts):
    """Steps 3-4 on a stacked parameter pytree: per-cluster masked mean
    of every leaf over the leading client axis, gathered back per client.
    ``onehot`` is (C, K'), ``counts`` (K') clamped >= 1; the contraction
    is a psum over 'data' when the client axis is mesh-sharded.  Shared
    by the host path below and the device engine (``engine/aggregate``)
    so the two stay parity-exact."""
    def cluster_avg(leaf):
        means = _flat_cluster_means(leaf, onehot, counts)             # (K', n)
        back = onehot @ means                                         # (C, n)
        return back.reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree_util.tree_map(cluster_avg, params)


def one_shot_aggregate(state: FederatedState, cfg: Optional[ModelConfig],
                       *,
                       algorithm="kmeans++", k: Optional[int] = None,
                       algo_options: Optional[dict] = None,
                       assert_separable: bool = False,
                       sketch_dim: int = 256, seed: int = 0,
                       cluster_seed: Optional[int] = None,
                       engine: str = "auto", mesh=None,
                       aggregator="mean",
                       return_sketches: bool = False):
    """The single communication round of Algorithm 1 at LM scale.

    Step 2 goes through the admissible-clustering registry:
    ``algorithm=`` is a registered name or a ``ClusteringAlgorithm``
    instance, with ``k``/``algo_options`` forwarded to it.  ``seed``
    drives the JL sketch; ``cluster_seed`` (default: ``seed``) drives
    the clustering init.

    ``engine`` selects the execution path: ``"auto"`` (default) runs the
    whole round on device via ``engine.one_shot_aggregate_device``
    whenever the resolved algorithm is device-capable — including
    host-only names with a registered ``"<name>-device"`` twin
    (``"convex"`` / ``"clusterpath"`` / ``"gradient"`` upgrade to their
    device ports) — and falls back to the host path otherwise;
    ``"host"``/``"device"`` force one path.  ``aggregator`` names the
    per-cluster step-3 reduction (``mean`` | ``trimmed_mean`` |
    ``median`` | an ``Aggregator`` instance), identical on both paths.
    ``info["sketches"]`` (the full (C, sketch_dim) host copy) is only
    populated with ``return_sketches=True`` so large-C runs don't pay
    the transfer.  Returns (new_state, labels, info).
    """
    if engine not in ("auto", "host", "device"):
        raise ValueError(f"engine must be auto|host|device, got {engine!r}")
    if cluster_seed is None:
        cluster_seed = seed
    algo = get_algorithm(algorithm)
    dev_algo = algo if is_device_algorithm(algo) else device_twin(algo)
    if engine == "device" and dev_algo is None:
        raise ValueError(
            f"engine='device' needs a device-capable algorithm, but "
            f"{algo.name!r} is host-only with no registered "
            f"'{algo.name}-device' twin (try 'kmeans-device')")
    use_device = engine != "host" and dev_algo is not None
    if use_device and assert_separable:
        if engine == "device":
            raise ValueError("assert_separable requires engine='host' (the "
                             "Definition-1 margin is computed host-side)")
        use_device = False          # auto: the host oracle can satisfy it
    if use_device:
        from repro.core.engine.aggregate import one_shot_aggregate_device

        return one_shot_aggregate_device(
            state, cfg, algorithm=dev_algo, k=k, algo_options=algo_options,
            sketch_dim=sketch_dim, seed=seed, cluster_seed=cluster_seed,
            mesh=mesh, aggregator=aggregator,
            return_sketches=return_sketches)

    key = jax.random.PRNGKey(seed)
    leaf_filter = (_router_invariant_filter
                   if cfg is not None and cfg.is_moe else None)

    def sketch_one(client_params):
        return sketch_tree(key, client_params, sketch_dim,
                           leaf_filter=leaf_filter)

    sketches = jax.vmap(sketch_one)(state.params)          # (C, sketch_dim)
    result = run_clustering(jax.random.PRNGKey(cluster_seed),
                            np.asarray(sketches), algo, k=k,
                            assert_separable=assert_separable,
                            **(algo_options or {}))
    labels, meta = result.labels, result.meta

    # cluster-wise reduction of the full parameters (step 3) + gather-back
    labels_j = jnp.asarray(labels)
    n_clusters = int(labels.max()) + 1
    onehot = jax.nn.one_hot(labels_j, n_clusters, dtype=jnp.float32)  # (C,K')
    counts = jnp.sum(onehot, axis=0)                                  # (K',)
    new_params = cluster_aggregate_tree(state.params, labels_j, onehot,
                                        counts, aggregator)
    new_state = FederatedState(params=new_params,
                               opt_state=jax.vmap(adamw_init)(new_params),
                               n_clients=state.n_clients, step=state.step)
    info = {"n_clusters": n_clusters, "meta": meta, "engine": "host"}
    if return_sketches:
        info["sketches"] = np.asarray(sketches)
    return new_state, labels, info


def evaluate_per_client(state: FederatedState, cfg: ModelConfig,
                        batch) -> np.ndarray:
    """(C,) mean loss of each client's model on its own eval batch."""
    batch = jax.tree_util.tree_map(jnp.asarray, batch)

    @jax.jit
    def ev(params_c, batch_c):
        return jax.vmap(lambda p, b: tr.train_loss(p, cfg, b))(params_c, batch_c)

    return np.asarray(ev(state.params, batch))
