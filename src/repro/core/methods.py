"""Unified server-side API for every federated method in the paper.

One protocol — ``Method.fit(key, xs, ys, erm) -> MethodResult`` — covers
the paper's whole Section-5 cast, so benchmarks, examples, and tests
drive every method through a single interface (the jax-native analogue
of FedLab's ``ParameterServerHandler``/topology split):

  * ``ODCL``            — Algorithm 1 over ANY registered admissible
                          clustering algorithm (the tentpole family).
  * ``IFCA``            — the iterative baseline [Ghosh et al., 2020].
  * ``GlobalERM``       — naive all-users averaging (heterogeneity-blind).
  * ``LocalOnly``       — every user keeps its local ERM (0 rounds).
  * ``OracleAveraging`` — averaging within the TRUE clusters.
  * ``ClusterOracle``   — centralized training on pooled true clusters.

``erm`` is the batched local solver ``erm(xs, ys) -> (m, d)`` — e.g.
``batched_ridge_erm`` partially applied; methods that do not use local
ERMs (IFCA) ignore it.  ``MethodResult`` carries per-user models,
labels, comm-round counts, and MSE-vs-oracle accessors.

A small name registry (``register_method``/``get_method``/
``list_methods``) mirrors the clustering registry so new federated
methods are drop-in plugins as well.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Protocol, Union, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import oracles
from repro.core.clustering.api import ClusteringAlgorithm, get_algorithm
from repro.core.ifca import IFCAConfig, ifca
from repro.core.odcl import aggregate, run_clustering


@dataclasses.dataclass
class MethodResult:
    """What every federated method hands back to the driver."""
    user_models: np.ndarray            # (m, d) model each user ends with
    labels: np.ndarray                 # (m,) cluster id per user
    cluster_models: Optional[np.ndarray]  # (K', d) shared models, if any
    n_clusters: int
    comm_rounds: float                 # uplink+downlink rounds consumed
    meta: dict

    def mse(self, optima, true_labels) -> float:
        """Mean squared parameter error vs the true per-user optimum."""
        opt = np.asarray(optima)[np.asarray(true_labels)]
        return float(np.mean(np.sum((self.user_models - opt) ** 2, axis=1)))

    def nmse(self, optima, true_labels, eps: float = 0.0) -> float:
        """Per-user normalized MSE (the paper's Figure-1/2 metric)."""
        opt = np.asarray(optima)[np.asarray(true_labels)]
        num = np.sum((self.user_models - opt) ** 2, axis=1)
        den = np.sum(opt ** 2, axis=1)
        if eps:
            den = np.maximum(den, eps)
        return float(np.mean(num / den))


ERMSolver = Callable[[Any, Any], Any]   # erm(xs, ys) -> (m, d) models


@runtime_checkable
class Method(Protocol):
    """A federated method the server can run end-to-end."""
    name: str

    def fit(self, key, xs, ys, erm: Optional[ERMSolver] = None
            ) -> MethodResult: ...


def _local_models(erm: Optional[ERMSolver], xs, ys) -> np.ndarray:
    if erm is None:
        raise ValueError("this method needs a batched local ERM solver "
                         "erm(xs, ys) -> (m, d)")
    return np.asarray(erm(xs, ys), np.float32)


def _cluster_means(user_models: np.ndarray, labels: np.ndarray):
    """(K', d) distinct shared models + K' for label-constant user models."""
    ks = np.unique(labels)
    return np.stack([user_models[labels == k][0] for k in ks]), len(ks)


# ------------------------------------------------------------------ ODCL

@dataclasses.dataclass
class ODCL:
    """Algorithm 1 over any registered admissible clustering algorithm.

    ``ODCL(algorithm="kmeans++", k=10)`` reproduces ODCL-KM++;
    ``ODCL(algorithm="clusterpath")`` the k-free ODCL-CC variant; any
    algorithm registered via ``register_algorithm`` works by name.
    ``options`` are forwarded to the algorithm's ``__call__``;
    ``aggregator`` names the step-3 reduction from the aggregator
    registry (``mean`` | ``trimmed_mean`` | ``median``).
    """
    algorithm: Union[str, ClusteringAlgorithm] = "kmeans++"
    k: Optional[int] = None
    options: dict = dataclasses.field(default_factory=dict)
    assert_separable: bool = False
    aggregator: Any = "mean"

    COMM_ROUNDS = 1   # one uplink of local ERMs + one downlink, always

    @property
    def name(self) -> str:
        return f"odcl-{get_algorithm(self.algorithm).name}"

    def fit(self, key, xs, ys, erm: Optional[ERMSolver] = None) -> MethodResult:
        local = _local_models(erm, xs, ys)
        res = run_clustering(key, local, self.algorithm, k=self.k,
                             assert_separable=self.assert_separable,
                             **self.options)
        cluster_avg, user_models = aggregate(local, res.labels,
                                             aggregator=self.aggregator)
        return MethodResult(user_models=user_models, labels=res.labels,
                            cluster_models=cluster_avg,
                            n_clusters=cluster_avg.shape[0],
                            comm_rounds=self.COMM_ROUNDS,
                            meta=dict(res.meta))


# ------------------------------------------------------------------ IFCA

@dataclasses.dataclass
class IFCA:
    """The iterative baseline: alternating assignment + cluster updates.

    ``init`` is either a (k, d) initial-model array or a callable
    ``init(key, xs, ys) -> (k, d)``; ``loss_fn(theta, x, y)`` and
    ``grad_fn(theta, x, y)`` are the per-user objective pieces.
    """
    k: int
    loss_fn: Callable
    grad_fn: Callable
    init: Any = None
    rounds: int = 200
    step_size: float = 0.1
    mode: str = "gradient"
    local_steps: int = 5
    name: str = "ifca"

    def _theta0(self, key, xs, ys):
        if self.init is None:
            d = int(np.asarray(xs).shape[-1])
            return jax.random.normal(key, (self.k, d))
        if callable(self.init):
            return self.init(key, xs, ys)
        return jnp.asarray(self.init)

    def fit(self, key, xs, ys, erm: Optional[ERMSolver] = None) -> MethodResult:
        cfg = IFCAConfig(k=self.k, rounds=self.rounds,
                         step_size=self.step_size, mode=self.mode,
                         local_steps=self.local_steps)
        theta0 = self._theta0(key, xs, ys)
        theta, labels, hist = ifca(theta0, jnp.asarray(xs), jnp.asarray(ys),
                                   self.loss_fn, self.grad_fn, cfg)
        theta = np.asarray(theta)
        labels = np.asarray(labels)
        return MethodResult(user_models=theta[labels], labels=labels,
                            cluster_models=theta, n_clusters=self.k,
                            comm_rounds=float(self.rounds),
                            meta={"history": np.asarray(hist)})


# -------------------------------------------------------------- baselines

@dataclasses.dataclass
class GlobalERM:
    """Naive averaging of every local ERM — oblivious to heterogeneity."""
    name: str = "global-erm"

    def fit(self, key, xs, ys, erm: Optional[ERMSolver] = None) -> MethodResult:
        local = _local_models(erm, xs, ys)
        user_models = oracles.naive_averaging(local)
        return MethodResult(user_models=user_models,
                            labels=np.zeros(local.shape[0], np.int32),
                            cluster_models=user_models[:1], n_clusters=1,
                            comm_rounds=1, meta={})


@dataclasses.dataclass
class LocalOnly:
    """Every user keeps its own local ERM — zero communication."""
    name: str = "local-only"

    def fit(self, key, xs, ys, erm: Optional[ERMSolver] = None) -> MethodResult:
        local = _local_models(erm, xs, ys)
        m = local.shape[0]
        return MethodResult(user_models=oracles.local_erm(local),
                            labels=np.arange(m, dtype=np.int32),
                            cluster_models=None, n_clusters=m,
                            comm_rounds=0, meta={})


@dataclasses.dataclass
class OracleAveraging:
    """Average local ERMs within the TRUE clusters (knows the labels)."""
    true_labels: np.ndarray = None
    name: str = "oracle-averaging"

    def fit(self, key, xs, ys, erm: Optional[ERMSolver] = None) -> MethodResult:
        local = _local_models(erm, xs, ys)
        labels = np.asarray(self.true_labels)
        user_models = oracles.oracle_averaging(local, labels)
        cluster_models, n_clusters = _cluster_means(user_models, labels)
        return MethodResult(user_models=user_models, labels=labels,
                            cluster_models=cluster_models,
                            n_clusters=n_clusters, comm_rounds=1, meta={})


@dataclasses.dataclass
class ClusterOracle:
    """Centralized training on each true cluster's pooled data.

    ``solve_fn(x, y) -> theta`` is the centralized solver; this is the
    order-optimal target every clustered method is measured against.
    """
    solve_fn: Callable = None
    true_labels: np.ndarray = None
    name: str = "cluster-oracle"

    def fit(self, key, xs, ys, erm: Optional[ERMSolver] = None) -> MethodResult:
        labels = np.asarray(self.true_labels)
        user_models = oracles.cluster_oracle(self.solve_fn, xs, ys, labels)
        cluster_models, n_clusters = _cluster_means(user_models, labels)
        return MethodResult(user_models=user_models, labels=labels,
                            cluster_models=cluster_models,
                            n_clusters=n_clusters, comm_rounds=1, meta={})


# ------------------------------------------------------------------ registry

_METHODS: dict[str, type] = {}


def register_method(cls: type, *, name: Optional[str] = None,
                    overwrite: bool = False) -> type:
    """Register a Method class under a name. Returns it (decorator-safe)."""
    key = name if name is not None else getattr(cls, "name", None)
    if not isinstance(key, str) or not key:
        key = cls.__name__.lower()
    if key in _METHODS and not overwrite:
        raise ValueError(f"federated method {key!r} already registered "
                         "(pass overwrite=True to replace)")
    _METHODS[key] = cls
    return cls


def get_method(name: str) -> type:
    try:
        return _METHODS[name]
    except KeyError:
        raise KeyError(f"unknown federated method {name!r}; "
                       f"registered: {sorted(_METHODS)}") from None


def list_methods() -> tuple[str, ...]:
    return tuple(sorted(_METHODS))


for _cls, _name in ((ODCL, "odcl"), (IFCA, "ifca"),
                    (GlobalERM, "global-erm"), (LocalOnly, "local-only"),
                    (OracleAveraging, "oracle-averaging"),
                    (ClusterOracle, "cluster-oracle")):
    register_method(_cls, name=_name)
del _cls, _name
