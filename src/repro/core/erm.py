"""Local empirical-risk minimization — step 1 of Algorithm 1.

Every user solves  theta_hat_i = argmin_theta f_i(theta)  on its own
data.  Three solvers:

  * ``ridge_erm``      — closed form for quadratic losses (the paper's
                         synthetic linear-regression experiments).
  * ``logistic_erm``   — Newton iterations for l2-regularized logistic
                         regression (paper Appendix E.2 / MNIST Table 2).
  * ``sgd_erm``        — projected SGD, the *inexact* ERM of Appendix D
                         (Assumptions 7-8, step size 1/(mu t)).

All are vmapped across users so the whole federation solves its local
problems in one batched call.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- ridge

@jax.jit
def ridge_erm(x, y, reg: float = 1e-6):
    """Closed-form ERM for 1/2n ||X theta - y||^2 + reg/2 ||theta||^2.

    x: (n, d), y: (n,) -> theta (d,)
    """
    n, d = x.shape
    gram = x.T @ x / n + reg * jnp.eye(d, dtype=x.dtype)
    rhs = x.T @ y / n
    return jnp.linalg.solve(gram, rhs)


batched_ridge_erm = jax.jit(jax.vmap(ridge_erm, in_axes=(0, 0, None)))


# ------------------------------------------------------------- logistic

def _logistic_loss(theta, x, y, reg):
    """Mean l2-regularized logistic loss; y in {-1, +1}; theta[(d+1)] = [w, b]."""
    w, b = theta[:-1], theta[-1]
    z = x @ w + b
    return jnp.mean(jnp.logaddexp(0.0, -y * z)) + 0.5 * reg * jnp.sum(w * w)


@functools.partial(jax.jit, static_argnames=("iters",))
def logistic_erm(x, y, reg: float = 1e-5, iters: int = 25):
    """Damped-Newton solver for the logistic ERM. Returns theta=(d+1,)."""
    d = x.shape[1]
    theta0 = jnp.zeros((d + 1,), jnp.float32)

    grad_fn = jax.grad(_logistic_loss)
    hess_fn = jax.hessian(_logistic_loss)

    def body(theta, _):
        g = grad_fn(theta, x, y, reg)
        h = hess_fn(theta, x, y, reg) + 1e-6 * jnp.eye(d + 1)
        return theta - jnp.linalg.solve(h, g), None

    theta, _ = jax.lax.scan(body, theta0, None, length=iters)
    return theta


batched_logistic_erm = jax.jit(
    jax.vmap(logistic_erm, in_axes=(0, 0, None, None)), static_argnums=(3,)
)


# ------------------------------------------------------------------ sgd

@functools.partial(jax.jit, static_argnames=("loss_fn", "steps", "batch"))
def sgd_erm(key, theta0, data, loss_fn: Callable, *, steps: int = 200,
            batch: int = 8, mu: float = 1.0, radius: float | None = None):
    """Projected SGD with the Appendix-D step rule eta_t = 1/(mu t).

    loss_fn(theta, batch_data) -> scalar. ``data`` is a pytree whose
    leaves have leading axis n. Projection onto the ball of ``radius``
    implements Assumption 2's compact Theta.
    """
    n = jax.tree_util.tree_leaves(data)[0].shape[0]
    grad_fn = jax.grad(loss_fn)

    def body(carry, t):
        theta, key = carry
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch,), 0, n)
        mb = jax.tree_util.tree_map(lambda a: a[idx], data)
        g = grad_fn(theta, mb)
        eta = 1.0 / (mu * (t + 1.0))
        theta = jax.tree_util.tree_map(lambda p, gg: p - eta * gg, theta, g)
        if radius is not None:
            nrm = jnp.sqrt(sum(jnp.sum(l * l) for l in jax.tree_util.tree_leaves(theta)))
            scale = jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-30))
            theta = jax.tree_util.tree_map(lambda p: p * scale, theta)
        return (theta, key), None

    (theta, _), _ = jax.lax.scan(body, (theta0, key), jnp.arange(steps, dtype=jnp.float32))
    return theta
