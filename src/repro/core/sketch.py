"""Model-vector sketching for server-side clustering at scale.

The paper clusters raw model vectors theta_hat_i in R^d.  For the
assigned architectures d is 1e8..3e11, so the server clusters a
Johnson-Lindenstrauss random projection  S theta in R^s  instead
(DESIGN.md §3.3): JL preserves all pairwise distances to (1±eps) with
s = O(log m / eps^2), which preserves the separability condition (4)
with margin alpha' = alpha * (1-eps)/(1+eps).

The projection is computed *shard-locally*: each device projects its
parameter shard with the matching slice of S (regenerated from the seed
and the global offset, never materialized whole) and the per-device
partial sketches are psum'd.  Communication: s floats per client.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.utils import tree_to_vector


def _sketch_block(key, block, sketch_dim: int, offset: int):
    """Project a flat block (n,) with a fresh N(0, 1/s) matrix slice."""
    sub = jax.random.fold_in(key, offset)
    s = jax.random.normal(sub, (block.shape[0], sketch_dim), jnp.float32)
    return block.astype(jnp.float32) @ s / jnp.sqrt(jnp.float32(sketch_dim))


@functools.partial(jax.jit, static_argnames=("sketch_dim", "block"))
def sketch_vector(key, vec, sketch_dim: int = 256, block: int = 1 << 16):
    """Sketch a flat vector in fixed-size blocks (bounds peak memory).

    Equivalent to vec @ S with S ~ N(0, 1/s), S generated blockwise.
    """
    n = vec.shape[0]
    # never pad a short vector out to the full block: the engine vmaps
    # this over C clients, and a (C, 1, block) batch of mostly-padding
    # dominated peak memory for shallow models (C=16k, d=16 clients)
    block = max(256, min(block, ((n + 255) // 256) * 256))
    nb = (n + block - 1) // block
    pad = nb * block - n
    v = jnp.pad(vec, (0, pad)).reshape(nb, block)

    def body(acc, i):
        acc = acc + _sketch_block(key, v[i], sketch_dim, i)
        return acc, None

    acc, _ = jax.lax.scan(body, jnp.zeros((sketch_dim,), jnp.float32),
                          jnp.arange(nb))
    return acc


def sketch_tree(key, params, sketch_dim: int = 256, *,
                leaf_filter=None) -> jnp.ndarray:
    """Sketch a parameter pytree. ``leaf_filter(path, leaf) -> bool``
    selects which leaves participate (used for the router-invariant MoE
    sketch, DESIGN.md §4)."""
    if leaf_filter is not None:
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        leaves = [l for p, l in flat if leaf_filter(p, l)]
        vec = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    else:
        vec = tree_to_vector(params)
    return sketch_vector(key, vec, sketch_dim)
