"""The paper's primary contribution: the ODCL-C one-shot framework.

Two plugin layers sit at the center of the package:

  clustering/api.py — the admissible set C as a *registry*: a
                  ``ClusteringAlgorithm`` protocol (unified
                  ``ClusteringResult``, per-algorithm Lemma-1/Lemma-2
                  ``admissibility_alpha``) with kmeans / kmeans++ /
                  spectral / gradient / convex / clusterpath
                  pre-registered; ``register_algorithm`` makes a new
                  algorithm usable everywhere by name.
  methods.py    — the unified federated-method API: ``Method.fit(key,
                  xs, ys, erm) -> MethodResult`` with ``ODCL`` (over
                  any registered algorithm), ``IFCA``, ``GlobalERM``,
                  ``LocalOnly``, ``OracleAveraging``, ``ClusterOracle``
                  — every benchmark, example, and test drives methods
                  through this one interface.

Around them:

  odcl.py       — Algorithm 1 primitives (registry-backed step 2 via
                  ``run_clustering``, aggregator-registry-backed
                  cluster-wise ``aggregate``)
  clustering/   — the admissible algorithm implementations +
                  admissibility theory (Lemmas 1-2, condition (4))
  erm.py        — local ERM solvers (closed-form ridge, Newton logistic,
                  Appendix-D inexact SGD)
  ifca.py       — IFCA iteration kernel [7] (wrapped by methods.IFCA)
  oracles.py    — oracle/naive reference computations (wrapped by the
                  oracle methods)
  theory.py     — Table 1 & Theorem 1 sample thresholds and bounds
  sketch.py     — JL sketching of parameter pytrees for at-scale clustering
  federated.py  — multi-pod integration: client axis on the mesh,
                  local-SGD train step (no cross-client collectives) and
                  the one-shot clustered aggregation step (clusters
                  sketches through the same registry)
  federated_methods.py — the LM-scale analogue of methods.py: a
                  ``FederatedMethod.run(key, state, cfg, batches)``
                  protocol over ``FederatedState`` pytrees with its own
                  registry (``register_federated_method``), pre-populated
                  with ``ODCLFederated`` / ``IFCAFederated`` /
                  ``FedAvgGlobal`` / ``LocalOnlyFederated`` — what
                  ``launch/train.py --method`` and ``launch/simulate.py``
                  dispatch through (exported lazily: it pulls in the
                  model/launch stack)
"""
from repro.core.odcl import (
    ODCLResult,
    odcl,
    aggregate,
    run_clustering,
)
from repro.core.erm import (
    ridge_erm,
    batched_ridge_erm,
    logistic_erm,
    batched_logistic_erm,
    sgd_erm,
)
from repro.core.ifca import IFCAConfig, ifca, ifca_init_near_optima, ifca_init_annulus
from repro.core import oracles, theory
from repro.core.sketch import sketch_vector, sketch_tree
from repro.core.clustering.api import (
    ClusteringAlgorithm,
    ClusteringResult,
    DeviceClusteringAlgorithm,
    DeviceClusteringResult,
    get_algorithm,
    is_device_algorithm,
    list_algorithms,
    register_algorithm,
    unregister_algorithm,
)
from repro.core.methods import (
    Method,
    MethodResult,
    ODCL,
    IFCA,
    GlobalERM,
    LocalOnly,
    OracleAveraging,
    ClusterOracle,
    get_method,
    list_methods,
    register_method,
)

__all__ = [
    "ODCLResult",
    "odcl",
    "aggregate",
    "run_clustering",
    "ridge_erm",
    "batched_ridge_erm",
    "logistic_erm",
    "batched_logistic_erm",
    "sgd_erm",
    "IFCAConfig",
    "ifca",
    "ifca_init_near_optima",
    "ifca_init_annulus",
    "oracles",
    "theory",
    "sketch_vector",
    "sketch_tree",
    "ClusteringAlgorithm",
    "ClusteringResult",
    "DeviceClusteringAlgorithm",
    "DeviceClusteringResult",
    "get_algorithm",
    "is_device_algorithm",
    "list_algorithms",
    "register_algorithm",
    "unregister_algorithm",
    "Method",
    "MethodResult",
    "ODCL",
    "IFCA",
    "GlobalERM",
    "LocalOnly",
    "OracleAveraging",
    "ClusterOracle",
    "get_method",
    "list_methods",
    "register_method",
]

# LM-scale federated methods — lazy for the same reason engine/ is:
# federated_methods.py imports federated.py (models, launch.steps), which
# light consumers of repro.core (theory, clustering, erm) must not pay for.
_FEDERATED_METHOD_EXPORTS = (
    "FederatedMethod",
    "FederatedMethodResult",
    "ODCLFederated",
    "IFCAFederated",
    "FedAvgGlobal",
    "LocalOnlyFederated",
    "register_federated_method",
    "unregister_federated_method",
    "get_federated_method",
    "list_federated_methods",
    "build_federated_method",
    "cluster_agreement",
    "params_bytes_per_client",
)
__all__ += list(_FEDERATED_METHOD_EXPORTS)


def __getattr__(name):
    if name in _FEDERATED_METHOD_EXPORTS:
        from repro.core import federated_methods
        return getattr(federated_methods, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
