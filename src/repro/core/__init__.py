"""The paper's primary contribution: the ODCL-C one-shot framework.

  odcl.py       — Algorithm 1 (local ERM -> server clustering -> averaging)
  clustering/   — admissible clustering algorithms (KM/KM++/spectral, CC,
                  clusterpath, gradient clustering) + admissibility theory
  erm.py        — local ERM solvers (closed-form ridge, Newton logistic,
                  Appendix-D inexact SGD)
  ifca.py       — IFCA baseline [7]
  oracles.py    — Oracle Averaging / Cluster Oracle / Local / Naive baselines
  theory.py     — Table 1 & Theorem 1 sample thresholds and bounds
  sketch.py     — JL sketching of parameter pytrees for at-scale clustering
  federated.py  — multi-pod integration: client axis on the mesh,
                  local-SGD train step (no cross-client collectives) and
                  the one-shot clustered aggregation step
"""
from repro.core.odcl import ODCLConfig, ODCLResult, odcl, cluster_models, aggregate
from repro.core.erm import (
    ridge_erm,
    batched_ridge_erm,
    logistic_erm,
    batched_logistic_erm,
    sgd_erm,
)
from repro.core.ifca import IFCAConfig, ifca, ifca_init_near_optima, ifca_init_annulus
from repro.core import oracles, theory
from repro.core.sketch import sketch_vector, sketch_tree

__all__ = [
    "ODCLConfig",
    "ODCLResult",
    "odcl",
    "cluster_models",
    "aggregate",
    "ridge_erm",
    "batched_ridge_erm",
    "logistic_erm",
    "batched_logistic_erm",
    "sgd_erm",
    "IFCAConfig",
    "ifca",
    "ifca_init_near_optima",
    "ifca_init_annulus",
    "oracles",
    "theory",
    "sketch_vector",
    "sketch_tree",
]
