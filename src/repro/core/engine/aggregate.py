"""The one-shot round as a single jitted device program (Algorithm 1).

``one_shot_aggregate_device`` fuses the whole server side —

    sketch every client's parameters (JL projection, step 1 upload)
    -> cluster the (C, sketch_dim) sketch matrix on device (step 2)
    -> per-cluster masked parameter mean (steps 3-4)

— into one ``jax.jit`` program.  Sketches, centers and the averaged
parameters never cross the host boundary; the only host outputs are the
(C,) label vector and a handful of scalar diagnostics.  Pass
``return_sketches=True`` to additionally pull the sketch matrix to host
(small-C debugging only — large-C runs must not pay that transfer).

The cluster->average stage is shared with the streaming server API
(``engine/session.py``): ``_finalize_program`` is the same program
minus the sketch vmap, run on a sketch matrix that was accumulated
wave-by-wave — the two paths stay bit-exact because they trace the
identical ``_cluster_and_average`` body.

Under a mesh the client axis shards over ``data`` (the same stacked
layout as ``federated.py``): the label/center reductions inside the
device clustering loop and the one-hot contraction of the cluster mean
both lower to psums over the client shards, so the round runs without
any host-driven collective.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.clustering.api import get_algorithm, is_device_algorithm
from repro.core.engine.aggregators import (
    cluster_aggregate_tree,
    get_aggregator,
)
from repro.core.federated import FederatedState, _router_invariant_filter
from repro.core.sketch import sketch_tree
from repro.optim import adamw_init


def _constrainer(mesh, client_axis):
    def constrain(x):
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(client_axis)))

    return constrain


def _cluster_and_average(algo, options, k, constrain, cluster_key,
                         sketches, params, aggregator="mean"):
    """Steps 2-4 on an already-materialized sketch matrix (traceable).

    The single source of truth for the server's cluster->average stage:
    both the fused one-shot round below and the streaming session's
    ``finalize`` trace this exact body, which is what keeps the two
    bit-exact on identical inputs.  ``aggregator`` selects the
    per-cluster reduction from the registry (``engine/aggregators.py``);
    the default ``mean`` traces the identical contraction as before the
    registry existed.
    """
    res = algo.device_call(cluster_key, sketches, k=k, **options)
    kk = res.centers.shape[0]
    onehot = jax.nn.one_hot(res.labels, kk, dtype=jnp.float32)  # (C, K)
    counts = jnp.sum(onehot, axis=0)                            # (K,) raw
    new_params = jax.tree_util.tree_map(
        constrain, cluster_aggregate_tree(params, res.labels, onehot,
                                          counts, aggregator))
    return new_params, res


@functools.lru_cache(maxsize=16)
def _round_program(algo, k, opts, sketch_dim, leaf_filter, mesh, client_axis,
                   aggregator="mean"):
    """Build the jitted end-to-end round for one static configuration.

    Cached on the static pieces (``aggregator`` resolves to a frozen
    registry instance, so it joins the key) so repeated rounds (sweeps,
    parity tests, multi-round drivers) reuse the compiled program
    instead of retracing a fresh closure every call.
    """
    options = dict(opts)
    constrain = _constrainer(mesh, client_axis)

    @jax.jit
    def round_fn(sketch_key, cluster_key, params):
        sketches = jax.vmap(
            lambda p: sketch_tree(sketch_key, p, sketch_dim,
                                  leaf_filter=leaf_filter)
        )(params)                                        # (C, sketch_dim)
        sketches = constrain(sketches)
        new_params, res = _cluster_and_average(
            algo, options, k, constrain, cluster_key, sketches, params,
            aggregator)
        return new_params, res, sketches

    return round_fn


@functools.lru_cache(maxsize=16)
def _finalize_program(algo, k, opts, mesh, client_axis, aggregator="mean"):
    """Steps 2-4 alone, jitted — the streaming session's finalize.

    Identical trace body to the fused round's tail, fed the sketch
    matrix the session accumulated wave by wave instead of re-sketching.
    """
    options = dict(opts)
    constrain = _constrainer(mesh, client_axis)

    @jax.jit
    def finalize_fn(cluster_key, sketches, params):
        return _cluster_and_average(algo, options, k, constrain,
                                    cluster_key, sketches, params,
                                    aggregator)

    return finalize_fn


def resolve_device_algorithm(algorithm):
    """Registry lookup + the hard device-capability check of the fused
    round (the session resolves engine='auto' fallbacks itself)."""
    algo = get_algorithm(algorithm)
    if not is_device_algorithm(algo):
        raise ValueError(
            f"algorithm {getattr(algo, 'name', algo)!r} is host-only; the "
            "device engine needs a DeviceClusteringAlgorithm "
            "(e.g. 'kmeans-device'), or use engine='host'")
    return algo


def compact_labels(raw_labels):
    """Host-side label compaction: device clusterings may emit
    non-contiguous ids (empty Lloyd clusters, convex root ids).  Returns
    (labels in [0, K'), uniq raw ids, first index per compact id)."""
    raw = np.asarray(raw_labels)
    uniq, first, labels = np.unique(raw, return_index=True,
                                    return_inverse=True)
    return labels.astype(np.int32), uniq, first


def materialize_round(new_params, res, state: FederatedState):
    """Host materialization of a device round: compacted labels + scalar
    meta are the ONLY transfers; params/opt state stay device pytrees.
    Returns ``(new_state, labels, info, uniq, first)`` — ``uniq`` the raw
    ids behind each compact label, ``first`` one member index per compact
    id (the session's routing/serving handles)."""
    labels, uniq, first = compact_labels(res.labels)
    meta = {name: float(np.asarray(v)) for name, v in res.meta.items()}
    new_state = FederatedState(
        params=new_params,
        opt_state=jax.vmap(adamw_init)(new_params),
        n_clients=state.n_clients, step=state.step)
    info = {"n_clusters": int(len(uniq)), "meta": meta, "engine": "device"}
    return new_state, labels, info, uniq, first


def one_shot_aggregate_device(state: FederatedState, cfg=None, *,
                              algorithm="kmeans-device",
                              k: Optional[int] = None,
                              algo_options: Optional[dict] = None,
                              sketch_dim: int = 256, seed: int = 0,
                              cluster_seed: Optional[int] = None,
                              mesh=None, client_axis: str = "data",
                              aggregator="mean",
                              return_sketches: bool = False):
    """Device-resident one-shot aggregation. Returns (state, labels, info).

    ``algorithm`` must be device-capable (a ``DeviceClusteringAlgorithm``,
    e.g. the registered ``"kmeans-device"``).  ``cfg`` is optional and
    only consulted for the MoE router-invariant sketch filter — pass
    ``None`` for shallow per-client models (``launch/simulate.py``).
    ``seed`` drives the JL sketch; ``cluster_seed`` (default: ``seed``)
    drives the clustering init, mirroring the host path's seed split.
    ``aggregator`` names a registered per-cluster reduction (or passes
    an ``Aggregator`` instance) — the robust step-3 variants run inside
    the same jitted program.  With ``mesh`` given, the client axis of
    sketches and parameters is constrained to ``client_axis`` and XLA
    shards the round over it.
    """
    algo = resolve_device_algorithm(algorithm)
    aggregator = get_aggregator(aggregator)
    leaf_filter = (_router_invariant_filter
                   if cfg is not None and getattr(cfg, "is_moe", False)
                   else None)
    opts = tuple(sorted((algo_options or {}).items()))
    try:
        round_fn = _round_program(algo, k, opts, sketch_dim, leaf_filter,
                                  mesh, client_axis, aggregator)
    except TypeError:  # unhashable algorithm/options/mesh: build uncached
        round_fn = _round_program.__wrapped__(algo, k, opts, sketch_dim,
                                              leaf_filter, mesh, client_axis,
                                              aggregator)

    sketch_key = jax.random.PRNGKey(seed)
    cluster_key = jax.random.PRNGKey(
        seed if cluster_seed is None else cluster_seed)
    new_params, res, sketches = round_fn(sketch_key, cluster_key,
                                         state.params)

    new_state, labels, info, _, _ = materialize_round(new_params, res, state)
    if return_sketches:
        info["sketches"] = np.asarray(sketches)
    return new_state, labels, info
