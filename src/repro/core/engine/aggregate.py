"""The one-shot round as a single jitted device program (Algorithm 1).

``one_shot_aggregate_device`` fuses the whole server side —

    sketch every client's parameters (JL projection, step 1 upload)
    -> cluster the (C, sketch_dim) sketch matrix on device (step 2)
    -> per-cluster masked parameter mean (steps 3-4)

— into one ``jax.jit`` program.  Sketches, centers and the averaged
parameters never cross the host boundary; the only host outputs are the
(C,) label vector and a handful of scalar diagnostics.  Pass
``return_sketches=True`` to additionally pull the sketch matrix to host
(small-C debugging only — large-C runs must not pay that transfer).

The cluster->average stage is shared with the streaming server API
(``engine/session.py``): the session's finalize runs the same stage as
two AOT programs (``_cluster_program`` + ``_mean_program``, split so
the obs layer can time the cluster vs mean phases separately) over the
sketch matrix it accumulated wave-by-wave — the paths stay bit-exact
because both trace the identical ``device_call`` /
``_average_clusters`` bodies (pinned by ``tests/test_session.py``).
Every program here is a ``_Program``: AOT ``lower().compile()`` per
input shape with compile-vs-execute spans and XLA cost-analysis
(flops / bytes) gauges recorded to ``repro.obs``.

Under a mesh the client axis shards over ``data`` (the same stacked
layout as ``federated.py``): the label/center reductions inside the
device clustering loop and the one-hot contraction of the cluster mean
both lower to psums over the client shards, so the round runs without
any host-driven collective.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.clustering.api import (
    get_algorithm,
    is_device_algorithm,
    meta_to_host,
)
from repro.core.engine.aggregators import (
    cluster_aggregate_tree,
    get_aggregator,
)
from repro.core.federated import FederatedState, _router_invariant_filter
from repro.core.sketch import sketch_tree
from repro.kernels import ops as kops
from repro.optim import adamw_init


def _constrainer(mesh, client_axis):
    def constrain(x):
        if mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(client_axis)))

    return constrain


def _average_clusters(constrain, labels, centers, params, aggregator):
    """Steps 3-4: the per-cluster parameter reduction (traceable).

    The single source of truth for the averaging stage: the fused round
    traces it through ``_cluster_and_average`` and the session's split
    finalize traces it alone (``_mean_program``) — same body, which is
    what keeps the two bit-exact on identical inputs."""
    kk = centers.shape[0]
    onehot = jax.nn.one_hot(labels, kk, dtype=jnp.float32)      # (C, K)
    counts = jnp.sum(onehot, axis=0)                            # (K,) raw
    return jax.tree_util.tree_map(
        constrain, cluster_aggregate_tree(params, labels, onehot,
                                          counts, aggregator))


def _cluster_and_average(algo, options, k, constrain, cluster_key,
                         sketches, params, aggregator="mean"):
    """Steps 2-4 on an already-materialized sketch matrix (traceable).

    ``aggregator`` selects the per-cluster reduction from the registry
    (``engine/aggregators.py``); the default ``mean`` traces the
    identical contraction as before the registry existed.
    """
    res = algo.device_call(cluster_key, sketches, k=k, **options)
    new_params = _average_clusters(constrain, res.labels, res.centers,
                                   params, aggregator)
    return new_params, res


class _Program:
    """AOT-compiled program with compile-vs-execute telemetry.

    Wraps a traceable function: the first call per input-shape
    signature runs ``jit(fn).lower(*args).compile()`` under a
    ``"<label>.compile"`` span and records the compiled module's XLA
    cost analysis as ``"<label>.flops"`` / ``"<label>.bytes"`` gauges;
    every call then executes (blocking to completion) under a
    ``"<label>.execute"`` span.  This is what splits the historically
    conflated "first round is slow" wall clock into trace/compile vs
    execute in the bench rows, and what feeds
    ``roofline.engine_costs`` its achieved-vs-peak numbers without a
    second compile of the round.
    """

    def __init__(self, label: str, fn):
        self.label = label
        self._fn = fn
        self._cache = {}

    @staticmethod
    def _signature(args):
        return tuple((l.shape, str(l.dtype))
                     for l in jax.tree_util.tree_leaves(args))

    def __call__(self, *args):
        sig = self._signature(args)
        compiled = self._cache.get(sig)
        if compiled is None:
            with obs.span(f"{self.label}.compile"):
                compiled = jax.jit(self._fn).lower(*args).compile()
            cost = compiled.cost_analysis() or {}
            if isinstance(cost, (list, tuple)):   # older jax: per-device list
                cost = cost[0] if cost else {}
            obs.gauge(f"{self.label}.flops", float(cost.get("flops", 0.0)))
            obs.gauge(f"{self.label}.bytes",
                      float(cost.get("bytes accessed", 0.0)))
            self._cache[sig] = compiled
        with obs.span(f"{self.label}.execute"):
            out = compiled(*args)
            jax.block_until_ready(out)
        return out


@functools.lru_cache(maxsize=16)
def _round_program(algo, k, opts, sketch_dim, leaf_filter, mesh, client_axis,
                   aggregator="mean"):
    """Build the fused end-to-end round for one static configuration.

    Cached on the static pieces (``aggregator`` resolves to a frozen
    registry instance, so it joins the key) so repeated rounds (sweeps,
    parity tests, multi-round drivers) reuse the compiled program
    instead of retracing a fresh closure every call.  Returns a
    ``_Program`` — AOT-compiled per shape with compile/execute spans
    and roofline counters under the ``"engine.round"`` label.
    """
    options = dict(opts)
    constrain = _constrainer(mesh, client_axis)

    def round_fn(sketch_key, cluster_key, params):
        sketches = jax.vmap(
            lambda p: sketch_tree(sketch_key, p, sketch_dim,
                                  leaf_filter=leaf_filter)
        )(params)                                        # (C, sketch_dim)
        sketches = constrain(sketches)
        new_params, res = _cluster_and_average(
            algo, options, k, constrain, cluster_key, sketches, params,
            aggregator)
        return new_params, res, sketches

    return _Program("engine.round", round_fn)


@functools.lru_cache(maxsize=16)
def _cluster_program(algo, k, opts):
    """Step 2 alone — the session finalize's clustering phase.

    Same ``device_call`` trace as inside the fused round; splitting it
    from the mean program gives the cluster/mean latency breakdown
    (``session.finalize.cluster`` vs ``session.finalize.mean`` spans)
    that decides *what* an incremental re-finalize would need to re-run.
    The bit-exactness property tests in ``tests/test_session.py`` pin
    that the split stays identical to the fused round."""
    options = dict(opts)

    def cluster_fn(cluster_key, sketches):
        return algo.device_call(cluster_key, sketches, k=k, **options)

    return _Program("session.finalize.cluster", cluster_fn)


@functools.lru_cache(maxsize=16)
def _mean_program(mesh, client_axis, aggregator="mean"):
    """Steps 3-4 alone — the session finalize's averaging phase (the
    shared ``_average_clusters`` body, fed the cluster program's
    labels/centers, which stay on device between the two programs)."""
    constrain = _constrainer(mesh, client_axis)

    def mean_fn(labels, centers, params):
        return _average_clusters(constrain, labels, centers, params,
                                 aggregator)

    return _Program("session.finalize.mean", mean_fn)


@functools.lru_cache(maxsize=16)
def _warm_cluster_program(algo, k, opts):
    """Step 2 warm-started — the session's incremental re-finalize.

    Same static configuration as ``_cluster_program`` but traced through
    the family's ``device_warm_call``: the warm state (previous centers
    for Lloyd, the AMA dual for the convex family) enters as a TRACED
    argument, so re-finalizes with fresh warm states reuse one compiled
    program instead of retracing per state."""
    options = dict(opts)

    def cluster_fn(cluster_key, sketches, warm):
        return algo.device_warm_call(cluster_key, sketches, warm, k=k,
                                     **options)

    return _Program("session.refinalize.cluster", cluster_fn)


@functools.lru_cache(maxsize=16)
def _weighted_mean_program(mesh, client_axis):
    """Steps 3-4 with per-client weights — the exponential-decay
    staleness policy's averaging phase.  The per-cluster reduction is
    the normalized weighted mean ``sum_i w_i x_i / sum_i w_i`` (uniform
    weights reduce to the plain mean on non-empty clusters); robust
    aggregators have no weighted form here, which the session enforces."""
    constrain = _constrainer(mesh, client_axis)

    def mean_fn(labels, centers, params, weights):
        kk = centers.shape[0]
        onehot = jax.nn.one_hot(labels, kk, dtype=jnp.float32)     # (C, K)
        weighted = onehot * weights.astype(jnp.float32)[:, None]   # (C, K)
        denom = jnp.maximum(jnp.sum(weighted, axis=0), 1e-12)[:, None]

        def back(leaf):
            flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
            means = (weighted.T @ flat) / denom                    # (K, n)
            return constrain(
                (onehot @ means).reshape(leaf.shape).astype(leaf.dtype))

        return jax.tree_util.tree_map(back, params)

    return _Program("session.finalize.mean", mean_fn)


@functools.lru_cache(maxsize=4)
def _route_program():
    """Serving-time step 4 over a request batch, as ONE program: the
    fused nearest-center assignment plus the drift accumulator (total
    squared distance of the batch to its assigned centers).  The
    per-request host round-trips of the old route path (a label pull,
    then a separate ``float()`` sync for the drift gauge) collapse into
    a single execute with one host sync per batch."""

    def route_fn(pts, centers):
        labels, _, _ = kops.kmeans_assign(pts, centers)
        assigned = centers[labels]
        d2 = jnp.sum((pts - assigned) ** 2)
        return labels, d2

    return _Program("session.route.batch", route_fn)


@functools.lru_cache(maxsize=4)
def _gather_rows_program():
    """Live-row gather: compact a holey fixed-capacity buffer (sketches
    or a stacked params pytree) down to the surviving rows before a
    finalize.  Sessions with a contiguous live prefix never call this —
    they keep the bit-exact slice path."""

    def gather_fn(buf, rows):
        return jax.tree_util.tree_map(lambda l: l[rows], buf)

    return _Program("session.gather", gather_fn)


def cached_program(builder, *key):
    """Call an ``lru_cache``d program builder, falling back to the
    uncached build when a key piece (algorithm instance, options dict,
    mesh) is unhashable — shared by the fused round and the session."""
    try:
        return builder(*key)
    except TypeError:
        return builder.__wrapped__(*key)


def resolve_device_algorithm(algorithm):
    """Registry lookup + the hard device-capability check of the fused
    round (the session resolves engine='auto' fallbacks itself)."""
    algo = get_algorithm(algorithm)
    if not is_device_algorithm(algo):
        raise ValueError(
            f"algorithm {getattr(algo, 'name', algo)!r} is host-only; the "
            "device engine needs a DeviceClusteringAlgorithm "
            "(e.g. 'kmeans-device'), or use engine='host'")
    return algo


def compact_labels(raw_labels):
    """Host-side label compaction: device clusterings may emit
    non-contiguous ids (empty Lloyd clusters, convex root ids).  Returns
    (labels in [0, K'), uniq raw ids, first index per compact id)."""
    raw = np.asarray(raw_labels)
    uniq, first, labels = np.unique(raw, return_index=True,
                                    return_inverse=True)
    return labels.astype(np.int32), uniq, first


def materialize_round(new_params, res, state: FederatedState):
    """Host materialization of a device round: compacted labels + scalar
    meta are the ONLY transfers; params/opt state stay device pytrees.
    Returns ``(new_state, labels, info, uniq, first)`` — ``uniq`` the raw
    ids behind each compact label, ``first`` one member index per compact
    id (the session's routing/serving handles)."""
    labels, uniq, first = compact_labels(res.labels)
    meta = meta_to_host(res.meta)
    new_state = FederatedState(
        params=new_params,
        opt_state=jax.vmap(adamw_init)(new_params),
        n_clients=state.n_clients, step=state.step)
    info = {"n_clusters": int(len(uniq)), "meta": meta, "engine": "device"}
    return new_state, labels, info, uniq, first


def one_shot_aggregate_device(state: FederatedState, cfg=None, *,
                              algorithm="kmeans-device",
                              k: Optional[int] = None,
                              algo_options: Optional[dict] = None,
                              sketch_dim: int = 256, seed: int = 0,
                              cluster_seed: Optional[int] = None,
                              mesh=None, client_axis: str = "data",
                              aggregator="mean",
                              return_sketches: bool = False):
    """Device-resident one-shot aggregation. Returns (state, labels, info).

    ``algorithm`` must be device-capable (a ``DeviceClusteringAlgorithm``,
    e.g. the registered ``"kmeans-device"``).  ``cfg`` is optional and
    only consulted for the MoE router-invariant sketch filter — pass
    ``None`` for shallow per-client models (``launch/simulate.py``).
    ``seed`` drives the JL sketch; ``cluster_seed`` (default: ``seed``)
    drives the clustering init, mirroring the host path's seed split.
    ``aggregator`` names a registered per-cluster reduction (or passes
    an ``Aggregator`` instance) — the robust step-3 variants run inside
    the same jitted program.  With ``mesh`` given, the client axis of
    sketches and parameters is constrained to ``client_axis`` and XLA
    shards the round over it.
    """
    algo = resolve_device_algorithm(algorithm)
    aggregator = get_aggregator(aggregator)
    leaf_filter = (_router_invariant_filter
                   if cfg is not None and getattr(cfg, "is_moe", False)
                   else None)
    opts = tuple(sorted((algo_options or {}).items()))
    round_fn = cached_program(_round_program, algo, k, opts, sketch_dim,
                              leaf_filter, mesh, client_axis, aggregator)

    sketch_key = jax.random.PRNGKey(seed)
    cluster_key = jax.random.PRNGKey(
        seed if cluster_seed is None else cluster_seed)
    with obs.span("engine.one_shot", clients=state.n_clients,
                  algorithm=getattr(algo, "name", str(algo))):
        new_params, res, sketches = round_fn(sketch_key, cluster_key,
                                             state.params)

    new_state, labels, info, _, _ = materialize_round(new_params, res, state)
    if return_sketches:
        info["sketches"] = np.asarray(sketches)
    return new_state, labels, info
