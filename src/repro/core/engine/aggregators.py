"""Pluggable robust per-cluster aggregation — step 3 of Algorithm 1 as
a registry.

The paper's server averages models within each recovered cluster; under
a hostile client population (Byzantine uploads at fraction f, the
clustered-FL robustness setting of Ghosh et al.) the plain mean has a
breakdown point of zero — one colluding client drags its cluster's
model arbitrarily far.  This module makes the per-cluster reduction a
plugin, mirroring the clustering / edge-set registries
(``register_aggregator`` / ``get_aggregator`` / ``list_aggregators`` /
``unregister_aggregator``):

  * ``mean``          — the paper's step 3 (bit-exact with the
                        pre-registry ``cluster_average_tree`` path).
  * ``trimmed_mean``  — coordinate-wise beta-trimmed mean: per cluster
                        and coordinate, drop the t = floor(beta * cnt)
                        smallest and largest values and average the
                        rest.  Breakdown point beta.
  * ``median``        — coordinate-wise median per cluster.
  * ``geometric_median`` — fixed-iteration Weiszfeld in the full sketch
                        space: row-wise (not coordinate-wise) robust,
                        the defense against colluding spoof blobs that
                        beat coordinate-wise trims.  Breakdown 1/2.

Every aggregator is jit-traceable with static shapes: the segment-wise
order statistics run as ONE column-parallel ``jax.lax.sort`` keyed on
the cluster label (stable, two keys), so the reduction stays inside the
single jitted one-shot round — sketches, parameters, and per-cluster
aggregates never cross the host boundary, exactly like the mean path it
generalizes.

Signature contract (what a registered aggregator implements)::

    agg(flat, labels, onehot, counts) -> (K, n) float32

``flat`` is the (C, n) float32 stack of one flattened leaf, ``labels``
the (C,) int32 cluster ids in [0, K), ``onehot`` the (C, K) float32
indicator, ``counts`` the RAW (K,) float32 cluster sizes (empty
clusters are 0; aggregators clamp internally).  Empty clusters must
aggregate to 0 (the masked-matmul convention of the mean path — the
gather-back never reads them).

The tree-level wrappers ``cluster_reduce_tree`` (to (K, ...) cluster
representatives) and ``cluster_aggregate_tree`` (gather-back to
(C, ...) per-client models) are the shapes the engine, the streaming
session, and IFCA's round loop consume.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp


@runtime_checkable
class Aggregator(Protocol):
    """A per-cluster reduction usable inside the jitted round.

    ``breakdown`` is the aggregator's breakdown point (the largest
    in-cluster corruption fraction it tolerates): 0 for the mean, beta
    for the trimmed mean, 1/2 for the median.  The device Lloyd loop
    also reads it to make multi-restart *selection* robust — restarts
    are scored by the breakdown-trimmed inertia (the trimmed k-means
    objective of Cuesta-Albertos et al.), because a robust center
    update is worthless if the plain inertia still rewards the restart
    whose center was captured by a coherent attacker blob.
    """
    name: str
    breakdown: float = 0.0

    def __call__(self, flat: jnp.ndarray, labels: jnp.ndarray,
                 onehot: jnp.ndarray, counts: jnp.ndarray) -> jnp.ndarray: ...


# ------------------------------------------------- segment order statistics

def _segment_sort(flat, labels):
    """Column-wise stable sort of ``flat`` keyed on the cluster label.

    Returns ``(vals, sorted_labels, perm)``: ``vals[i, j]`` the i-th
    value of column j in (label, value) order, ``sorted_labels`` the
    (C,) ascending label of each sorted slot (identical across columns
    — the label is the primary key), ``perm[i, j]`` the original row
    behind sorted slot i of column j.
    """
    c, n = flat.shape
    lab_b = jnp.broadcast_to(labels[:, None].astype(jnp.int32), (c, n))
    row_b = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32)[:, None], (c, n))
    sl, vals, perm = jax.lax.sort((lab_b, flat, row_b), dimension=0,
                                  num_keys=2)
    return vals, sl[:, 0], perm


def _cluster_ranks(flat, labels):
    """(C, n) rank of every coordinate within its cluster's column.

    Ranks are scattered back to the ORIGINAL row layout, so masks built
    from them compose with the same ``onehot.T @ masked`` contraction as
    the mean — at trim budget 0 the masked matrix IS ``flat`` and the
    reduction is bit-exact with the mean aggregator.
    """
    c, n = flat.shape
    _, sl, perm = _segment_sort(flat, labels)
    pos = jnp.arange(c, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sl[1:] != sl[:-1]])
    seg_start = jax.lax.cummax(jnp.where(is_start, pos, 0))
    rank_sorted = jnp.broadcast_to((pos - seg_start)[:, None], (c, n))
    cols = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], (c, n))
    return jnp.zeros((c, n), jnp.int32).at[perm, cols].set(rank_sorted)


# ------------------------------------------------------------- aggregators

@dataclasses.dataclass(frozen=True)
class MeanAggregator:
    """The paper's step 3: masked per-cluster mean (breakdown point 0)."""
    name: str = "mean"
    breakdown = 0.0

    def __call__(self, flat, labels, onehot, counts):
        return (onehot.T @ flat) / jnp.maximum(counts, 1.0)[:, None]


@dataclasses.dataclass(frozen=True)
class TrimmedMeanAggregator:
    """Coordinate-wise beta-trimmed mean (breakdown point beta).

    Per cluster of size cnt the trim budget is
    ``t = min(floor(beta * cnt), (cnt - 1) // 2)`` — degenerate clusters
    (size 1, or smaller than the trim window) clamp t so at least one
    value always survives; at t = 0 the keep-mask is all-ones and the
    reduction is bit-exact with ``mean``.
    """
    beta: float = 0.1
    name: str = "trimmed_mean"

    @property
    def breakdown(self) -> float:
        return self.beta

    def __post_init__(self):
        if not 0.0 <= self.beta < 0.5:
            raise ValueError(f"trim fraction beta must be in [0, 0.5), "
                             f"got {self.beta}")

    def __call__(self, flat, labels, onehot, counts):
        cnt_i = counts.astype(jnp.int32)                          # (K,)
        t = jnp.minimum(jnp.floor(self.beta * counts).astype(jnp.int32),
                        jnp.maximum((cnt_i - 1) // 2, 0))
        rank = _cluster_ranks(flat, labels)                       # (C, n)
        t_row = t[labels][:, None]
        cnt_row = cnt_i[labels][:, None]
        keep = (rank >= t_row) & (rank < cnt_row - t_row)
        masked = jnp.where(keep, flat, jnp.zeros((), flat.dtype))
        denom = jnp.maximum(counts - 2.0 * t.astype(counts.dtype), 1.0)
        return (onehot.T @ masked) / denom[:, None]


@dataclasses.dataclass(frozen=True)
class GeometricMedianAggregator:
    """Per-cluster geometric median by fixed-iteration Weiszfeld
    (breakdown point 1/2 — and, unlike the coordinate-wise trims, a
    GENUINELY multivariate notion of center).

    A colluding-spoof attacker that concentrates every corrupted row on
    ONE shared point beats coordinate-wise trimming at fractions below
    the trim budget's bite (the blob survives partially in every
    coordinate and drags the mean of the survivors); the geometric
    median weights whole ROWS by inverse distance, so a coherent blob
    of fraction < 1/2 holds no leverage regardless of its geometry.

    ``iters`` fixed Weiszfeld steps run inside the jitted round (no
    host sync, no dynamic shapes): ``y <- sum_i w_i x_i / sum_i w_i``
    with ``w_i = [label_i == k] / max(||x_i - y||, eps)``.  Init is the
    masked per-cluster mean; size-1 clusters converge to their single
    member in one step; empty clusters aggregate to 0 per the registry
    contract.
    """
    iters: int = 16
    eps: float = 1e-8
    name: str = "geometric_median"
    breakdown = 0.5

    def __post_init__(self):
        if self.iters < 1:
            raise ValueError(f"iters must be >= 1, got {self.iters}")
        if self.eps <= 0:
            raise ValueError(f"eps must be > 0, got {self.eps}")

    def __call__(self, flat, labels, onehot, counts):
        denom = jnp.maximum(counts, 1.0)[:, None]                 # (K, 1)
        y0 = (onehot.T @ flat) / denom                            # (K, n)
        sq = jnp.sum(flat * flat, axis=1)                         # (C,)

        def step(_, y):
            # (C, K) pairwise distances via the expanded square (one
            # matmul; never materializes a (C, K, n) difference tensor)
            d2 = (sq[:, None] - 2.0 * (flat @ y.T)
                  + jnp.sum(y * y, axis=1)[None, :])
            d = jnp.sqrt(jnp.maximum(d2, 0.0))
            w = onehot / jnp.maximum(d, self.eps)                 # (C, K)
            return (w.T @ flat) / jnp.maximum(
                jnp.sum(w, axis=0), self.eps)[:, None]

        y = jax.lax.fori_loop(0, self.iters, step, y0)
        return jnp.where(counts[:, None] > 0, y,
                         jnp.zeros((), flat.dtype))


@dataclasses.dataclass(frozen=True)
class MedianAggregator:
    """Coordinate-wise per-cluster median (breakdown point 1/2).

    Gathers the two middle order statistics of every (cluster, column)
    segment from the stable segment sort; size-1 and size-2 clusters
    reduce bit-exactly to ``mean`` (a and (a + b) / 2).
    """
    name: str = "median"
    breakdown = 0.5

    def __call__(self, flat, labels, onehot, counts):
        c, _ = flat.shape
        cnt_i = counts.astype(jnp.int32)
        vals, _, _ = _segment_sort(flat, labels)
        starts = jnp.cumsum(cnt_i) - cnt_i                        # (K,)
        lo = jnp.clip(starts + (cnt_i - 1) // 2, 0, c - 1)
        hi = jnp.clip(starts + cnt_i // 2, 0, c - 1)
        med = 0.5 * (vals[lo] + vals[hi])                         # (K, n)
        return jnp.where(counts[:, None] > 0, med,
                         jnp.zeros((), flat.dtype))


# --------------------------------------------------------- tree wrappers

def _reduce_leaf(leaf, labels, onehot, counts, aggregator):
    flat = leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
    return aggregator(flat, labels, onehot, counts)


def cluster_reduce_tree(params, labels, onehot, counts, aggregator):
    """Step 3 alone through an aggregator: (K', ...) per-cluster
    representatives of a stacked pytree (the server-side state iterative
    methods carry between rounds)."""
    agg = get_aggregator(aggregator)
    k = onehot.shape[1]

    def red(leaf):
        means = _reduce_leaf(leaf, labels, onehot, counts, agg)
        return means.reshape((k,) + leaf.shape[1:]).astype(leaf.dtype)

    return jax.tree_util.tree_map(red, params)


def cluster_aggregate_tree(params, labels, onehot, counts, aggregator):
    """Steps 3-4 through an aggregator: per-cluster reduction of every
    leaf, gathered back per client (``onehot @ reduced``).  With the
    ``mean`` aggregator this is bit-exact with the pre-registry
    ``federated.cluster_average_tree`` path."""
    agg = get_aggregator(aggregator)

    def back(leaf):
        means = _reduce_leaf(leaf, labels, onehot, counts, agg)
        return (onehot @ means).reshape(leaf.shape).astype(leaf.dtype)

    return jax.tree_util.tree_map(back, params)


# ------------------------------------------------------------- registry

_AGGREGATORS: dict[str, Aggregator] = {}


def register_aggregator(agg: Aggregator, *, name: Optional[str] = None,
                        overwrite: bool = False) -> Aggregator:
    """Register a per-cluster aggregator. Returns it (decorator-safe)."""
    key = name if name is not None else agg.name
    if not key:
        raise ValueError("aggregator needs a non-empty name")
    if key in _AGGREGATORS and not overwrite:
        raise ValueError(f"aggregator {key!r} already registered "
                         "(pass overwrite=True to replace)")
    _AGGREGATORS[key] = agg
    return agg


def unregister_aggregator(name: str) -> None:
    """Remove a registered aggregator (used by tests/plugins)."""
    _AGGREGATORS.pop(name, None)


def get_aggregator(name) -> Aggregator:
    """Resolve a name (or pass through an instance) to an aggregator."""
    if not isinstance(name, str):
        return name
    try:
        return _AGGREGATORS[name]
    except KeyError:
        raise KeyError(f"unknown aggregator {name!r}; "
                       f"registered: {sorted(_AGGREGATORS)}") from None


def list_aggregators() -> tuple[str, ...]:
    """Names of every registered per-cluster aggregator."""
    return tuple(sorted(_AGGREGATORS))


def make_aggregator(name, **options: Any) -> Aggregator:
    """Resolve ``name`` and specialize its dataclass fields from
    ``options`` (unknown keys are ignored, like ``build_federated_method``
    — drivers pass one flat option superset)::

        make_aggregator("trimmed_mean", beta=0.2)
    """
    agg = get_aggregator(name)
    if options and dataclasses.is_dataclass(agg):
        fields = {f.name for f in dataclasses.fields(agg) if f.init}
        kept = {k: v for k, v in options.items()
                if k in fields and k != "name" and v is not None}
        if kept:
            agg = dataclasses.replace(agg, **kept)
    return agg


for _agg in (MeanAggregator(), TrimmedMeanAggregator(), MedianAggregator(),
             GeometricMedianAggregator()):
    register_aggregator(_agg)
del _agg
