"""Device-resident convex clustering — ODCL-CC inside the jitted round.

The host solver (``core/clustering/convex.py``) already runs its AMA
iteration on device, but extracts clusters with a NumPy union-find and
chooses lambdas with host-side probing — every one-shot aggregation
through the convex family therefore round-trips the sketch matrix
through host memory.  This module is the all-jnp, traceable port:

  * ``_ama_fixed_point`` — the Chi & Lange (2015) AMA splitting as a
    ``lax.while_loop`` with a tolerance/max-iter schedule, batched over
    a leading lambda axis so the clusterpath ladder advances all L
    solves in lock-step.  The inner dual prox is the group-prox Pallas
    kernel (``kernels.ops.group_ball_proj_batched``: compiled on TPU,
    interpret mode under ``REPRO_FORCE_PALLAS=1``, jnp oracle
    elsewhere).
  * ``_fusion_components`` — cluster extraction as iterated min-label
    propagation over the fusion graph (||u_i - u_j|| <= merge_tol),
    converging in graph-diameter steps; no host union-find.
  * ``device_convex_cluster`` / ``device_clusterpath`` — fixed-lambda
    ODCL-CC and the K-free lambda-ladder variant.  Everything returned
    is device-resident; labels are fusion-graph root ids in [0, m) and
    ``centers`` is root-indexed (one row per potential cluster, zero
    rows for non-roots), so the result plugs straight into the engine's
    one-hot cluster mean without dynamic shapes.

The registry adapters exposing these as ``"convex-device"`` /
``"clusterpath-device"`` live in ``core/clustering/api.py``; the host
solver remains the parity oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


class DeviceConvexResult(NamedTuple):
    """Device-resident result (every field is a jnp array)."""
    labels: jnp.ndarray       # (m,) int32 fusion-graph root id per point
    centers: jnp.ndarray      # (m, d) root-indexed cluster means of u
    u: jnp.ndarray            # (m, d) final fused representatives
    n_clusters: jnp.ndarray   # () int32 number of distinct roots
    n_iter: jnp.ndarray       # () int32 AMA iterations actually run
    lam: jnp.ndarray          # () float32 fusion penalty used


def _edges(m: int):
    """Static upper-triangular edge list of the complete graph."""
    iu, ju = np.triu_indices(m, k=1)
    return jnp.asarray(iu, jnp.int32), jnp.asarray(ju, jnp.int32)


def _ama_fixed_point(a, lams, weights, *, iters: int, tol: float):
    """Batched AMA: a (m, d), lams (L,), weights (E,) -> u (L, m, d).

    All L solves advance together inside one ``lax.while_loop``; the
    loop stops when every solve's dual update falls below the
    scale-aware tolerance or after ``iters`` iterations.  Mirrors the
    host ``_ama_solve`` update exactly (same eta = 1/m, same prox).
    """
    m, d = a.shape
    i_idx, j_idx = _edges(m)
    e = i_idx.shape[0]
    L = lams.shape[0]
    eta = 1.0 / m
    radius = lams[:, None] * weights[None, :]              # (L, E)
    thresh = tol * (1.0 + jnp.max(jnp.abs(a)))

    def u_of(nu):
        delta = jnp.zeros((L, m, d), jnp.float32)
        delta = delta.at[:, i_idx].add(nu).at[:, j_idx].add(-nu)
        return a[None] + delta

    def cond(carry):
        _, it, moved = carry
        return (it < iters) & (moved > thresh)

    def body(carry):
        nu, it, _ = carry
        u = u_of(nu)
        grad = u[:, i_idx] - u[:, j_idx]                   # (L, E, d)
        new_nu = kops.group_ball_proj_batched(nu - eta * grad, radius)
        # max dual step, rescaled by 1/eta to the primal's units
        moved = jnp.max(jnp.abs(new_nu - nu)) / eta
        return new_nu, it + 1, moved

    nu0 = jnp.zeros((L, e, d), jnp.float32)
    nu, n_iter, _ = jax.lax.while_loop(
        cond, body, (nu0, jnp.array(0, jnp.int32), jnp.array(jnp.inf)))
    return u_of(nu), n_iter


def _fusion_components(u, merge_tol):
    """Connected components of the fusion graph as min-label propagation.

    Each step every point adopts the smallest label among its fusion
    neighbours (||u_i - u_j|| <= merge_tol, self included); the loop
    reaches the component-min fixed point in graph-diameter steps.
    """
    m = u.shape[0]
    d2 = kops.pairwise_sqdist(u, u)
    adj = d2 <= merge_tol * merge_tol          # diag is 0 => self included

    def cond(carry):
        _, changed = carry
        return changed

    def body(carry):
        lab, _ = carry
        neigh = jnp.min(jnp.where(adj, lab[None, :], m), axis=1)
        new = jnp.minimum(lab, neigh).astype(jnp.int32)
        return new, jnp.any(new != lab)

    labels, _ = jax.lax.while_loop(
        cond, body, (jnp.arange(m, dtype=jnp.int32), jnp.array(True)))
    return labels


def _default_merge_tol(u):
    """Host parity: max(1e-6, 1e-3 * diameter of the fused u's)."""
    diam = jnp.max(jnp.linalg.norm(u - jnp.mean(u, axis=0, keepdims=True),
                                   axis=1)) + 1e-12
    return jnp.maximum(1e-6, 1e-3 * diam)


def _root_indexed_centers(u, labels):
    """(m, d) per-root cluster means + (m,) member counts of u's fusion
    components — static shapes, zero rows for non-root ids.  Segment
    scatter-adds, O(m d): an (m, m) one-hot contraction here would
    dominate peak memory once the clusterpath vmaps this over L rungs."""
    m, d = u.shape
    sums = jnp.zeros((m, d), jnp.float32).at[labels].add(u)
    counts = jnp.zeros((m,), jnp.float32).at[labels].add(1.0)
    centers = sums / jnp.maximum(counts, 1.0)[:, None]
    return centers, counts


def _extract(u, lam, n_iter, merge_tol) -> DeviceConvexResult:
    tol = _default_merge_tol(u) if merge_tol is None else merge_tol
    labels = _fusion_components(u, tol)
    centers, counts = _root_indexed_centers(u, labels)
    return DeviceConvexResult(
        labels=labels, centers=centers, u=u,
        n_clusters=jnp.sum(counts > 0).astype(jnp.int32),
        n_iter=jnp.asarray(n_iter, jnp.int32),
        lam=jnp.asarray(lam, jnp.float32))


def _min_pairwise_dist(a):
    d2 = kops.pairwise_sqdist(a, a)
    m = a.shape[0]
    off = jnp.where(jnp.eye(m, dtype=bool), jnp.inf, d2)
    return jnp.sqrt(jnp.min(off))


@functools.partial(jax.jit, static_argnames=("iters",))
def device_convex_cluster(key, points, *, lam=None, iters: int = 400,
                          tol: float = 1e-7, weights=None,
                          merge_tol=None) -> DeviceConvexResult:
    """Fixed-lambda sum-of-norms clustering, fully on device.

    ``lam=None`` reproduces the host default (the upper recovery bound
    (17) of the all-singletons clustering, min pairwise distance over
    2(m-1)) as a traced value.  ``key`` is unused (the solver is
    deterministic) but kept for the ``device_call`` protocol signature.
    """
    del key
    a = jnp.asarray(points, jnp.float32)
    m, d = a.shape
    e = m * (m - 1) // 2
    if e == 0:          # single client: nothing to fuse
        lam0 = jnp.asarray(1e-3 if lam is None else lam, jnp.float32)
        return _extract(a, lam0, jnp.array(0, jnp.int32), merge_tol)
    if lam is None:
        lam = _min_pairwise_dist(a) / (2.0 * (m - 1))
    lam = jnp.asarray(lam, jnp.float32)
    w = (jnp.ones((e,), jnp.float32) if weights is None
         else jnp.asarray(weights, jnp.float32))
    u, n_iter = _ama_fixed_point(a, lam[None], w, iters=iters, tol=tol)
    return _extract(u[0], lam, n_iter, merge_tol)


@functools.partial(jax.jit, static_argnames=("n_lambdas", "iters"))
def device_clusterpath(key, points, *, n_lambdas: int = 10,
                       iters: int = 300, tol: float = 1e-7,
                       merge_tol=None) -> DeviceConvexResult:
    """K-free lambda-ladder convex clustering, fully on device.

    A ladder of ``n_lambdas`` equidistant penalties (the host sweep's
    spacing) spans the singleton recovery bound (17) up to the
    complete-graph fusion regime (lam ~ 2 max_i ||a_i - abar|| / m,
    above the uniform-weight full-fusion threshold); the batched AMA
    advances
    every rung in lock-step (one (L, E, d) dual block through the
    batched group-prox kernel) and the clustering recovered by the most
    rungs wins (plurality plateau, K' > 1 breaking ties) — the
    device analogue of the host clusterpath's rule (b).  The host
    probe-and-verify refinement (rule (a), the interval check (17))
    stays host-side; parity tests compare recovered partitions, not the
    selection diagnostics.
    """
    del key
    a = jnp.asarray(points, jnp.float32)
    m, d = a.shape
    e = m * (m - 1) // 2
    if e == 0:
        return _extract(a, jnp.float32(1e-3), jnp.array(0, jnp.int32),
                        merge_tol)
    lam_lo = jnp.maximum(_min_pairwise_dist(a) / (2.0 * (m - 1)), 1e-8)
    centred = a - jnp.mean(a, axis=0, keepdims=True)
    lam_hi = jnp.maximum(
        2.0 * jnp.max(jnp.linalg.norm(centred, axis=1)) / m, lam_lo * 10.0)
    lams = jnp.linspace(lam_lo, lam_hi, n_lambdas).astype(jnp.float32)
    w = jnp.ones((e,), jnp.float32)
    u, n_iter = _ama_fixed_point(a, lams, w, iters=iters, tol=tol)

    def extract_one(u_l):
        tol_l = (_default_merge_tol(u_l) if merge_tol is None
                 else jnp.asarray(merge_tol, jnp.float32))
        labels_l = _fusion_components(u_l, tol_l)
        centers_l, counts_l = _root_indexed_centers(u_l, labels_l)
        return labels_l, centers_l, jnp.sum(counts_l > 0)

    labels_L, centers_L, ncl = jax.vmap(extract_one)(u)     # (L, ...)
    plurality = jnp.sum(ncl[None, :] == ncl[:, None], axis=1)
    sel = jnp.argmax(plurality * 2 + (ncl > 1))
    return DeviceConvexResult(
        labels=labels_L[sel], centers=centers_L[sel], u=u[sel],
        n_clusters=ncl[sel].astype(jnp.int32),
        n_iter=jnp.asarray(n_iter, jnp.int32),
        lam=lams[sel])
