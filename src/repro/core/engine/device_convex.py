"""Device-resident convex clustering — ODCL-CC inside the jitted round.

The host solver (``core/clustering/convex.py``) already runs its AMA
iteration on device, but extracts clusters with a NumPy union-find and
chooses lambdas with host-side probing — every one-shot aggregation
through the convex family therefore round-trips the sketch matrix
through host memory.  This module is the all-jnp, traceable port:

  * ``_ama_fixed_point`` — the Chi & Lange (2015) AMA splitting as a
    ``lax.while_loop`` with a tolerance/max-iter schedule, batched over
    a leading lambda axis so the clusterpath ladder advances all L
    solves in lock-step.  The inner dual prox is the group-prox Pallas
    kernel (``kernels.ops.group_ball_proj_batched``: compiled on TPU,
    interpret mode under ``REPRO_FORCE_PALLAS=1``, jnp oracle
    elsewhere).
  * the fusion graph is a pluggable ``EdgeSet`` (``engine/edges.py``):
    ``edges="complete"`` is the paper's all-pairs graph (bit-parity
    with the host solver, E = m(m-1)/2 — the C=4k wall), ``edges="knn"``
    the sparse mutual-kNN graph (E = m*k via a tiled top-k over the
    ``pairwise_l2`` kernel) that scales the family to C=16k+.
  * cluster extraction as iterated min-label propagation over the
    fusion graph (||u_i - u_j|| <= merge_tol), converging in
    graph-diameter steps; no host union-find.  The complete graph keeps
    the dense (m, m) propagation (exact PR-4 behaviour); sparse edge
    sets propagate over the edge list only, so the dense matrix is
    never materialized.
  * ``device_convex_cluster`` / ``device_clusterpath`` — fixed-lambda
    ODCL-CC and the K-free lambda-ladder variant.  Everything returned
    is device-resident; labels are fusion-graph root ids in [0, m) and
    ``centers`` is root-indexed (one row per potential cluster, zero
    rows for non-roots), so the result plugs straight into the engine's
    one-hot cluster mean without dynamic shapes.

The registry adapters exposing these as ``"convex-device"`` /
``"clusterpath-device"`` live in ``core/clustering/api.py``; the host
solver remains the parity oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.engine.edges import Edges, get_edge_set
from repro.kernels import ops as kops


class DeviceConvexResult(NamedTuple):
    """Device-resident result (every field is a jnp array)."""
    labels: jnp.ndarray       # (m,) int32 fusion-graph root id per point
    centers: jnp.ndarray      # (m, d) root-indexed cluster means of u
    u: jnp.ndarray            # (m, d) final fused representatives
    n_clusters: jnp.ndarray   # () int32 number of distinct roots
    n_iter: jnp.ndarray       # () int32 AMA iterations actually run
    lam: jnp.ndarray          # () float32 fusion penalty used
    nu: Optional[jnp.ndarray] = None
    #                           (E, d) final AMA dual (fixed-lambda path
    #                           only) — feed back as ``warm_nu`` to
    #                           warm-start the next solve on the same
    #                           edge set


def _ama_fixed_point(a, lams, edges: Edges, *, iters: int, tol: float,
                     nu0=None):
    """Batched AMA: a (m, d), lams (L,), edges E slots -> u (L, m, d).

    All L solves advance together inside one ``lax.while_loop``; the
    loop stops when every solve's dual update falls below the
    scale-aware tolerance or after ``iters`` iterations.  On the
    complete edge set this mirrors the host ``_ama_solve`` update
    exactly (same eta = 1/m, same prox); sparse edge sets use the
    builder's ``inv_eta`` (their incidence-spectrum bound).

    ``nu0`` warm-starts the dual ((L, E, d), e.g. the previous round's
    fixed point on the same edge set) — the AMA dual is feasible for
    any radius after the first prox, so a stale dual is a valid start
    that lands near the new fixed point when the data moved little.
    """
    m, d = a.shape
    i_idx, j_idx = edges.i_idx, edges.j_idx
    e = i_idx.shape[0]
    L = lams.shape[0]
    if e == 0:
        # degenerate edge set (m=1 falls back to an empty complete
        # graph): the objective has no fusion term, u == a is the fixed
        # point and the dual is the empty block.  jnp.max over the
        # zero-slot dual would be ill-defined, so short-circuit.
        u = jnp.broadcast_to(a[None], (L, m, d))
        return u, jnp.zeros((L, 0, d), jnp.float32), jnp.array(0, jnp.int32)
    eta = 1.0 / edges.inv_eta
    radius = lams[:, None] * edges.weights[None, :]         # (L, E)
    thresh = tol * (1.0 + jnp.max(jnp.abs(a)))

    def u_of(nu):
        delta = jnp.zeros((L, m, d), jnp.float32)
        delta = delta.at[:, i_idx].add(nu).at[:, j_idx].add(-nu)
        return a[None] + delta

    def cond(carry):
        _, it, moved = carry
        return (it < iters) & (moved > thresh)

    def body(carry):
        nu, it, _ = carry
        u = u_of(nu)
        grad = u[:, i_idx] - u[:, j_idx]                   # (L, E, d)
        new_nu = kops.group_ball_proj_batched(nu - eta * grad, radius)
        # max dual step, rescaled by 1/eta to the primal's units
        moved = jnp.max(jnp.abs(new_nu - nu)) / eta
        return new_nu, it + 1, moved

    if nu0 is None:
        nu0 = jnp.zeros((L, e, d), jnp.float32)
    else:
        nu0 = jnp.asarray(nu0, jnp.float32).reshape(L, e, d)
    nu, n_iter, _ = jax.lax.while_loop(
        cond, body, (nu0, jnp.array(0, jnp.int32), jnp.array(jnp.inf)))
    return u_of(nu), nu, n_iter


def _fusion_components_dense(u, merge_tol):
    """Connected components of the dense fusion graph as min-label
    propagation.

    Each step every point adopts the smallest label among its fusion
    neighbours (||u_i - u_j|| <= merge_tol, self included); the loop
    reaches the component-min fixed point in graph-diameter steps.
    Materializes the (m, m) distance matrix — complete-edge-set only.
    """
    m = u.shape[0]
    d2 = kops.pairwise_sqdist(u, u)
    adj = d2 <= merge_tol * merge_tol          # diag is 0 => self included

    def cond(carry):
        _, changed = carry
        return changed

    def body(carry):
        lab, _ = carry
        neigh = jnp.min(jnp.where(adj, lab[None, :], m), axis=1)
        new = jnp.minimum(lab, neigh).astype(jnp.int32)
        return new, jnp.any(new != lab)

    labels, _ = jax.lax.while_loop(
        cond, body, (jnp.arange(m, dtype=jnp.int32), jnp.array(True)))
    return labels


def _fusion_components_edges(u, i_idx, j_idx, merge_tol):
    """Min-label propagation restricted to the edge list — O(E) per
    step, never materializes (m, m).  Two points fuse only along a path
    of fused *edges*, which is the meaningful notion of the fusion
    graph on a sparse edge set (non-adjacent points never interact in
    the objective either)."""
    m = u.shape[0]
    du = u[i_idx] - u[j_idx]
    fused = jnp.sum(du * du, axis=1) <= merge_tol * merge_tol   # (E,)
    sentinel = jnp.asarray(m, jnp.int32)

    def cond(carry):
        _, changed = carry
        return changed

    def body(carry):
        lab, _ = carry
        cand = jnp.where(fused, jnp.minimum(lab[i_idx], lab[j_idx]),
                         sentinel)
        new = lab.at[i_idx].min(cand).at[j_idx].min(cand)
        return new, jnp.any(new != lab)

    labels, _ = jax.lax.while_loop(
        cond, body, (jnp.arange(m, dtype=jnp.int32), jnp.array(True)))
    return labels


def _default_merge_tol(u):
    """Host parity: max(1e-6, 1e-3 * diameter of the fused u's)."""
    diam = jnp.max(jnp.linalg.norm(u - jnp.mean(u, axis=0, keepdims=True),
                                   axis=1)) + 1e-12
    return jnp.maximum(1e-6, 1e-3 * diam)


def _root_indexed_centers(u, labels):
    """(m, d) per-root cluster means + (m,) member counts of u's fusion
    components — static shapes, zero rows for non-root ids.  Segment
    scatter-adds, O(m d): an (m, m) one-hot contraction here would
    dominate peak memory once the clusterpath vmaps this over L rungs."""
    m, d = u.shape
    sums = jnp.zeros((m, d), jnp.float32).at[labels].add(u)
    counts = jnp.zeros((m,), jnp.float32).at[labels].add(1.0)
    centers = sums / jnp.maximum(counts, 1.0)[:, None]
    return centers, counts


def _components(u, merge_tol, edge_set: Optional[Edges]):
    tol = _default_merge_tol(u) if merge_tol is None else merge_tol
    if edge_set is None:
        return _fusion_components_dense(u, tol)
    return _fusion_components_edges(u, edge_set.i_idx, edge_set.j_idx, tol)


def _extract(u, lam, n_iter, merge_tol,
             edge_set: Optional[Edges] = None, nu=None) -> DeviceConvexResult:
    labels = _components(u, merge_tol, edge_set)
    centers, counts = _root_indexed_centers(u, labels)
    return DeviceConvexResult(
        labels=labels, centers=centers, u=u,
        n_clusters=jnp.sum(counts > 0).astype(jnp.int32),
        n_iter=jnp.asarray(n_iter, jnp.int32),
        lam=jnp.asarray(lam, jnp.float32), nu=nu)


def _min_pairwise_dist(a):
    d2 = kops.pairwise_sqdist(a, a)
    m = a.shape[0]
    off = jnp.where(jnp.eye(m, dtype=bool), jnp.inf, d2)
    return jnp.sqrt(jnp.min(off))


def _build_edges(points, edges: str, knn_k: int) -> Edges:
    return get_edge_set(edges)(points, knn_k=knn_k)


def _nearest_dist(a, edge_set: Edges):
    """Min pairwise distance, free from the kNN builder when available."""
    if edge_set.min_dist is not None:
        return edge_set.min_dist
    return _min_pairwise_dist(a)


@functools.partial(jax.jit, static_argnames=("iters", "edges", "knn_k"))
def device_convex_cluster(key, points, *, lam=None, iters: int = 400,
                          tol: float = 1e-7, weights=None,
                          merge_tol=None, edges: str = "complete",
                          knn_k: int = 8,
                          warm_nu=None) -> DeviceConvexResult:
    """Fixed-lambda sum-of-norms clustering, fully on device.

    ``lam=None`` reproduces the host default (the upper recovery bound
    (17) of the all-singletons clustering, min pairwise distance over
    2(m-1)) as a traced value.  ``edges`` selects the registered fusion
    graph (``"complete"`` | ``"knn"``; ``knn_k`` neighbours for the
    latter).  ``weights`` overrides the edge set's per-slot weights
    (complete-graph (E,) order — only meaningful with the complete
    edge set).  ``warm_nu`` ((E, d), a previous result's ``.nu`` on an
    identically-shaped edge set) warm-starts the AMA dual — the
    session's incremental re-finalize path.  ``key`` is unused (the
    solver is deterministic) but kept for the ``device_call`` protocol
    signature.
    """
    del key
    a = jnp.asarray(points, jnp.float32)
    m, d = a.shape
    if m < 2:           # single client: nothing to fuse
        lam0 = jnp.asarray(1e-3 if lam is None else lam, jnp.float32)
        return _extract(a, lam0, jnp.array(0, jnp.int32), merge_tol)
    edge_set = _build_edges(a, edges, knn_k)
    if weights is not None:
        if edges != "complete":
            raise ValueError("explicit weights= are defined in complete-"
                             "graph edge order; use edge-set options for "
                             f"edges={edges!r}")
        edge_set = edge_set._replace(
            weights=jnp.asarray(weights, jnp.float32))
    if lam is None:
        lam = _nearest_dist(a, edge_set) / (2.0 * (m - 1))
    lam = jnp.asarray(lam, jnp.float32)
    nu0 = None if warm_nu is None else jnp.asarray(warm_nu, jnp.float32)[None]
    u, nu, n_iter = _ama_fixed_point(a, lam[None], edge_set, iters=iters,
                                     tol=tol, nu0=nu0)
    sparse = None if edges == "complete" else edge_set
    return _extract(u[0], lam, n_iter, merge_tol, sparse, nu=nu[0])


@functools.partial(jax.jit,
                   static_argnames=("n_lambdas", "iters", "edges", "knn_k"))
def device_clusterpath(key, points, *, n_lambdas: int = 10,
                       iters: int = 300, tol: float = 1e-7,
                       merge_tol=None, edges: str = "complete",
                       knn_k: int = 8) -> DeviceConvexResult:
    """K-free lambda-ladder convex clustering, fully on device.

    A ladder of ``n_lambdas`` equidistant penalties (the host sweep's
    spacing) spans the singleton recovery bound (17) up to the
    complete-graph fusion regime (lam ~ 2 max_i ||a_i - abar|| / m,
    above the uniform-weight full-fusion threshold); the batched AMA
    advances
    every rung in lock-step (one (L, E, d) dual block through the
    batched group-prox kernel) and the clustering recovered by the most
    rungs wins (plurality plateau, K' > 1 breaking ties) — the
    device analogue of the host clusterpath's rule (b).  The host
    probe-and-verify refinement (rule (a), the interval check (17))
    stays host-side; parity tests compare recovered partitions, not the
    selection diagnostics.  ``edges="knn"`` swaps in the sparse fusion
    graph (degree-normalized weights keep the ladder's lambda scales
    transferable).
    """
    del key
    a = jnp.asarray(points, jnp.float32)
    m, d = a.shape
    if m < 2:
        return _extract(a, jnp.float32(1e-3), jnp.array(0, jnp.int32),
                        merge_tol)
    edge_set = _build_edges(a, edges, knn_k)
    lam_lo = jnp.maximum(_nearest_dist(a, edge_set) / (2.0 * (m - 1)), 1e-8)
    centred = a - jnp.mean(a, axis=0, keepdims=True)
    lam_hi = jnp.maximum(
        2.0 * jnp.max(jnp.linalg.norm(centred, axis=1)) / m, lam_lo * 10.0)
    lams = jnp.linspace(lam_lo, lam_hi, n_lambdas).astype(jnp.float32)
    u, _, n_iter = _ama_fixed_point(a, lams, edge_set, iters=iters, tol=tol)
    sparse = None if edges == "complete" else edge_set

    def extract_one(u_l):
        tol_l = (None if merge_tol is None
                 else jnp.asarray(merge_tol, jnp.float32))
        labels_l = _components(u_l, tol_l, sparse)
        centers_l, counts_l = _root_indexed_centers(u_l, labels_l)
        return labels_l, centers_l, jnp.sum(counts_l > 0)

    labels_L, centers_L, ncl = jax.vmap(extract_one)(u)     # (L, ...)
    plurality = jnp.sum(ncl[None, :] == ncl[:, None], axis=1)
    sel = jnp.argmax(plurality * 2 + (ncl > 1))
    return DeviceConvexResult(
        labels=labels_L[sel], centers=centers_L[sel], u=u[sel],
        n_clusters=ncl[sel].astype(jnp.int32),
        n_iter=jnp.asarray(n_iter, jnp.int32),
        lam=lams[sel])
