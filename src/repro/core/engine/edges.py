"""Pluggable edge sets for the device convex-clustering family.

The AMA solver (``engine/device_convex.py``) is generic over the fusion
graph: the sum-of-norms penalty runs over an edge list, the dual state
is one (E, d) block, and the step size is governed by the unweighted
incidence spectrum.  Until now the graph was hardcoded to the complete
graph — E = C(C-1)/2 edges, which is the C=4k wall in BENCH_engine.json
(8.4M edges, 954s on this container).  This module makes the graph a
registry plugin:

  * ``Edges`` — the static-shape device representation every builder
    returns: upper-triangular ``(i_idx, j_idx)`` endpoint vectors,
    per-edge ``weights`` (0 marks an inert slot, e.g. a deduplicated
    mutual-kNN copy — a zero radius projects its dual to zero, so inert
    slots cost FLOPs but never move the solution), and ``inv_eta``, the
    reciprocal AMA step (``eta <= 1/rho(A A^T)`` for the unweighted
    incidence A).
  * ``CompleteEdges`` — the paper's choice (uniform weights over all
    pairs); ``inv_eta = m`` mirrors the host solver exactly.
  * ``KnnEdges`` — the sparse mutual-kNN graph: a tiled top-k over the
    ``pairwise_l2`` kernel (row tiles of the (m, m) distance matrix
    stream through ``kernels.ops.pairwise_sqdist``; the full matrix is
    never materialized), duplicate mutual pairs collapsed to one slot,
    E = m*k slots total.  Weights are degree-normalized to
    ``(m-1)/avg_degree`` so a fusion penalty lambda calibrated on the
    complete graph (the paper's interval (17)) transfers: the aggregate
    pull on a point matches the complete graph's.  ``inv_eta = 2 *
    max_degree`` (the unweighted-Laplacian bound).
  * ``register_edge_set`` / ``get_edge_set`` / ``list_edge_sets`` — the
    registry, mirroring the clustering and federated-method registries;
    new graphs (epsilon-balls, cluster-aware samplers, ...) drop in
    without touching the solver.

Builders are all-jnp and traceable — ``device_convex_cluster`` inlines
them into the jitted one-shot round, so C=16k convex clustering runs
with E = 16k * k edges instead of 134M.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


class Edges(NamedTuple):
    """Device-resident fusion graph with static shapes."""
    i_idx: jnp.ndarray            # (E,) int32, i < j on active slots
    j_idx: jnp.ndarray            # (E,) int32
    weights: jnp.ndarray          # (E,) float32, 0 = inert slot
    inv_eta: Any                  # () f32 (or python float), step = 1/inv_eta
    min_dist: Optional[jnp.ndarray] = None   # () min pairwise distance,
    #                                          when the builder gets it
    #                                          for free (kNN does)

    @property
    def n_edges(self) -> int:
        return int(self.i_idx.shape[0])


@runtime_checkable
class EdgeSet(Protocol):
    """A registered fusion-graph builder (all-jnp, traceable)."""
    name: str

    def __call__(self, points, **options: Any) -> Edges: ...


@dataclasses.dataclass(frozen=True)
class CompleteEdges:
    """All m(m-1)/2 pairs, uniform weight 1 — the paper's fusion graph.

    ``inv_eta = m`` (rho(A A^T) = m for the complete graph), identical
    to the host solver's hardcoded step, so the complete edge set keeps
    the device/host AMA parity bit-for-bit.
    """
    name: str = "complete"

    def __call__(self, points, **_: Any) -> Edges:
        m = points.shape[0]
        iu, ju = np.triu_indices(m, k=1)
        e = iu.shape[0]
        # inv_eta stays a python float: eta = 1/m is then computed in
        # host precision exactly as the host solver does (bit parity)
        return Edges(
            i_idx=jnp.asarray(iu, jnp.int32),
            j_idx=jnp.asarray(ju, jnp.int32),
            weights=jnp.ones((e,), jnp.float32),
            inv_eta=float(max(m, 1)))


def _tiled_topk(points, k: int, tile: int):
    """Per-row k nearest neighbours without the dense (m, m) matrix.

    Row tiles of the distance matrix stream through the ``pairwise_l2``
    kernel dispatch ((tile, m) at a time) and ``lax.top_k`` reduces each
    tile to its k smallest off-diagonal entries — peak memory O(tile*m)
    instead of O(m^2).  Returns (idx (m, k) int32, dist (m, k) f32).
    """
    m, d = points.shape
    tile = max(8, min(tile, m))
    mt = ((m + tile - 1) // tile) * tile
    blocks = jnp.pad(points, ((0, mt - m), (0, 0))).reshape(-1, tile, d)
    starts = jnp.arange(blocks.shape[0], dtype=jnp.int32) * tile
    cols = jnp.arange(m, dtype=jnp.int32)

    def one(_, blk_start):
        blk, start = blk_start
        d2 = kops.pairwise_sqdist(blk, points)              # (tile, m)
        rows = start + jnp.arange(tile, dtype=jnp.int32)
        d2 = jnp.where(rows[:, None] == cols[None, :], jnp.inf, d2)
        neg, idx = jax.lax.top_k(-d2, k)
        return _, (idx.astype(jnp.int32), -neg)

    _, (idx, d2) = jax.lax.scan(one, None, (blocks, starts))
    idx = idx.reshape(mt, k)[:m]
    d2 = d2.reshape(mt, k)[:m]
    return idx, jnp.sqrt(jnp.maximum(d2, 0.0))


@dataclasses.dataclass(frozen=True)
class KnnEdges:
    """Sparse mutual-kNN fusion graph — the C >> 4k convex edge set.

    E = m*k static slots (one per (row, neighbour) pair).  Each slot is
    canonicalized to (min, max); when a pair is mutually nearest the
    copy owned by the larger endpoint is zero-weighted, so every
    unordered edge contributes exactly once.  Active weights are the
    uniform degree-normalized value (m-1)/avg_degree: the total pull
    lambda * sum_j w_ij on a point matches the complete graph's
    lambda * (m-1), which keeps the paper's interval-(17) lambda scales
    meaningful on the sparse graph.
    """
    name: str = "knn"

    def __call__(self, points, *, knn_k: int = 8, tile: int = 1024,
                 **_: Any) -> Edges:
        m = points.shape[0]
        k = int(min(max(knn_k, 1), max(m - 1, 1)))
        if m < 2:
            return CompleteEdges()(points)
        idx, dist = _tiled_topk(points, k, tile)            # (m, k)
        rows = jnp.repeat(jnp.arange(m, dtype=jnp.int32), k)
        nbrs = idx.reshape(-1)
        # mutual-pair dedup: slot (i -> j) with i > j is a duplicate iff
        # i also appears in knn(j) — that edge already exists as (j -> i)
        back = idx[idx]                                     # (m, k, k)
        mutual = jnp.any(
            back == jnp.arange(m, dtype=jnp.int32)[:, None, None], axis=-1)
        keep = (rows < nbrs) | ~mutual.reshape(-1)
        i_idx = jnp.minimum(rows, nbrs)
        j_idx = jnp.maximum(rows, nbrs)
        n_active = jnp.maximum(jnp.sum(keep.astype(jnp.float32)), 1.0)
        avg_deg = 2.0 * n_active / m
        w0 = jnp.asarray(m - 1, jnp.float32) / avg_deg
        weights = jnp.where(keep, w0, 0.0)
        deg = (jnp.zeros((m,), jnp.float32)
               .at[i_idx].add(keep.astype(jnp.float32))
               .at[j_idx].add(keep.astype(jnp.float32)))
        inv_eta = jnp.maximum(2.0 * jnp.max(deg), 1.0)
        return Edges(i_idx=i_idx, j_idx=j_idx, weights=weights,
                     inv_eta=inv_eta, min_dist=jnp.min(dist))


# --------------------------------------------------------------- registry

_EDGE_SETS: dict[str, EdgeSet] = {}


def register_edge_set(builder: EdgeSet, *, name: Optional[str] = None,
                      overwrite: bool = False) -> EdgeSet:
    """Add a fusion-graph builder. Returns it (decorator-safe)."""
    key = name if name is not None else builder.name
    if not key:
        raise ValueError("edge set needs a non-empty name")
    if key in _EDGE_SETS and not overwrite:
        raise ValueError(f"edge set {key!r} already registered "
                         "(pass overwrite=True to replace)")
    _EDGE_SETS[key] = builder
    return builder


def unregister_edge_set(name: str) -> None:
    """Remove a registered edge set (used by tests/plugins)."""
    _EDGE_SETS.pop(name, None)


def get_edge_set(name) -> EdgeSet:
    """Resolve a name (or pass through an instance) to a builder."""
    if not isinstance(name, str):
        return name
    try:
        return _EDGE_SETS[name]
    except KeyError:
        raise KeyError(f"unknown edge set {name!r}; "
                       f"registered: {sorted(_EDGE_SETS)}") from None


def list_edge_sets() -> tuple[str, ...]:
    """Names of every registered fusion-graph builder."""
    return tuple(sorted(_EDGE_SETS))


for _b in (CompleteEdges(), KnnEdges()):
    register_edge_set(_b)
del _b
