"""Pluggable edge sets for the device convex-clustering family.

The AMA solver (``engine/device_convex.py``) is generic over the fusion
graph: the sum-of-norms penalty runs over an edge list, the dual state
is one (E, d) block, and the step size is governed by the unweighted
incidence spectrum.  Until now the graph was hardcoded to the complete
graph — E = C(C-1)/2 edges, which is the C=4k wall in BENCH_engine.json
(8.4M edges, 954s on this container).  This module makes the graph a
registry plugin:

  * ``Edges`` — the static-shape device representation every builder
    returns: upper-triangular ``(i_idx, j_idx)`` endpoint vectors,
    per-edge ``weights`` (0 marks an inert slot, e.g. a deduplicated
    mutual-kNN copy — a zero radius projects its dual to zero, so inert
    slots cost FLOPs but never move the solution), and ``inv_eta``, the
    reciprocal AMA step (``eta <= 1/rho(A A^T)`` for the unweighted
    incidence A).
  * ``CompleteEdges`` — the paper's choice (uniform weights over all
    pairs); ``inv_eta = m`` mirrors the host solver exactly.
  * ``KnnEdges`` — the sparse mutual-kNN graph: a tiled top-k over the
    ``pairwise_l2`` kernel (row tiles of the (m, m) distance matrix
    stream through ``kernels.ops.pairwise_sqdist``; the full matrix is
    never materialized), duplicate mutual pairs collapsed to one slot,
    E = m*k slots total.  Weights are degree-normalized to
    ``(m-1)/avg_degree`` so a fusion penalty lambda calibrated on the
    complete graph (the paper's interval (17)) transfers: the aggregate
    pull on a point matches the complete graph's.  ``inv_eta = 2 *
    max_degree`` (the unweighted-Laplacian bound).
  * ``ApproxKnnEdges`` (``"knn-approx"``) — the exact tiled top-k still
    streams all m^2 distances, the remaining O(m^2) wall of the convex
    family.  This builder replaces it with an LSH candidate stage:
    ``n_tables`` random projection directions each impose a sorted
    1-D order on the sketches (projection LSH — nearby points land at
    nearby ranks w.h.p.), the sorted order is cut into ``bucket``-sized
    buckets, and the EXACT top-k runs only within each bucket and its
    two neighbours (3*bucket candidates per point, per table); per-row
    results merge across tables by index-dedup + top-k.  Edge assembly
    (mutual dedup, degree-normalized weights, ``inv_eta``) is shared
    with ``KnnEdges``, so the solver sees an identical ``Edges``
    contract — the distance work drops from O(m^2 d) to
    O(m * tables * bucket * d).  Small inputs (m <= 3*bucket, where the
    candidate window already covers everything) fall back to the exact
    builder bit-for-bit.
  * ``register_edge_set`` / ``get_edge_set`` / ``list_edge_sets`` — the
    registry, mirroring the clustering and federated-method registries;
    new graphs (epsilon-balls, cluster-aware samplers, ...) drop in
    without touching the solver.

Builders are all-jnp and traceable — ``device_convex_cluster`` inlines
them into the jitted one-shot round, so C=16k convex clustering runs
with E = 16k * k edges instead of 134M.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops


class Edges(NamedTuple):
    """Device-resident fusion graph with static shapes."""
    i_idx: jnp.ndarray            # (E,) int32, i < j on active slots
    j_idx: jnp.ndarray            # (E,) int32
    weights: jnp.ndarray          # (E,) float32, 0 = inert slot
    inv_eta: Any                  # () f32 (or python float), step = 1/inv_eta
    min_dist: Optional[jnp.ndarray] = None   # () min pairwise distance,
    #                                          when the builder gets it
    #                                          for free (kNN does)

    @property
    def n_edges(self) -> int:
        return int(self.i_idx.shape[0])


@runtime_checkable
class EdgeSet(Protocol):
    """A registered fusion-graph builder (all-jnp, traceable)."""
    name: str

    def __call__(self, points, **options: Any) -> Edges: ...


# Above this many points the complete graph's host-side index arrays
# alone (two int64 vectors of m(m-1)/2 entries from np.triu_indices)
# cross the ~4 GB line and climb quadratically — m=65k is ~34 GB, which
# OOM-kills the container long before the solver even starts.  The
# sparse builders exist precisely for that regime, so refuse loudly
# instead of letting the allocation take the process down.
COMPLETE_EDGES_MAX_M = 16384


@dataclasses.dataclass(frozen=True)
class CompleteEdges:
    """All m(m-1)/2 pairs, uniform weight 1 — the paper's fusion graph.

    ``inv_eta = m`` (rho(A A^T) = m for the complete graph), identical
    to the host solver's hardcoded step, so the complete edge set keeps
    the device/host AMA parity bit-for-bit.  Above
    ``COMPLETE_EDGES_MAX_M`` points the quadratic edge list is refused
    (``max_m=`` overrides) — use the sparse ``edges="knn"`` /
    ``"knn-approx"`` builders there.
    """
    name: str = "complete"

    def __call__(self, points, *, max_m: int = COMPLETE_EDGES_MAX_M,
                 **_: Any) -> Edges:
        m = points.shape[0]
        if m > max_m:
            raise ValueError(
                f"edges='complete' on m={m} points would build "
                f"{m * (m - 1) // 2:,} edges (~"
                f"{m * (m - 1) * 8 / 1e9:.0f} GB of host index arrays "
                f"alone); use the sparse edges='knn' or "
                f"edges='knn-approx' fusion graphs above m={max_m}, or "
                "pass max_m= to raise the guard deliberately")
        iu, ju = np.triu_indices(m, k=1)
        e = iu.shape[0]
        # inv_eta stays a python float: eta = 1/m is then computed in
        # host precision exactly as the host solver does (bit parity)
        return Edges(
            i_idx=jnp.asarray(iu, jnp.int32),
            j_idx=jnp.asarray(ju, jnp.int32),
            weights=jnp.ones((e,), jnp.float32),
            inv_eta=float(max(m, 1)))


def _tiled_topk(points, k: int, tile: int):
    """Per-row k nearest neighbours without the dense (m, m) matrix.

    Row tiles of the distance matrix stream through the ``pairwise_l2``
    kernel dispatch ((tile, m) at a time) and ``lax.top_k`` reduces each
    tile to its k smallest off-diagonal entries — peak memory O(tile*m)
    instead of O(m^2).  Returns (idx (m, k) int32, dist (m, k) f32).
    """
    m, d = points.shape
    tile = max(8, min(tile, m))
    mt = ((m + tile - 1) // tile) * tile
    blocks = jnp.pad(points, ((0, mt - m), (0, 0))).reshape(-1, tile, d)
    starts = jnp.arange(blocks.shape[0], dtype=jnp.int32) * tile
    cols = jnp.arange(m, dtype=jnp.int32)

    def one(_, blk_start):
        blk, start = blk_start
        d2 = kops.pairwise_sqdist(blk, points)              # (tile, m)
        rows = start + jnp.arange(tile, dtype=jnp.int32)
        d2 = jnp.where(rows[:, None] == cols[None, :], jnp.inf, d2)
        neg, idx = jax.lax.top_k(-d2, k)
        return _, (idx.astype(jnp.int32), -neg)

    _, (idx, d2) = jax.lax.scan(one, None, (blocks, starts))
    idx = idx.reshape(mt, k)[:m]
    d2 = d2.reshape(mt, k)[:m]
    return idx, jnp.sqrt(jnp.maximum(d2, 0.0))


def _edges_from_neighbors(idx, dist) -> Edges:
    """Assemble the mutual-kNN ``Edges`` from per-row neighbour lists.

    Shared by the exact and approximate builders: E = m*k static slots
    (one per (row, neighbour) pair), each canonicalized to (min, max);
    when a pair is mutually nearest the copy owned by the larger
    endpoint is zero-weighted, so every unordered edge contributes
    exactly once.  Active weights are the uniform degree-normalized
    value (m-1)/avg_degree: the total pull lambda * sum_j w_ij on a
    point matches the complete graph's lambda * (m-1), which keeps the
    paper's interval-(17) lambda scales meaningful on the sparse graph.
    """
    m, k = idx.shape
    rows = jnp.repeat(jnp.arange(m, dtype=jnp.int32), k)
    nbrs = idx.reshape(-1)
    # mutual-pair dedup: slot (i -> j) with i > j is a duplicate iff
    # i also appears in knn(j) — that edge already exists as (j -> i)
    back = idx[idx]                                     # (m, k, k)
    mutual = jnp.any(
        back == jnp.arange(m, dtype=jnp.int32)[:, None, None], axis=-1)
    keep = (rows < nbrs) | ~mutual.reshape(-1)
    i_idx = jnp.minimum(rows, nbrs)
    j_idx = jnp.maximum(rows, nbrs)
    n_active = jnp.maximum(jnp.sum(keep.astype(jnp.float32)), 1.0)
    avg_deg = 2.0 * n_active / m
    w0 = jnp.asarray(m - 1, jnp.float32) / avg_deg
    weights = jnp.where(keep, w0, 0.0)
    deg = (jnp.zeros((m,), jnp.float32)
           .at[i_idx].add(keep.astype(jnp.float32))
           .at[j_idx].add(keep.astype(jnp.float32)))
    inv_eta = jnp.maximum(2.0 * jnp.max(deg), 1.0)
    return Edges(i_idx=i_idx, j_idx=j_idx, weights=weights,
                 inv_eta=inv_eta, min_dist=jnp.min(dist))


@dataclasses.dataclass(frozen=True)
class KnnEdges:
    """Sparse mutual-kNN fusion graph — the C >> 4k convex edge set.

    Exact per-row k nearest neighbours (``_tiled_topk`` streams row
    tiles of the distance matrix, O(tile*m) peak memory but still
    O(m^2 d) distance work), assembled by ``_edges_from_neighbors``.
    """
    name: str = "knn"

    def __call__(self, points, *, knn_k: int = 8, tile: int = 1024,
                 **_: Any) -> Edges:
        m = points.shape[0]
        k = int(min(max(knn_k, 1), max(m - 1, 1)))
        if m < 2:
            return CompleteEdges()(points)
        idx, dist = _tiled_topk(points, k, tile)            # (m, k)
        return _edges_from_neighbors(idx, dist)


def _bucketed_topk(points, k: int, *, n_tables: int, bucket: int, seed: int):
    """Approximate per-row k nearest neighbours via projection LSH.

    Each table draws one random unit-less direction, sorts the points by
    their 1-D projection (nearby points land at nearby ranks with high
    probability), cuts the sorted order into ``bucket``-sized buckets,
    and runs the exact top-k against each bucket's own + two adjacent
    buckets (3*bucket candidates, so every point sees its full sorted
    neighbourhood regardless of where the bucket boundary falls).
    Tables merge by per-row index-dedup + top-k.  Distance work is
    O(m * n_tables * bucket * d); the (m, m) matrix is never touched.
    Returns (idx (m, k) int32, d2 (m, k) f32 squared distances).
    """
    m, d = points.shape
    key = jax.random.PRNGKey(seed)
    nb = (m + bucket - 1) // bucket
    mp = nb * bucket
    pad_rows = mp - m

    def one_table(t):
        vt = jax.random.normal(jax.random.fold_in(key, t), (d,), jnp.float32)
        order = jnp.argsort(points @ vt).astype(jnp.int32)       # (m,)
        # pad the sorted order with sentinel index m (masked below) and
        # far-away points so pads never win a top-k slot
        order_p = jnp.concatenate(
            [order, jnp.full((pad_rows,), m, jnp.int32)])
        pts_p = jnp.concatenate(
            [points[order], jnp.full((pad_rows, d), 1e30, jnp.float32)])
        blocks = pts_p.reshape(nb, bucket, d)
        idx_blocks = order_p.reshape(nb, bucket)
        cands = jnp.concatenate([jnp.roll(blocks, 1, axis=0), blocks,
                                 jnp.roll(blocks, -1, axis=0)], axis=1)
        cand_idx = jnp.concatenate(
            [jnp.roll(idx_blocks, 1, axis=0), idx_blocks,
             jnp.roll(idx_blocks, -1, axis=0)], axis=1)          # (nb, 3B)
        d2 = jax.vmap(kops.pairwise_sqdist)(blocks, cands)       # (nb,B,3B)
        invalid = ((cand_idx[:, None, :] == idx_blocks[:, :, None])
                   | (cand_idx[:, None, :] >= m))    # self + pad slots
        d2 = jnp.where(invalid, jnp.inf, d2)
        neg, sel = jax.lax.top_k(-d2, k)                         # (nb,B,k)
        nbr = jnp.take_along_axis(cand_idx[:, None, :], sel, axis=2)
        # unsort back to original row order (pad rows sliced off first)
        idx_t = jnp.zeros((m, k), jnp.int32).at[order].set(
            nbr.reshape(mp, k)[:m].astype(jnp.int32))
        d2_t = jnp.zeros((m, k), jnp.float32).at[order].set(
            (-neg).reshape(mp, k)[:m])
        return idx_t, d2_t

    idx_all, d2_all = [], []
    for t in range(n_tables):       # static unroll, n_tables is small
        it, dt = one_table(t)
        idx_all.append(it)
        d2_all.append(dt)
    idx_all = jnp.concatenate(idx_all, axis=1)                   # (m, T*k)
    d2_all = jnp.concatenate(d2_all, axis=1)
    # cross-table dedup: sort candidates by index, inf-out repeats (the
    # same neighbour found by two tables has the same distance), top-k
    ord_ = jnp.argsort(idx_all, axis=1)
    idx_s = jnp.take_along_axis(idx_all, ord_, axis=1)
    d2_s = jnp.take_along_axis(d2_all, ord_, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros((m, 1), bool), idx_s[:, 1:] == idx_s[:, :-1]], axis=1)
    d2_s = jnp.where(dup, jnp.inf, d2_s)
    neg, sel = jax.lax.top_k(-d2_s, k)
    return jnp.take_along_axis(idx_s, sel, axis=1), -neg


@dataclasses.dataclass(frozen=True)
class ApproxKnnEdges:
    """Approximate mutual-kNN fusion graph — the C >> 100k convex edge
    set.

    The candidate stage (``_bucketed_topk``) replaces the exact
    builder's O(m^2 d) streamed distance matrix with projection-LSH
    bucketing + exact top-k within bucket windows; edge assembly is
    byte-identical with ``KnnEdges``.  ``min_dist`` is the minimum over
    the *found* neighbour distances — on the sparse graph that is
    already the quantity the lambda heuristics consume.  When the
    candidate window covers the whole input (m <= 3*bucket) the exact
    builder runs instead, bit-for-bit.
    """
    name: str = "knn-approx"

    def __call__(self, points, *, knn_k: int = 8, n_tables: int = 4,
                 bucket: Optional[int] = None, seed: int = 0,
                 tile: int = 1024, **_: Any) -> Edges:
        m = points.shape[0]
        k = int(min(max(knn_k, 1), max(m - 1, 1)))
        if m < 2:
            return CompleteEdges()(points)
        if bucket is None:
            bucket = max(8 * k, 64)
        bucket = max(int(bucket), k + 1)
        if m <= 3 * bucket:
            # the window already spans every point: exact is both
            # cheaper and a strictly better answer
            idx, dist = _tiled_topk(points, k, tile)
            return _edges_from_neighbors(idx, dist)
        idx, d2 = _bucketed_topk(points, k, n_tables=int(n_tables),
                                 bucket=bucket, seed=int(seed))
        return _edges_from_neighbors(idx, jnp.sqrt(jnp.maximum(d2, 0.0)))


# --------------------------------------------------------------- registry

_EDGE_SETS: dict[str, EdgeSet] = {}


def register_edge_set(builder: EdgeSet, *, name: Optional[str] = None,
                      overwrite: bool = False) -> EdgeSet:
    """Add a fusion-graph builder. Returns it (decorator-safe)."""
    key = name if name is not None else builder.name
    if not key:
        raise ValueError("edge set needs a non-empty name")
    if key in _EDGE_SETS and not overwrite:
        raise ValueError(f"edge set {key!r} already registered "
                         "(pass overwrite=True to replace)")
    _EDGE_SETS[key] = builder
    return builder


def unregister_edge_set(name: str) -> None:
    """Remove a registered edge set (used by tests/plugins)."""
    _EDGE_SETS.pop(name, None)


def get_edge_set(name) -> EdgeSet:
    """Resolve a name (or pass through an instance) to a builder."""
    if not isinstance(name, str):
        return name
    try:
        return _EDGE_SETS[name]
    except KeyError:
        raise KeyError(f"unknown edge set {name!r}; "
                       f"registered: {sorted(_EDGE_SETS)}") from None


def list_edge_sets() -> tuple[str, ...]:
    """Names of every registered fusion-graph builder."""
    return tuple(sorted(_EDGE_SETS))


for _b in (CompleteEdges(), KnnEdges(), ApproxKnnEdges()):
    register_edge_set(_b)
del _b
