"""Device-resident aggregation engine — Algorithm 1 with no host round-trip.

The paper's one-shot protocol, step by step, and where each step runs
in this subsystem:

  step 1  (every user solves its local ERM and uploads theta_hat_i)
          — upstream of the engine: ``federated.local_training`` at LM
          scale, or the batched vmap-wave ERMs of ``launch/simulate.py``
          for C = 10k-100k shallow clients.  "Upload" is the JL sketch:
          ``engine/aggregate.py`` vmaps ``core.sketch.sketch_tree`` over
          the client axis, producing the device-resident (C, sketch_dim)
          matrix (communication: sketch_dim floats per client).
  step 2  (the server clusters {theta_hat_i} with an admissible
          algorithm) — one module per admissible family:
          ``engine/device_kmeans.py`` is a Lloyd loop whose
          assign+accumulate is the fused Pallas kernel
          ``kernels/kmeans_assign.py`` (jnp oracle / interpret mode
          off-TPU), hardened for huge C with multi-restart
          (``restarts=r`` vmapped inits, best inertia wins) and
          minibatch updates (``batch_m``); ``engine/device_convex.py``
          is the convex/clusterpath family — the AMA fixed point as a
          ``lax.while_loop`` over the ``kernels/group_prox.py`` dual
          prox (batched over the lambda ladder), with fusion-graph
          cluster extraction by iterated min-label propagation.  Both
          register through the ``DeviceClusteringAlgorithm`` protocol
          variant (``clustering/api.py``) that takes and returns jnp
          arrays, as ``"kmeans-device"`` and ``"convex-device"`` /
          ``"clusterpath-device"``; the host names ``"convex"`` /
          ``"clusterpath"`` auto-upgrade to their twins under
          ``engine='auto'|'device'``.
  step 3  (the server averages models within each recovered cluster)
          — the pluggable per-cluster reduction of
          ``engine/aggregators.py`` (``mean`` | coordinate-wise
          ``trimmed_mean(beta)`` | ``median``, a registry mirroring the
          clustering one), fused into the same jitted program as steps
          1-2; the robust variants run as a static-shape segment sort
          per cluster, no host transfer.
  step 4  (each user receives its cluster's model) — the gather-back
          ``onehot @ means``; under a mesh both 3 and 4 lower to psums
          over the ``data``-sharded client axis.

The host-side path (``core/clustering/{kmeans,convex}.py`` +
``federated.one_shot_aggregate(engine="host")``) is kept as the parity
oracle; ``federated.one_shot_aggregate`` auto-dispatches here whenever
the chosen algorithm is device-capable or has a device twin.

Two server-shape layers sit on top of the fused round:

  * ``engine/session.py`` — ``AggregationSession``, the streaming
    server API: ``ingest`` accumulates the (C, sketch_dim) sketch
    matrix wave by wave in a fixed-capacity device buffer,
    ``finalize`` runs steps 2-4 through the same traced body as the
    fused round (bit-exact), and ``route``/``cluster_model`` serve
    never-seen clients by nearest sketch-space cluster.
  * ``engine/edges.py`` — the pluggable fusion-graph registry for the
    convex family (``complete`` | ``knn``); the sparse mutual-kNN
    builder (tiled top-k over the ``pairwise_l2`` kernel) is what takes
    ``convex-device`` past the complete graph's C=4k edge wall.

Extension point (worked example: the convex family): implement a
normal registry algorithm that additionally offers ``device_call(key,
jnp_points, *, k, **options) -> DeviceClusteringResult`` — all-jnp and
traceable, like ``device_convex_cluster`` — and ``register_algorithm``
it; register it under ``"<host-name>-device"`` and the host name
auto-upgrades too.
"""
from repro.core.engine.aggregators import (
    Aggregator,
    GeometricMedianAggregator,
    MeanAggregator,
    MedianAggregator,
    TrimmedMeanAggregator,
    cluster_aggregate_tree,
    cluster_reduce_tree,
    get_aggregator,
    list_aggregators,
    make_aggregator,
    register_aggregator,
    unregister_aggregator,
)
from repro.core.engine.device_convex import (
    DeviceConvexResult,
    device_clusterpath,
    device_convex_cluster,
)
from repro.core.engine.device_kmeans import DeviceKMeansResult, device_kmeans
from repro.core.engine.edges import (
    ApproxKnnEdges,
    CompleteEdges,
    Edges,
    EdgeSet,
    KnnEdges,
    get_edge_set,
    list_edge_sets,
    register_edge_set,
    unregister_edge_set,
)
from repro.core.engine.staleness import (
    ExpDecay,
    NoStaleness,
    SlidingWindow,
    make_staleness_policy,
)

__all__ = [
    "AggregationSession",
    "Aggregator",
    "ApproxKnnEdges",
    "CompleteEdges",
    "HierarchicalSession",
    "hierarchical_one_shot_aggregate",
    "DeviceConvexResult",
    "DeviceKMeansResult",
    "Edges",
    "EdgeSet",
    "ExpDecay",
    "GeometricMedianAggregator",
    "KnnEdges",
    "MeanAggregator",
    "MedianAggregator",
    "NoStaleness",
    "SlidingWindow",
    "TrimmedMeanAggregator",
    "make_staleness_policy",
    "cluster_aggregate_tree",
    "cluster_reduce_tree",
    "device_clusterpath",
    "device_convex_cluster",
    "device_kmeans",
    "get_aggregator",
    "list_aggregators",
    "make_aggregator",
    "one_shot_aggregate_device",
    "register_aggregator",
    "register_edge_set",
    "unregister_aggregator",
    "unregister_edge_set",
]


def __getattr__(name):
    # lazy: aggregate.py/session.py import federated.py (models,
    # launch.steps); loading that eagerly from clustering/api.py's
    # registration import would both slow light imports and close an
    # import cycle
    if name == "one_shot_aggregate_device":
        from repro.core.engine.aggregate import one_shot_aggregate_device
        return one_shot_aggregate_device
    if name == "AggregationSession":
        from repro.core.engine.session import AggregationSession
        return AggregationSession
    if name == "HierarchicalSession":
        from repro.core.engine.hierarchy import HierarchicalSession
        return HierarchicalSession
    if name == "hierarchical_one_shot_aggregate":
        from repro.core.engine.hierarchy import hierarchical_one_shot_aggregate
        return hierarchical_one_shot_aggregate
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
