"""Device-resident Lloyd loop — the clustering half of the aggregation
engine.

Unlike ``core/clustering/kmeans.py`` (the host parity oracle, which
materializes an (m, k) one-hot in HBM at every update), the
assign+accumulate step here is the fused kernel behind
``kernels.ops.kmeans_assign``: the compiled Pallas kernel
``kernels/kmeans_assign.py`` on TPU, its interpret-mode build under
``REPRO_FORCE_PALLAS=1``, and the pure-jnp oracle elsewhere.  Per Lloyd
iteration the only materialized state is the (k, d) sums / (k,) counts
accumulator, so the loop scales to C >> 1k sketch rows and stays fully
traceable inside the jitted one-shot round (``engine/aggregate.py``).

Everything returned is device-resident (no NumPy boundary); the
registry adapter that exposes this loop as the ``kmeans-device``
algorithm lives in ``core/clustering/api.py``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


class DeviceKMeansResult(NamedTuple):
    """Device-resident result (every field is a jnp array)."""
    labels: jnp.ndarray     # (m,) int32 cluster assignment
    centers: jnp.ndarray    # (k, d) float32 cluster centers
    inertia: jnp.ndarray    # () sum of squared distances to assigned center
    n_iter: jnp.ndarray     # () Lloyd iterations actually run


@functools.partial(jax.jit, static_argnames=("k", "iters", "init"))
def device_kmeans(key, points, k: int, iters: int = 50,
                  init: str = "kmeans++", tol: float = 1e-8) -> DeviceKMeansResult:
    """Lloyd's algorithm with the fused assign+accumulate kernel.

    Mirrors ``clustering.kmeans.kmeans`` exactly (same inits, same
    early-freeze update rule) so that identical (key, points, k, init)
    produce identical center trajectories — the parity tests rely on
    this.  The difference is purely mechanical: the per-iteration
    reduction never builds the (m, k) one-hot, and the result stays on
    device.
    """
    # local import: clustering.api registers the adapter for this loop,
    # so a module-level import here would be circular
    from repro.core.clustering.kmeans import kmeans_plus_plus_init, spectral_init

    points = points.astype(jnp.float32)
    m, d = points.shape
    if init == "kmeans++":
        centers = kmeans_plus_plus_init(key, points, k)
    elif init == "spectral":
        centers = spectral_init(points, k)
    elif init == "random":
        sel = jax.random.choice(key, m, (k,), replace=False)
        centers = points[sel]
    else:  # pragma: no cover - guarded by static arg
        raise ValueError(f"unknown init {init!r}")

    def body(carry, _):
        centers, done, it = carry
        _, sums, counts = kops.kmeans_assign(points, centers)
        means = sums / jnp.maximum(counts, 1.0)[:, None]
        new_centers = jnp.where(counts[:, None] > 0, means, centers)
        moved = jnp.max(jnp.sum((new_centers - centers) ** 2, axis=1))
        new_done = done | (moved < tol)
        centers = jnp.where(done, centers, new_centers)
        return (centers, new_done, it + jnp.where(done, 0, 1)), None

    (centers, _, n_iter), _ = jax.lax.scan(
        body, (centers, jnp.array(False), jnp.array(0, jnp.int32)), None,
        length=iters)

    labels, sums, counts = kops.kmeans_assign(points, centers)
    # inertia from the accumulator instead of an (m, k) distance matrix:
    # sum_i ||x_i - c_{l(i)}||^2
    #   = sum ||x||^2 - 2 sum_k <sums_k, c_k> + sum_k counts_k ||c_k||^2
    inertia = (jnp.sum(points * points)
               - 2.0 * jnp.sum(sums * centers)
               + jnp.sum(counts * jnp.sum(centers * centers, axis=1)))
    return DeviceKMeansResult(labels=labels, centers=centers,
                              inertia=jnp.maximum(inertia, 0.0),
                              n_iter=n_iter)
