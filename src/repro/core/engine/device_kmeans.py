"""Device-resident Lloyd loop — the clustering half of the aggregation
engine.

Unlike ``core/clustering/kmeans.py`` (the host parity oracle, which
materializes an (m, k) one-hot in HBM at every update), the
assign+accumulate step here is the fused kernel behind
``kernels.ops.kmeans_assign``: the compiled Pallas kernel
``kernels/kmeans_assign.py`` on TPU, its interpret-mode build under
``REPRO_FORCE_PALLAS=1``, and the pure-jnp oracle elsewhere.  Per Lloyd
iteration the only materialized state is the (k, d) sums / (k,) counts
accumulator, so the loop scales to C >> 1k sketch rows and stays fully
traceable inside the jitted one-shot round (``engine/aggregate.py``).

Two huge-C hardening knobs on top of the plain loop:

  * ``restarts=r`` — run r independent inits (vmapped over restart
    keys) and keep the best-inertia clustering.  The restart-key fan
    always includes the caller's key itself, so ``restarts=r`` inertia
    is monotonically <= the single-restart run for the same key — the
    guard against kmeans++ D^2 seeding's merge/split local optima.
  * ``batch_m=b`` — minibatch Lloyd: every iteration assigns and
    re-accumulates a without-replacement sample of b sketch rows
    instead of all m (the final labels/inertia are still computed on
    the full data).  ``batch_m >= m`` (or ``None``) takes the full-Lloyd
    path bit-exactly.

Everything returned is device-resident (no NumPy boundary); the
registry adapter that exposes this loop as the ``kmeans-device``
algorithm lives in ``core/clustering/api.py``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


class DeviceKMeansResult(NamedTuple):
    """Device-resident result (every field is a jnp array)."""
    labels: jnp.ndarray     # (m,) int32 cluster assignment
    centers: jnp.ndarray    # (k, d) float32 cluster centers
    inertia: jnp.ndarray    # () sum of squared distances to assigned center
    n_iter: jnp.ndarray     # () Lloyd iterations actually run
    restart_spread: jnp.ndarray = jnp.float32(0.0)
    #                         () max-min final inertia over the vmapped
    #                         restarts (0 for a single restart): the
    #                         init-sensitivity diagnostic the obs layer
    #                         surfaces as meta["restart_spread"]


def _init_centers(key, points, k: int, init: str, init_centers=None):
    # local import: clustering.api registers the adapter for this loop,
    # so a module-level import here would be circular
    from repro.core.clustering.kmeans import kmeans_plus_plus_init, spectral_init

    m, _ = points.shape
    if init == "warm":
        if init_centers is None:
            raise ValueError("init='warm' requires init_centers")
        return jnp.asarray(init_centers, jnp.float32)
    if init == "kmeans++":
        return kmeans_plus_plus_init(key, points, k)
    if init == "spectral":
        return spectral_init(points, k)
    if init == "random":
        sel = jax.random.choice(key, m, (k,), replace=False)
        return points[sel]
    raise ValueError(f"unknown init {init!r}")  # pragma: no cover - static


def _lloyd(key, points, k: int, iters: int, init: str, tol: float,
           batch_m: Optional[int],
           aggregator=None, init_centers=None) -> DeviceKMeansResult:
    """One Lloyd run.  ``batch_m=None`` is the full (PR-2 bit-exact)
    path; otherwise each iteration updates from a fresh without-
    replacement sample of ``batch_m`` rows.  ``aggregator`` (a registry
    ``Aggregator`` instance, or ``None`` for the fused-kernel mean)
    replaces the center update with a robust per-cluster reduction —
    sign-flip Byzantine sketch rows then stop dragging the centers,
    which is what keeps the recovered partition honest under attack."""
    m, d = points.shape
    centers = _init_centers(key, points, k, init, init_centers)
    # the init consumes ``key`` exactly as the full path always did;
    # minibatch sampling draws from a fold so full-Lloyd stays bit-exact
    iter_keys = jax.random.split(jax.random.fold_in(key, 0x6d62), iters)

    def body(carry, it_key):
        centers, done, it = carry
        if batch_m is None:
            batch = points
        else:
            sel = jax.random.choice(it_key, m, (batch_m,), replace=False)
            batch = points[sel]
        labels_b, sums, counts = kops.kmeans_assign(batch, centers)
        if aggregator is None:
            means = sums / jnp.maximum(counts, 1.0)[:, None]
        else:
            onehot = jax.nn.one_hot(labels_b, k, dtype=jnp.float32)
            means = aggregator(batch, labels_b, onehot, counts)
        new_centers = jnp.where(counts[:, None] > 0, means, centers)
        moved = jnp.max(jnp.sum((new_centers - centers) ** 2, axis=1))
        new_done = done | (moved < tol)
        centers = jnp.where(done, centers, new_centers)
        return (centers, new_done, it + jnp.where(done, 0, 1)), None

    (centers, _, n_iter), _ = jax.lax.scan(
        body, (centers, jnp.array(False), jnp.array(0, jnp.int32)),
        iter_keys)

    labels, sums, counts = kops.kmeans_assign(points, centers)
    trim = min(float(getattr(aggregator, "breakdown", 0.0) or 0.0), 0.45)
    t = int(trim * m)
    if t == 0:
        # inertia from the accumulator instead of an (m, k) distance
        # matrix: sum_i ||x_i - c_{l(i)}||^2
        #   = sum ||x||^2 - 2 sum_k <sums_k,c_k> + sum_k counts_k ||c_k||^2
        inertia = (jnp.sum(points * points)
                   - 2.0 * jnp.sum(sums * centers)
                   + jnp.sum(counts * jnp.sum(centers * centers, axis=1)))
    else:
        # robust aggregator -> robust restart SELECTION: score the run by
        # the trimmed k-means objective (drop the floor(breakdown * m)
        # farthest rows).  Plain inertia rewards spending a center on a
        # coherent far attacker blob (capturing it removes huge distance
        # terms), so under a Byzantine fraction the best-"inertia"
        # restart is exactly the poisoned partition; the trimmed
        # objective never pays for attacker rows in the first place.
        assigned = centers[labels]                               # (m, d)
        row_d2 = jnp.maximum(
            jnp.sum(points * points, axis=1)
            - 2.0 * jnp.sum(points * assigned, axis=1)
            + jnp.sum(assigned * assigned, axis=1), 0.0)
        inertia = jnp.sum(jnp.sort(row_d2)[: m - t])
    return DeviceKMeansResult(labels=labels, centers=centers,
                              inertia=jnp.maximum(inertia, 0.0),
                              n_iter=n_iter)


@functools.partial(jax.jit, static_argnames=("k", "iters", "init",
                                             "restarts", "batch_m",
                                             "aggregator"))
def device_kmeans(key, points, k: int, iters: int = 50,
                  init: str = "kmeans++", tol: float = 1e-8,
                  restarts: int = 1,
                  batch_m: Optional[int] = None,
                  aggregator=None,
                  init_centers=None) -> DeviceKMeansResult:
    """Lloyd's algorithm with the fused assign+accumulate kernel.

    With ``restarts=1`` and full batches this mirrors
    ``clustering.kmeans.kmeans`` exactly (same inits, same early-freeze
    update rule) so that identical (key, points, k, init) produce
    identical center trajectories — the parity tests rely on this.
    ``restarts=r`` vmaps r inits (the caller's key first, then r-1
    splits) and selects the lowest final inertia; ``batch_m`` samples
    that many rows per update (values >= m reduce to full Lloyd
    bit-exactly).  ``aggregator`` (static: a frozen registry
    ``Aggregator``, e.g. ``make_aggregator("trimmed_mean", beta=0.2)``)
    swaps the center update for a robust per-cluster reduction; ``None``
    keeps the fused-kernel mean path bit-exact with the host oracle.

    ``init="warm"`` starts Lloyd from the caller's ``init_centers``
    ((k, d), e.g. the previous round's centers) instead of seeding —
    the session's drift-triggered incremental re-finalize: near a fixed
    point the loop early-freezes in one or two iterations and the
    kmeans++ D^2 seeding pass (the dominant cost at large C) is skipped
    entirely.
    """
    points = points.astype(jnp.float32)
    m, d = points.shape
    if batch_m is not None and batch_m >= m:
        batch_m = None                      # full Lloyd, bit-exact
    if init in ("spectral", "warm") and batch_m is None:
        restarts = 1    # spectral seeding / a warm start ignore the key:
        #                 every restart would be the identical run
    run = functools.partial(_lloyd, points=points, k=k, iters=iters,
                            init=init, tol=tol, batch_m=batch_m,
                            aggregator=aggregator,
                            init_centers=init_centers)
    if restarts <= 1:
        return run(key)
    keys = jnp.concatenate([key[None], jax.random.split(key, restarts - 1)])
    stacked = jax.vmap(lambda kk: run(kk))(keys)
    best = jnp.argmin(stacked.inertia)
    picked = jax.tree_util.tree_map(lambda x: x[best], stacked)
    return picked._replace(
        restart_spread=jnp.max(stacked.inertia) - jnp.min(stacked.inertia))
