"""AggregationSession — the server side of Algorithm 1 as a long-lived,
streaming, *mutable* service.

The paper's server is not a function call: clients upload sketches over
time, the server clusters once enough arrived, and later traffic is
*routed* — a fresh client is assigned to its nearest recovered cluster
and served that cluster's model (IFCA's serving loop, k-FED's one-shot
estimate).  ``one_shot_aggregate`` compresses all of that into a single
invocation that needs every client's parameters in one stacked pytree;
this module is the stateful redesign:

  * ``ingest(wave)`` / ``ingest(sketches=...)`` — step-1 uploads, wave
    by wave.  Parameter waves are sketched on device (the same vmapped
    JL projection as the fused round) and written into a fixed-capacity
    (capacity, sketch_dim) device buffer; nothing federation-sized ever
    crosses to host.  With ``client_ids=`` the wave is KEYED: a
    host-side slot table maps stable client ids to buffer rows, so a
    returning client's row is replaced in place (sketch and params
    buffers both) instead of appended — ``count`` means live clients,
    not uploads.  Contiguous writes keep the ``dynamic_update_slice``
    fast path; keyed replacements and free-list reuse go through a
    row-scatter program.
  * staleness — the session advances a logical clock per wave and
    stamps every written row; a pluggable policy
    (``engine/staleness.py``: ``none`` | ``max_age`` sliding window |
    ``exp_decay`` weighting) evicts aged rows back onto a free list
    (masked out of every later finalize) or fades their weight in the
    per-cluster parameter mean.
  * ``finalize(algorithm=..., engine=...)`` — steps 2-4 over the LIVE
    rows: the registered clustering + per-cluster parameter mean.  The
    device path traces the exact ``_cluster_and_average`` body of the
    fused round (``engine/aggregate.py``), so a session fed any wave
    partition of a federation is **bit-exact** with
    ``one_shot_aggregate(engine="device")`` on the same clients — the
    property tests in ``tests/test_session.py`` pin this, re-uploads
    and evictions included.
  * ``maybe_refinalize(threshold=...)`` — the drift gauge (routed
    traffic's inertia over the finalized clustering's own) triggers an
    INCREMENTAL re-finalize: device Lloyd warm-starts from the previous
    round's centers (``init="warm"``), the convex family warm-starts
    its AMA dual — measured as ``session.refinalize.*`` spans vs the
    cold ``session.finalize.*`` ones.
  * ``route(sketch | params)`` — serving: nearest recovered cluster in
    sketch space through ONE fused program per request batch (label
    assignment + drift accumulation, one host sync per batch);
    ``cluster_model(cid)`` hands back that cluster's averaged model.
    Serving keeps working from the last finalized clustering while the
    buffers mutate underneath — that staleness is exactly what the
    drift gauge measures and ``maybe_refinalize`` repairs.

The session is deliberately dumb about *which* clustering runs: it
resolves ``algorithm`` through the admissible registry exactly like
``one_shot_aggregate`` (device twins upgrade host names under
``engine='auto'|'device'``; explicit device names downgrade to their
host base under ``engine='host'``), so every registered family —
including ``convex-device`` with the sparse ``edges="knn"`` fusion
graph — streams the same way.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.clustering.api import (
    device_twin,
    get_algorithm,
    is_device_algorithm,
    meta_to_host,
    resolve_device_request,
    resolve_host_request,
)
from repro.core.engine.aggregate import (
    _cluster_program,
    _gather_rows_program,
    _mean_program,
    _route_program,
    _warm_cluster_program,
    _weighted_mean_program,
    cached_program,
    compact_labels,
    materialize_round,
)
from repro.core.engine.aggregators import (
    cluster_aggregate_tree,
    get_aggregator,
)
from repro.core.engine.staleness import make_staleness_policy
from repro.core.federated import FederatedState
from repro.core.sketch import sketch_tree
from repro.optim import adamw_init


@jax.jit
def _sum_sq_to_assigned(pts, centers, labels):
    """Sum over rows of ||pt - centers[label]||^2 — the inertia of a
    point set against an existing clustering (drift bookkeeping)."""
    return jnp.sum((pts - centers[labels]) ** 2)


@jax.jit
def _mean_row_scale(pts):
    """Mean squared deviation of the rows from their centroid — the
    absolute scale the drift gauge falls back to when the finalized
    inertia itself is degenerate (~0)."""
    centred = pts - jnp.mean(pts, axis=0, keepdims=True)
    return jnp.mean(jnp.sum(centred * centred, axis=1))


class SessionSnapshot(NamedTuple):
    """An immutable view of the live rows at one logical clock tick.

    ``snapshot()`` gathers the live sketch/param rows into standalone
    device arrays; jnp arrays are immutable and every later ingest
    rebinds the session's buffers functionally, so the snapshot stays
    valid while ingest keeps mutating the live buffers underneath —
    the double-buffer half of ingest-while-finalize.  ``clock`` keys
    the serialized-replay equivalence contract: a round computed from
    this snapshot is bit-exact with a sequential replay that finalizes
    right after the ``clock``-th ingested wave.
    """
    sketches: jnp.ndarray          # (count, sketch_dim), live rows only
    params: Optional[object]       # stacked live-params pytree or None
    weights: Optional[object]      # staleness weights or None
    count: int                     # live clients at snapshot time
    clock: int                     # session clock at snapshot time


class ServedRound(NamedTuple):
    """Everything the serving paths read, bundled so a finalize can
    publish its result as ONE attribute write — atomic under the GIL,
    which is what lets a background finalize swap the served round
    while concurrent ``route()`` callers keep reading the old one."""
    out: tuple                     # (state | None, labels, info)
    centers: jnp.ndarray           # (K', sketch_dim) active centers
    first_idx: np.ndarray          # (K',) one member index per cluster
    n_clusters: int
    finalized_d2: float            # mean row d^2 at finalize (drift anchor)
    finalized_scale: float         # mean row scale (degenerate fallback)
    clock: int                     # snapshot clock this round was built from
    count: int                     # snapshot live-client count


class AggregationSession:
    """Streaming, mutable server-side aggregation over a fixed capacity.

    Args:
      capacity: maximum number of live clients (the sketch buffer is
        allocated once at this size; evicted slots are reused).
      sketch_dim: JL sketch width (step-1 upload size per client).
      cfg: optional ``ModelConfig`` — only consulted for the MoE
        router-invariant sketch filter, exactly as in
        ``one_shot_aggregate``.
      seed / cluster_seed: drive the shared JL projection and the
        clustering init (same split as the fused round).
      sketch_transform: optional traceable ``(sk, offset) -> sk`` hook
        applied to every wave's (w, sketch_dim) rows INSIDE the jitted
        ingest — the scenario subsystem's sketch-channel hooks (DP
        Gaussian release, colluding spoof) run here.  ``offset`` is the
        wave's first target row.
      staleness: a policy instance from ``engine/staleness.py`` or a
        spec string (``"none"`` | ``"max_age=3"`` | ``"exp_decay=2.0"``).
      mesh / client_axis: shard the client axis of the buffers.
    """

    def __init__(self, capacity: int, *, sketch_dim: int = 256, cfg=None,
                 seed: int = 0, cluster_seed: Optional[int] = None,
                 sketch_transform=None, staleness="none",
                 mesh=None, client_axis: str = "data"):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.sketch_dim = int(sketch_dim)
        self.seed = int(seed)
        self.cluster_seed = self.seed if cluster_seed is None else int(
            cluster_seed)
        self.mesh, self.client_axis = mesh, client_axis
        self.staleness = make_staleness_policy(staleness)
        from repro.core.federated import _router_invariant_filter
        self._leaf_filter = (_router_invariant_filter
                             if cfg is not None
                             and getattr(cfg, "is_moe", False) else None)
        self._sketch_key = jax.random.PRNGKey(self.seed)
        self._sketches = self._constrain(
            jnp.zeros((self.capacity, self.sketch_dim), jnp.float32))
        self._params = None            # stacked buffer, lazily allocated
        self._mode: Optional[str] = None    # 'params' | 'sketches'
        # ---- slot table: host-side row bookkeeping -------------------
        self._slots: dict = {}         # client id -> buffer row
        self._row_ids: dict = {}       # buffer row -> client id (keyed only)
        self._live = np.zeros(self.capacity, bool)
        self._stamps = np.zeros(self.capacity, np.int64)
        self._free: list = []          # evicted rows, ready for reuse
        self._high = 0                 # high-water mark of ever-written rows
        self._count = 0                # LIVE clients (not uploads)
        self._clock = 0                # logical time, +1 per ingested wave
        # ---- finalize / serving state --------------------------------
        self._final = None             # round of the CURRENT buffer contents
        self._served: Optional[ServedRound] = None  # atomically-swapped
        self._finalize_kwargs = None   # replayed by refinalize()
        # warm-start cache for the incremental re-finalize
        self._warm_algo_name = None
        self._warm_state = None
        self._warm_count = 0
        # drift bookkeeping: the finalized anchor lives in the served
        # round; these accumulate routed traffic's inertia since the
        # last install — the gauge maybe_refinalize() triggers on
        self._routed_d2_sum = 0.0      # accumulated routed row d^2
        self._routed_n = 0

        def _sketch_wave(wave, offset):
            sk = jax.vmap(
                lambda p: sketch_tree(self._sketch_key, p, self.sketch_dim,
                                      leaf_filter=self._leaf_filter))(wave)
            if sketch_transform is not None:
                sk = sketch_transform(sk, offset)
            return sk

        def _ingest(sk_buf, p_buf, wave, offset):
            sk = _sketch_wave(wave, offset)
            sk_buf = self._constrain(
                jax.lax.dynamic_update_slice_in_dim(sk_buf, sk, offset, 0))
            p_buf = jax.tree_util.tree_map(
                lambda b, w: self._constrain(
                    jax.lax.dynamic_update_slice_in_dim(b, w, offset, 0)),
                p_buf, wave)
            return sk_buf, p_buf

        def _ingest_scatter(sk_buf, p_buf, wave, rows):
            sk = _sketch_wave(wave, rows[0])
            sk_buf = self._constrain(sk_buf.at[rows].set(sk))
            p_buf = jax.tree_util.tree_map(
                lambda b, w: self._constrain(b.at[rows].set(w)),
                p_buf, wave)
            return sk_buf, p_buf

        def _ingest_sk(sk_buf, sk, offset):
            if sketch_transform is not None:
                sk = sketch_transform(sk, offset)
            return self._constrain(
                jax.lax.dynamic_update_slice_in_dim(sk_buf, sk, offset, 0))

        def _ingest_sk_scatter(sk_buf, sk, rows):
            if sketch_transform is not None:
                sk = sketch_transform(sk, rows[0])
            return self._constrain(sk_buf.at[rows].set(sk))

        # donate the capacity-sized buffers so XLA updates them in place
        # (a fresh full-size copy per wave would defeat the streaming
        # design); the CPU backend can't donate and would warn per wave
        donate = jax.default_backend() != "cpu"
        self._ingest_fn = jax.jit(_ingest,
                                  donate_argnums=(0, 1) if donate else ())
        self._ingest_scatter_fn = jax.jit(
            _ingest_scatter, donate_argnums=(0, 1) if donate else ())
        self._ingest_sk_fn = jax.jit(_ingest_sk,
                                     donate_argnums=(0,) if donate else ())
        self._ingest_sk_scatter_fn = jax.jit(
            _ingest_sk_scatter, donate_argnums=(0,) if donate else ())
        self._sketch_one = jax.jit(
            lambda p: sketch_tree(self._sketch_key, p, self.sketch_dim,
                                  leaf_filter=self._leaf_filter))

    # ------------------------------------------------------------ ingest

    def _constrain(self, x):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(self.client_axis)))

    @property
    def count(self) -> int:
        """Live clients currently held (re-uploads replace, evictions
        subtract — not a lifetime upload counter)."""
        return self._count

    @property
    def clients(self) -> dict:
        """Copy of the live slot table: client id -> buffer row (keyed
        ingests only; anonymous waves don't appear)."""
        return dict(self._slots)

    def _live_rows(self) -> np.ndarray:
        """Sorted buffer rows currently holding live clients."""
        return np.flatnonzero(self._live[:self._high])

    @property
    def sketches(self) -> jnp.ndarray:
        """Device-resident (count, sketch_dim) view of the live sketch
        rows (a slice while the live set is contiguous, a gather after
        evictions punch holes)."""
        rows = self._live_rows()
        if rows.size == self._high:
            return self._sketches[:self._high]
        return self._sketches[jnp.asarray(rows, jnp.int32)]

    def _validate_params_wave(self, wave, leaves):
        """Structure/shape validation BEFORE any bookkeeping mutates —
        a rejected wave must leave count, buffers, and the finalized
        round exactly as they were."""
        w = int(leaves[0].shape[0])
        if w < 1:
            raise ValueError("empty wave")
        if any(l.shape[0] != w for l in leaves):
            raise ValueError("parameter wave leaves disagree on the "
                             "leading (client) axis")
        if self._params is not None:
            buf_def = jax.tree_util.tree_structure(self._params)
            wave_def = jax.tree_util.tree_structure(wave)
            if buf_def != wave_def:
                raise ValueError(
                    f"wave tree structure {wave_def} does not match the "
                    f"session's first wave {buf_def}")
            for b, l in zip(jax.tree_util.tree_leaves(self._params), leaves):
                if tuple(l.shape[1:]) != tuple(b.shape[1:]):
                    raise ValueError(
                        f"wave leaf shape {tuple(l.shape[1:])} does not "
                        f"match the session's {tuple(b.shape[1:])}")
        return w

    def _alloc_rows(self, w: int, client_ids) -> tuple[np.ndarray, int]:
        """Map a wave onto buffer rows (no mutation on failure).

        Returning client ids keep their row (in-place replace); new ids
        (and anonymous waves) take evicted rows from the free list
        first, then extend the high-water mark.  Returns ``(rows,
        n_new)``; raises on duplicate ids or capacity exhaustion."""
        if client_ids is not None:
            ids = list(client_ids)
            if len(ids) != w:
                raise ValueError(f"client_ids has {len(ids)} entries for a "
                                 f"wave of {w}")
            if len(set(ids)) != len(ids):
                raise ValueError("duplicate client ids within one wave")
        else:
            ids = [None] * w
        rows = np.empty(w, np.int64)
        new_at = []
        for i, cid in enumerate(ids):
            row = self._slots.get(cid) if cid is not None else None
            if row is None:
                new_at.append(i)
            else:
                rows[i] = row
        n_new = len(new_at)
        headroom = len(self._free) + (self.capacity - self._high)
        if n_new > headroom:
            raise ValueError(
                f"session capacity exceeded: {self._count} live + "
                f"{n_new} new clients > capacity {self.capacity}")
        free = list(self._free)
        high = self._high
        for i in new_at:
            if free:
                rows[i] = free.pop()
            else:
                rows[i] = high
                high += 1
        return rows, n_new

    def _commit_rows(self, rows: np.ndarray, client_ids) -> None:
        """Post-write bookkeeping: slot table, free list, stamps, clock."""
        ids = list(client_ids) if client_ids is not None else [None] * len(rows)
        self._clock += 1
        for row, cid in zip(rows, ids):
            row = int(row)
            if not self._live[row]:
                self._count += 1
            self._live[row] = True
            if row in self._free:
                self._free.remove(row)
            if cid is not None:
                self._slots[cid] = row
                self._row_ids[row] = cid
        self._high = max(self._high, int(rows.max()) + 1)
        self._stamps[rows] = self._clock
        self._final = None             # buffer contents left the round
        self.evict_stale()
        self._gauge_slots()

    def _gauge_slots(self) -> None:
        obs.gauge("session.slots.live", float(self._count))
        obs.gauge("session.slots.free", float(self.capacity - self._count))

    @staticmethod
    def _contiguous(rows: np.ndarray) -> bool:
        return bool(np.array_equal(
            rows, np.arange(rows[0], rows[0] + len(rows))))

    def ingest(self, wave=None, *, sketches=None, client_ids=None):
        """Ingest one wave of step-1 uploads.

        ``wave`` is a stacked parameter pytree (every leaf has leading
        axis w) or a ``FederatedState``; ``sketches=`` takes an already
        projected (w, sketch_dim) matrix instead (sketch-only servers).
        Modes cannot be mixed within one session: parameter averaging in
        ``finalize`` needs every client's parameters.

        ``client_ids=`` (length-w sequence of stable hashable ids) keys
        the wave: a returning id's buffer row is replaced in place, a
        new id takes a free (possibly previously evicted) row.  Returns
        the (w,) row assignment for keyed waves, the wave's offset for
        anonymous ones.
        """
        if (wave is None) == (sketches is None):
            raise ValueError("pass exactly one of wave= or sketches=")
        if sketches is not None:
            return self._ingest_sketches(sketches, client_ids)
        if isinstance(wave, FederatedState):
            wave = wave.params
        if self._mode == "sketches":
            raise ValueError("session already holds sketch-only waves; "
                             "cannot mix in parameter waves")
        leaves = jax.tree_util.tree_leaves(wave)
        if not leaves:
            raise ValueError("empty parameter wave")
        w = self._validate_params_wave(wave, leaves)
        rows, _ = self._alloc_rows(w, client_ids)
        self._mode = "params"      # only after validation: a rejected
        #                            wave must not lock the mode in
        if self._params is None:
            # the stacked buffer shards its client axis like the sketch
            # buffer: per-device memory stays bounded by the shard
            self._params = jax.tree_util.tree_map(
                lambda l: self._constrain(
                    jnp.zeros((self.capacity,) + l.shape[1:], l.dtype)),
                wave)
        offset = int(rows[0])
        with obs.span("session.ingest", wave=w, offset=offset,
                      mode="params"):
            if self._contiguous(rows):
                self._sketches, self._params = self._ingest_fn(
                    self._sketches, self._params, wave,
                    jnp.asarray(offset, jnp.int32))
            else:
                self._sketches, self._params = self._ingest_scatter_fn(
                    self._sketches, self._params, wave,
                    jnp.asarray(rows, jnp.int32))
            jax.block_until_ready(self._sketches)
        obs.count("session.ingest.clients", w)
        obs.count("session.ingest.bytes",
                  sum(l.size * l.dtype.itemsize for l in leaves))
        self._commit_rows(rows, client_ids)
        return rows if client_ids is not None else offset

    def _ingest_sketches(self, sketches, client_ids=None):
        if self._mode == "params":
            raise ValueError("session already holds parameter waves; "
                             "cannot mix in sketch-only waves")
        sketches = jnp.asarray(sketches, jnp.float32)
        if sketches.ndim != 2 or sketches.shape[1] != self.sketch_dim:
            raise ValueError(f"sketch wave must be (w, {self.sketch_dim}), "
                             f"got {sketches.shape}")
        w = int(sketches.shape[0])
        if w < 1:
            raise ValueError("empty wave")
        rows, _ = self._alloc_rows(w, client_ids)
        self._mode = "sketches"    # only after validation, as above
        offset = int(rows[0])
        with obs.span("session.ingest", wave=w, offset=offset,
                      mode="sketches"):
            if self._contiguous(rows):
                self._sketches = self._ingest_sk_fn(
                    self._sketches, sketches, jnp.asarray(offset, jnp.int32))
            else:
                self._sketches = self._ingest_sk_scatter_fn(
                    self._sketches, sketches, jnp.asarray(rows, jnp.int32))
            jax.block_until_ready(self._sketches)
        obs.count("session.ingest.clients", w)
        obs.count("session.ingest.bytes",
                  sketches.size * sketches.dtype.itemsize)
        self._commit_rows(rows, client_ids)
        return rows if client_ids is not None else offset

    # --------------------------------------------------------- staleness

    def evict_stale(self) -> list:
        """Apply the staleness policy's eviction mask to the live rows.

        Evicted rows return to the free list and are masked out of
        every later finalize; returns the evicted client ids (``None``
        placeholders for anonymous rows).  Runs automatically after
        every ingest and before every finalize."""
        rows = self._live_rows()
        if rows.size == 0:
            return []
        ages = self._clock - self._stamps[rows]
        mask = np.asarray(self.staleness.evict(ages), bool)
        evicted = rows[mask]
        if evicted.size == 0:
            return []
        out = []
        for row in evicted:
            row = int(row)
            cid = self._row_ids.pop(row, None)
            if cid is not None:
                del self._slots[cid]
            self._live[row] = False
            self._free.append(row)
            out.append(cid)
        self._count -= len(out)
        self._final = None
        obs.count("session.evictions", len(out))
        self._gauge_slots()
        return out

    def _live_weights(self, rows: np.ndarray):
        """Per-row staleness weights in live-row (gathered) order, or
        ``None`` for unweighted policies."""
        ages = self._clock - self._stamps[rows]
        return self.staleness.weights(ages)

    # ---------------------------------------------------------- finalize

    def snapshot(self) -> SessionSnapshot:
        """Atomically capture the live rows at the current clock.

        The returned arrays are standalone (immutable jnp values; the
        session rebinds its buffers functionally on every ingest), so a
        finalize computed from a snapshot on a background thread stays
        bit-exact even while ingest keeps mutating the live buffers —
        the double-buffer half of ingest-while-finalize.  Callers that
        ingest from multiple threads must serialize ``ingest`` and
        ``snapshot`` against each other (``serving.RouteServer`` does)
        so the snapshot lands between wave commits at a definite clock.
        """
        self.evict_stale()
        if self._count == 0:
            raise ValueError("nothing ingested")
        rows = self._live_rows()
        if rows.size == self._high:
            sketches = self._sketches[:self._high]
            params = (None if self._params is None else
                      jax.tree_util.tree_map(lambda l: l[:self._high],
                                             self._params))
        else:
            rows_j = jnp.asarray(rows, jnp.int32)
            sketches, params = cached_program(_gather_rows_program)(
                (self._sketches, self._params), rows_j)
        if jax.default_backend() != "cpu":
            # ingest donates the capacity buffers on accelerator
            # backends; force materialized copies so the snapshot never
            # aliases memory a later wave is allowed to overwrite
            sketches = jnp.array(sketches, copy=True)
            if params is not None:
                params = jax.tree_util.tree_map(
                    lambda l: jnp.array(l, copy=True), params)
        return SessionSnapshot(sketches=sketches, params=params,
                               weights=self._live_weights(rows),
                               count=self._count, clock=self._clock)

    def finalize(self, *, algorithm="kmeans-device", k: Optional[int] = None,
                 algo_options: Optional[dict] = None,
                 engine: str = "device", aggregator="mean"):
        """Steps 2-4 over the live rows: cluster the accumulated sketch
        matrix, average parameters per recovered cluster.

        Returns ``(new_state, labels, info)`` with the same contract as
        ``one_shot_aggregate`` (``new_state is None`` for sketch-only
        sessions, which have nothing to average — labels/centers still
        come back and routing becomes available).  The device path is
        bit-exact with the fused round on the same clients.
        ``aggregator`` selects the per-cluster parameter reduction from
        the registry (``mean`` | ``trimmed_mean`` | ``median`` |
        ``geometric_median`` | an ``Aggregator`` instance) on both
        engines.  The call's arguments are remembered: ``refinalize()``
        / ``maybe_refinalize()`` replay them warm-started.

        Equivalent to ``finalize_snapshot(self.snapshot(), ...)`` —
        concurrent servers take the snapshot under their ingest lock
        and run the compute off-thread instead.
        """
        return self.finalize_snapshot(
            self.snapshot(), algorithm=algorithm, k=k,
            algo_options=algo_options, engine=engine, aggregator=aggregator)

    def refinalize(self):
        """Re-run the last ``finalize`` configuration over the current
        live rows, warm-starting the clustering from the previous
        round's state when the family supports it (Lloyd restarts from
        the old centers, AMA from its old dual; cold fallback
        otherwise).  Requires a prior ``finalize()``."""
        if self._finalize_kwargs is None:
            raise ValueError("refinalize() needs a prior finalize()")
        return self.finalize_snapshot(self.snapshot(), warm=True,
                                      **self._finalize_kwargs)

    def maybe_refinalize(self, threshold: float = 1.5):
        """Drift-triggered incremental re-finalize: when the ``drift``
        gauge (routed-traffic inertia over finalized inertia) exceeds
        ``threshold``, replay the last finalize warm-started and
        re-anchor the gauge.  Returns the new round, or ``None`` when
        drift is below threshold (or unmeasured)."""
        d = self.drift
        if d is None or d <= threshold:
            return None
        obs.count("session.refinalize.triggered")
        return self.refinalize()

    def finalize_snapshot(self, snap: SessionSnapshot, *, warm: bool = False,
                          **kwargs):
        """Compute a round from ``snap`` and publish it: the synchronous
        compose of ``compute_round`` + ``install_round``.  Accepts the
        same keyword arguments as ``finalize``."""
        out, served = self.compute_round(snap, warm=warm, **kwargs)
        return self.install_round(out, served)

    def compute_round(self, snap: SessionSnapshot, *, warm: bool = False,
                      algorithm="kmeans-device", k: Optional[int] = None,
                      algo_options: Optional[dict] = None,
                      engine: str = "device", aggregator="mean"):
        """Steps 2-4 over a snapshot WITHOUT touching the serving state.

        Returns ``(out, served)`` where ``out`` is the usual round tuple
        and ``served`` is the ``ServedRound`` that ``install_round``
        publishes.  Safe to run on a background thread while ingest and
        route keep going (the warm-start cache is the one piece of
        shared mutable state — concurrent ``compute_round`` calls must
        be serialized by the caller, as ``RouteServer`` does with its
        finalize lock)."""
        if engine not in ("auto", "host", "device"):
            raise ValueError(f"engine must be auto|host|device, got "
                             f"{engine!r}")
        kwargs = dict(algorithm=algorithm, k=k, algo_options=algo_options,
                      engine=engine, aggregator=aggregator)
        if engine == "host":
            # explicit device names downgrade to their host base (or
            # raise for twin-less device-only families) instead of
            # silently running the device loop under engine='host'
            algorithm, algo_options = resolve_host_request(
                algorithm, algo_options)
        else:
            # the legacy Lloyd-name mapping (kmeans++ -> kmeans-device
            # with init='kmeans++'), shared with ODCLFederated; raises
            # for host-only no-twin names under engine='device'
            algorithm, algo_options = resolve_device_request(
                algorithm, algo_options, strict=engine == "device")
        algo = get_algorithm(algorithm)
        dev = algo if is_device_algorithm(algo) else device_twin(algo)
        use_device = engine != "host" and dev is not None
        if use_device:
            algo = dev
        k_eff = k if algo.requires_k else None
        span = "session.refinalize" if warm else "session.finalize"
        with obs.span(span, count=snap.count,
                      algorithm=getattr(algo, "name", str(algo)),
                      engine="device" if use_device else "host"):
            if use_device:
                out, served = self._finalize_device(
                    algo, k_eff, algo_options, snap, aggregator, warm)
            else:
                out, served = self._finalize_host(
                    algo, k_eff, algo_options, snap, aggregator)
        self._finalize_kwargs = kwargs
        return out, served

    def install_round(self, out, served: ServedRound):
        """Publish a computed round: ONE attribute write swaps what
        ``route()`` / ``cluster_model()`` serve (atomic under the GIL),
        and the drift gauge re-anchors on the new round.  ``_final``
        (the this-round-matches-the-buffer marker) is only set when the
        snapshot's clock is still current — a round computed while
        ingest kept mutating stays served but is known stale."""
        self._served = served
        self._routed_d2_sum = 0.0
        self._routed_n = 0
        if served.clock == self._clock:
            self._final = out
        return out

    def _warm_usable(self, algo, warm: bool, count: int) -> bool:
        if not warm or self._warm_state is None:
            return False
        if getattr(algo, "name", None) != self._warm_algo_name:
            return False
        if not callable(getattr(algo, "device_warm_call", None)):
            return False
        if (getattr(algo, "warm_requires_same_count", False)
                and count != self._warm_count):
            obs.count("session.refinalize.cold_fallback")
            return False
        return True

    def _cache_warm_state(self, algo, res, count: int) -> None:
        if not callable(getattr(algo, "device_warm_call", None)):
            return
        state = algo.warm_state(res)
        if state is not None:
            self._warm_algo_name = getattr(algo, "name", None)
            self._warm_state = state
            self._warm_count = count

    def _average_params(self, res, params, aggregator, weights):
        """The finalize's parameter-averaging phase: the shared
        unweighted mean program (bit-exact with the fused round) unless
        the staleness policy supplies decay weights."""
        if weights is None:
            return cached_program(_mean_program, self.mesh,
                                  self.client_axis,
                                  get_aggregator(aggregator))(
                res.labels, res.centers, params)
        if get_aggregator(aggregator).name != "mean":
            raise ValueError(
                "staleness weighting (exp_decay) requires the 'mean' "
                f"aggregator, got {get_aggregator(aggregator).name!r}")
        return cached_program(_weighted_mean_program, self.mesh,
                              self.client_axis)(
            res.labels, res.centers, params,
            jnp.asarray(weights, jnp.float32))

    def _finalize_device(self, algo, k, algo_options, snap, aggregator,
                         warm):
        sketches, params = snap.sketches, snap.params
        cluster_key = jax.random.PRNGKey(self.cluster_seed)
        opts = tuple(sorted((algo_options or {}).items()))
        # the cluster and mean phases run as two AOT programs (labels /
        # centers stay on device between them) so the obs layer sees the
        # finalize latency split; the warm path swaps only the cluster
        # program (the mean phase is identical either way)
        if self._warm_usable(algo, warm, snap.count):
            res = cached_program(_warm_cluster_program, algo, k, opts)(
                cluster_key, sketches, self._warm_state)
            mode = "warm"
        else:
            res = cached_program(_cluster_program, algo, k, opts)(
                cluster_key, sketches)
            mode = "cold"
        self._cache_warm_state(algo, res, snap.count)
        if params is None:
            labels, uniq, first = compact_labels(res.labels)
            info = {"n_clusters": int(len(uniq)),
                    "meta": meta_to_host(res.meta),
                    "engine": "device", "count": snap.count,
                    "refinalize": mode if warm else None,
                    "snapshot_clock": snap.clock}
            out = (None, labels, info)
            served = self._make_served(out, res.centers[jnp.asarray(uniq)],
                                       first, int(len(uniq)), sketches,
                                       res.centers, res.labels, snap)
            return out, served
        new_params = self._average_params(res, params, aggregator,
                                          snap.weights)
        state = FederatedState(params=params, opt_state=None,
                               n_clients=snap.count, step=0)
        new_state, labels, info, uniq, first = materialize_round(
            new_params, res, state)
        info["count"] = snap.count
        info["refinalize"] = mode if warm else None
        info["snapshot_clock"] = snap.clock
        out = (new_state, labels, info)
        served = self._make_served(out, res.centers[jnp.asarray(uniq)],
                                   first, int(len(uniq)), sketches,
                                   res.centers, res.labels, snap)
        return out, served

    def _make_served(self, out, centers, first_idx, n_clusters, sketches,
                     all_centers, labels, snap) -> ServedRound:
        """Bundle a computed round with its drift anchor (the finalized
        clustering's mean per-row inertia, plus the absolute row scale
        as the degenerate-inertia fallback) into the one value
        ``install_round`` swaps in."""
        finalized_d2 = float(
            _sum_sq_to_assigned(sketches, all_centers, jnp.asarray(labels))
        ) / max(snap.count, 1)
        return ServedRound(out=out, centers=centers,
                           first_idx=np.asarray(first_idx),
                           n_clusters=int(n_clusters),
                           finalized_d2=finalized_d2,
                           finalized_scale=float(_mean_row_scale(sketches)),
                           clock=snap.clock, count=snap.count)

    def _finalize_host(self, algo, k, algo_options, snap, aggregator):
        from repro.core.odcl import run_clustering

        sketches, params, weights = snap.sketches, snap.params, snap.weights
        with obs.span("session.finalize.cluster", engine="host"):
            result = run_clustering(jax.random.PRNGKey(self.cluster_seed),
                                    np.asarray(sketches), algo, k=k,
                                    **(algo_options or {}))
        labels, _, first = compact_labels(result.labels)
        info = {"n_clusters": result.n_clusters, "meta": result.meta,
                "engine": "host", "count": snap.count,
                "snapshot_clock": snap.clock}
        centers = jnp.asarray(result.centers, jnp.float32)
        labels_j = jnp.asarray(labels)
        if params is None:
            out = (None, labels, info)
            served = self._make_served(out, centers, first,
                                       result.n_clusters, sketches, centers,
                                       labels_j, snap)
            return out, served
        with obs.span("session.finalize.mean", engine="host"):
            if weights is not None:
                if get_aggregator(aggregator).name != "mean":
                    raise ValueError(
                        "staleness weighting (exp_decay) requires the "
                        "'mean' aggregator")
                new_params = cached_program(
                    _weighted_mean_program, self.mesh, self.client_axis)(
                    labels_j, centers, params,
                    jnp.asarray(weights, jnp.float32))
            else:
                onehot = jax.nn.one_hot(labels_j, result.n_clusters,
                                        dtype=jnp.float32)
                counts = jnp.sum(onehot, axis=0)
                new_params = cluster_aggregate_tree(params, labels_j, onehot,
                                                    counts, aggregator)
            jax.block_until_ready(new_params)
        new_state = FederatedState(
            params=new_params, opt_state=jax.vmap(adamw_init)(new_params),
            n_clients=snap.count, step=0)
        out = (new_state, labels, info)
        served = self._make_served(out, centers, first, result.n_clusters,
                                   sketches, centers, labels_j, snap)
        return out, served

    # ------------------------------------------------------------- serve

    def route(self, sketch=None, *, params=None):
        """Assign a (possibly never-seen) client to its nearest recovered
        cluster — the serving-time step 4.

        Pass either a (sketch_dim,) / (n, sketch_dim) sketch or a raw
        parameter pytree (sketched with the session's own projection).
        The whole batch runs as ONE fused program (nearest-center
        assignment + the drift accumulator), with a single host sync per
        batch; returns an int (or (n,) int array).  Serving stays on the
        LAST finalized clustering even while later ingests/evictions
        mutate the buffers — ``drift`` measures how stale that is, and
        ``maybe_refinalize`` repairs it.
        """
        served = self._served
        if served is None:
            raise ValueError("route() needs finalize() first")
        if (sketch is None) == (params is None):
            raise ValueError("pass exactly one of sketch or params=")
        if params is not None:
            sketch = self._sketch_one(params)
        sketch = jnp.asarray(sketch, jnp.float32)
        single = sketch.ndim == 1
        pts = sketch[None] if single else sketch
        n = int(pts.shape[0])
        if n == 0:
            # tracing a zero-row assign program would succeed and cache
            # a useless signature; fail loudly instead
            raise ValueError("route() needs at least one probe "
                             "(got an empty batch)")
        with obs.span("session.route", n=n):
            labels, batch_d2 = cached_program(_route_program)(
                pts, served.centers)
            # one transfer for both outputs — the route hot path's only
            # host sync (asserted by tests/test_session_mutation.py)
            out, batch_d2 = jax.device_get((labels, batch_d2))
            out = np.asarray(out)
            batch_d2 = float(batch_d2)
        obs.count("session.route.requests", n)
        # drift gauge: routed traffic's mean d^2 to its assigned center,
        # relative to the finalized clustering's own mean d^2 — the
        # trigger signal of maybe_refinalize(); accumulated on device
        # inside the route program, synced once per batch
        self._routed_d2_sum += batch_d2
        self._routed_n += n
        d = self.drift
        if d is not None:
            obs.gauge("session.drift", d)
        return int(out[0]) if single else out

    def sketch_params(self, wave):
        """Sketch a stacked parameter wave (leading axis = clients) with
        the session's own JL projection, WITHOUT ingesting — the input
        shape batched ``route()`` consumes for request batches."""
        leaves = jax.tree_util.tree_leaves(wave)
        if not leaves:
            raise ValueError("empty parameter wave")
        if int(leaves[0].shape[0]) == 0:
            raise ValueError("sketch_params() needs at least one client "
                             "row (got an empty wave)")
        return jax.vmap(self._sketch_one)(wave)

    def cluster_model(self, cluster_id: int):
        """The averaged model of one recovered cluster (a single-model
        pytree, no leading client axis) — what a routed client is served.
        """
        served = self._served
        if served is None:
            raise ValueError("cluster_model() needs finalize() first")
        state = served.out[0]
        if state is None:
            raise ValueError("sketch-only session holds no parameters")
        cid = int(cluster_id)
        if not 0 <= cid < served.n_clusters:
            # a negative id would silently wrap to another cluster's row
            raise IndexError(
                f"cluster id {cid} out of range for {served.n_clusters} "
                "recovered clusters")
        idx = int(served.first_idx[cid])
        return jax.tree_util.tree_map(lambda l: l[idx], state.params)

    @property
    def clock(self) -> int:
        """Logical session time: +1 per ingested wave.  The key of the
        serialized-replay equivalence contract — a snapshot at clock t
        replays as 'finalize right after the t-th wave'."""
        return self._clock

    @property
    def served_round(self) -> Optional[ServedRound]:
        """The ``ServedRound`` route() currently reads (``None`` before
        the first finalize) — one immutable value, so concurrent readers
        see a consistent centers/first_idx/drift-anchor bundle."""
        return self._served

    @property
    def finalize_config(self) -> Optional[dict]:
        """The last finalize()'s arguments (what refinalize replays),
        or ``None`` before any finalize."""
        return (None if self._finalize_kwargs is None
                else dict(self._finalize_kwargs))

    @property
    def n_clusters(self) -> int:
        """Recovered cluster count of the clustering currently served."""
        served = self._served
        if served is None:
            raise ValueError("finalize() first")
        return served.n_clusters

    @property
    def route_centers(self) -> jnp.ndarray:
        """(K', sketch_dim) active cluster centers (device-resident)."""
        served = self._served
        if served is None:
            raise ValueError("finalize() first")
        return served.centers

    @property
    def drift(self) -> Optional[float]:
        """Routed-traffic inertia relative to the finalized clustering's
        own inertia: (mean routed row d^2) / (mean finalized row d^2).

        ~1.0 means serving traffic looks like the federation that was
        clustered; growth means the recovered centers are going stale —
        the signal ``maybe_refinalize`` triggers on.  A degenerate
        finalize (zero inertia: duplicate/tight sketches, k == count)
        falls back to the absolute sketch-row scale as denominator so
        the gauge cannot explode to ~1e12 and mis-trigger.  ``None``
        until at least one finalize and one route happened.
        """
        served = self._served
        if served is None or self._routed_n == 0:
            return None
        routed = self._routed_d2_sum / self._routed_n
        scale = served.finalized_scale or 0.0
        if served.finalized_d2 > 1e-9 * max(scale, 1e-30):
            return routed / served.finalized_d2
        return routed / max(scale, 1e-12)

    # ------------------------------------------------------------- state

    def state(self) -> FederatedState:
        """The live federation as a stacked ``FederatedState`` — feeds
        any registered ``FederatedMethod`` (how ``simulate.py`` runs
        iterative baselines over a streamed-in federation)."""
        if self._mode != "params":
            raise ValueError("state() needs parameter waves")
        rows = self._live_rows()
        if rows.size == self._high:
            params = jax.tree_util.tree_map(lambda l: l[:self._high],
                                            self._params)
        else:
            params = jax.tree_util.tree_map(
                lambda l: l[jnp.asarray(rows, jnp.int32)], self._params)
        return FederatedState(params=params,
                              opt_state=jax.vmap(adamw_init)(params),
                              n_clients=self._count)
