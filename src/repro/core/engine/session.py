"""AggregationSession — the server side of Algorithm 1 as a long-lived,
streaming service.

The paper's server is not a function call: clients upload sketches over
time, the server clusters once enough arrived, and later traffic is
*routed* — a fresh client is assigned to its nearest recovered cluster
and served that cluster's model (IFCA's serving loop, k-FED's one-shot
estimate).  ``one_shot_aggregate`` compresses all of that into a single
invocation that needs every client's parameters in one stacked pytree;
this module is the stateful redesign:

  * ``ingest(wave)`` / ``ingest(sketches=...)`` — step-1 uploads, wave
    by wave.  Parameter waves are sketched on device (the same vmapped
    JL projection as the fused round) and written into a fixed-capacity
    (capacity, sketch_dim) device buffer by ``dynamic_update_slice``;
    nothing federation-sized ever crosses to host, and the wave size is
    the caller's memory knob (``launch/simulate.py`` feeds its ERM
    waves straight in).  Sketch-only waves support servers that never
    see raw parameters (the paper's actual communication model).
  * ``finalize(algorithm=..., engine=...)`` — steps 2-4: the registered
    clustering + per-cluster parameter mean over everything ingested.
    The device path traces the exact ``_cluster_and_average`` body of
    the fused round (``engine/aggregate.py``), so a session fed any
    wave partition of a federation is **bit-exact** with
    ``one_shot_aggregate(engine="device")`` on the same clients — the
    property tests in ``tests/test_session.py`` pin this down.
  * ``route(sketch | params)`` — serving: nearest recovered cluster in
    sketch space through the fused ``kernels/kmeans_assign`` dispatch;
    ``cluster_model(cid)`` hands back that cluster's averaged model
    (what ``launch/serve.py --route-by-sketch`` serves).

The session is deliberately dumb about *which* clustering runs: it
resolves ``algorithm`` through the admissible registry exactly like
``one_shot_aggregate`` (device twins upgrade host names), so every
registered family — including ``convex-device`` with the sparse
``edges="knn"`` fusion graph — streams the same way.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.clustering.api import (
    device_twin,
    get_algorithm,
    is_device_algorithm,
    meta_to_host,
    resolve_device_request,
)
from repro.core.engine.aggregate import (
    _cluster_program,
    _mean_program,
    cached_program,
    compact_labels,
    materialize_round,
)
from repro.core.engine.aggregators import (
    cluster_aggregate_tree,
    get_aggregator,
)
from repro.core.federated import FederatedState
from repro.core.sketch import sketch_tree
from repro.kernels import ops as kops
from repro.optim import adamw_init


@jax.jit
def _sum_sq_to_assigned(pts, centers, labels):
    """Sum over rows of ||pt - centers[label]||^2 — the inertia of a
    point set against an existing clustering (drift bookkeeping)."""
    return jnp.sum((pts - centers[labels]) ** 2)


class AggregationSession:
    """Streaming server-side aggregation over a fixed client capacity.

    Args:
      capacity: maximum number of clients this session can ingest (the
        sketch buffer is allocated once at this size).
      sketch_dim: JL sketch width (step-1 upload size per client).
      cfg: optional ``ModelConfig`` — only consulted for the MoE
        router-invariant sketch filter, exactly as in
        ``one_shot_aggregate``.
      seed / cluster_seed: drive the shared JL projection and the
        clustering init (same split as the fused round).
      sketch_transform: optional traceable ``(sk, offset) -> sk`` hook
        applied to every wave's (w, sketch_dim) rows INSIDE the jitted
        ingest — the scenario subsystem's sketch-channel hooks (DP
        Gaussian release, colluding spoof) run here, so the transformed
        rows are the only sketches that ever exist, on device or off.
      mesh / client_axis: shard the client axis of the buffers.
    """

    def __init__(self, capacity: int, *, sketch_dim: int = 256, cfg=None,
                 seed: int = 0, cluster_seed: Optional[int] = None,
                 sketch_transform=None,
                 mesh=None, client_axis: str = "data"):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.sketch_dim = int(sketch_dim)
        self.seed = int(seed)
        self.cluster_seed = self.seed if cluster_seed is None else int(
            cluster_seed)
        self.mesh, self.client_axis = mesh, client_axis
        from repro.core.federated import _router_invariant_filter
        self._leaf_filter = (_router_invariant_filter
                             if cfg is not None
                             and getattr(cfg, "is_moe", False) else None)
        self._sketch_key = jax.random.PRNGKey(self.seed)
        self._sketches = self._constrain(
            jnp.zeros((self.capacity, self.sketch_dim), jnp.float32))
        self._params = None            # stacked buffer, lazily allocated
        self._count = 0
        self._mode: Optional[str] = None    # 'params' | 'sketches'
        self._final = None             # (state, labels, info) of finalize
        self._route_centers = None     # (K', sketch_dim) active centers
        self._first_idx = None         # (K',) one member index per cluster
        # drift bookkeeping: per-row inertia of the finalized clustering
        # vs the running per-row inertia of everything routed since —
        # the gauge the incremental-re-finalize policy will trigger on
        self._finalized_d2 = None      # mean row d^2 at finalize time
        self._routed_d2_sum = 0.0      # accumulated routed row d^2
        self._routed_n = 0

        def _ingest(sk_buf, p_buf, wave, offset):
            sk = jax.vmap(
                lambda p: sketch_tree(self._sketch_key, p, self.sketch_dim,
                                      leaf_filter=self._leaf_filter))(wave)
            if sketch_transform is not None:
                sk = sketch_transform(sk, offset)
            sk_buf = self._constrain(
                jax.lax.dynamic_update_slice_in_dim(sk_buf, sk, offset, 0))
            p_buf = jax.tree_util.tree_map(
                lambda b, w: self._constrain(
                    jax.lax.dynamic_update_slice_in_dim(b, w, offset, 0)),
                p_buf, wave)
            return sk_buf, p_buf

        def _ingest_sk(sk_buf, sk, offset):
            if sketch_transform is not None:
                sk = sketch_transform(sk, offset)
            return self._constrain(
                jax.lax.dynamic_update_slice_in_dim(sk_buf, sk, offset, 0))

        # donate the capacity-sized buffers so XLA updates them in place
        # (a fresh full-size copy per wave would defeat the streaming
        # design); the CPU backend can't donate and would warn per wave
        donate = jax.default_backend() != "cpu"
        self._ingest_fn = jax.jit(_ingest,
                                  donate_argnums=(0, 1) if donate else ())
        self._ingest_sk_fn = jax.jit(_ingest_sk,
                                     donate_argnums=(0,) if donate else ())
        self._sketch_one = jax.jit(
            lambda p: sketch_tree(self._sketch_key, p, self.sketch_dim,
                                  leaf_filter=self._leaf_filter))

    # ------------------------------------------------------------ ingest

    def _constrain(self, x):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(self.client_axis)))

    @property
    def count(self) -> int:
        """Clients ingested so far."""
        return self._count

    @property
    def sketches(self) -> jnp.ndarray:
        """Device-resident (count, sketch_dim) view of the accumulated
        sketch matrix (no host copy)."""
        return self._sketches[:self._count]

    def _reserve(self, w: int) -> int:
        if w < 1:
            raise ValueError("empty wave")
        if self._count + w > self.capacity:
            raise ValueError(
                f"session capacity exceeded: {self._count} ingested + wave "
                f"of {w} > capacity {self.capacity}")
        offset, self._count = self._count, self._count + w
        self._final = None             # new uploads invalidate the round
        return offset

    def ingest(self, wave=None, *, sketches=None) -> int:
        """Ingest one wave of step-1 uploads; returns the wave's offset.

        ``wave`` is a stacked parameter pytree (every leaf has leading
        axis w) or a ``FederatedState``; ``sketches=`` takes an already
        projected (w, sketch_dim) matrix instead (sketch-only servers).
        Modes cannot be mixed within one session: parameter averaging in
        ``finalize`` needs every client's parameters.
        """
        if (wave is None) == (sketches is None):
            raise ValueError("pass exactly one of wave= or sketches=")
        if sketches is not None:
            return self._ingest_sketches(sketches)
        if isinstance(wave, FederatedState):
            wave = wave.params
        if self._mode == "sketches":
            raise ValueError("session already holds sketch-only waves; "
                             "cannot mix in parameter waves")
        leaves = jax.tree_util.tree_leaves(wave)
        if not leaves:
            raise ValueError("empty parameter wave")
        w = int(leaves[0].shape[0])
        offset = self._reserve(w)
        self._mode = "params"      # only after validation: a rejected
        #                            wave must not lock the mode in
        if self._params is None:
            # the stacked buffer shards its client axis like the sketch
            # buffer: per-device memory stays bounded by the shard
            self._params = jax.tree_util.tree_map(
                lambda l: self._constrain(
                    jnp.zeros((self.capacity,) + l.shape[1:], l.dtype)),
                wave)
        with obs.span("session.ingest", wave=w, offset=offset,
                      mode="params"):
            self._sketches, self._params = self._ingest_fn(
                self._sketches, self._params, wave,
                jnp.asarray(offset, jnp.int32))
            jax.block_until_ready(self._sketches)
        obs.count("session.ingest.clients", w)
        obs.count("session.ingest.bytes",
                  sum(l.size * l.dtype.itemsize for l in leaves))
        return offset

    def _ingest_sketches(self, sketches) -> int:
        if self._mode == "params":
            raise ValueError("session already holds parameter waves; "
                             "cannot mix in sketch-only waves")
        sketches = jnp.asarray(sketches, jnp.float32)
        if sketches.ndim != 2 or sketches.shape[1] != self.sketch_dim:
            raise ValueError(f"sketch wave must be (w, {self.sketch_dim}), "
                             f"got {sketches.shape}")
        w = int(sketches.shape[0])
        offset = self._reserve(w)
        self._mode = "sketches"    # only after validation, as above
        with obs.span("session.ingest", wave=w, offset=offset,
                      mode="sketches"):
            self._sketches = self._ingest_sk_fn(
                self._sketches, sketches, jnp.asarray(offset, jnp.int32))
            jax.block_until_ready(self._sketches)
        obs.count("session.ingest.clients", w)
        obs.count("session.ingest.bytes",
                  sketches.size * sketches.dtype.itemsize)
        return offset

    # ---------------------------------------------------------- finalize

    def finalize(self, *, algorithm="kmeans-device", k: Optional[int] = None,
                 algo_options: Optional[dict] = None,
                 engine: str = "device", aggregator="mean"):
        """Steps 2-4 over everything ingested: cluster the accumulated
        sketch matrix, average parameters per recovered cluster.

        Returns ``(new_state, labels, info)`` with the same contract as
        ``one_shot_aggregate`` (``new_state is None`` for sketch-only
        sessions, which have nothing to average — labels/centers still
        come back and routing becomes available).  The device path is
        bit-exact with the fused round on the same clients.
        ``aggregator`` selects the per-cluster parameter reduction from
        the registry (``mean`` | ``trimmed_mean`` | ``median`` | an
        ``Aggregator`` instance) on both engines.
        """
        if engine not in ("auto", "host", "device"):
            raise ValueError(f"engine must be auto|host|device, got "
                             f"{engine!r}")
        if self._count == 0:
            raise ValueError("nothing ingested")
        if engine != "host":
            # the legacy Lloyd-name mapping (kmeans++ -> kmeans-device
            # with init='kmeans++'), shared with ODCLFederated; raises
            # for host-only no-twin names under engine='device'
            algorithm, algo_options = resolve_device_request(
                algorithm, algo_options, strict=engine == "device")
        algo = get_algorithm(algorithm)
        dev = algo if is_device_algorithm(algo) else device_twin(algo)
        use_device = engine != "host" and dev is not None
        if use_device:
            algo = dev
        k_eff = k if algo.requires_k else None
        sketches = self.sketches                   # (count, sketch_dim)
        params = (None if self._params is None else
                  jax.tree_util.tree_map(lambda l: l[:self._count],
                                         self._params))
        with obs.span("session.finalize", count=self._count,
                      algorithm=getattr(algo, "name", str(algo)),
                      engine="device" if use_device else "host"):
            if use_device:
                out = self._finalize_device(algo, k_eff, algo_options,
                                            sketches, params, aggregator)
            else:
                out = self._finalize_host(algo, k_eff, algo_options,
                                          sketches, params, aggregator)
        self._final = out
        return out

    def _finalize_device(self, algo, k, algo_options, sketches, params,
                         aggregator="mean"):
        cluster_key = jax.random.PRNGKey(self.cluster_seed)
        aggregator = get_aggregator(aggregator)
        opts = tuple(sorted((algo_options or {}).items()))
        # the cluster and mean phases run as two AOT programs (labels /
        # centers stay on device between them) so the obs layer sees the
        # finalize latency split — the breakdown an incremental
        # re-finalize would consult to decide what to re-run
        res = cached_program(_cluster_program, algo, k, opts)(
            cluster_key, sketches)
        if params is None:
            labels, uniq, first = compact_labels(res.labels)
            info = {"n_clusters": int(len(uniq)),
                    "meta": meta_to_host(res.meta),
                    "engine": "device", "count": self._count}
            self._set_routing(res.centers[jnp.asarray(uniq)], first)
            self._note_finalized(sketches, res)
            return None, labels, info
        new_params = cached_program(_mean_program, self.mesh,
                                    self.client_axis, aggregator)(
            res.labels, res.centers, params)
        state = FederatedState(params=params, opt_state=None,
                               n_clients=self._count, step=0)
        new_state, labels, info, uniq, first = materialize_round(
            new_params, res, state)
        info["count"] = self._count
        self._set_routing(res.centers[jnp.asarray(uniq)], first)
        self._note_finalized(sketches, res)
        return new_state, labels, info

    def _note_finalized(self, sketches, res):
        """Anchor the drift gauge: record the finalized clustering's mean
        per-row inertia and reset the routed-traffic accumulator."""
        self._finalized_d2 = float(
            _sum_sq_to_assigned(sketches, res.centers, res.labels)
        ) / max(self._count, 1)
        self._routed_d2_sum = 0.0
        self._routed_n = 0

    def _finalize_host(self, algo, k, algo_options, sketches, params,
                       aggregator="mean"):
        from repro.core.odcl import run_clustering

        with obs.span("session.finalize.cluster", engine="host"):
            result = run_clustering(jax.random.PRNGKey(self.cluster_seed),
                                    np.asarray(sketches), algo, k=k,
                                    **(algo_options or {}))
        labels, _, first = compact_labels(result.labels)
        info = {"n_clusters": result.n_clusters, "meta": result.meta,
                "engine": "host", "count": self._count}
        centers = jnp.asarray(result.centers, jnp.float32)
        self._set_routing(centers, first)
        self._finalized_d2 = float(_sum_sq_to_assigned(
            sketches, centers, jnp.asarray(labels))) / max(self._count, 1)
        self._routed_d2_sum = 0.0
        self._routed_n = 0
        if params is None:
            return None, labels, info
        labels_j = jnp.asarray(labels)
        with obs.span("session.finalize.mean", engine="host"):
            onehot = jax.nn.one_hot(labels_j, result.n_clusters,
                                    dtype=jnp.float32)
            counts = jnp.sum(onehot, axis=0)
            new_params = cluster_aggregate_tree(params, labels_j, onehot,
                                                counts, aggregator)
            jax.block_until_ready(new_params)
        new_state = FederatedState(
            params=new_params, opt_state=jax.vmap(adamw_init)(new_params),
            n_clients=self._count, step=0)
        return new_state, labels, info

    def _set_routing(self, centers, first_idx):
        self._route_centers = centers
        self._first_idx = np.asarray(first_idx)

    # ------------------------------------------------------------- serve

    def route(self, sketch=None, *, params=None):
        """Assign a (possibly never-seen) client to its nearest recovered
        cluster — the serving-time step 4.

        Pass either a (sketch_dim,) / (n, sketch_dim) sketch or a raw
        parameter pytree (sketched with the session's own projection).
        Runs the fused ``kernels/kmeans_assign`` dispatch against the
        active cluster centers; returns an int (or (n,) int array).
        """
        if self._final is None:
            raise ValueError("route() needs finalize() first")
        if (sketch is None) == (params is None):
            raise ValueError("pass exactly one of sketch or params=")
        if params is not None:
            sketch = self._sketch_one(params)
        sketch = jnp.asarray(sketch, jnp.float32)
        single = sketch.ndim == 1
        pts = sketch[None] if single else sketch
        with obs.span("session.route", n=int(pts.shape[0])):
            labels, _, _ = kops.kmeans_assign(pts, self._route_centers)
            out = np.asarray(labels)
        obs.count("session.route.requests", int(pts.shape[0]))
        # drift gauge: routed traffic's mean d^2 to its assigned center,
        # relative to the finalized clustering's own mean d^2 — the
        # trigger signal for the roadmap's incremental re-finalize
        self._routed_d2_sum += float(_sum_sq_to_assigned(
            pts, self._route_centers, labels))
        self._routed_n += int(pts.shape[0])
        d = self.drift
        if d is not None:
            obs.gauge("session.drift", d)
        return int(out[0]) if single else out

    def cluster_model(self, cluster_id: int):
        """The averaged model of one recovered cluster (a single-model
        pytree, no leading client axis) — what a routed client is served.
        """
        if self._final is None:
            raise ValueError("cluster_model() needs finalize() first")
        state = self._final[0]
        if state is None:
            raise ValueError("sketch-only session holds no parameters")
        idx = int(self._first_idx[int(cluster_id)])
        return jax.tree_util.tree_map(lambda l: l[idx], state.params)

    @property
    def route_centers(self) -> jnp.ndarray:
        """(K', sketch_dim) active cluster centers (device-resident)."""
        if self._final is None:
            raise ValueError("finalize() first")
        return self._route_centers

    @property
    def drift(self) -> Optional[float]:
        """Routed-traffic inertia relative to the finalized clustering's
        own inertia: (mean routed row d^2) / (mean finalized row d^2).

        ~1.0 means serving traffic looks like the federation that was
        clustered; growth means the recovered centers are going stale —
        the signal a future incremental re-finalize would trigger on.
        ``None`` until at least one finalize and one route happened.
        """
        if self._finalized_d2 is None or self._routed_n == 0:
            return None
        return (self._routed_d2_sum / self._routed_n) / max(
            self._finalized_d2, 1e-12)

    # ------------------------------------------------------------- state

    def state(self) -> FederatedState:
        """The ingested federation as a stacked ``FederatedState`` —
        feeds any registered ``FederatedMethod`` (how ``simulate.py``
        runs iterative baselines over a streamed-in federation)."""
        if self._mode != "params":
            raise ValueError("state() needs parameter waves")
        params = jax.tree_util.tree_map(lambda l: l[:self._count],
                                        self._params)
        return FederatedState(params=params,
                              opt_state=jax.vmap(adamw_init)(params),
                              n_clients=self._count)
