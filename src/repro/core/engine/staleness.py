"""Staleness policies for the mutable ``AggregationSession``.

A long-lived server ingests the same clients repeatedly and keeps rows
for clients that stopped uploading; freshness is a policy decision.
Ages are LOGICAL: the session advances a clock by one per ingested
wave, stamps every written row with the post-ingest clock, and asks the
policy about ``age = clock - stamp`` (the latest wave is age 0).  Two
orthogonal knobs:

  * ``evict(ages) -> bool mask``     — hard forgetting: masked rows are
    removed from the slot table, returned to the free list, and never
    reach another finalize.
  * ``weights(ages) -> None | (n,)`` — soft forgetting: per-row weights
    for the finalize's per-cluster parameter mean (``None`` keeps the
    unweighted path, which stays bit-exact with the fused round).

Policies are small frozen dataclasses (hashable, like the aggregator
and edge-set registries): ``none`` keeps everything forever,
``max_age`` is the sliding window, ``exp_decay`` keeps every row but
halves its averaging weight every ``half_life`` waves.
``make_staleness_policy`` also parses the CLI spellings
(``"max_age=3"``, ``"exp_decay=2.0"``) used by ``launch/simulate.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class NoStaleness:
    """Keep every row forever, unweighted (the pre-mutation behaviour)."""
    name: str = "none"

    def evict(self, ages) -> np.ndarray:
        return np.zeros(np.shape(ages), bool)

    def weights(self, ages) -> Optional[np.ndarray]:
        return None


@dataclasses.dataclass(frozen=True)
class SlidingWindow:
    """Hard sliding window: evict rows whose age exceeds ``max_age``
    waves (a client survives by re-uploading before the window closes)."""
    max_age: int = 4
    name: str = "max_age"

    def __post_init__(self):
        if self.max_age < 1:
            raise ValueError(f"max_age must be >= 1, got {self.max_age}")

    def evict(self, ages) -> np.ndarray:
        return np.asarray(ages) > self.max_age

    def weights(self, ages) -> Optional[np.ndarray]:
        return None


@dataclasses.dataclass(frozen=True)
class ExpDecay:
    """Soft forgetting: never evict, but weight each row's contribution
    to the per-cluster parameter mean by ``0.5 ** (age / half_life)`` —
    stale uploads fade instead of falling off a cliff."""
    half_life: float = 4.0
    name: str = "exp_decay"

    def __post_init__(self):
        if self.half_life <= 0:
            raise ValueError(
                f"half_life must be > 0, got {self.half_life}")

    def evict(self, ages) -> np.ndarray:
        return np.zeros(np.shape(ages), bool)

    def weights(self, ages) -> Optional[np.ndarray]:
        return 0.5 ** (np.asarray(ages, np.float64) / self.half_life)


def make_staleness_policy(spec, **options):
    """Resolve a policy: an instance passes through; a name builds one
    (``"none"`` | ``"max_age"`` | ``"exp_decay"``) with keyword options
    (``max_age=``, ``half_life=``); the CLI spellings ``"max_age=3"``
    and ``"exp_decay=2.0"`` parse their single parameter inline."""
    if spec is None:
        return NoStaleness()
    if not isinstance(spec, str):
        return spec
    name, _, arg = spec.partition("=")
    if name == "none":
        return NoStaleness()
    if name in ("max_age", "sliding_window"):
        try:
            max_age = int(arg) if arg else options.get("max_age")
            return (SlidingWindow() if max_age is None
                    else SlidingWindow(max_age))
        except ValueError as err:
            raise ValueError(
                f"invalid staleness spec {spec!r}: max_age must be an "
                f"integer >= 1 ({err})") from None
    if name == "exp_decay":
        try:
            half_life = float(arg) if arg else options.get("half_life")
            return ExpDecay() if half_life is None else ExpDecay(half_life)
        except ValueError as err:
            raise ValueError(
                f"invalid staleness spec {spec!r}: half_life must be a "
                f"number > 0 ({err})") from None
    raise ValueError(f"unknown staleness policy {spec!r}; "
                     "known: none | max_age | exp_decay")
