"""Two-level hierarchical ODCL — the million-client round.

A single ``AggregationSession`` holds one fixed-capacity
(capacity, sketch_dim) buffer and clusters it in one shot; that buffer
is the C ceiling.  k-FED ("Heterogeneity for the Win: One-Shot
Federated Clustering") shows the one-shot estimate composes: cluster
each shard of clients independently, then cluster the shard-level
centers — under the paper's Definition-1 separation the shard centers
of a true cluster concentrate around its mean, so the top level
recovers the same partition from S*k points instead of C.

``HierarchicalSession`` is that composition over S independent
``AggregationSession`` shards sharing one JL projection:

  * **ingest** fills shards contiguously (global client order is the
    concatenation of shard orders), splitting waves at shard
    boundaries.  Anonymous waves only — keyed mutation composes with a
    single session, not with a sharded one (a re-upload would have to
    find its shard), and raises a clear ``ValueError``.
  * **finalize** is two levels.  Level 0 runs the existing fused
    sketch -> cluster -> mean round per shard (the exact
    ``session.finalize`` body — every registered family, edge sets
    included, streams unchanged).  Level 1 gathers the ~S*k active
    shard centers with their member counts, clusters them through a
    sketch-only ``AggregationSession`` (same resolution machinery,
    same obs spans), and composes:

      - top cluster centers  = count-weighted means of member shard
        centers (== the global mean of the member clients' sketches
        when the family's centers are member means),
      - top cluster models   = count-weighted means of member shard
        models through the engine's ``_weighted_mean_program``
        (== the exact global per-cluster parameter mean),
      - per-client labels    = ``top_labels[offset_s + shard_labels]``.

    Top-level communication is O(S*k*sketch_dim) where the flat round
    pays O(C*sketch_dim); both levels' bytes are reported in
    ``info["comm_level_bytes"]`` and as ``hierarchy.comm.*`` gauges.
  * **route / cluster_model** serve from the composed top-level
    clustering with the session's single-sync batched route program.

``shards=1`` delegates every call to the single underlying session —
bit-exact with ``one_shot_aggregate(engine="device")`` on the same
clients (the hypothesis property in ``tests/test_hierarchy.py``), not
merely equal-up-to-relabeling as a 1-shard two-level pass would be.

``hierarchical_one_shot_aggregate`` wraps the session as a functional
round for the fused-round call sites (``launch/simulate.py --shards``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.engine.aggregate import (
    _route_program,
    _weighted_mean_program,
    cached_program,
)
from repro.core.engine.session import AggregationSession
from repro.core.federated import FederatedState
from repro.optim import adamw_init

_F32 = 4  # bytes per sketch coordinate on the wire


class HierarchicalSession:
    """S-sharded two-level aggregation with the session serving contract.

    Args:
      capacity: total live-client ceiling, split evenly across shards
        (per-shard capacity = ceil(capacity / shards)).
      shards: number of level-0 ``AggregationSession`` instances.  1
        delegates everything to the flat session (bit-exact).
      sketch_dim / cfg / seed / cluster_seed / sketch_transform /
        mesh / client_axis: forwarded to every shard session; all
        shards share ``seed`` so their JL projections — and therefore
        the sketch space the top level clusters in — are identical.
    """

    def __init__(self, capacity: int, *, shards: int = 1,
                 sketch_dim: int = 256, cfg=None, seed: int = 0,
                 cluster_seed: Optional[int] = None, sketch_transform=None,
                 mesh=None, client_axis: str = "data"):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if capacity < shards:
            raise ValueError(f"capacity {capacity} < shards {shards}: "
                             "every shard needs at least one slot")
        self.shards = int(shards)
        self.capacity = int(capacity)
        self.shard_capacity = -(-self.capacity // self.shards)
        self.sketch_dim = int(sketch_dim)
        self.seed = int(seed)
        self.cluster_seed = self.seed if cluster_seed is None else int(
            cluster_seed)
        self.mesh, self.client_axis = mesh, client_axis
        self._sessions = [
            AggregationSession(self.shard_capacity, sketch_dim=sketch_dim,
                               cfg=cfg, seed=seed, cluster_seed=cluster_seed,
                               sketch_transform=sketch_transform, mesh=mesh,
                               client_axis=client_axis)
            for _ in range(self.shards)]
        self._fill = 0                 # global clients ingested so far
        # composed top-level serving state (shards > 1 only)
        self._serving = None           # (state | None, labels, info)
        self._route_centers = None     # (K'', sketch_dim) weighted centers
        self._n_clusters = 0

    # ------------------------------------------------------------ ingest

    @property
    def count(self) -> int:
        return sum(s.count for s in self._sessions)

    def ingest(self, wave=None, *, sketches=None, client_ids=None):
        """Ingest one anonymous wave, split at shard boundaries.

        Clients fill shard 0's buffer first, then shard 1's, and so on;
        a wave straddling a boundary is sliced so each piece lands in
        its shard.  Returns the wave's global offset (its first
        client's position in ingestion order)."""
        if client_ids is not None:
            raise ValueError(
                "hierarchical sessions are anonymous-only: keyed client "
                "slots (client_ids=) need the flat AggregationSession "
                "(shards=1 via HierarchicalSession delegates to it)")
        if (wave is None) == (sketches is None):
            raise ValueError("pass exactly one of wave= or sketches=")
        if sketches is not None:
            sketches = jnp.asarray(sketches, jnp.float32)
            w = int(sketches.shape[0]) if sketches.ndim == 2 else -1
        else:
            leaves = jax.tree_util.tree_leaves(wave)
            if not leaves:
                raise ValueError("empty parameter wave")
            w = int(leaves[0].shape[0])
        if w < 1:
            raise ValueError("empty wave")
        if self._fill + w > self.shard_capacity * self.shards:
            raise ValueError(
                f"hierarchical capacity exceeded: {self._fill} live + {w} "
                f"new > {self.shard_capacity * self.shards}")
        offset = self._fill
        start = 0
        while start < w:
            shard = self._fill // self.shard_capacity
            room = (shard + 1) * self.shard_capacity - self._fill
            take = min(room, w - start)
            if sketches is not None:
                self._sessions[shard].ingest(
                    sketches=sketches[start:start + take])
            else:
                piece = jax.tree_util.tree_map(
                    lambda l: l[start:start + take], wave)
                self._sessions[shard].ingest(piece)
            self._fill += take
            start += take
        self._serving = None if self.shards > 1 else self._serving
        return offset

    @property
    def sketches(self) -> jnp.ndarray:
        """(count, sketch_dim) concatenation of the live shard sketch
        rows, in global (ingestion) order — a copy for shards > 1."""
        if self.shards == 1:
            return self._sessions[0].sketches
        live = [s.sketches for s in self._sessions if s.count > 0]
        return jnp.concatenate(live, axis=0)

    def state(self) -> FederatedState:
        """The live federation as one stacked ``FederatedState`` (shard
        states concatenated in global order) — a copy for shards > 1."""
        if self.shards == 1:
            return self._sessions[0].state()
        states = [s.state() for s in self._sessions if s.count > 0]
        params = jax.tree_util.tree_map(
            lambda *ls: jnp.concatenate(ls, axis=0),
            *[st.params for st in states])
        return FederatedState(params=params,
                              opt_state=jax.vmap(adamw_init)(params),
                              n_clients=self.count)

    # ---------------------------------------------------------- finalize

    def finalize(self, *, algorithm="kmeans-device", k: Optional[int] = None,
                 algo_options: Optional[dict] = None, engine: str = "device",
                 aggregator="mean"):
        """Two-level steps 2-4; same ``(new_state, labels, info)``
        contract as ``AggregationSession.finalize`` with hierarchy
        fields added to ``info`` (``shards``, ``per_shard_clusters``,
        ``comm_level_bytes``)."""
        if self.count == 0:
            raise ValueError("nothing ingested")
        kwargs = dict(algorithm=algorithm, k=k, algo_options=algo_options,
                      engine=engine, aggregator=aggregator)
        if self.shards == 1:
            out = self._sessions[0].finalize(**kwargs)
            out[2].setdefault("shards", 1)
            self._serving = out
            return out
        with obs.span("hierarchy.finalize", shards=self.shards,
                      count=self.count):
            return self._finalize_two_level(**kwargs)

    def _finalize_two_level(self, *, algorithm, k, algo_options, engine,
                            aggregator):
        live = [s for s in self._sessions if s.count > 0]
        # ---- level 0: the fused round per shard -----------------------
        shard_rounds = []
        with obs.span("hierarchy.level0", shards=len(live)):
            for s in live:
                shard_rounds.append(s.finalize(
                    algorithm=algorithm, k=k, algo_options=algo_options,
                    engine=engine, aggregator=aggregator))
        centers, counts, models, offsets = [], [], [], []
        off = 0
        for s, (state_s, labels_s, _) in zip(live, shard_rounds):
            kp = s.n_clusters
            offsets.append(off)
            off += kp
            centers.append(s.route_centers)                    # (K'_s, dim)
            counts.append(np.bincount(labels_s, minlength=kp))
            if state_s is not None:
                first = np.unique(labels_s, return_index=True)[1]
                models.append(jax.tree_util.tree_map(
                    lambda l: l[jnp.asarray(first, jnp.int32)],
                    state_s.params))                           # (K'_s, ...)
        top_points = jnp.concatenate(centers, axis=0)          # (M, dim)
        weights = np.concatenate(counts).astype(np.float64)    # (M,)
        m_top = int(top_points.shape[0])
        level0_bytes = self.count * self.sketch_dim * _F32
        level1_bytes = m_top * (self.sketch_dim + 1) * _F32    # + the count
        obs.gauge("hierarchy.comm.level0_bytes", float(level0_bytes))
        obs.gauge("hierarchy.comm.level1_bytes", float(level1_bytes))
        obs.gauge("hierarchy.top_points", float(m_top))

        # ---- level 1: cluster the size-weighted shard centers ---------
        k_top = None if k is None else min(int(k), m_top)
        with obs.span("hierarchy.level1", points=m_top):
            top = AggregationSession(m_top, sketch_dim=self.sketch_dim,
                                     seed=self.seed,
                                     cluster_seed=self.cluster_seed,
                                     mesh=self.mesh,
                                     client_axis=self.client_axis)
            top.ingest(sketches=top_points)
            _, top_labels, top_info = top.finalize(
                algorithm=algorithm, k=k_top, algo_options=algo_options,
                engine=engine, aggregator="mean")
        k2 = int(top_info["n_clusters"])
        w_j = jnp.asarray(weights, jnp.float32)
        lab_j = jnp.asarray(top_labels, jnp.int32)
        # count-weighted top centers: the global sketch mean of each top
        # cluster's member clients (shard centers are member means)
        sums = jnp.zeros((k2, self.sketch_dim), jnp.float32).at[lab_j].add(
            w_j[:, None] * top_points)
        denom = jnp.maximum(
            jnp.zeros((k2,), jnp.float32).at[lab_j].add(w_j), 1e-12)
        top_centers = sums / denom[:, None]

        # ---- compose ---------------------------------------------------
        labels = np.concatenate([
            np.asarray(top_labels)[offsets[i] + labels_s]
            for i, (_, labels_s, _) in enumerate(shard_rounds)])
        info = {
            "n_clusters": k2,
            "engine": top_info["engine"],
            "count": self.count,
            "meta": top_info["meta"],
            "shards": len(live),
            "per_shard_clusters": [s.n_clusters for s in live],
            "comm_level_bytes": {"level0": level0_bytes,
                                 "level1": level1_bytes},
        }
        new_state = None
        if models:
            # (M, ...) shard-cluster models -> per-row weighted top means
            stacked = jax.tree_util.tree_map(
                lambda *ls: jnp.concatenate(ls, axis=0), *models)
            top_models = cached_program(_weighted_mean_program, self.mesh,
                                        self.client_axis)(
                lab_j, top_centers, stacked, w_j)
            per_client = jax.tree_util.tree_map(
                lambda l: jnp.concatenate([
                    l[jnp.asarray(offsets[i] + labels_s, jnp.int32)]
                    for i, (_, labels_s, _) in enumerate(shard_rounds)],
                    axis=0),
                top_models)
            new_state = FederatedState(
                params=per_client,
                opt_state=jax.vmap(adamw_init)(per_client),
                n_clients=self.count, step=0)
        self._route_centers = top_centers
        self._n_clusters = k2
        self._serving = (new_state, labels, info)
        return new_state, labels, info

    # ------------------------------------------------------------- serve

    def route(self, sketch=None, *, params=None):
        """Nearest composed top-level cluster, single-sync per batch —
        the flat session's serving contract over the hierarchy."""
        if self.shards == 1:
            return self._sessions[0].route(sketch, params=params)
        if self._serving is None:
            raise ValueError("route() needs finalize() first")
        if (sketch is None) == (params is None):
            raise ValueError("pass exactly one of sketch or params=")
        if params is not None:
            sketch = self._sessions[0]._sketch_one(params)
        sketch = jnp.asarray(sketch, jnp.float32)
        single = sketch.ndim == 1
        pts = sketch[None] if single else sketch
        with obs.span("hierarchy.route", n=int(pts.shape[0])):
            labels, _ = cached_program(_route_program)(
                pts, self._route_centers)
            out = np.asarray(jax.device_get(labels))
        return int(out[0]) if single else out

    def cluster_model(self, cluster_id: int):
        if self.shards == 1:
            return self._sessions[0].cluster_model(cluster_id)
        state = self._require_serving()[0]
        if state is None:
            raise ValueError("sketch-only session holds no parameters")
        cid = int(cluster_id)
        if not 0 <= cid < self._n_clusters:
            raise IndexError(
                f"cluster id {cid} out of range for {self._n_clusters} "
                "recovered clusters")
        # any member client row of the top cluster carries its model;
        # labels are compact, so first occurrence is a member
        labels = self._require_serving()[1]
        idx = int(np.argmax(labels == cid))
        return jax.tree_util.tree_map(lambda l: l[idx], state.params)

    def _require_serving(self):
        if self._serving is None:
            raise ValueError("finalize() first")
        return self._serving

    @property
    def n_clusters(self) -> int:
        if self.shards == 1:
            return self._sessions[0].n_clusters
        self._require_serving()
        return self._n_clusters

    @property
    def route_centers(self) -> jnp.ndarray:
        if self.shards == 1:
            return self._sessions[0].route_centers
        self._require_serving()
        return self._route_centers


def hierarchical_one_shot_aggregate(state: FederatedState, cfg=None, *,
                                    shards: int, algorithm="kmeans-device",
                                    k: Optional[int] = None,
                                    algo_options: Optional[dict] = None,
                                    sketch_dim: int = 256, seed: int = 0,
                                    cluster_seed: Optional[int] = None,
                                    aggregator="mean",
                                    engine: str = "device",
                                    mesh=None, client_axis: str = "data"):
    """The two-level round as a function call — ``one_shot_aggregate``'s
    contract (``(new_state, labels, info)``) over a sharded server.
    ``shards=1`` is bit-exact with the flat device round."""
    sess = HierarchicalSession(state.n_clients, shards=shards,
                               sketch_dim=sketch_dim, cfg=cfg, seed=seed,
                               cluster_seed=cluster_seed, mesh=mesh,
                               client_axis=client_axis)
    cap = sess.shard_capacity
    for start in range(0, state.n_clients, cap):
        stop = min(start + cap, state.n_clients)
        sess.ingest(jax.tree_util.tree_map(lambda l: l[start:stop],
                                           state.params))
    return sess.finalize(algorithm=algorithm, k=k, algo_options=algo_options,
                         engine=engine, aggregator=aggregator)
