"""IFCA baseline [Ghosh et al., 2022] — the paper's main comparison.

Iterative Federated Clustering Algorithm (Appendix C description):

  repeat T rounds:
    1. server broadcasts K models {theta_k^t}
    2. each user picks the model with the smallest local loss
    3. gradient averaging: users send grad f_i(theta_(i)) and the server
       does theta_k <- theta_k - alpha * mean_{i in C_k^t} g_i
       (or model averaging: tau local steps then cluster-average)

Needs knowledge of K and — per the paper's experiments — succeeds only
with sufficiently close initialization (IFCA-1/IFCA-2/IFCA-R variants).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class IFCAConfig:
    k: int
    rounds: int = 200
    step_size: float = 0.1
    mode: str = "gradient"         # 'gradient' | 'model'
    local_steps: int = 5           # for mode='model'


def ifca_init_near_optima(key, optima, noise_std: float):
    """IFCA-1/IFCA-2 init: true optima + N(0, std^2) noise (Section 5)."""
    return optima + noise_std * jax.random.normal(key, optima.shape)


def ifca_init_annulus(key, optima, d_min: float, lo_frac: float = 0.2,
                      hi_frac: float = 1.0 / 3.0):
    """Appendix E.4 init: random point with D/5 <= ||.|| - opt <= D/3."""
    k, d = optima.shape
    k1, k2 = jax.random.split(key)
    dirs = jax.random.normal(k1, (k, d))
    dirs = dirs / jnp.linalg.norm(dirs, axis=1, keepdims=True)
    radii = jax.random.uniform(k2, (k, 1), minval=lo_frac * d_min,
                               maxval=hi_frac * d_min)
    return optima + dirs * radii


def per_user_model_losses(theta, xs, ys, loss_fn: Callable):
    """(m, K) local loss of every broadcast model at every user.

    The cluster-estimate rule of step 2 — argmin over the K columns is
    the IFCA assignment.  Shared by the flat loop below and mirrored on
    model pytrees by ``core.federated_methods.IFCAFederated``.
    """
    return jax.vmap(lambda x, y: jax.vmap(
        lambda t: loss_fn(t, x, y))(theta))(xs, ys)


@functools.partial(jax.jit, static_argnames=("loss_fn", "grad_fn", "cfg"))
def ifca(theta0, xs, ys, loss_fn: Callable, grad_fn: Callable, cfg: IFCAConfig):
    """Run IFCA.

    theta0: (K, d) initial models.  xs: (m, n, ...), ys: (m, n).
    loss_fn(theta, x, y) -> scalar;  grad_fn(theta, x, y) -> (d,).
    Returns (theta_T (K,d), labels (m,), history (T, K, d)).
    """
    m = xs.shape[0]

    def losses_for(theta):
        return per_user_model_losses(theta, xs, ys, loss_fn)

    def round_fn(theta, _):
        per_user = losses_for(theta)                        # (m, K)
        assign = jnp.argmin(per_user, axis=1)               # (m,)
        onehot = jax.nn.one_hot(assign, cfg.k, dtype=jnp.float32)  # (m, K)
        if cfg.mode == "gradient":
            grads = jax.vmap(
                lambda x, y, a: grad_fn(theta[a], x, y)
            )(xs, ys, assign)                               # (m, d)
            gsum = onehot.T @ grads                         # (K, d)
            cnt = jnp.maximum(jnp.sum(onehot, axis=0), 1.0)[:, None]
            theta = theta - cfg.step_size * gsum / cnt
        else:  # model averaging with tau local GD steps
            def local(theta_i, x, y):
                def step(t, _):
                    return t - cfg.step_size * grad_fn(t, x, y), None
                t, _ = jax.lax.scan(step, theta_i, None, length=cfg.local_steps)
                return t
            locals_ = jax.vmap(lambda x, y, a: local(theta[a], x, y))(xs, ys, assign)
            msum = onehot.T @ locals_
            cnt = jnp.maximum(jnp.sum(onehot, axis=0), 1.0)[:, None]
            avg = msum / cnt
            hit = (jnp.sum(onehot, axis=0) > 0)[:, None]
            theta = jnp.where(hit, avg, theta)
        return theta, theta

    theta, hist = jax.lax.scan(round_fn, theta0, None, length=cfg.rounds)
    final_assign = jnp.argmin(losses_for(theta), axis=1)
    return theta, final_assign, hist
