"""Algorithm 1: the ODCL-C one-shot protocol.

    1. every user solves its local ERM and uploads theta_hat_i  (1 round)
    2. the server clusters {theta_hat_i} with an admissible algorithm
    3. the server averages models within each recovered cluster
    4. each user receives its cluster's averaged model

``odcl`` operates on an (m, d) stack of model vectors — the exact
paper algorithm (used by the paper-scale experiments and benchmarks).
The multi-pod deep-learning integration lives in ``federated.py`` and
reuses this module's server step on sketched parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import (
    kmeans,
    gradient_clustering,
    convex_clustering,
    clusterpath,
    lambda_interval,
)


@dataclasses.dataclass(frozen=True)
class ODCLConfig:
    """Server-side configuration of Algorithm 1's step 2."""
    algo: Literal["kmeans", "kmeans++", "spectral", "convex", "clusterpath",
                  "gradient"] = "kmeans++"
    k: Optional[int] = None          # required by kmeans/gradient variants
    lam: Optional[float] = None      # required by 'convex'; None -> interval mid
    kmeans_iters: int = 100
    cc_iters: int = 400
    n_lambdas: int = 10              # clusterpath sweep size
    seed: int = 0


@dataclasses.dataclass
class ODCLResult:
    labels: np.ndarray               # (m,) recovered cluster of each user
    cluster_models: np.ndarray       # (K', d) averaged model per cluster
    user_models: np.ndarray          # (m, d) model each user receives
    n_clusters: int
    meta: dict


def cluster_models(local_models, cfg: ODCLConfig):
    """Step 2 — run the chosen admissible clustering algorithm."""
    pts = jnp.asarray(local_models, jnp.float32)
    key = jax.random.PRNGKey(cfg.seed)
    if cfg.algo in ("kmeans", "kmeans++", "spectral"):
        assert cfg.k is not None, f"{cfg.algo} requires k"
        init = {"kmeans": "random", "kmeans++": "kmeans++", "spectral": "spectral"}[cfg.algo]
        res = kmeans(key, pts, cfg.k, iters=cfg.kmeans_iters, init=init)
        return np.asarray(res.labels), {"inertia": float(res.inertia),
                                        "n_iter": int(res.n_iter)}
    if cfg.algo == "gradient":
        assert cfg.k is not None, "gradient clustering requires k"
        res = gradient_clustering(key, pts, cfg.k, iters=cfg.kmeans_iters)
        return np.asarray(res.labels), {"inertia": float(res.inertia)}
    if cfg.algo == "convex":
        lam = cfg.lam
        if lam is None:
            # paper E.1 heuristic: take the upper recovery bound of the
            # all-singletons clustering as a starting penalty
            lo, hi = lambda_interval(np.asarray(pts), np.arange(pts.shape[0]))
            lam = hi if np.isfinite(hi) else lo + 1e-3
        res = convex_clustering(pts, float(lam), iters=cfg.cc_iters)
        return res.labels, {"lam": res.lam, "n_clusters": res.n_clusters}
    if cfg.algo == "clusterpath":
        best, _ = clusterpath(pts, n_lambdas=cfg.n_lambdas, iters=cfg.cc_iters)
        return best.labels, {"lam": best.lam, "n_clusters": best.n_clusters}
    raise ValueError(f"unknown clustering algo {cfg.algo!r}")


def aggregate(local_models, labels):
    """Steps 3-4 — cluster-wise averaging + per-user model assignment."""
    local_models = np.asarray(local_models, np.float32)
    labels = np.asarray(labels)
    n_clusters = int(labels.max()) + 1
    cluster_avg = np.stack([
        local_models[labels == c].mean(axis=0) for c in range(n_clusters)
    ])
    return cluster_avg, cluster_avg[labels]


def odcl(local_models, cfg: ODCLConfig) -> ODCLResult:
    """Run the full server side of Algorithm 1 on an (m, d) model stack."""
    labels, meta = cluster_models(local_models, cfg)
    cluster_avg, user_models = aggregate(local_models, labels)
    return ODCLResult(
        labels=labels,
        cluster_models=cluster_avg,
        user_models=user_models,
        n_clusters=cluster_avg.shape[0],
        meta=meta,
    )
