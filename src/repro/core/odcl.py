"""Algorithm 1: the ODCL-C one-shot protocol.

    1. every user solves its local ERM and uploads theta_hat_i  (1 round)
    2. the server clusters {theta_hat_i} with an admissible algorithm
    3. the server averages models within each recovered cluster
    4. each user receives its cluster's averaged model

``odcl`` operates on an (m, d) stack of model vectors — the exact
paper algorithm (used by the paper-scale experiments and benchmarks).
Step 2 dispatches through the admissible-clustering registry
(``clustering.api``): any registered ``ClusteringAlgorithm`` is usable
here by name, and ``ODCLConfig`` remains as the thin legacy shim over
that registry.  The object-style server API (``methods.ODCL``) wraps
this module; the multi-pod deep-learning integration lives in
``federated.py`` and reuses the same server step on sketched
parameters.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering.admissible import separability_alpha
from repro.core.clustering.api import (
    ClusteringAlgorithm,
    ClusteringResult,
    get_algorithm,
)


@dataclasses.dataclass(frozen=True)
class ODCLConfig:
    """Server-side configuration of Algorithm 1's step 2.

    Legacy shim: ``algo`` is resolved through the clustering registry,
    so any name accepted by ``get_algorithm`` works — including
    algorithms registered after import.  New code should prefer
    ``methods.ODCL(algorithm=...)``.
    """
    algo: str = "kmeans++"
    k: Optional[int] = None          # required by kmeans/gradient variants
    lam: Optional[float] = None      # required by 'convex'; None -> interval mid
    kmeans_iters: int = 100
    cc_iters: int = 400
    n_lambdas: int = 10              # clusterpath sweep size
    seed: int = 0
    assert_separable: bool = False   # raise if condition (4) fails vs Lemma alpha

    def __post_init__(self):
        warnings.warn(
            "ODCLConfig is a legacy shim scheduled for removal; use "
            "methods.Method.fit (e.g. ODCL(algorithm=...).fit(...)) or "
            "one_shot_aggregate(algorithm=..., k=..., algo_options=...) "
            "instead", DeprecationWarning, stacklevel=2)

    def algorithm_options(self) -> dict:
        """Map the legacy flat fields onto registry-call options."""
        if self.algo in ("kmeans", "kmeans++", "spectral", "gradient",
                         "kmeans-device"):
            return {"iters": self.kmeans_iters}
        if self.algo in ("convex", "convex-device"):
            return {"lam": self.lam, "iters": self.cc_iters}
        if self.algo in ("clusterpath", "clusterpath-device"):
            return {"n_lambdas": self.n_lambdas, "iters": self.cc_iters}
        return {}                    # externally registered algorithms


@dataclasses.dataclass
class ODCLResult:
    labels: np.ndarray               # (m,) recovered cluster of each user
    cluster_models: np.ndarray       # (K', d) averaged model per cluster
    user_models: np.ndarray          # (m, d) model each user receives
    n_clusters: int
    meta: dict


def run_clustering(key, points,
                   algorithm: Union[str, ClusteringAlgorithm],
                   *, k: Optional[int] = None,
                   assert_separable: bool = False,
                   **options) -> ClusteringResult:
    """Step 2 through the registry, with Definition-1 reporting.

    Resolves ``algorithm`` by name, runs it, and attaches the achieved
    separability margin (condition (4)) and the algorithm's Lemma-1/2
    admissibility margin to ``result.meta``.  With
    ``assert_separable=True`` a clustering whose achieved margin falls
    at or below the admissible alpha raises ``ValueError``.
    """
    algo = get_algorithm(algorithm)
    pts = jnp.asarray(points, jnp.float32)
    result = algo(key, pts, k=k, **options)
    m = int(pts.shape[0])
    counts = np.bincount(result.labels, minlength=result.n_clusters)
    c_min = int(counts[counts > 0].min()) if m else 0
    achieved = separability_alpha(np.asarray(pts), result.labels)
    admissible = float(algo.admissibility_alpha(m, max(c_min, 1)))
    meta = dict(result.meta)
    meta["separability_alpha"] = float(achieved)
    meta["admissible_alpha"] = admissible
    if assert_separable and not achieved > admissible:
        raise ValueError(
            f"clustering by {algo.name!r} is not separable per Definition 1: "
            f"achieved alpha {achieved:.3g} <= admissible {admissible:.3g}")
    return dataclasses.replace(result, meta=meta)


def cluster_models(local_models, cfg: ODCLConfig):
    """Step 2 — legacy entrypoint; dispatches through the registry."""
    key = jax.random.PRNGKey(cfg.seed)
    result = run_clustering(key, local_models, cfg.algo, k=cfg.k,
                            assert_separable=cfg.assert_separable,
                            **cfg.algorithm_options())
    return result.labels, result.meta


def aggregate(local_models, labels):
    """Steps 3-4 — cluster-wise averaging + per-user model assignment."""
    local_models = np.asarray(local_models, np.float32)
    labels = np.asarray(labels)
    n_clusters = int(labels.max()) + 1
    cluster_avg = np.stack([
        local_models[labels == c].mean(axis=0) for c in range(n_clusters)
    ])
    return cluster_avg, cluster_avg[labels]


def odcl(local_models, cfg: ODCLConfig) -> ODCLResult:
    """Run the full server side of Algorithm 1 on an (m, d) model stack."""
    labels, meta = cluster_models(local_models, cfg)
    cluster_avg, user_models = aggregate(local_models, labels)
    return ODCLResult(
        labels=labels,
        cluster_models=cluster_avg,
        user_models=user_models,
        n_clusters=cluster_avg.shape[0],
        meta=meta,
    )
