"""Algorithm 1: the ODCL-C one-shot protocol.

    1. every user solves its local ERM and uploads theta_hat_i  (1 round)
    2. the server clusters {theta_hat_i} with an admissible algorithm
    3. the server averages models within each recovered cluster
    4. each user receives its cluster's averaged model

``odcl`` operates on an (m, d) stack of model vectors — the exact
paper algorithm (used by the paper-scale experiments and benchmarks).
Step 2 dispatches through the admissible-clustering registry
(``clustering.api``): any registered ``ClusteringAlgorithm`` is usable
here by name; step 3 dispatches through the aggregator registry
(``engine.aggregators``), so the robust variants (``trimmed_mean`` /
``median``) drop in by name too.  The object-style server API
(``methods.ODCL``) wraps this module; the multi-pod deep-learning
integration lives in ``federated.py`` and reuses the same server step
on sketched parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering.admissible import separability_alpha
from repro.core.clustering.api import (
    ClusteringAlgorithm,
    ClusteringResult,
    get_algorithm,
)


@dataclasses.dataclass
class ODCLResult:
    labels: np.ndarray               # (m,) recovered cluster of each user
    cluster_models: np.ndarray       # (K', d) averaged model per cluster
    user_models: np.ndarray          # (m, d) model each user receives
    n_clusters: int
    meta: dict


def run_clustering(key, points,
                   algorithm: Union[str, ClusteringAlgorithm],
                   *, k: Optional[int] = None,
                   assert_separable: bool = False,
                   **options) -> ClusteringResult:
    """Step 2 through the registry, with Definition-1 reporting.

    Resolves ``algorithm`` by name, runs it, and attaches the achieved
    separability margin (condition (4)) and the algorithm's Lemma-1/2
    admissibility margin to ``result.meta``.  With
    ``assert_separable=True`` a clustering whose achieved margin falls
    at or below the admissible alpha raises ``ValueError``.
    """
    algo = get_algorithm(algorithm)
    pts = jnp.asarray(points, jnp.float32)
    result = algo(key, pts, k=k, **options)
    m = int(pts.shape[0])
    counts = np.bincount(result.labels, minlength=result.n_clusters)
    c_min = int(counts[counts > 0].min()) if m else 0
    achieved = separability_alpha(np.asarray(pts), result.labels)
    admissible = float(algo.admissibility_alpha(m, max(c_min, 1)))
    meta = dict(result.meta)
    meta["separability_alpha"] = float(achieved)
    meta["admissible_alpha"] = admissible
    if assert_separable and not achieved > admissible:
        raise ValueError(
            f"clustering by {algo.name!r} is not separable per Definition 1: "
            f"achieved alpha {achieved:.3g} <= admissible {admissible:.3g}")
    return dataclasses.replace(result, meta=meta)


def aggregate(local_models, labels, aggregator="mean"):
    """Steps 3-4 — per-cluster reduction + per-user model assignment.

    ``aggregator`` resolves through the registry
    (``engine.aggregators``); the default ``mean`` reproduces the
    paper's within-cluster average exactly.
    """
    from repro.core.engine.aggregators import cluster_reduce_tree

    local = jnp.asarray(local_models, jnp.float32)
    labels = np.asarray(labels)
    n_clusters = int(labels.max()) + 1
    labels_j = jnp.asarray(labels, jnp.int32)
    onehot = jax.nn.one_hot(labels_j, n_clusters, dtype=jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    cluster_avg = np.asarray(
        cluster_reduce_tree(local, labels_j, onehot, counts, aggregator))
    return cluster_avg, cluster_avg[labels]


def odcl(local_models, *, algorithm: Union[str, ClusteringAlgorithm]
         = "kmeans++", k: Optional[int] = None, seed: int = 0,
         assert_separable: bool = False, aggregator="mean",
         **options) -> ODCLResult:
    """Run the full server side of Algorithm 1 on an (m, d) model stack.

    ``algorithm`` and ``aggregator`` resolve through their registries;
    remaining keyword ``options`` go to the clustering algorithm
    (``iters=``, ``lam=``, ...).
    """
    result = run_clustering(jax.random.PRNGKey(seed), local_models,
                            algorithm, k=k,
                            assert_separable=assert_separable, **options)
    cluster_avg, user_models = aggregate(local_models, result.labels,
                                         aggregator=aggregator)
    return ODCLResult(
        labels=result.labels,
        cluster_models=cluster_avg,
        user_models=user_models,
        n_clusters=cluster_avg.shape[0],
        meta=result.meta,
    )
