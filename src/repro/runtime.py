"""Backend / environment configuration, applied BEFORE the first JAX
import.

JAX reads ``JAX_PLATFORMS`` / ``JAX_ENABLE_X64`` / ``XLA_FLAGS`` once,
at import time — so a serving process that wants a pinned backend or a
deterministic CPU thread count must set them before ``import jax`` runs
anywhere in the process.  This module is import-safe for that purpose:
it imports neither jax nor anything that does (``repro`` is a namespace
package), so drivers can do::

    from repro import runtime
    runtime.apply_env_presets()      # reads REPRO_* overrides
    runtime.pin_cpu_threads(1)       # deterministic CPU-container runs

    import jax                       # only now

Every setter degrades gracefully when jax is already imported: the
platform / x64 toggles fall back to ``jax.config.update`` (which still
works post-import) and the XLA flag setters warn that the flags will
only take effect in a fresh process.

Environment overrides read by :func:`apply_env_presets`:

``REPRO_PLATFORM``     — ``cpu`` | ``gpu`` | ``tpu`` (JAX_PLATFORMS)
``REPRO_X64``          — ``1``/``true`` to enable float64
``REPRO_CPU_THREADS``  — pin host thread pools (OMP/MKL/Eigen) to N
``REPRO_HOST_DEVICES`` — fake N host devices (mesh tests on CPU)
``REPRO_XLA_FLAGS``    — extra raw XLA flags, merged (last wins)
"""
from __future__ import annotations

import os
import sys
import warnings

_TRUTHY = {"1", "true", "yes", "on"}


def jax_imported() -> bool:
    """Whether jax is already in this process (flag changes that only
    apply at import time are too late once this is True)."""
    return "jax" in sys.modules


def _warn_too_late(what: str) -> None:
    warnings.warn(
        f"{what} was requested after jax was imported; it only takes "
        "effect in a fresh process (set it before the first jax import)",
        RuntimeWarning, stacklevel=3)


def merge_xla_flags(*flag_strings: str) -> str:
    """Merge whitespace-separated ``--flag=value`` strings, deduplicating
    by flag name — later strings win, order otherwise preserved."""
    merged: dict = {}
    for s in flag_strings:
        for tok in (s or "").split():
            name = tok.split("=", 1)[0]
            merged.pop(name, None)
            merged[name] = tok
    return " ".join(merged.values())


def add_xla_flags(flags: str) -> str:
    """Merge ``flags`` into ``XLA_FLAGS`` (existing different flags kept,
    same-name flags overridden).  Returns the resulting value."""
    if jax_imported():
        _warn_too_late(f"XLA_FLAGS {flags!r}")
    value = merge_xla_flags(os.environ.get("XLA_FLAGS", ""), flags)
    os.environ["XLA_FLAGS"] = value
    return value


def set_platform(name: str) -> None:
    """Pin the JAX backend (``cpu`` | ``gpu`` | ``tpu``).

    Before the first jax import this sets ``JAX_PLATFORMS``; after it,
    falls back to ``jax.config.update("jax_platforms", ...)``.
    """
    name = str(name).lower()
    if name not in ("cpu", "gpu", "tpu"):
        raise ValueError(f"platform must be cpu|gpu|tpu, got {name!r}")
    os.environ["JAX_PLATFORMS"] = name
    if jax_imported():
        import jax
        jax.config.update("jax_platforms", name)


def enable_x64(on: bool = True) -> None:
    """Toggle 64-bit mode (works before or after the jax import)."""
    os.environ["JAX_ENABLE_X64"] = "1" if on else "0"
    if jax_imported():
        import jax
        jax.config.update("jax_enable_x64", bool(on))


def set_host_device_count(n: int) -> None:
    """Fake ``n`` host devices on the CPU backend (multi-process mesh
    tests without hardware) — import-time only."""
    n = int(n)
    if n < 1:
        raise ValueError("host device count must be >= 1")
    add_xla_flags(f"--xla_force_host_platform_device_count={n}")


def pin_cpu_threads(n: int) -> None:
    """Pin every host-side thread pool to ``n`` threads so CPU-container
    runs (serving benchmarks especially) are deterministic: OMP / MKL /
    OpenBLAS workers plus, at ``n == 1``, XLA:CPU's multi-threaded Eigen
    contractions."""
    n = int(n)
    if n < 1:
        raise ValueError("thread count must be >= 1")
    for var in ("OMP_NUM_THREADS", "OPENBLAS_NUM_THREADS",
                "MKL_NUM_THREADS", "VECLIB_MAXIMUM_THREADS",
                "NUMEXPR_NUM_THREADS"):
        os.environ[var] = str(n)
    if n == 1:
        add_xla_flags("--xla_cpu_multi_thread_eigen=false "
                      "intra_op_parallelism_threads=1")


def apply_env_presets() -> dict:
    """Apply the ``REPRO_*`` environment overrides (see module
    docstring).  Returns the settings that were applied — empty when no
    override is set, so calling this unconditionally is free."""
    applied: dict = {}
    platform = os.environ.get("REPRO_PLATFORM")
    if platform:
        set_platform(platform)
        applied["platform"] = platform.lower()
    x64 = os.environ.get("REPRO_X64")
    if x64 is not None:
        on = x64.strip().lower() in _TRUTHY
        enable_x64(on)
        applied["x64"] = on
    threads = os.environ.get("REPRO_CPU_THREADS")
    if threads:
        pin_cpu_threads(int(threads))
        applied["cpu_threads"] = int(threads)
    devices = os.environ.get("REPRO_HOST_DEVICES")
    if devices:
        set_host_device_count(int(devices))
        applied["host_devices"] = int(devices)
    extra = os.environ.get("REPRO_XLA_FLAGS")
    if extra:
        add_xla_flags(extra)
        applied["xla_flags"] = extra
    return applied
