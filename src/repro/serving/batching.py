"""Cross-caller micro-batching primitives for the route server.

``RouteServer`` owns one ``RequestQueue``; concurrent callers ``put``
``_Request``s into it and a single batcher thread pulls coalesced
batches out with ``next_batch`` — the ONE place the ``max_batch`` /
``max_wait_ms`` micro-batching policy lives.  Everything here is plain
stdlib threading (no jax): the queue never touches device state, so
backpressure and timeout behavior are testable without a session.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Optional

from repro import obs


class ServingError(RuntimeError):
    """Base class of every route-server error."""


class BackpressureError(ServingError):
    """The bounded request queue is full (and stayed full for the
    caller's timeout) — shed load upstream instead of queueing."""


class ServerClosed(ServingError):
    """The server is stopped (or stopping) and takes no new requests."""


class RouteTimeout(ServingError):
    """The request's deadline passed before a flush served it."""


class RouteFuture:
    """Single-use result slot a submitted request resolves into.

    Thread-safe: the batcher (or a background finalize worker) calls
    ``set_result`` / ``set_error`` exactly once; any number of callers
    can ``result(timeout=)``.  ``done_at`` records the monotonic
    completion time, which is what lets an open-loop load generator
    compute latencies without a waiter thread per request.
    """

    __slots__ = ("_event", "_result", "_error", "done_at")

    def __init__(self):
        self._event = threading.Event()
        self._result = None
        self._error: Optional[BaseException] = None
        self.done_at: Optional[float] = None

    def set_result(self, value) -> None:
        self._result = value
        self.done_at = time.monotonic()
        self._event.set()

    def set_error(self, exc: BaseException) -> None:
        self._error = exc
        self.done_at = time.monotonic()
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block for the result; raises the request's error (including
        ``RouteTimeout`` when the batcher expired it) or, if no
        resolution arrives within ``timeout`` seconds, a caller-side
        ``RouteTimeout``."""
        if not self._event.wait(timeout):
            raise RouteTimeout(
                f"no route result within {timeout}s (request still queued "
                "or in flight)")
        if self._error is not None:
            raise self._error
        return self._result


class _Request:
    """One queued route probe: the host-side sketch row plus its future
    and timing (``deadline`` is absolute monotonic time or None)."""

    __slots__ = ("sketch", "future", "enqueued_at", "deadline")

    def __init__(self, sketch, future: RouteFuture, enqueued_at: float,
                 deadline: Optional[float]):
        self.sketch = sketch
        self.future = future
        self.enqueued_at = enqueued_at
        self.deadline = deadline


class RequestQueue:
    """Bounded FIFO between callers and the batcher thread.

    * ``put`` — appends or applies backpressure: a full queue either
      raises ``BackpressureError`` immediately (``block=False``) or
      blocks until space frees / ``timeout`` passes.  Every time a
      caller finds the queue full, the ``serving.backpressure`` counter
      ticks.
    * ``next_batch`` — blocks until at least one request is queued,
      then coalesces up to ``max_batch`` requests, waiting at most
      ``max_wait_s`` past the HEAD request's enqueue time for stragglers
      (so a lone request is never delayed more than the micro-batching
      window).  Returns ``None`` when the queue is stopped and drained.
    * ``stop`` — wakes everyone; with ``drop=True`` the backlog is
      returned to the caller (to fail fast) instead of being flushed.
    """

    def __init__(self, maxsize: int):
        if maxsize < 1:
            raise ValueError("queue maxsize must be >= 1")
        self.maxsize = int(maxsize)
        self._items: collections.deque = collections.deque()
        self._cond = threading.Condition()
        self._stopping = False

    def __len__(self) -> int:
        with self._cond:
            return len(self._items)

    @property
    def stopping(self) -> bool:
        return self._stopping

    def put(self, req: _Request, *, block: bool = True,
            timeout: Optional[float] = None) -> None:
        with self._cond:
            if self._stopping:
                raise ServerClosed("server is shutting down")
            if len(self._items) >= self.maxsize:
                obs.count("serving.backpressure")
                if not block:
                    raise BackpressureError(
                        f"request queue full ({self.maxsize})")
                deadline = (None if timeout is None
                            else time.monotonic() + timeout)
                while len(self._items) >= self.maxsize:
                    if self._stopping:
                        raise ServerClosed("server is shutting down")
                    remaining = (None if deadline is None
                                 else deadline - time.monotonic())
                    if remaining is not None and remaining <= 0:
                        raise BackpressureError(
                            f"request queue full ({self.maxsize}) for "
                            f"{timeout}s")
                    self._cond.wait(remaining)
            self._items.append(req)
            depth = float(len(self._items))
            obs.gauge("serving.queue_depth", depth)
            obs.observe("serving.queue_depth", depth)
            self._cond.notify_all()

    def next_batch(self, max_batch: int,
                   max_wait_s: float) -> Optional[list]:
        with self._cond:
            while not self._items:
                if self._stopping:
                    return None
                self._cond.wait()
            flush_by = self._items[0].enqueued_at + max_wait_s
            batch = [self._items.popleft()]
            while len(batch) < max_batch:
                if self._items:
                    batch.append(self._items.popleft())
                    continue
                if self._stopping:
                    break          # drain fast: flush what we hold
                remaining = flush_by - time.monotonic()
                if remaining <= 0:
                    break
                self._cond.wait(remaining)
                if not self._items and time.monotonic() >= flush_by:
                    break
            self._cond.notify_all()    # space freed: wake blocked putters
            return batch

    def stop(self, *, drop: bool = False) -> list:
        with self._cond:
            self._stopping = True
            dropped: list = []
            if drop:
                dropped = list(self._items)
                self._items.clear()
            self._cond.notify_all()
            return dropped
