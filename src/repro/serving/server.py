"""RouteServer — ``AggregationSession`` behind a thread-safe, batching
serving frontend.

The session (``core/engine/session.py``) is a single-threaded object;
this module is what makes it a *server*: concurrent callers submit
sketch / parameter route requests, a batcher thread coalesces them into
ONE fused batched ``route()`` program per flush, and finalize runs on
an atomically-snapshotted buffer in a background worker while ingest
keeps mutating the live one (double-buffered ingest-while-finalize).

Locking model — three locks, never nested except as noted:

* ``_ingest_lock`` serializes ``ingest`` against ``snapshot``: every
  snapshot lands between wave commits at a definite session clock,
  which is what makes the serialized-replay contract hold (any
  interleaving of ingest/route/finalize serves a round bit-exact with
  the sequential replay "same keyed ingests in clock order, finalize
  right after wave ``snapshot_clock``").
* ``_serve_lock`` serializes the batcher's ``session.route`` call
  against ``install_round`` — the served-round swap and the drift
  accumulators stay consistent; route callers themselves never hold it
  (they only wait on futures).
* ``_finalize_lock`` admits ONE finalize/refinalize at a time (the
  warm-start cache is shared mutable state); ``maybe_refinalize`` uses
  a non-blocking acquire so the drift-triggered path is a no-op while
  a round is already in flight.

Example — serving while uploading::

    from repro.core.engine import AggregationSession
    from repro.serving import RouteServer

    session = AggregationSession(capacity=4096, sketch_dim=64)
    session.ingest(sketches=first_wave)
    session.finalize(algorithm="kmeans-device", k=8)

    with RouteServer(session, max_batch=64, max_wait_ms=2.0) as srv:
        fut = srv.submit(probe_sketch)           # non-blocking
        cid = fut.result(timeout=1.0)            # -> cluster id
        cid2 = srv.route(another_sketch)         # submit + wait
        srv.ingest(sketches=next_wave,           # safe during routing
                   client_ids=ids)
        srv.refinalize(background=True)          # ingest keeps going
"""
from __future__ import annotations

import threading
import time
from typing import Optional

import numpy as np

from repro import obs
from repro.serving.batching import (
    BackpressureError,
    RequestQueue,
    RouteFuture,
    RouteTimeout,
    ServerClosed,
    ServingError,
    _Request,
)

__all__ = [
    "RouteServer",
    "RouteFuture",
    "BackpressureError",
    "RouteTimeout",
    "ServerClosed",
    "ServingError",
]


class RouteServer:
    """Concurrent serving frontend over one ``AggregationSession``.

    Args:
      session: the session to serve (finalized or not — routes fail
        with the session's own ``ValueError`` until a round exists).
      max_batch: largest number of requests fused into one route
        program dispatch.
      max_wait_ms: micro-batching window — how long a flush waits past
        its head request for stragglers.  ``0`` flushes immediately
        (per-arrival batching only under concurrency).
      queue_depth: bound of the request queue; a full queue applies
        backpressure.
      block_on_full: full-queue behavior of ``submit`` — block until
        space (default) or raise ``BackpressureError`` immediately.
    """

    def __init__(self, session, *, max_batch: int = 64,
                 max_wait_ms: float = 2.0, queue_depth: int = 256,
                 block_on_full: bool = True, pad_buckets: bool = True):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        self.session = session
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.block_on_full = bool(block_on_full)
        # the route program AOT-compiles per (batch, dim) signature; a
        # flush of every size 1..max_batch would recompile continuously,
        # so pad flushes up to the next power of two (repeating the last
        # probe; extra labels are discarded) — at most log2(max_batch)+1
        # signatures ever compile
        self.pad_buckets = bool(pad_buckets)
        self._queue = RequestQueue(queue_depth)
        self._ingest_lock = threading.Lock()
        self._serve_lock = threading.Lock()
        self._finalize_lock = threading.Lock()
        self._batcher: Optional[threading.Thread] = None
        self._closed = False

    # ---------------------------------------------------------- lifecycle

    def start(self) -> "RouteServer":
        """Start the batcher thread (idempotent)."""
        if self._closed:
            raise ServerClosed("server already stopped")
        if self._batcher is None:
            self._batcher = threading.Thread(
                target=self._batcher_loop, name="repro-route-batcher",
                daemon=True)
            self._batcher.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        """Stop taking requests and shut the batcher down.

        ``drain=True`` (default) flushes the queued backlog first;
        ``drain=False`` fails queued requests with ``ServerClosed``.
        Waits for any in-flight background finalize to land either way.
        """
        self._closed = True
        dropped = self._queue.stop(drop=not drain)
        for req in dropped:
            req.future.set_error(
                ServerClosed("server stopped before this request ran"))
        if self._batcher is not None:
            self._batcher.join()
            self._batcher = None
        # wait out an in-flight background finalize so stop() leaves no
        # worker mutating the session behind the caller's back
        self._finalize_lock.acquire()
        self._finalize_lock.release()

    def __enter__(self) -> "RouteServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=True)

    # ------------------------------------------------------------- routes

    def submit(self, sketch=None, *, params=None,
               timeout: Optional[float] = None) -> RouteFuture:
        """Enqueue one route request; returns its ``RouteFuture``.

        Pass a ``(sketch_dim,)`` sketch or a single parameter pytree
        (sketched with the session's own projection).  ``timeout``
        bounds BOTH the backpressure wait (when ``block_on_full``) and
        the request's serving deadline — an expired request resolves
        with ``RouteTimeout`` instead of occupying a flush.
        """
        if self._closed:
            raise ServerClosed("server already stopped")
        if (sketch is None) == (params is None):
            raise ValueError("pass exactly one of sketch or params=")
        if params is not None:
            import jax
            wave = jax.tree_util.tree_map(lambda l: l[None], params)
            sketch = self.session.sketch_params(wave)[0]
        sk = np.asarray(sketch, np.float32)
        if sk.shape != (self.session.sketch_dim,):
            raise ValueError(
                f"route sketch must be ({self.session.sketch_dim},), "
                f"got {sk.shape}")
        now = time.monotonic()
        future = RouteFuture()
        req = _Request(sk, future, now,
                       None if timeout is None else now + timeout)
        self._queue.put(req, block=self.block_on_full, timeout=timeout)
        obs.count("serving.requests")
        return future

    def route(self, sketch=None, *, params=None,
              timeout: Optional[float] = None) -> int:
        """Submit one request and wait for its cluster id — what a
        serving caller thread runs in a loop."""
        return self.submit(sketch, params=params,
                           timeout=timeout).result(timeout)

    def route_direct(self, sketch):
        """Per-request baseline: one route program dispatch for this
        caller alone, bypassing the queue/batcher — what the loadgen
        compares cross-caller batching against."""
        with self._serve_lock:
            return self.session.route(sketch)

    # ------------------------------------------------------------- ingest

    def ingest(self, wave=None, *, sketches=None, client_ids=None):
        """Thread-safe ingest; returns ``(rows_or_offset, clock)`` where
        ``clock`` is the session clock right after this wave's commit —
        the replay key of the serialized-equivalence contract."""
        with self._ingest_lock:
            result = self.session.ingest(wave, sketches=sketches,
                                         client_ids=client_ids)
            return result, self.session.clock

    # ----------------------------------------------------------- finalize

    def finalize(self, *, background: bool = False, **kwargs):
        """Snapshot-and-finalize.  Synchronous by default (returns the
        round tuple); with ``background=True`` the compute runs on a
        worker thread while ingest/route continue, and a ``RouteFuture``
        resolving to the round is returned.  Raises ``ServingError`` if
        another finalize is already in flight."""
        return self._start_round(warm=False, kwargs=kwargs,
                                 background=background)

    def refinalize(self, *, background: bool = False):
        """Replay the last finalize configuration warm-started (same
        sync/background split as ``finalize``)."""
        cfg = self.session.finalize_config
        if cfg is None:
            raise ValueError("refinalize() needs a prior finalize()")
        return self._start_round(warm=True, kwargs=cfg,
                                 background=background)

    def maybe_refinalize(self, threshold: float = 1.5, *,
                         background: bool = True):
        """Drift-triggered warm re-finalize; ``None`` when drift is
        below threshold, unmeasured, or a finalize is already running
        (non-blocking — safe to call from a periodic ticker)."""
        d = self.session.drift
        if d is None or d <= threshold:
            return None
        cfg = self.session.finalize_config
        if cfg is None:
            return None
        obs.count("session.refinalize.triggered")
        return self._start_round(warm=True, kwargs=cfg,
                                 background=background, non_blocking=True)

    def _start_round(self, *, warm: bool, kwargs: dict, background: bool,
                     non_blocking: bool = False):
        if not self._finalize_lock.acquire(blocking=not non_blocking):
            return None
        try:
            with self._ingest_lock:
                snap = self.session.snapshot()
        except BaseException:
            self._finalize_lock.release()
            raise
        if not background:
            try:
                return self._run_round(snap, warm, kwargs)
            finally:
                self._finalize_lock.release()
        future = RouteFuture()
        worker = threading.Thread(
            target=self._round_worker, args=(snap, warm, kwargs, future),
            name="repro-finalize-worker", daemon=True)
        worker.start()
        return future

    def _round_worker(self, snap, warm, kwargs, future):
        try:
            future.set_result(self._run_round(snap, warm, kwargs))
        except BaseException as exc:       # noqa: BLE001 — relayed
            future.set_error(exc)
        finally:
            self._finalize_lock.release()

    def _run_round(self, snap, warm, kwargs):
        t0 = time.perf_counter()
        out, served = self.session.compute_round(snap, warm=warm, **kwargs)
        with self._serve_lock:
            self.session.install_round(out, served)
        name = ("serving.refinalize_under_load.ms" if warm
                else "serving.finalize_under_load.ms")
        obs.observe(name, (time.perf_counter() - t0) * 1e3)
        return out

    # ------------------------------------------------------------ batcher

    def _batcher_loop(self) -> None:
        while True:
            batch = self._queue.next_batch(self.max_batch, self.max_wait_s)
            if batch is None:
                return
            now = time.monotonic()
            live = []
            for req in batch:
                if req.deadline is not None and now > req.deadline:
                    obs.count("serving.timeouts")
                    req.future.set_error(RouteTimeout(
                        "request expired before a flush served it "
                        f"({(now - req.enqueued_at) * 1e3:.1f}ms queued)"))
                else:
                    live.append(req)
            if not live:
                continue
            pts = np.stack([r.sketch for r in live])
            n = len(live)
            if self.pad_buckets and n < self.max_batch:
                bucket = 1
                while bucket < n:
                    bucket *= 2
                bucket = min(bucket, self.max_batch)
                if bucket > n:
                    pts = np.concatenate(
                        [pts, np.repeat(pts[-1:], bucket - n, axis=0)])
            try:
                with self._serve_lock:
                    served = self.session.served_round
                    labels = self.session.route(pts)
                    staleness = (None if served is None
                                 else self.session.clock - served.clock)
            except Exception as exc:       # e.g. "route() needs finalize()"
                obs.count("serving.flush_errors")
                for req in live:
                    req.future.set_error(exc)
                continue
            obs.observe("serving.flush_size", float(n))
            if staleness is not None:
                obs.observe("serving.staleness_at_serve", float(staleness))
            labels = np.atleast_1d(np.asarray(labels))
            done = time.monotonic()
            for req, label in zip(live, labels):
                obs.observe("serving.request.ms",
                            (done - req.enqueued_at) * 1e3)
                req.future.set_result(int(label))
