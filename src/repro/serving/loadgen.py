"""Load generators for the route server -> ``BENCH_serving.json``.

Two driving modes against a ``RouteServer`` over a finalized
sketch-only session:

  * closed loop — M caller threads, each routing as fast as its last
    answer returns (fixed concurrency; what the qps criterion uses).
    ``batched=False`` switches the same callers to the per-request
    ``route_direct`` baseline, which is what cross-caller batching has
    to beat.
  * open loop — Poisson arrivals at a target rate, submitted
    asynchronously; latency is measured from the INTENDED arrival time
    (queueing delay included), the honest open-loop convention.

An optional ingest-while-serving mode re-uploads keyed sketch waves
during the run and triggers one background warm refinalize midway, so
``staleness_at_serve`` and ``refinalize_under_load_ms`` measure the
double-buffered ingest-while-finalize path under route traffic.

``BENCH_serving.json`` schema_version 1: one row per (mode, batched,
concurrency) point with qps, route p50/p99 ms, flush-size and
queue-depth percentiles, timeout/backpressure counts, staleness at
serve, and refinalize-under-load latency.

Run as a module (this applies ``repro.runtime`` env presets BEFORE the
first jax import, so ``REPRO_CPU_THREADS=1`` pins the container)::

    PYTHONPATH=src python -m repro.serving.loadgen \
        --clients 4096 --clusters 8 --sketch-dim 64 \
        --callers 4,16 --duration 5 --out BENCH_serving.json
"""
from __future__ import annotations

from repro import runtime

runtime.apply_env_presets()        # must precede the first jax import

import argparse                    # noqa: E402
import json                        # noqa: E402
import threading                   # noqa: E402
import time                        # noqa: E402
from typing import Optional        # noqa: E402

import numpy as np                 # noqa: E402

from repro import obs              # noqa: E402
from repro.core.engine import AggregationSession   # noqa: E402
from repro.serving.batching import (               # noqa: E402
    RouteTimeout,
    ServingError,
)
from repro.serving.server import RouteServer       # noqa: E402

SCHEMA_VERSION = 1


# --------------------------------------------------------------- fixture


def make_population(*, clients: int, clusters: int, sketch_dim: int,
                    seed: int = 0, spread: float = 8.0):
    """A separable Gaussian mixture directly in sketch space: cluster
    centers at ``spread * N(0, I)``, unit-variance rows.  Returns
    ``(rows, assignment, centers)`` as numpy arrays."""
    rng = np.random.default_rng(seed)
    centers = spread * rng.standard_normal((clusters, sketch_dim))
    assignment = rng.integers(0, clusters, size=clients)
    rows = centers[assignment] + rng.standard_normal((clients, sketch_dim))
    return (rows.astype(np.float32), assignment,
            centers.astype(np.float32))


def build_session(*, clients: int, clusters: int, sketch_dim: int,
                  seed: int = 0, wave: int = 1024,
                  capacity: Optional[int] = None):
    """Ingest the mixture in keyed waves and finalize kmeans-device —
    the serving fixture every loadgen mode starts from.  Returns
    ``(session, rows)`` (the rows double as route probes and as the
    re-upload pool for the ingest-while-serving mode)."""
    rows, _, _ = make_population(clients=clients, clusters=clusters,
                                 sketch_dim=sketch_dim, seed=seed)
    session = AggregationSession(capacity or clients,
                                 sketch_dim=sketch_dim, seed=seed)
    for lo in range(0, clients, wave):
        chunk = rows[lo:lo + wave]
        session.ingest(sketches=chunk,
                       client_ids=list(range(lo, lo + len(chunk))))
    session.finalize(algorithm="kmeans-device", k=clusters)
    return session, rows


def warm_route_buckets(session, probe: np.ndarray, max_batch: int) -> None:
    """Pre-compile every padded flush signature (1, 2, 4, ...,
    max_batch) so AOT compiles never land inside a measured run."""
    n = 1
    while True:
        session.route(np.repeat(probe[None], n, axis=0))
        if n >= max_batch:
            break
        n = min(n * 2, max_batch)


# ------------------------------------------------------------ generators


def closed_loop(server: RouteServer, probes: np.ndarray, *, callers: int,
                duration_s: float, batched: bool = True,
                timeout: float = 5.0) -> dict:
    """Fixed-concurrency driving: each of ``callers`` threads routes
    back-to-back until the deadline.  Returns qps + latency stats."""
    start = time.monotonic() + 0.05        # let every thread reach the line
    stop_at = start + duration_s
    results: list = [None] * callers

    def worker(tid: int) -> None:
        lat: list = []
        n_err = n_to = 0
        idx = tid
        while True:
            now = time.monotonic()
            if now >= stop_at:
                break
            if now < start:
                time.sleep(start - now)
                continue
            sk = probes[idx % len(probes)]
            idx += callers
            t0 = time.perf_counter()
            try:
                if batched:
                    server.route(sk, timeout=timeout)
                else:
                    server.route_direct(sk)
            except RouteTimeout:
                n_to += 1
                continue
            except ServingError:
                n_err += 1
                continue
            lat.append((time.perf_counter() - t0) * 1e3)
        results[tid] = (lat, n_err, n_to)

    threads = [threading.Thread(target=worker, args=(tid,), daemon=True)
               for tid in range(callers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(duration_s + timeout + 10.0)
    lats = [v for r in results if r for v in r[0]]
    n_err = sum(r[1] for r in results if r)
    n_to = sum(r[2] for r in results if r)
    return _latency_stats(lats, n_err, n_to, duration_s)


def open_loop(server: RouteServer, probes: np.ndarray, *, rate: float,
              duration_s: float, timeout: float = 5.0) -> dict:
    """Poisson-arrival driving at ``rate`` requests/s; latency is
    completion minus INTENDED arrival, so batching delay and queueing
    both count against the server."""
    rng = np.random.default_rng(1)
    arrivals: list = []
    t = rng.exponential(1.0 / rate)
    while t < duration_s:
        arrivals.append(t)
        t += rng.exponential(1.0 / rate)
    start = time.monotonic()
    pending: list = []
    n_err = 0
    for i, t_arr in enumerate(arrivals):
        target = start + t_arr
        now = time.monotonic()
        if target > now:
            time.sleep(target - now)
        try:
            fut = server.submit(probes[i % len(probes)], timeout=timeout)
        except ServingError:
            n_err += 1         # shed by backpressure / shutdown
            continue
        pending.append((target, fut))
    lats: list = []
    n_to = 0
    settle_by = time.monotonic() + timeout + 1.0
    for target, fut in pending:
        try:
            fut.result(max(0.01, settle_by - time.monotonic()))
            lats.append((fut.done_at - target) * 1e3)
        except RouteTimeout:
            n_to += 1
        except ServingError:
            n_err += 1
    stats = _latency_stats(lats, n_err, n_to, duration_s)
    stats["offered_rate"] = float(rate)
    return stats


def _latency_stats(lats: list, n_err: int, n_to: int,
                   duration_s: float) -> dict:
    arr = np.asarray(lats, np.float64)
    return {
        "n_requests": int(arr.size),
        "n_errors": int(n_err),
        "timeouts": int(n_to),
        "qps": float(arr.size / duration_s),
        "route_p50_ms": float(np.percentile(arr, 50)) if arr.size else None,
        "route_p99_ms": float(np.percentile(arr, 99)) if arr.size else None,
        "duration_s": float(duration_s),
    }


class _IngestLoad:
    """Background keyed re-uploads during a serving run: waves of
    existing client ids get fresh (noised) rows, so capacity stays fixed
    while the live buffer genuinely mutates under the served round."""

    def __init__(self, server: RouteServer, rows: np.ndarray, *,
                 wave: int = 256, period_s: float = 0.2, seed: int = 7):
        self.server, self.rows = server, rows
        self.wave, self.period_s = int(wave), float(period_s)
        self.rng = np.random.default_rng(seed)
        self.waves_done = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self) -> None:
        n = len(self.rows)
        while not self._stop.is_set():
            ids = self.rng.choice(n, size=min(self.wave, n), replace=False)
            noise = 0.1 * self.rng.standard_normal(
                (len(ids), self.rows.shape[1])).astype(np.float32)
            self.server.ingest(sketches=self.rows[ids] + noise,
                               client_ids=[int(i) for i in ids])
            self.waves_done += 1
            self._stop.wait(self.period_s)

    def start(self) -> "_IngestLoad":
        self._thread.start()
        return self

    def stop(self) -> int:
        self._stop.set()
        self._thread.join(30.0)
        return self.waves_done


# ------------------------------------------------------------ bench rows


def run_row(session, probes, *, mode: str, batched: bool,
            callers: Optional[int] = None, rate: Optional[float] = None,
            duration_s: float = 5.0, max_batch: int = 64,
            max_wait_ms: float = 0.5, queue_depth: int = 1024,
            ingest: bool = False, config: Optional[dict] = None) -> dict:
    """One bench point: a fresh ``RouteServer`` over the shared session,
    one load-generator run, obs aggregates folded into the row."""
    obs.reset()
    warm_route_buckets(session, probes[0], max_batch)
    server = RouteServer(session, max_batch=max_batch,
                         max_wait_ms=max_wait_ms, queue_depth=queue_depth)
    server.start()
    load = None
    refinal = None
    timer = None
    try:
        if ingest:
            load = _IngestLoad(server, probes).start()
            # one warm refinalize mid-run, computed on a snapshot while
            # ingest + routing continue
            def _trigger():
                nonlocal refinal
                refinal = server.refinalize(background=True)
            timer = threading.Timer(duration_s / 2, _trigger)
            timer.daemon = True
            timer.start()
        if mode == "closed":
            stats = closed_loop(server, probes, callers=int(callers),
                                duration_s=duration_s, batched=batched)
        elif mode == "open":
            stats = open_loop(server, probes, rate=float(rate),
                              duration_s=duration_s)
        else:
            raise ValueError(f"mode must be closed|open, got {mode!r}")
        if refinal is not None:
            refinal.result(120.0)
    finally:
        if timer is not None:
            timer.cancel()
        waves = load.stop() if load is not None else 0
        server.stop(drain=True)
    snap = obs.snapshot()
    hists = snap["histograms"]
    counters = snap["counters"]

    def _h(name, field):
        h = hists.get(name, {})
        return h.get(field) if h.get("count") else None

    row = {
        "mode": mode,
        "batched": bool(batched),
        "callers": None if callers is None else int(callers),
        "rate": None if rate is None else float(rate),
        "max_batch": int(max_batch),
        "max_wait_ms": float(max_wait_ms),
        "queue_depth": int(queue_depth),
        "ingest_waves": int(waves),
        "backpressure": int(counters.get("serving.backpressure", 0)),
        "flush_size_p50": _h("serving.flush_size", "p50"),
        "flush_size_p95": _h("serving.flush_size", "p95"),
        "flush_size_max": _h("serving.flush_size", "max"),
        "queue_depth_p95": _h("serving.queue_depth", "p95"),
        "staleness_at_serve_p95": _h("serving.staleness_at_serve", "p95"),
        "refinalize_under_load_ms": _h("serving.refinalize_under_load.ms",
                                       "p50"),
        "drops": 0,     # every submitted request resolves: result/timeout
        **stats,
    }
    if config:
        row.update(config)
    return row


def run(*, clients: int = 4096, clusters: int = 8, sketch_dim: int = 64,
        callers=(4, 16), duration_s: float = 5.0, max_batch: int = 64,
        max_wait_ms: float = 0.5, queue_depth: int = 1024,
        open_rate: Optional[float] = None, ingest: bool = True,
        seed: int = 0, out: Optional[str] = None) -> dict:
    """The full sweep: per concurrency point one batched + one
    per-request closed-loop row, plus (optionally) one open-loop row
    and one batched-under-ingest row; emits the schema-1 report with
    the batching-beats-per-request criterion."""
    config = {"clients": int(clients), "clusters": int(clusters),
              "sketch_dim": int(sketch_dim)}
    session, rows = build_session(clients=clients, clusters=clusters,
                                  sketch_dim=sketch_dim, seed=seed)
    bench_rows: list = []
    criterion: dict = {}
    for m in callers:
        direct = run_row(session, rows, mode="closed", batched=False,
                         callers=m, duration_s=duration_s,
                         max_batch=max_batch, max_wait_ms=max_wait_ms,
                         queue_depth=queue_depth, config=config)
        batched = run_row(session, rows, mode="closed", batched=True,
                          callers=m, duration_s=duration_s,
                          max_batch=max_batch, max_wait_ms=max_wait_ms,
                          queue_depth=queue_depth, config=config)
        bench_rows += [direct, batched]
        criterion[f"callers={m}"] = {
            "batched_qps": batched["qps"],
            "direct_qps": direct["qps"],
            "speedup": (batched["qps"] / direct["qps"]
                        if direct["qps"] else None),
            "pass": batched["qps"] > direct["qps"],
        }
        print(f"closed callers={m}: direct {direct['qps']:.0f}/s, "
              f"batched {batched['qps']:.0f}/s "
              f"(p50 {batched['route_p50_ms']:.2f}ms)")
    if ingest:
        under = run_row(session, rows, mode="closed", batched=True,
                        callers=max(callers), duration_s=duration_s,
                        max_batch=max_batch, max_wait_ms=max_wait_ms,
                        queue_depth=queue_depth, ingest=True,
                        config=config)
        bench_rows.append(under)
        ref_ms = under["refinalize_under_load_ms"]
        print(f"under-ingest callers={max(callers)}: "
              f"{under['qps']:.0f}/s, refinalize "
              f"{'n/a' if ref_ms is None else f'{ref_ms:.0f}ms'}, "
              f"{under['ingest_waves']} waves")
    if open_rate:
        op = run_row(session, rows, mode="open", batched=True,
                     rate=open_rate, duration_s=duration_s,
                     max_batch=max_batch, max_wait_ms=max_wait_ms,
                     queue_depth=queue_depth, config=config)
        bench_rows.append(op)
        print(f"open rate={open_rate}/s: served {op['qps']:.0f}/s "
              f"(p99 {op['route_p99_ms']:.2f}ms)")
    report = {
        "bench": "serving",
        "schema_version": SCHEMA_VERSION,
        "config": {**config, "duration_s": float(duration_s),
                   "max_batch": int(max_batch),
                   "max_wait_ms": float(max_wait_ms),
                   "queue_depth": int(queue_depth), "seed": int(seed)},
        "criterion": criterion,
        "rows": bench_rows,
    }
    if out:
        with open(out, "w") as fh:
            json.dump(report, fh, indent=1)
        print(f"wrote {out} ({len(bench_rows)} rows)")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--clients", type=int, default=4096)
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--sketch-dim", type=int, default=64)
    ap.add_argument("--callers", default="4,16",
                    help="comma-separated closed-loop concurrency points")
    ap.add_argument("--duration", type=float, default=5.0)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--max-wait-ms", type=float, default=0.5)
    ap.add_argument("--queue-depth", type=int, default=1024)
    ap.add_argument("--open-rate", type=float, default=None,
                    help="also run one Poisson open-loop row at this rate")
    ap.add_argument("--no-ingest", action="store_true",
                    help="skip the ingest-while-serving row")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--floor-qps", type=float, default=None,
                    help="exit 1 unless the best batched closed-loop row "
                         "reaches this many routes/s (the smoke gate)")
    ap.add_argument("--require-criterion", action="store_true",
                    help="exit 1 unless batched beats per-request at EVERY "
                         "concurrency point (needs enough callers to "
                         "amortize — batching has nothing to coalesce "
                         "below ~4)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    callers = tuple(int(c) for c in str(args.callers).split(",") if c)
    report = run(clients=args.clients, clusters=args.clusters,
                 sketch_dim=args.sketch_dim, callers=callers,
                 duration_s=args.duration, max_batch=args.max_batch,
                 max_wait_ms=args.max_wait_ms,
                 queue_depth=args.queue_depth, open_rate=args.open_rate,
                 ingest=not args.no_ingest, seed=args.seed, out=args.out)
    if not all(c["pass"] for c in report["criterion"].values()):
        print("criterion not met: cross-caller batching did not beat "
              "per-request routing at every concurrency point")
        if args.require_criterion:
            return 1
    if args.floor_qps is not None:
        best = max(r["qps"] for r in report["rows"]
                   if r["mode"] == "closed" and r["batched"])
        if best < args.floor_qps:
            print(f"floor FAILED: best batched qps {best:.0f} < "
                  f"{args.floor_qps}")
            return 1
        print(f"floor OK: best batched qps {best:.0f} >= {args.floor_qps}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
