"""Concurrent serving subsystem: the QPS front-end over
``AggregationSession``.

``RouteServer`` (``serving/server.py``) batches concurrent callers'
route requests into one fused program per flush and runs finalize on
snapshotted buffers while ingest continues; ``serving/loadgen.py`` is
the open/closed-loop load generator producing ``BENCH_serving.json``.
"""
from repro.serving.batching import (
    BackpressureError,
    RequestQueue,
    RouteFuture,
    RouteTimeout,
    ServerClosed,
    ServingError,
)
from repro.serving.server import RouteServer

__all__ = [
    "RouteServer",
    "RouteFuture",
    "RequestQueue",
    "ServingError",
    "BackpressureError",
    "RouteTimeout",
    "ServerClosed",
]
