"""The built-in adversity scenarios: drift, longtail, byzantine, dp.

Each is a frozen dataclass over the ``Scenario`` hook protocol
(``scenarios/api.py``); registration at import time mirrors the
clustering registry.  Role randomness folds fixed tags into the
driver's scenario key so the same client is e.g. an attacker in
``corrupt_uploads``, ``sketch_transform``, and ``honest_mask``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.scenarios.api import Scenario, register_scenario

# role tags folded into the scenario key per hook — constants, so every
# hook that needs the same role (the Byzantine mask) derives the same
# stream regardless of which pipeline stage calls it
_TAG_ROLE = 0x0b1e
_TAG_NOISE = 0x6e01
_TAG_SPOOF = 0x5f00
_TAG_DRIFT = 0xd41f
_TAG_DP = 0xd9a0


def _mask_by_index(key, idx, frac):
    """(|idx|,) bool Bernoulli(frac) mask, deterministic per GLOBAL
    client index (wave-partition invariant: the same client draws the
    same coin whatever wave it arrives in)."""
    return jax.vmap(
        lambda i: jax.random.bernoulli(jax.random.fold_in(key, i), frac)
    )(idx.astype(jnp.int32))


@dataclasses.dataclass(frozen=True)
class DriftScenario(Scenario):
    """Clients migrate source distribution mid-stream.

    Streams arrive in waves (``AggregationSession.ingest``); clients at
    stream position >= ``drift_at * clients`` belong to the drifted
    regime, where a ``drift_frac`` Bernoulli subset draws from its
    cluster shifted by ``shift`` (mod K).  The effective labels ARE the
    truth for those clients — the driver scores purity against the
    drifted labels, so a server that clusters well under drift still
    scores 1.0.
    """
    name: str = "drift"
    drift_frac: float = 0.5
    drift_at: float = 0.5
    shift: int = 1

    def wave_labels(self, key, labels, offset, clients, clusters):
        w = labels.shape[0]
        idx = offset + jnp.arange(w, dtype=jnp.int32)
        migrate = _mask_by_index(jax.random.fold_in(key, _TAG_DRIFT), idx,
                                 self.drift_frac)
        drifted = migrate & (idx >= jnp.int32(self.drift_at * clients))
        return jnp.where(drifted, (labels + self.shift) % clusters, labels)


@dataclasses.dataclass(frozen=True)
class LongtailScenario(Scenario):
    """Zipf cluster occupancy: cluster k holds ~ k^-a of the clients.

    Replaces the balanced round-robin population; largest-remainder
    rounding keeps the occupancy deterministic and every cluster
    nonempty (the admissibility bounds need c_min >= 1).
    """
    name: str = "longtail"
    zipf_a: float = 1.2

    def population(self, key, clients, clusters):
        del key
        if clients < clusters:
            raise ValueError(
                f"longtail occupancy needs clients >= clusters "
                f"({clients} < {clusters})")
        ranks = np.arange(1, clusters + 1, dtype=np.float64)
        p = ranks ** -float(self.zipf_a)
        p /= p.sum()
        counts = np.maximum(np.floor(p * clients).astype(np.int64), 1)
        # largest-remainder: hand leftover slots to the largest shares,
        # trim overshoot from the head (which can spare them)
        rem = clients - int(counts.sum())
        order = np.argsort(-(p * clients - np.floor(p * clients)))
        i = 0
        while rem > 0:
            counts[order[i % clusters]] += 1
            rem -= 1
            i += 1
        while rem < 0:
            j = int(np.argmax(counts))
            take = min(int(counts[j]) - 1, -rem)
            counts[j] -= take
            rem += take
        labels = np.repeat(np.arange(clusters), counts)
        return jnp.asarray(labels, jnp.int32)


@dataclasses.dataclass(frozen=True)
class ByzantineScenario(Scenario):
    """A Bernoulli(``frac``) subset of clients uploads adversarially.

    ``attack='sign_flip'``: attackers upload -theta — the JL sketch is
    linear, so the attack lands in sketch space as the mirrored point
    and drags its cluster's Lloyd center toward the reflection (the
    hardest mean-breaking direction at magnitude ||theta||).
    ``attack='noise'``: theta + scale * N(0, I).
    ``attack='spoof'``: colluding sketch-channel forgery — params are
    untouched but every attacker's sketch row is replaced with one
    shared crafted vector (a fake zero-variance cluster), exercising
    servers that only ever see sketches.
    Attackers are excluded from ``honest_mask``.
    """
    name: str = "byzantine"
    frac: float = 0.1
    attack: str = "sign_flip"          # sign_flip | noise | spoof
    scale: float = 10.0

    def _role(self, key, idx):
        return _mask_by_index(jax.random.fold_in(key, _TAG_ROLE), idx,
                              self.frac)

    def honest_mask(self, key, clients):
        return ~self._role(key, jnp.arange(clients, dtype=jnp.int32))

    def corrupt_uploads(self, key, theta, labels, offset, clients):
        del labels, clients
        w = theta.shape[0]
        idx = offset + jnp.arange(w, dtype=jnp.int32)
        bad = self._role(key, idx)[:, None]
        if self.attack == "sign_flip":
            return jnp.where(bad, -theta, theta)
        if self.attack == "noise":
            noise = self.scale * jax.random.normal(
                jax.random.fold_in(jax.random.fold_in(key, _TAG_NOISE),
                                   offset), theta.shape, theta.dtype)
            return jnp.where(bad, theta + noise, theta)
        if self.attack == "spoof":
            return theta               # spoof forges the sketch channel
        raise ValueError(f"unknown byzantine attack {self.attack!r}")

    def sketch_transform(self, key, sketches, offset):
        if self.attack != "spoof":
            return sketches
        w, s = sketches.shape
        idx = offset + jnp.arange(w, dtype=jnp.int32)
        bad = self._role(key, idx)[:, None]
        forged = self.scale * jax.random.normal(
            jax.random.fold_in(key, _TAG_SPOOF), (s,), sketches.dtype)
        return jnp.where(bad, forged[None, :], sketches)

    @property
    def transforms_sketches(self) -> bool:
        return self.attack == "spoof"


@dataclasses.dataclass(frozen=True)
class DPScenario(Scenario):
    """(epsilon, delta)-DP release of the sketch uploads.

    The sketch is all the server ever sees, so local DP is one Gaussian
    mechanism on the JL rows: L2-clip each client's sketch to ``clip``
    (the sensitivity bound) and add N(0, sigma^2 I) with
    ``sigma = clip * sqrt(2 ln(1.25 / delta)) / epsilon`` — applied
    inside the session's jitted ingest, so the noised rows never exist
    on host either.  Clipping preserves direction; separability then
    degrades purely with 1/epsilon, which is the trade-off curve
    ``bench_robustness.py`` sweeps.
    """
    name: str = "dp"
    epsilon: float = 1.0
    delta: float = 1e-5
    clip: float = 1.0

    def sketch_transform(self, key, sketches, offset):
        norms = jnp.linalg.norm(sketches, axis=1, keepdims=True)
        clipped = sketches * jnp.minimum(
            1.0, self.clip / jnp.maximum(norms, 1e-12))
        sigma = (self.clip * jnp.sqrt(2.0 * jnp.log(1.25 / self.delta))
                 / self.epsilon)
        noise = sigma * jax.random.normal(
            jax.random.fold_in(jax.random.fold_in(key, _TAG_DP), offset),
            sketches.shape, sketches.dtype)
        return clipped + noise


for _s in (DriftScenario(), LongtailScenario(), ByzantineScenario(),
           DPScenario()):
    register_scenario(_s)
del _s
