"""Adversity scenarios over the synthetic client population.

Public surface of the subsystem:

  * ``Scenario`` — the hook protocol (population / wave_labels /
    corrupt_uploads / sketch_transform / honest_mask); the base class is
    the identity scenario ``"none"``.
  * Built-ins: ``drift`` (mid-stream distribution migration),
    ``longtail`` (Zipf occupancy), ``byzantine`` (sign-flip /
    scaled-noise / colluding sketch-spoof attackers), ``dp``
    ((eps, delta)-Gaussian sketch release).
  * Registry: ``register_scenario`` / ``get_scenario`` /
    ``list_scenarios`` / ``unregister_scenario``; ``build_scenario``
    resolves '+'-composed specs from one flat driver-option superset.

Wired through ``data/synthetic.py`` (scenario-shaped flat federations),
``launch/simulate.py`` (``--scenario``/``--byzantine-frac``/
``--dp-epsilon``), ``engine/session.py`` (``sketch_transform=`` inside
the jitted ingest), and ``benchmarks/bench_robustness.py``.
"""
from repro.scenarios.api import (
    ComposedScenario,
    Scenario,
    ScenarioLike,
    build_scenario,
    get_scenario,
    list_scenarios,
    register_scenario,
    unregister_scenario,
)
from repro.scenarios.library import (
    ByzantineScenario,
    DPScenario,
    DriftScenario,
    LongtailScenario,
)

__all__ = [
    "ByzantineScenario",
    "ComposedScenario",
    "DPScenario",
    "DriftScenario",
    "LongtailScenario",
    "Scenario",
    "ScenarioLike",
    "build_scenario",
    "get_scenario",
    "list_scenarios",
    "register_scenario",
    "unregister_scenario",
]
