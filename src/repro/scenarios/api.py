"""The Scenario registry — adversity as composable population transforms.

Every benchmark so far ran planted, balanced, honest clusters; the
paper's guarantees are only interesting when heterogeneity is hostile.
A ``Scenario`` is a bundle of hooks over the synthetic client
population, each bound to one stage of the pipeline:

  ``population(key, clients, clusters)``
      the (C,) true cluster occupancy (host-side, before any data is
      drawn) — ``longtail`` replaces the balanced round-robin with a
      Zipf law here.
  ``wave_labels(key, labels, offset, clients, clusters)``
      per-wave relabeling BEFORE data generation — ``drift`` migrates
      late-stream clients to a shifted source distribution (pairing
      with ``AggregationSession``'s wave ingest: the stream position is
      the wave offset).
  ``corrupt_uploads(key, theta, labels, offset, clients)``
      the step-1 upload attack surface, applied to the (w, d) stack of
      local ERMs after solving — ``byzantine`` sign-flips or noises the
      attackers' models.  Traceable (jnp in, jnp out).
  ``sketch_transform(key, sketches, offset)``
      applied to the (w, sketch_dim) JL sketch rows INSIDE the
      session's jitted ingest — ``dp`` clips + noises here (the sketch
      is all the server ever sees), ``byzantine``'s colluding
      sketch-spoof forges rows here.  Traceable; must not move data to
      host.
  ``honest_mask(key, clients)``
      which clients count toward quality metrics (Byzantine attackers
      are excluded from purity/MSE — they have no honest model to
      recover).

All hooks are deterministic in ``key``: a scenario derives per-role
streams by folding role tags into the one key the driver passes, so an
attacker flagged in ``corrupt_uploads`` is the same client flagged in
``honest_mask``.  The base class is the identity scenario ("none");
implementations override only the hooks they bend.

Registry + composition mirror ``clustering/api.py`` / ``engine/edges.py``:
``register_scenario`` / ``get_scenario`` / ``list_scenarios`` /
``unregister_scenario``, plus ``build_scenario("byzantine+dp",
frac=0.1, epsilon=2.0)`` which resolves a '+'-chain into a
``ComposedScenario`` and specializes each member's dataclass fields
from one flat option superset (unknown keys skip, like
``build_federated_method``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp


@runtime_checkable
class ScenarioLike(Protocol):
    """Anything with the five population hooks (see module docstring)."""
    name: str

    def population(self, key, clients: int, clusters: int): ...
    def wave_labels(self, key, labels, offset, clients: int,
                    clusters: int): ...
    def corrupt_uploads(self, key, theta, labels, offset, clients: int): ...
    def sketch_transform(self, key, sketches, offset): ...
    def honest_mask(self, key, clients: int): ...


@dataclasses.dataclass(frozen=True)
class Scenario:
    """The identity client population — every hook is a passthrough.

    Subclass and override the hooks the scenario bends; frozen
    dataclasses keep instances hashable (scenario options can ride in
    jit cache keys next to the aggregator).
    """
    name: str = "none"

    def population(self, key, clients: int, clusters: int) -> jnp.ndarray:
        """(C,) int32 true cluster per client (balanced round-robin)."""
        del key
        return jnp.arange(clients, dtype=jnp.int32) % clusters

    def wave_labels(self, key, labels, offset, clients: int,
                    clusters: int) -> jnp.ndarray:
        del key, offset, clients, clusters
        return labels

    def corrupt_uploads(self, key, theta, labels, offset,
                        clients: int) -> jnp.ndarray:
        del key, labels, offset, clients
        return theta

    def sketch_transform(self, key, sketches, offset) -> jnp.ndarray:
        del key, offset
        return sketches

    def honest_mask(self, key, clients: int) -> jnp.ndarray:
        del key
        return jnp.ones((clients,), bool)

    @property
    def transforms_sketches(self) -> bool:
        """Whether the session needs this scenario's sketch hook wired
        into its jitted ingest (identity hooks skip the closure)."""
        return type(self).sketch_transform is not Scenario.sketch_transform


@dataclasses.dataclass(frozen=True)
class ComposedScenario(Scenario):
    """Hooks applied left-to-right over member scenarios.

    ``population`` takes the LAST member that overrides it (occupancy
    is a choice, not a transform); every other hook chains.
    """
    name: str = "composed"
    members: tuple = ()

    def population(self, key, clients, clusters):
        labels = Scenario.population(self, key, clients, clusters)
        for i, s in enumerate(self.members):
            if type(s).population is not Scenario.population:
                labels = s.population(jax.random.fold_in(key, i),
                                      clients, clusters)
        return labels

    def wave_labels(self, key, labels, offset, clients, clusters):
        for i, s in enumerate(self.members):
            labels = s.wave_labels(jax.random.fold_in(key, i), labels,
                                   offset, clients, clusters)
        return labels

    def corrupt_uploads(self, key, theta, labels, offset, clients):
        for i, s in enumerate(self.members):
            theta = s.corrupt_uploads(jax.random.fold_in(key, i), theta,
                                      labels, offset, clients)
        return theta

    def sketch_transform(self, key, sketches, offset):
        for i, s in enumerate(self.members):
            sketches = s.sketch_transform(jax.random.fold_in(key, i),
                                          sketches, offset)
        return sketches

    def honest_mask(self, key, clients):
        mask = jnp.ones((clients,), bool)
        for i, s in enumerate(self.members):
            mask &= s.honest_mask(jax.random.fold_in(key, i), clients)
        return mask

    @property
    def transforms_sketches(self) -> bool:
        return any(s.transforms_sketches for s in self.members)


# ------------------------------------------------------------- registry

_SCENARIOS: dict[str, Scenario] = {}


def register_scenario(scenario: Scenario, *, name: Optional[str] = None,
                      overwrite: bool = False) -> Scenario:
    """Register a scenario under a name. Returns it (decorator-safe)."""
    key = name if name is not None else scenario.name
    if not key:
        raise ValueError("scenario needs a non-empty name")
    if key in _SCENARIOS and not overwrite:
        raise ValueError(f"scenario {key!r} already registered "
                         "(pass overwrite=True to replace)")
    _SCENARIOS[key] = scenario
    return scenario


def unregister_scenario(name: str) -> None:
    """Remove a registered scenario (used by tests/plugins)."""
    _SCENARIOS.pop(name, None)


def get_scenario(name) -> Scenario:
    """Resolve a name (or pass through an instance) to a scenario."""
    if not isinstance(name, str):
        return name
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {sorted(_SCENARIOS)}") from None


def list_scenarios() -> tuple[str, ...]:
    """Names of every registered scenario."""
    return tuple(sorted(_SCENARIOS))


def build_scenario(spec, **options: Any) -> Scenario:
    """Resolve a scenario spec from driver flags.

    ``spec`` is a registered name, a '+'-chain of names (composed
    left-to-right, e.g. ``"longtail+byzantine"``), a ``Scenario``
    instance, or ``None`` (the identity).  ``options`` is one flat
    superset; each member keeps only the dataclass fields it declares.
    """
    if spec is None:
        spec = "none"
    if not isinstance(spec, str):
        return spec
    members = []
    for part in spec.split("+"):
        part = part.strip()
        if not part:
            continue
        s = get_scenario(part)
        if options and dataclasses.is_dataclass(s):
            fields = {f.name for f in dataclasses.fields(s) if f.init}
            kept = {k: v for k, v in options.items()
                    if k in fields and k != "name" and v is not None}
            if kept:
                s = dataclasses.replace(s, **kept)
        members.append(s)
    if not members:
        raise ValueError(f"empty scenario spec {spec!r}")
    if len(members) == 1:
        return members[0]
    return ComposedScenario(name=spec, members=tuple(members))


register_scenario(Scenario())
