from repro.models.transformer import (
    DecodeCache,
    abstract_params,
    decode_step,
    forward,
    init_decode_cache,
    init_params,
    train_loss,
)

__all__ = [
    "DecodeCache",
    "abstract_params",
    "decode_step",
    "forward",
    "init_decode_cache",
    "init_params",
    "train_loss",
]
