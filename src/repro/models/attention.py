"""Attention: GQA projections, chunked (flash-style) kernel, KV caches.

Training/prefill uses a q-chunk x kv-chunk online-softmax scan — the
pure-jnp analogue of the Pallas ``flash_attention`` kernel (which takes
over on real TPUs; see ``repro.kernels.ops``) — so the (S, S) score
matrix never materializes for 32k+ sequences.

Decode uses a ring-buffer KV cache: for sliding-window configs the
cache holds only ``window`` positions, giving O(window) per-token cost
(the sub-quadratic path required by ``long_500k``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import rope

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jnp.ndarray        # (b, hkv, C, dh) ring buffer
    v: jnp.ndarray        # (b, hkv, C, dh)
    pos: jnp.ndarray      # () int32 — absolute position of next token


def rope_transpose(x, positions, theta):
    """Apply RoPE to (b, h, s, dh) given positions (b, s)."""
    return rope(x.transpose(0, 2, 1, 3), positions, theta).transpose(0, 2, 1, 3)


def qkv_proj(params, x, cfg):
    """x (b,s,D) -> q (b,h,s,dh), k/v (b,hkv,s,dh)."""
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, cfg.n_heads, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, dh).transpose(0, 2, 1, 3)
    return q, k, v


def out_proj(params, attn_out):
    """(b,h,s,dh) -> (b,s,D)."""
    b, h, s, dh = attn_out.shape
    return attn_out.transpose(0, 2, 1, 3).reshape(b, s, h * dh) @ params["wo"]


def _direct_attention(q, k, v, *, causal, window, q_offset):
    """Small-sequence einsum path. q (b,h,sq,dh), k/v (b,h,skv,dh)."""
    dh = q.shape[-1]
    scale = dh ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    sq, skv = q.shape[2], k.shape[2]
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def _chunked_attention(q, k, v, *, causal, window, chunk_q, chunk_kv):
    """Online-softmax scan over (q-chunk, kv-chunk) tiles."""
    b, h, s, dh = q.shape
    scale = dh ** -0.5
    nq, nkv = s // chunk_q, s // chunk_kv
    qc = q.reshape(b, h, nq, chunk_q, dh).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(b, h, nkv, chunk_kv, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, h, nkv, chunk_kv, dh).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_q):
        qi, qblk = qi_q
        qblk = qblk.astype(jnp.float32)

        def kv_step(carry, kj_kv):
            o, m, l = carry
            kj, kblk, vblk = kj_kv
            sc = jnp.einsum("bhqd,bhkd->bhqk", qblk,
                            kblk.astype(jnp.float32)) * scale
            qpos = qi * chunk_q + jnp.arange(chunk_q)[:, None]
            kpos = kj * chunk_kv + jnp.arange(chunk_kv)[None, :]
            mask = jnp.ones((chunk_q, chunk_kv), bool)
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            sc = jnp.where(mask[None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            o = o * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, vblk.astype(jnp.float32))
            return (o, m_new, l), None

        o0 = jnp.zeros((b, h, chunk_q, dh), jnp.float32)
        m0 = jnp.full((b, h, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, chunk_q), jnp.float32)
        (o, m, l), _ = jax.lax.scan(
            kv_step, (o0, m0, l0), (jnp.arange(nkv), kc, vc))
        return None, (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qc))
    return outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, dh)


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              q_offset=0, chunk: int = 1024):
    """GQA attention dispatcher. q (b,h,sq,dh), k/v (b,hkv,skv,dh).

    chunk=0 forces the direct einsum path (used by the roofline
    cost-calibration lowerings, which must avoid inner while loops).
    """
    hkv, h = k.shape[1], q.shape[1]
    if h != hkv:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    sq, skv = q.shape[2], k.shape[2]
    if chunk > 0 and sq == skv and sq > 2 * chunk and sq % chunk == 0:
        return _chunked_attention(q, k, v, causal=causal, window=window,
                                  chunk_q=chunk, chunk_kv=chunk)
    return _direct_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)


# ----------------------------------------------------------------- caches

def init_kv_cache(batch: int, n_kv_heads: int, capacity: int, head_dim: int,
                  dtype=jnp.bfloat16, pos: int | jnp.ndarray = 0) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, n_kv_heads, capacity, head_dim), dtype),
        v=jnp.zeros((batch, n_kv_heads, capacity, head_dim), dtype),
        pos=jnp.asarray(pos, jnp.int32),
    )


def decode_attention(params, x, cache: KVCache, cfg, *, rope_theta=None):
    """Single-token decode with a ring-buffer cache.

    x: (b, 1, D). Returns (out (b,1,D), new_cache). The ring buffer keeps
    ``capacity`` most-recent positions; for sliding-window archs capacity
    = window, giving O(window) decode for 500k contexts.
    """
    b = x.shape[0]
    dh = cfg.resolved_head_dim
    capacity = cache.k.shape[2]
    q, k, v = qkv_proj(params, x, cfg)                 # q (b,h,1,dh)
    pos = cache.pos
    theta = rope_theta if rope_theta is not None else cfg.rope_theta
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = rope(q.transpose(0, 2, 1, 3), posv, theta).transpose(0, 2, 1, 3)
    k = rope(k.transpose(0, 2, 1, 3), posv, theta).transpose(0, 2, 1, 3)
    slot = jnp.mod(pos, capacity)
    new_k = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                         (0, 0, slot, 0))
    new_v = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                         (0, 0, slot, 0))
    # attend over valid slots only
    kpos_abs = _ring_positions(pos, capacity)
    valid = (kpos_abs <= pos) & (kpos_abs >= 0)
    if cfg.serve_window is not None:
        valid &= kpos_abs > pos - cfg.serve_window
    hkv = cfg.n_kv_heads
    kk, vv = new_k, new_v
    if cfg.n_heads != hkv:
        rep = cfg.n_heads // hkv
        kk = jnp.repeat(kk, rep, axis=1)
        vv = jnp.repeat(vv, rep, axis=1)
    sc = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                    kk.astype(jnp.float32)) * dh ** -0.5
    sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(x.dtype)
    return out_proj(params, out), KVCache(k=new_k, v=new_v, pos=pos + 1)


def _ring_positions(pos, capacity):
    """Absolute position stored in each ring slot after writing ``pos``."""
    slots = jnp.arange(capacity)
    cur = jnp.mod(pos, capacity)
    # slots <= cur hold positions pos - (cur - slot); slots > cur hold
    # positions from the previous wrap: pos - capacity + (slot - cur)
    return jnp.where(slots <= cur,
                     pos - (cur - slots),
                     pos - capacity + (slots - cur))
