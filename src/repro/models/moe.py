"""Mixture-of-Experts layer: top-k router + sort-based per-sequence dispatch.

TPU mapping (DESIGN.md §3): tokens are routed *within each sequence*
(batch row).  All dispatch steps (argsort by expert id, positioning,
capacity clipping, scatter/gather) are then batched over the leading
batch axis, which is sharded over the data mesh axes — so the dispatch
never communicates across devices and compiled FLOPs match the
activated-parameter math (the dense (T,E,C) one-hot dispatch einsum
alternative would dwarf the experts' own FLOPs).

Expert weights: expert axis sharded over ``model`` when divisible
(DeepSeek/Moonshot: 64 experts / 16-way TP = 4 per device), otherwise
per-expert hidden dim sharded (Grok: 8 experts, F=32768/16).  Capacity
limits apply per sequence (capacity_factor over s*k/E tokens).

Supports DeepSeekMoE-style shared experts (always-on dense path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import mlp_forward
from repro.sharding.activations import constrain


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def moe_forward(params, x, cfg):
    """x (b, s, D) -> (y (b, s, D), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (b, s, e)
    topv, topi = jax.lax.top_k(probs, k)                        # (b, s, k)
    topv = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch-style, top-1 counts) ----
    me = jnp.mean(probs, axis=(0, 1))                           # (e,)
    rows = jnp.arange(b)[:, None]
    ce_cnt = jnp.zeros((e,), jnp.float32).at[topi[..., 0].reshape(-1)].add(1.0)
    ce = ce_cnt / (b * s)
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)

    # ---- per-sequence sort-based dispatch (GATHER-only: scatters would
    # materialize (b, e*cap, d)-sized u32 index tensors and defeat SPMD
    # batch partitioning) ----
    cap = _round_up(max(1, int(s * k / e * cfg.capacity_factor)), 8)
    sk = s * k
    flat_eid = topi.reshape(b, sk)                              # (b, sk)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s), k)[None], (b, sk))            # (b, sk)
    order = jnp.argsort(flat_eid, axis=1)
    s_eid = jnp.take_along_axis(flat_eid, order, axis=1)
    s_tok = jnp.take_along_axis(flat_tok, order, axis=1)
    counts = jnp.sum(
        (flat_eid[:, :, None] == jnp.arange(e)[None, None]), axis=1,
        dtype=jnp.int32)                                        # (b, e)
    starts = jnp.concatenate(
        [jnp.zeros((b, 1), jnp.int32), jnp.cumsum(counts, axis=1)[:, :-1]],
        axis=1)                                                 # (b, e)

    # expert_in[b, ec] = x[b, s_tok[starts[e] + c]]  masked by c < counts[e]
    slot = jnp.arange(cap)[None, None]                          # (1, 1, cap)
    src_sorted = starts[..., None] + slot                       # (b, e, cap)
    valid = slot < counts[..., None]                            # (b, e, cap)
    src_sorted = jnp.clip(src_sorted, 0, sk - 1).reshape(b, e * cap)
    tok_idx = jnp.take_along_axis(s_tok, src_sorted, axis=1)    # (b, e*cap)
    expert_in = jnp.take_along_axis(x, tok_idx[..., None], axis=1)
    expert_in = expert_in * valid.reshape(b, e * cap)[..., None].astype(x.dtype)
    expert_in = expert_in.reshape(b, e, cap, d)
    expert_in = constrain(expert_in, "batch", "experts", None, None)

    # ---- expert FFN (batched over experts) ----
    h = jnp.einsum("becd,edf->becf", expert_in, params["w_in"])
    h = constrain(h, "batch", "experts", None, "model")
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    expert_out = jnp.einsum("becf,efd->becd", h, params["w_out"])
    expert_out = constrain(expert_out, "batch", "experts", None, None)
    flat_out = expert_out.reshape(b, e * cap, d)

    # ---- combine: each token gathers its k expert outputs ----
    inv_order = jnp.argsort(order, axis=1)                      # (b, sk)
    pos_sorted = jnp.arange(sk)[None] - jnp.take_along_axis(starts, s_eid, axis=1)
    kept_sorted = pos_sorted < cap
    dest_sorted = jnp.clip(s_eid * cap + pos_sorted, 0, e * cap - 1)
    dest_flat = jnp.take_along_axis(dest_sorted, inv_order, axis=1)   # (b, sk)
    kept_flat = jnp.take_along_axis(kept_sorted, inv_order, axis=1)
    back = jnp.take_along_axis(flat_out, dest_flat[..., None], axis=1)
    back = back * kept_flat[..., None].astype(back.dtype)       # (b, sk, d)
    w = topv.reshape(b, sk)[..., None].astype(back.dtype)
    y = jnp.sum((back * w).reshape(b, s, k, d), axis=2)

    # ---- shared experts (always-on dense path) ----
    if cfg.n_shared_experts > 0:
        y = y + mlp_forward(params["shared"], x, "swiglu")

    return y, aux


def init_moe(key, cfg, dtype) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    params = {
        "router": (jax.random.normal(k1, (d, e), jnp.float32) * d ** -0.5
                   ).astype(jnp.float32),
        "w_in": (jax.random.normal(k2, (e, d, 2 * f), jnp.float32) * d ** -0.5
                 ).astype(dtype),
        "w_out": (jax.random.normal(k3, (e, f, d), jnp.float32) * f ** -0.5
                  ).astype(dtype),
    }
    if cfg.n_shared_experts > 0:
        fs = cfg.n_shared_experts * f
        params["shared"] = {
            "w_in": (jax.random.normal(k4, (d, 2 * fs), jnp.float32) * d ** -0.5
                     ).astype(dtype),
            "w_out": (jax.random.normal(k5, (fs, d), jnp.float32) * fs ** -0.5
                      ).astype(dtype),
        }
    return params
