"""Composable model assembly for all assigned architecture families.

One parameter pytree schema, one forward, one decode — block types
(attention / MoE / xLSTM / hybrid attn+SSM) selected by ``ModelConfig``.
Layer weights are stacked on a leading ``L`` axis and consumed with
``jax.lax.scan`` so HLO size is O(1) in depth (essential for the 64-layer
grok dry-run).

Input modes:
  * tokens      — ordinary decoder (or encoder) LM over token ids
  * embeddings  — audio carve-out: precomputed frame embeddings (stub
                  frontend) + masked-frame prediction head
  * multimodal  — VLM carve-out: token ids + precomputed patch embeddings
                  scattered at given positions
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import recurrent as rec
from repro.models.layers import (
    cross_entropy_loss,
    embed_init,
    init_mlp,
    mlp_forward,
    rms_norm,
    _dense_init,
)
from repro.sharding.activations import constrain

FRONTEND_DIM = 512     # stub audio frame-embedding dim
PATCH_DIM = 1024       # stub vision patch-embedding dim


class DecodeCache(NamedTuple):
    """Per-layer state stacked on a leading L axis + global position."""
    layers: Any
    pos: jnp.ndarray     # () int32


# ============================================================== init

def _dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def init_layer_params(key, cfg: ModelConfig) -> dict:
    """Parameters of ONE layer (un-stacked)."""
    dtype = _dtype_of(cfg)
    d, dh = cfg.d_model, cfg.resolved_head_dim
    keys = iter(jax.random.split(key, 24))
    p: dict = {}
    if cfg.block_pattern == "xlstm":
        inner = 2 * d
        h = cfg.n_heads
        p["m"] = {
            "ln": jnp.zeros((d,), dtype),
            "w_up": _dense_init(next(keys), (d, 2 * inner), dtype),
            "w_q": _dense_init(next(keys), (inner, inner), dtype),
            "w_k": _dense_init(next(keys), (inner, inner), dtype),
            "w_v": _dense_init(next(keys), (inner, inner), dtype),
            "w_if": _dense_init(next(keys), (inner, 2 * h), dtype),
            "b_if": jnp.concatenate([jnp.zeros((h,), dtype),
                                     jnp.full((h,), 2.0, dtype)]),
            "w_down": _dense_init(next(keys), (inner, d), dtype),
        }
        p["s"] = {
            "ln": jnp.zeros((d,), dtype),
            "w_zifo": _dense_init(next(keys), (d, 4 * d), dtype),
            "b_zifo": jnp.zeros((4 * d,), dtype),
            "w_out": _dense_init(next(keys), (d, d), dtype),
        }
        return p

    # --- attention (shared by dense/moe/hybrid) ---
    p["ln1"] = jnp.zeros((d,), dtype)
    p["attn"] = {
        "wq": _dense_init(next(keys), (d, cfg.n_heads * dh), dtype),
        "wk": _dense_init(next(keys), (d, cfg.n_kv_heads * dh), dtype),
        "wv": _dense_init(next(keys), (d, cfg.n_kv_heads * dh), dtype),
        "wo": _dense_init(next(keys), (cfg.n_heads * dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["attn"]["bq"] = jnp.zeros((cfg.n_heads * dh,), dtype)
        p["attn"]["bk"] = jnp.zeros((cfg.n_kv_heads * dh,), dtype)
        p["attn"]["bv"] = jnp.zeros((cfg.n_kv_heads * dh,), dtype)

    if cfg.block_pattern == "hybrid":
        di, n, r = d, cfg.ssm_state, max(16, d // 64)
        p["ssm"] = {
            "w_in": _dense_init(next(keys), (d, 2 * di), dtype),
            "conv_w": _dense_init(next(keys), (cfg.conv_width, di), dtype, scale=0.5),
            "w_xdb": _dense_init(next(keys), (di, r + 2 * n), dtype),
            "w_dt": _dense_init(next(keys), (r, di), dtype),
            "b_dt": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
            "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (di, 1))),
            "d_skip": jnp.ones((di,), jnp.float32),
            "w_out": _dense_init(next(keys), (di, d), dtype),
        }
        p["beta_attn"] = jnp.zeros((d,), dtype)
        p["beta_ssm"] = jnp.zeros((d,), dtype)

    p["ln2"] = jnp.zeros((d,), dtype)
    if cfg.is_moe:
        p["moe"] = moe_lib.init_moe(next(keys), cfg, dtype)
    if cfg.d_ff > 0 and not cfg.is_moe:
        p["mlp"] = init_mlp(next(keys), d, cfg.d_ff, cfg.mlp_variant, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = _dtype_of(cfg)
    k_embed, k_layers, k_head, k_extra = jax.random.split(key, 4)
    n_stack = cfg.n_layers // 2 if cfg.block_pattern == "xlstm" else cfg.n_layers
    layer_keys = jax.random.split(k_layers, n_stack)
    layers = jax.vmap(lambda k: init_layer_params(k, cfg))(layer_keys)
    params = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype)
    if cfg.input_mode == "embeddings":
        params["frontend_proj"] = _dense_init(k_extra, (FRONTEND_DIM, cfg.d_model), dtype)
        params["mask_embed"] = jnp.zeros((cfg.d_model,), dtype)
    elif cfg.input_mode == "multimodal":
        params["patch_proj"] = _dense_init(k_extra, (PATCH_DIM, cfg.d_model), dtype)
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


# ============================================================ block fwd

def _rope_qk(q, k, positions, theta):
    """Apply RoPE; q/k (b, h, s, dh); positions (b, s)."""
    q = attn_lib.rope_transpose(q, positions, theta)
    k = attn_lib.rope_transpose(k, positions, theta)
    return q, k


def _attn_block(lp, x, cfg: ModelConfig, positions):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = attn_lib.qkv_proj(lp["attn"], h, cfg)
    q = constrain(q, "batch", "heads", None, None)
    k = constrain(k, "batch", "heads", None, None)
    v = constrain(v, "batch", "heads", None, None)
    q, k = _rope_qk(q, k, positions, cfg.rope_theta)
    o = attn_lib.attention(q, k, v, causal=cfg.causal, window=cfg.window,
                           chunk=cfg.attn_chunk)
    o = constrain(o, "batch", "heads", None, None)
    out = constrain(attn_lib.out_proj(lp["attn"], o), "batch", None, None)
    return out, (k, v)


def _ssm_branch(lp, h, cfg: ModelConfig):
    """Returns (y, (final ssm_h, trailing conv state))."""
    sp = lp["ssm"]
    di, n = cfg.d_model, cfg.ssm_state
    r = sp["w_dt"].shape[0]
    xz = h @ sp["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = rec.causal_conv1d(xs, sp["conv_w"])
    xs = jax.nn.silu(xs)
    xdb = xs @ sp["w_xdb"]
    dt_r, bmat, cmat = jnp.split(xdb, [r, r + n], axis=-1)
    dt = dt_r @ sp["w_dt"] + sp["b_dt"]
    y, final_h = rec.ssm_scan(xs, dt, bmat, cmat, sp["a_log"], sp["d_skip"],
                              chunk=cfg.ssm_chunk)
    return (y * jax.nn.silu(z)) @ sp["w_out"], (final_h, conv_state)


def _layer_forward(lp, x, cfg: ModelConfig, positions, collect_cache=False):
    """One (stacked-scan) layer. Returns (x, (aux_loss, cache_parts))."""
    # residual stream sharded (batch over data, d_model over model): the
    # scan-saved per-layer residual stack is the dominant training buffer
    x = constrain(x, "batch", None, "model")
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if cfg.block_pattern == "xlstm":
        m_out, mstate = _mlstm_block(lp["m"], x, cfg)
        x = x + m_out
        s_out, sstate = _slstm_block(lp["s"], x, cfg)
        x = x + s_out
        if collect_cache:
            cache = {"m_c": mstate.c, "m_n": mstate.n,
                     "s_c": sstate.c, "s_n": sstate.n}
        return x, (aux, cache)
    if cfg.block_pattern == "hybrid":
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn_lib.qkv_proj(lp["attn"], h, cfg)
        q = constrain(q, "batch", "heads", None, None)
        k = constrain(k, "batch", "heads", None, None)
        v = constrain(v, "batch", "heads", None, None)
        q, k = _rope_qk(q, k, positions, cfg.rope_theta)
        o = attn_lib.attention(q, k, v, causal=cfg.causal, window=cfg.window,
                               chunk=cfg.attn_chunk)
        a_out = attn_lib.out_proj(lp["attn"], o)
        s_out, (ssm_h, conv_state) = _ssm_branch(lp, h, cfg)
        if collect_cache:
            cache = {"k": k, "v": v, "ssm_h": ssm_h, "conv": conv_state}
        x = x + 0.5 * (rms_norm(a_out, lp["beta_attn"], cfg.norm_eps)
                       + rms_norm(s_out, lp["beta_ssm"], cfg.norm_eps))
    else:
        a_out, (k, v) = _attn_block(lp, x, cfg, positions)
        if collect_cache:
            cache = {"k": k, "v": v}
        x = x + a_out
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_lib.moe_forward(lp["moe"], h2, cfg)
        x = x + y
    elif cfg.d_ff > 0:
        x = x + mlp_forward(lp["mlp"], h2, cfg.mlp_variant)
    return x, (aux, cache)


def _mlstm_block(mp, x, cfg: ModelConfig):
    b, s, d = x.shape
    h_heads = cfg.n_heads
    inner = mp["w_down"].shape[0]
    dh = inner // h_heads
    hx = rms_norm(x, mp["ln"], cfg.norm_eps)
    up = hx @ mp["w_up"]
    xm, gate = jnp.split(up, 2, axis=-1)
    q = (xm @ mp["w_q"]).reshape(b, s, h_heads, dh).transpose(0, 2, 1, 3)
    k = (xm @ mp["w_k"]).reshape(b, s, h_heads, dh).transpose(0, 2, 1, 3)
    v = (xm @ mp["w_v"]).reshape(b, s, h_heads, dh).transpose(0, 2, 1, 3)
    gates = xm @ mp["w_if"] + mp["b_if"]
    i_g = gates[..., :h_heads].transpose(0, 2, 1)
    f_g = gates[..., h_heads:].transpose(0, 2, 1)
    mchunk = s if cfg.mlstm_chunk <= 0 else min(cfg.mlstm_chunk, s)
    out, mstate = rec.mlstm_chunkwise(q, k, v, i_g, f_g, chunk=mchunk)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, inner).astype(x.dtype)
    return (out * jax.nn.silu(gate)) @ mp["w_down"], mstate


def _slstm_block(sp, x, cfg: ModelConfig):
    d = x.shape[-1]
    hx = rms_norm(x, sp["ln"], cfg.norm_eps)
    zifo = hx @ sp["w_zifo"] + sp["b_zifo"]
    z, i, f, o = jnp.split(zifo, 4, axis=-1)
    h, sstate = rec.slstm_scan(z, i, f, o)
    return h.astype(x.dtype) @ sp["w_out"], sstate


# ============================================================== forward

def embed_inputs(params, cfg: ModelConfig, batch: dict):
    """Produce the (b, s, D) input sequence for any input mode."""
    if cfg.input_mode == "tokens":
        return params["embed"][batch["tokens"]]
    if cfg.input_mode == "embeddings":
        x = batch["frames"] @ params["frontend_proj"]
        if "mask" in batch:
            x = jnp.where(batch["mask"][..., None], params["mask_embed"], x)
        return x
    if cfg.input_mode == "multimodal":
        x = params["embed"][batch["tokens"]]
        patches = batch["patch_embeds"] @ params["patch_proj"]
        b = x.shape[0]
        x = x.at[jnp.arange(b)[:, None], batch["patch_positions"]].set(
            patches.astype(x.dtype))
        return x
    raise ValueError(cfg.input_mode)


REMAT_POLICIES = {
    "none": None,
    "full": "full",          # save nothing, recompute the whole layer
    "dots": "dots",          # save matmul outputs (skip recompute of dots)
}


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(f"unknown remat policy {remat!r}")


def forward(params, cfg: ModelConfig, batch: dict, *, remat: str = "none",
            unroll: bool = False):
    """Full-sequence forward. Returns (logits (b,s,V), aux_loss)."""
    x = embed_inputs(params, cfg, batch)
    x = constrain(x, "batch", None, "model")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    body = _maybe_remat(
        lambda carry, lp: _layer_forward(lp, carry, cfg, positions), remat)

    def layer_fn(carry, lp):
        y, (aux, _) = body(carry, lp)
        return y, aux

    n_stack = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
    x, auxs = jax.lax.scan(layer_fn, x, params["layers"],
                           unroll=n_stack if unroll else 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = constrain(logits, "batch", None, "vocab")
    return logits, jnp.sum(auxs)


def train_loss(params, cfg: ModelConfig, batch: dict, *, remat: str = "none",
               unroll: bool = False):
    logits, aux = forward(params, cfg, batch, remat=remat, unroll=unroll)
    if cfg.input_mode == "embeddings":
        # masked-frame prediction (encoder-only audio)
        loss = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    else:
        loss = cross_entropy_loss(logits, batch["labels"])
    return loss + aux


def prefill_with_cache(params, cfg: ModelConfig, batch: dict,
                       capacity: int | None = None):
    """Forward over the prompt AND build the decode cache in one pass.

    Returns (logits (b,s,V), DecodeCache at pos=s).  ``capacity`` is the
    ring-buffer size (>= prompt len for full-cache serving; = window for
    sliding-window serving).
    """
    if cfg.serve_window is not None:
        # serving applies the sliding window during the prompt pass too,
        # so prefill logits match window-constrained decode exactly
        cfg = dataclasses.replace(cfg, window=cfg.serve_window)
    x = embed_inputs(params, cfg, batch)
    x = constrain(x, "batch", None, "model")
    b, s, _ = x.shape
    if capacity is None:
        capacity = s if cfg.serve_window is None else min(s, cfg.serve_window)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def layer_fn(carry, lp):
        y, (aux, cache) = _layer_forward(lp, carry, cfg, positions,
                                         collect_cache=True)
        return y, (aux, cache)

    x, (auxs, caches) = jax.lax.scan(layer_fn, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(x @ head, "batch", None, "vocab")

    def to_ring(kv):
        """(L, b, hkv, s, dh) -> ring buffer (L, b, hkv, cap, dh)."""
        if capacity >= s:
            pad = capacity - s
            return jnp.pad(kv, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        last = kv[:, :, :, s - capacity:]
        return jnp.roll(last, shift=s % capacity, axis=3)

    if cfg.block_pattern == "xlstm":
        layers = caches
    else:
        layers = dict(caches)
        layers["k"] = to_ring(caches["k"])
        layers["v"] = to_ring(caches["v"])
    return logits, DecodeCache(layers=layers, pos=jnp.asarray(s, jnp.int32))


# =============================================================== decode

def init_decode_cache(cfg: ModelConfig, batch: int, context: int) -> DecodeCache:
    """Abstract-friendly cache init (zeros; prefill fills it).

    Capacity is min(context, serve_window) for attention caches; SSM /
    xLSTM state is O(1).
    """
    dtype = _dtype_of(cfg)
    dh = cfg.resolved_head_dim
    cap = context if cfg.serve_window is None else min(context, cfg.serve_window)
    n_stack = cfg.n_layers // 2 if cfg.block_pattern == "xlstm" else cfg.n_layers

    def one_layer(_):
        if cfg.block_pattern == "xlstm":
            inner = 2 * cfg.d_model
            dhm = inner // cfg.n_heads
            return {
                "m_c": jnp.zeros((batch, cfg.n_heads, dhm, dhm), jnp.float32),
                "m_n": jnp.zeros((batch, cfg.n_heads, dhm), jnp.float32),
                "s_c": jnp.zeros((batch, cfg.d_model), jnp.float32),
                "s_n": jnp.zeros((batch, cfg.d_model), jnp.float32),
            }
        cache = {
            "k": jnp.zeros((batch, cfg.n_kv_heads, cap, dh), dtype),
            "v": jnp.zeros((batch, cfg.n_kv_heads, cap, dh), dtype),
        }
        if cfg.block_pattern == "hybrid":
            cache["ssm_h"] = jnp.zeros((batch, cfg.d_model, cfg.ssm_state), jnp.float32)
            cache["conv"] = jnp.zeros((batch, cfg.conv_width - 1, cfg.d_model), dtype)
        return cache

    layers = jax.vmap(one_layer)(jnp.arange(n_stack))
    return DecodeCache(layers=layers, pos=jnp.zeros((), jnp.int32))


def _attn_decode(lp, x, kc, vc, pos, cfg: ModelConfig):
    """One-token attention with ring-buffer cache. x (b,1,D)."""
    b = x.shape[0]
    dh = cfg.resolved_head_dim
    cap = kc.shape[2]
    q, k, v = attn_lib.qkv_proj(lp, x, cfg)
    posv = jnp.broadcast_to(pos[None, None], (b, 1))
    q, k = _rope_qk(q, k, posv, cfg.rope_theta)
    slot = jnp.mod(pos, cap)
    if cfg.splitk_decode:
        # split-K serving: the cache LENGTH dim is sharded over the model
        # axis, so each rank scores its slice of the context and the
        # softmax/output reductions psum tiny (b,h,1[,dh]) partials.  The
        # ring write must then be an elementwise select (a dynamic-update
        # -slice at an unknown shard boundary would force SPMD full
        # rematerialization of the cache).
        hit = (jnp.arange(cap) == slot)[None, None, :, None]
        kc = jnp.where(hit, k.astype(kc.dtype), kc)
        vc = jnp.where(hit, v.astype(vc.dtype), vc)
    else:
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, 0, slot, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, 0, slot, 0))
    kpos = attn_lib._ring_positions(pos, cap)
    valid = (kpos <= pos) & (kpos >= 0)
    if cfg.serve_window is not None:
        valid &= kpos > pos - cfg.serve_window
    # pin cache reads: anything else makes SPMD all-gather the 32k-entry
    # cache across model ranks every token
    q = constrain(q, "batch", "heads", None, None)
    if cfg.splitk_decode:
        kc = constrain(kc, "batch", None, "model", None)
        vc = constrain(vc, "batch", None, "model", None)
    else:
        kc = constrain(kc, "batch", "heads", None, None)
        vc = constrain(vc, "batch", "heads", None, None)
    # grouped-head GQA einsums read the cache DIRECTLY — a jnp.repeat to
    # n_heads would materialize an n_heads/hkv-times-larger cache copy
    # (and under split-K, SPMD then retiles it across ranks)
    b = x.shape[0]
    rep = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, cfg.n_kv_heads, rep, dh)
    sc = jnp.einsum("bgrd,bgcd->bgrc", qg.astype(jnp.float32),
                    kc.astype(jnp.float32)) * dh ** -0.5
    sc = jnp.where(valid[None, None, None, :], sc, attn_lib.NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bgrc,bgcd->bgrd", p, vc.astype(jnp.float32))
    o = o.reshape(b, cfg.n_heads, 1, dh).astype(x.dtype)
    return constrain(attn_lib.out_proj(lp, o), "batch", None, None), kc, vc


def _layer_decode(lp, cache_l, x, pos, cfg: ModelConfig):
    """Single-token decode through one layer. x (b, 1, D)."""
    if cfg.block_pattern == "xlstm":
        return _xlstm_decode(lp, cache_l, x, cfg)
    new_cache = dict(cache_l)
    if cfg.block_pattern == "hybrid":
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a_out, kc, vc = _attn_decode(lp["attn"], h, cache_l["k"], cache_l["v"], pos, cfg)
        s_out, ssm_h, conv = _ssm_decode(lp, h[:, 0], cache_l, cfg)
        new_cache.update(k=kc, v=vc, ssm_h=ssm_h, conv=conv)
        x = x + 0.5 * (rms_norm(a_out, lp["beta_attn"], cfg.norm_eps)
                       + rms_norm(s_out[:, None], lp["beta_ssm"], cfg.norm_eps))
    else:
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a_out, kc, vc = _attn_decode(lp["attn"], h, cache_l["k"], cache_l["v"], pos, cfg)
        new_cache.update(k=kc, v=vc)
        x = x + a_out
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        y, _ = moe_lib.moe_forward(lp["moe"], h2, cfg)
        x = x + y
    elif cfg.d_ff > 0:
        x = x + mlp_forward(lp["mlp"], h2, cfg.mlp_variant)
    return x, new_cache


def _ssm_decode(lp, h, cache_l, cfg: ModelConfig):
    """h (b, D) -> (y (b, D), new ssm_h, new conv state)."""
    sp = lp["ssm"]
    n = cfg.ssm_state
    r = sp["w_dt"].shape[0]
    xz = h @ sp["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)
    y1, conv = rec.causal_conv1d(xs[:, None], sp["conv_w"], state=cache_l["conv"])
    xs = jax.nn.silu(y1[:, 0])
    xdb = xs @ sp["w_xdb"]
    dt_r, bvec, cvec = jnp.split(xdb, [r, r + n], axis=-1)
    dt = dt_r @ sp["w_dt"] + sp["b_dt"]
    y, hh = rec.ssm_decode_step(xs, dt, bvec, cvec, sp["a_log"], sp["d_skip"],
                                cache_l["ssm_h"])
    y = (y * jax.nn.silu(z)) @ sp["w_out"]
    return y, hh, conv


def _xlstm_decode(lp, cache_l, x, cfg: ModelConfig):
    b = x.shape[0]
    mp, sp = lp["m"], lp["s"]
    inner = mp["w_down"].shape[0]
    hh = cfg.n_heads
    dh = inner // hh
    # mLSTM sub-block
    hx = rms_norm(x, mp["ln"], cfg.norm_eps)[:, 0]               # (b, d)
    up = hx @ mp["w_up"]
    xm, gate = jnp.split(up, 2, axis=-1)
    q = (xm @ mp["w_q"]).reshape(b, hh, dh)
    k = (xm @ mp["w_k"]).reshape(b, hh, dh)
    v = (xm @ mp["w_v"]).reshape(b, hh, dh)
    gates = xm @ mp["w_if"] + mp["b_if"]
    st = rec.MLSTMState(c=cache_l["m_c"], n=cache_l["m_n"])
    o, st2 = rec.mlstm_decode_step(q, k, v, gates[:, :hh], gates[:, hh:], st)
    o = o.reshape(b, inner).astype(x.dtype)
    x = x + ((o * jax.nn.silu(gate)) @ mp["w_down"])[:, None]
    # sLSTM sub-block
    hx = rms_norm(x, sp["ln"], cfg.norm_eps)[:, 0]
    zifo = hx @ sp["w_zifo"] + sp["b_zifo"]
    z, i, f, og = jnp.split(zifo, 4, axis=-1)
    sst = rec.SLSTMState(c=cache_l["s_c"], n=cache_l["s_n"])
    hs, sst2 = rec.slstm_decode_step(z, i, f, og, sst)
    x = x + (hs.astype(x.dtype) @ sp["w_out"])[:, None]
    return x, {"m_c": st2.c, "m_n": st2.n, "s_c": sst2.c, "s_n": sst2.n}


def decode_step(params, cfg: ModelConfig, cache: DecodeCache, tokens,
                unroll: bool = False):
    """Decode ONE token. tokens (b, 1) int32. Returns (logits, cache)."""
    x = params["embed"][tokens]
    pos = cache.pos

    def layer_fn(carry, scanned):
        lp, cache_l = scanned
        y, new_cache_l = _layer_decode(lp, cache_l, carry, pos, cfg)
        return y, new_cache_l

    n_stack = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]
    x, new_layers = jax.lax.scan(layer_fn, x, (params["layers"], cache.layers),
                                 unroll=n_stack if unroll else 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return logits, DecodeCache(layers=new_layers, pos=pos + 1)
