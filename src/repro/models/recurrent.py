"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and Mamba-style selective SSM.

TPU adaptation notes (DESIGN.md §3):

* **mLSTM** is implemented in the *chunkwise-parallel* form rather than a
  per-token scan: within a chunk of W tokens the matrix-memory recurrence
  collapses to a decay-masked attention (one MXU matmul pair), and only
  the inter-chunk (C, n) carry is sequential (S/W scan steps).  This
  bounds scan residuals to O(S/W) instead of O(S) matrix memories and is
  the standard TPU/GPU kernelization of xLSTM.
* **Selective SSM** uses ``jax.lax.associative_scan`` over time — the
  log-depth formulation suits TPU's preference for wide parallel ops
  over long sequential loops.
* **sLSTM** is an elementwise recurrence (cheap carry) via ``lax.scan``.

Decode paths carry O(1) state per layer: mLSTM (C, n), sLSTM (c, n),
SSM (h, conv window) — this is what makes ``long_500k`` native for these
architectures.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


# ===================================================================== mLSTM

class MLSTMState(NamedTuple):
    c: jnp.ndarray   # (b, h, dh, dh) matrix memory
    n: jnp.ndarray   # (b, h, dh) normalizer


def mlstm_chunkwise(q, k, v, i_gate, f_gate, *, chunk: int = 256,
                    state: MLSTMState | None = None):
    """Chunkwise-parallel mLSTM.

    q/k/v: (b, h, s, dh); i_gate/f_gate: (b, h, s) pre-activations.
    Returns (out (b,h,s,dh), final MLSTMState).
    """
    b, h, s, dh = q.shape
    w = min(chunk, s)
    assert s % w == 0, f"seq {s} not divisible by chunk {w}"
    nc = s // w
    scale = dh ** -0.5

    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))       # (b,h,s)
    logi = i_gate.astype(jnp.float32)                           # log-space input gate

    def to_chunks(x):
        return x.reshape(b, h, nc, w, *x.shape[3:]).transpose(2, 0, 1, 3, *range(4, x.ndim + 1))

    qc, kc, vc = to_chunks(q * scale), to_chunks(k), to_chunks(v)
    lfc, lic = to_chunks(logf), to_chunks(logi)                 # (nc,b,h,w)

    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
    else:
        c0, n0 = state.c.astype(jnp.float32), state.n.astype(jnp.float32)

    def chunk_step(carry, inp):
        c_prev, n_prev = carry
        qb, kb, vb, lf, li = inp                                 # (b,h,w,...)
        qb = qb.astype(jnp.float32); kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)
        csum = jnp.cumsum(lf, axis=-1)                           # log prod f_1..t
        total = csum[..., -1]                                    # (b,h)
        # intra-chunk decay: d[t,s] = exp(csum_t - csum_s + li_s), s <= t
        dmat = csum[..., :, None] - csum[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((w, w), bool))
        dmat = jnp.where(tri[None, None], dmat, -jnp.inf)
        dexp = jnp.exp(jnp.minimum(dmat, 30.0))                  # (b,h,w,w)
        attn = jnp.einsum("bhtd,bhsd->bhts", qb, kb) * dexp
        num_intra = jnp.einsum("bhts,bhsd->bhtd", attn, vb)
        den_intra = jnp.sum(attn, axis=-1)                       # (b,h,t)
        # inter-chunk: decay from chunk start to t = exp(csum_t)
        dstart = jnp.exp(jnp.minimum(csum, 30.0))                # (b,h,w)
        num_inter = jnp.einsum("bhtd,bhde->bhte", qb, c_prev) * dstart[..., None]
        den_inter = jnp.einsum("bhtd,bhd->bht", qb, n_prev) * dstart
        den = jnp.maximum(jnp.abs(den_intra + den_inter), 1.0)
        out = (num_intra + num_inter) / den[..., None]
        # carry update: C_new = e^{total} C + sum_s e^{csum_w - csum_s + li_s} k_s v_s^T
        wdecay = jnp.exp(jnp.minimum(total[..., None] - csum + li, 30.0))  # (b,h,w)
        kw = kb * wdecay[..., None]
        c_new = jnp.exp(jnp.minimum(total, 30.0))[..., None, None] * c_prev + \
            jnp.einsum("bhsd,bhse->bhde", kw, vb)
        n_new = jnp.exp(jnp.minimum(total, 30.0))[..., None] * n_prev + \
            jnp.sum(kw, axis=2)
        return (c_new, n_new), out

    (c_f, n_f), outs = jax.lax.scan(chunk_step, (c0, n0), (qc, kc, vc, lfc, lic))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, dh)
    return out, MLSTMState(c=c_f, n=n_f)


def mlstm_decode_step(q, k, v, i_gate, f_gate, state: MLSTMState):
    """One-token mLSTM update. q/k/v (b,h,dh); gates (b,h)."""
    dh = q.shape[-1]
    qf = q.astype(jnp.float32) * dh ** -0.5
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    f = jnp.exp(jax.nn.log_sigmoid(f_gate.astype(jnp.float32)))[..., None]
    i = jnp.exp(jnp.minimum(i_gate.astype(jnp.float32), 30.0))[..., None]
    c = f[..., None] * state.c + (i[..., None] * kf[..., :, None]) * vf[..., None, :]
    n = f * state.n + i * kf
    den = jnp.maximum(jnp.abs(jnp.sum(qf * n, axis=-1)), 1.0)
    out = jnp.einsum("bhd,bhde->bhe", qf, c) / den[..., None]
    return out, MLSTMState(c=c, n=n)


# ===================================================================== sLSTM

class SLSTMState(NamedTuple):
    c: jnp.ndarray   # (b, d)
    n: jnp.ndarray   # (b, d)


def slstm_scan(z, i_gate, f_gate, o_gate, state: SLSTMState | None = None):
    """Elementwise sLSTM over time. All inputs (b, s, d) pre-activations."""
    b, s, d = z.shape
    zf = jnp.tanh(z.astype(jnp.float32))
    lf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    li = jnp.minimum(i_gate.astype(jnp.float32), 30.0)
    o = jax.nn.sigmoid(o_gate.astype(jnp.float32))
    if state is None:
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.zeros((b, d), jnp.float32)
    else:
        c0, n0 = state.c.astype(jnp.float32), state.n.astype(jnp.float32)

    def step(carry, inp):
        c, n = carry
        zt, lft, lit, ot = inp
        f = jnp.exp(lft)
        i = jnp.exp(lit)
        c = f * c + i * zt
        n = f * n + i
        h = ot * c / jnp.maximum(jnp.abs(n), 1.0)
        return (c, n), h

    (c_f, n_f), hs = jax.lax.scan(
        step, (c0, n0),
        (zf.transpose(1, 0, 2), lf.transpose(1, 0, 2),
         li.transpose(1, 0, 2), o.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2), SLSTMState(c=c_f, n=n_f)


def slstm_decode_step(z, i_gate, f_gate, o_gate, state: SLSTMState):
    """One-token sLSTM update; all inputs (b, d)."""
    zf = jnp.tanh(z.astype(jnp.float32))
    f = jnp.exp(jax.nn.log_sigmoid(f_gate.astype(jnp.float32)))
    i = jnp.exp(jnp.minimum(i_gate.astype(jnp.float32), 30.0))
    o = jax.nn.sigmoid(o_gate.astype(jnp.float32))
    c = f * state.c + i * zf
    n = f * state.n + i
    h = o * c / jnp.maximum(jnp.abs(n), 1.0)
    return h, SLSTMState(c=c, n=n)


# ================================================================ selective SSM

class SSMState(NamedTuple):
    h: jnp.ndarray       # (b, di, n) ssm hidden
    conv: jnp.ndarray    # (b, cw-1, di) trailing conv window


def _ssm_assoc(x, dt, bmat, cmat, a_log, d_skip, *, state_h=None):
    """Associative-scan selective SSM over the full given length."""
    b, s, di = x.shape
    a = -jnp.exp(a_log.astype(jnp.float32))                      # (di, n) negative
    dtf = jax.nn.softplus(dt.astype(jnp.float32))                # (b, s, di)
    decay = jnp.exp(dtf[..., None] * a[None, None])              # (b, s, di, n)
    add = (dtf * x.astype(jnp.float32))[..., None] * bmat[..., None, :].astype(jnp.float32)

    if state_h is not None:
        # fold the incoming state into the first step's additive term
        add = add.at[:, 0].add(decay[:, 0] * state_h.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    decays, hs = jax.lax.associative_scan(combine, (decay, add), axis=1)
    del decays
    y = jnp.einsum("bsdn,bsn->bsd", hs, cmat.astype(jnp.float32))
    y = y + d_skip.astype(jnp.float32)[None, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), hs[:, -1]                          # final (b, di, n)


def ssm_scan(x, dt, bmat, cmat, a_log, d_skip, *, state_h=None,
             chunk: int = 0):
    """Selective state-space scan.

    chunk=0: one associative scan over the whole sequence — O(s log s)
    (di, n)-expanded materializations; used by the roofline FLOP
    calibration (no inner loops) and short sequences.

    chunk>0: sequential ``lax.scan`` over s/chunk chunks with the
    associative form inside and a remat'd body, so the live/saved
    expanded state is bounded by ONE chunk (the TPU-deployable form:
    the (b, s, di, n) expansion never exists at once).
    """
    b, s, di = x.shape
    if chunk <= 0 or s <= chunk or s % chunk:
        return _ssm_assoc(x, dt, bmat, cmat, a_log, d_skip, state_h=state_h)

    nc = s // chunk
    if state_h is None:
        state_h = jnp.zeros((b, di, bmat.shape[-1]), jnp.float32)

    def to_chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    @jax.checkpoint
    def body(h, xs):
        x_c, dt_c, b_c, c_c = xs
        y_c, h_new = _ssm_assoc(x_c, dt_c, b_c, c_c, a_log, d_skip,
                                state_h=h)
        return h_new, y_c

    h_final, ys = jax.lax.scan(
        body, state_h,
        (to_chunks(x), to_chunks(dt), to_chunks(bmat), to_chunks(cmat)))
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    return y, h_final


def ssm_decode_step(x, dt, bvec, cvec, a_log, d_skip, h):
    """One-token SSM update. x/dt (b, di); bvec/cvec (b, n); h (b, di, n)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dtf = jax.nn.softplus(dt.astype(jnp.float32))
    decay = jnp.exp(dtf[..., None] * a[None])
    h = decay * h + (dtf * x.astype(jnp.float32))[..., None] * bvec[:, None, :].astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, cvec.astype(jnp.float32))
    y = y + d_skip.astype(jnp.float32)[None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h


def causal_conv1d(x, w, *, state=None):
    """Depthwise causal conv. x (b, s, di), w (cw, di).

    Returns (y (b, s, di), new trailing state (b, cw-1, di)).
    """
    b, s, di = x.shape
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((b, cw - 1, di), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                     # (b, s+cw-1, di)
    y = sum(xp[:, i : i + s] * w[i][None, None] for i in range(cw))
    new_state = xp[:, s:]                                        # trailing cw-1
    return y, new_state
