"""Shared neural building blocks (pure JAX, no flax).

Parameters are plain nested dicts of jnp arrays; layer-stacked weights
carry a leading ``L`` axis and are consumed by ``jax.lax.scan`` in
``transformer.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embeddings. x: (..., s, h, dh), positions: (..., s)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(theta) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, half)
    cos = jnp.cos(angles)[..., None, :]                           # (..., s, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([
        x1 * cos - x2 * sin,
        x2 * cos + x1 * sin,
    ], axis=-1)
    return out.astype(x.dtype)


def mlp_forward(params, x, variant: str = "swiglu"):
    """Gated MLP. params: w_in (D, 2F) [packed gate|up] or (D, F), w_out (F, D)."""
    from repro.sharding.activations import constrain

    h = x @ params["w_in"]
    h = constrain(h, *(["batch"] + [None] * (h.ndim - 2) + ["model"]))
    if variant in ("swiglu", "geglu"):
        gate, up = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(gate) if variant == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.relu(h)
    return h @ params["w_out"]


def init_mlp(key, d_model: int, d_ff: int, variant: str, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    in_cols = 2 * d_ff if variant in ("swiglu", "geglu") else d_ff
    return {
        "w_in": _dense_init(k1, (d_model, in_cols), dtype),
        "w_out": _dense_init(k2, (d_ff, d_model), dtype),
    }


def _dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)


def cross_entropy_loss(logits, labels, mask=None):
    """Mean CE over valid positions. logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
