"""Achieved-vs-peak roofline numbers for the aggregation engine.

``roofline/analysis.py`` predicts LM train/serve step times from a
compiled dry run; this module closes the loop for the *engine* —
the fused one-shot round, the session's split finalize, and the two
engine kernels (``kmeans_assign``, ``group_ball_proj_batched``) — by
pairing each program's XLA ``cost_analysis()`` (flops / bytes accessed)
with its *measured* execute time:

  * ``program_rows_from_snapshot(snapshot, hw)`` — reads the
    ``"<label>.flops"`` / ``"<label>.bytes"`` gauges and
    ``"<label>.execute.ms"`` histograms that ``engine.aggregate._Program``
    records into ``repro.obs``, and turns every AOT program the run
    compiled into an achieved-vs-peak row.  Free: the costs were
    captured at the program's own compile, no second compile happens.
  * ``kernel_probe`` / ``engine_kernel_report`` — standalone AOT
    compile+time of the per-iteration kernels at a given problem size,
    for the bench rows' ``kernels`` section.

Peaks come from the shared ``Hardware`` dataclass.  On TPU the real
v5e numbers apply; elsewhere ``HW_CPU`` is a *nominal* reference chip
(order-of-magnitude laptop-class peaks) so the fraction-of-peak columns
stay comparable across bench runs on the same backend — they are NOT a
claim about the actual host silicon, and ``hw["name"]`` in every report
says which reference was used.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.roofline.analysis import HW_V5E, Hardware

# nominal laptop-class reference peaks for non-TPU backends: ~100 GFLOP/s
# f32, ~25 GB/s memory, ~10 GB/s interconnect.  Deliberately round
# numbers — the point is stable achieved/peak ratios across runs, not
# host-silicon accuracy.
HW_CPU = Hardware(name="cpu-nominal", peak_flops=1e11, hbm_bw=2.5e10,
                  link_bw=1e10)


def detect_hardware(backend: str | None = None) -> Hardware:
    """The reference Hardware for the active (or given) jax backend."""
    b = backend or jax.default_backend()
    return HW_V5E if b == "tpu" else HW_CPU


def _cost_dict(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):    # older jax: per-device list
        cost = cost[0] if cost else {}
    return cost


def achieved_vs_peak(cost: dict, seconds: float, hw: Hardware) -> dict:
    """One program's roofline row: cost_analysis dict + measured wall
    seconds -> achieved rates and fraction-of-peak."""
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    s = max(float(seconds), 1e-12)
    return {
        "flops": flops,
        "bytes": nbytes,
        "exec_s": float(seconds),
        "achieved_flops_per_s": flops / s,
        "achieved_bytes_per_s": nbytes / s,
        "flops_frac_of_peak": flops / s / hw.peak_flops,
        "bytes_frac_of_peak": nbytes / s / hw.hbm_bw,
    }


def kernel_probe(name: str, fn, args, hw: Hardware, iters: int = 3) -> dict:
    """AOT-compile ``fn`` at the shapes of ``args`` and time warm
    executions; returns an achieved-vs-peak row tagged with the arg
    shapes."""
    compiled = jax.jit(fn).lower(*args).compile()
    cost = _cost_dict(compiled)
    jax.block_until_ready(compiled(*args))            # warmup
    t0 = time.perf_counter()
    for _ in range(iters):
        out = compiled(*args)
    jax.block_until_ready(out)
    per_iter = (time.perf_counter() - t0) / iters
    row = achieved_vs_peak(cost, per_iter, hw)
    row["name"] = name
    row["shapes"] = [list(jnp.shape(a)) for a in args]
    return row


def engine_kernel_report(clients: int, sketch_dim: int, k: int,
                         algorithm: str, *, edges: str = "complete",
                         knn_k: int = 8, max_edges: int = 1 << 21,
                         hw: Hardware | None = None) -> list[dict]:
    """Probe the per-iteration kernel(s) a bench row's algorithm drives.

    Lloyd-family rows probe ``kmeans_assign`` at the row's (C, s) x
    (k, s); convex rows probe ``group_ball_proj_batched`` at the fusion
    graph's edge count (C*knn_k for knn, C(C-1)/2 complete, capped at
    ``max_edges`` with a ``capped`` flag so huge-C rows don't allocate
    an O(C^2) probe tensor).
    """
    from repro.kernels import ops as kops

    hw = hw or detect_hardware()
    key = jax.random.PRNGKey(0)
    rows = []
    if algorithm.startswith("kmeans"):
        pts = jax.random.normal(key, (clients, sketch_dim), jnp.float32)
        ctr = pts[:max(k, 1)]
        rows.append(kernel_probe("kmeans_assign", kops.kmeans_assign,
                                 (pts, ctr), hw))
    else:
        n_edges = (clients * knn_k if edges == "knn"
                   else clients * (clients - 1) // 2)
        capped = n_edges > max_edges
        e = min(n_edges, max_edges)
        v = jax.random.normal(key, (1, e, sketch_dim), jnp.float32)
        radius = jnp.ones((1, e), jnp.float32)
        row = kernel_probe("group_ball_proj_batched",
                           kops.group_ball_proj_batched, (v, radius), hw)
        row["edges"] = int(e)
        row["edges_capped"] = bool(capped)
        rows.append(row)
    return rows


def program_rows_from_snapshot(snapshot: dict,
                               hw: Hardware | None = None) -> dict:
    """Achieved-vs-peak per AOT program, from an ``obs.snapshot()``.

    Pairs every ``"<label>.flops"`` gauge with the matching
    ``"<label>.execute.ms"`` histogram's p50 (warm-execution latency)
    — the programs the run actually compiled and ran, at their real
    shapes, with zero extra compiles.
    """
    hw = hw or detect_hardware()
    gauges = snapshot.get("gauges", {})
    hists = snapshot.get("histograms", {})
    out = {}
    for name, flops in gauges.items():
        if not name.endswith(".flops"):
            continue
        label = name[:-len(".flops")]
        h = hists.get(f"{label}.execute.ms")
        if not h or not h.get("count"):
            continue
        cost = {"flops": flops,
                "bytes accessed": gauges.get(f"{label}.bytes", 0.0)}
        row = achieved_vs_peak(cost, h["p50"] / 1000.0, hw)
        row["exec_count"] = h["count"]
        out[label] = row
    return out


def hardware_info(hw: Hardware | None = None) -> dict:
    hw = hw or detect_hardware()
    return {"name": hw.name, "peak_flops": hw.peak_flops,
            "hbm_bw": hw.hbm_bw, "link_bw": hw.link_bw,
            "backend": jax.default_backend()}
