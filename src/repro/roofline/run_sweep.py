import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any other import (jax locks device count on first init).

# Roofline baseline sweep: calibrated three-term roofline for every
# (arch x shape) on the single-pod mesh (EXPERIMENTS.md section Roofline).

import argparse
import json
import sys

from repro.configs import ARCH_IDS, INPUT_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.roofline.measure import measure_combo


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default="roofline_baseline.jsonl")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    failed = 0
    for arch in archs:
        for shape in shapes:
            try:
                report, info = measure_combo(arch, shape, mesh)
            except Exception as e:  # noqa: BLE001
                info = {"arch": arch, "shape": shape, "status": "FAIL",
                        "error": f"{type(e).__name__}: {e}"}
                failed += 1
            if info["status"] == "OK":
                r = info["roofline"]
                print(f"[OK  ] {arch:22s} {shape:12s} "
                      f"compute {r['compute_s']*1e3:8.2f}ms  "
                      f"memory {r['memory_s']*1e3:8.2f}ms  "
                      f"coll {r['collective_s']*1e3:8.2f}ms  "
                      f"-> {r['bottleneck']:10s} useful={r['useful_flop_ratio']:.2f}",
                      flush=True)
            else:
                print(f"[{info['status']:4s}] {arch:22s} {shape:12s} "
                      f"{info.get('reason') or info.get('error')}", flush=True)
            with open(args.json, "a") as f:
                f.write(json.dumps(info) + "\n")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
