"""Roofline measurement orchestration.

XLA's cost model counts ``while`` bodies ONCE (not x trip count), so a
scan-over-layers program under-reports FLOPs/bytes by ~L.  We therefore
derive costs by *linear calibration*: lower UNROLLED variants of the
same architecture at L in {2, 4} (direct attention, single-chunk mLSTM —
no inner loops anywhere) and extrapolate

    cost(L) = cost(2) + (L - 2)/2 * (cost(4) - cost(2))

which is exact for any cost linear in depth (per-layer compute +
depth-independent embedding/head/optimizer work).  Memory-fit numbers
(peak bytes/device) still come from the REAL full-depth deploy compile
done by ``dryrun.lower_one``.

Known conventions (documented in EXPERIMENTS.md):
  * calibration uses direct (materialized) attention, so the HBM bytes
    term is an upper bound vs a flash/chunked deployment;
  * per-token sLSTM scans remain while-loops even in calibration; their
    elementwise FLOPs are negligible vs the projections (<1%).
"""
from __future__ import annotations

import dataclasses

from repro.configs import INPUT_SHAPES, get_config
from repro.launch import inputs as inp
from repro.models import transformer as tr
from repro.roofline.analysis import (
    HW_V5E,
    RooflineReport,
    collective_bytes_from_hlo,
    model_flops,
)


def _cal_config(cfg, n_layers: int, *, direct: bool):
    """Calibration variant: direct=True removes ALL inner loops (exact
    FLOP accounting); direct=False keeps the deploy chunked attention
    (whose one-tile-counted inner loop approximates a flash kernel's
    near-zero HBM score traffic)."""
    if direct:
        return dataclasses.replace(
            cfg, n_layers=n_layers, attn_chunk=0, mlstm_chunk=0, ssm_chunk=0)
    return dataclasses.replace(cfg, n_layers=n_layers)


def _extract(compiled):
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes_from_hlo(compiled.as_text())
    coll_total = float(sum(v for k, v in coll.items() if not k.startswith("_")))
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll_total,
        "coll_detail": coll,
    }


def _lerp(v2: float, v4: float, L: int) -> float:
    return v2 + (L - 2) / 2.0 * (v4 - v2)


def measure_combo(arch: str, shape_name: str, mesh, *, remat: str = "full",
                  deploy_info: dict | None = None, lower_one=None,
                  cfg_override=None, layout: str = "tp_fsdp"):
    """Calibrated roofline for one (arch, shape) on ``mesh``.

    ``deploy_info`` — optional result of the full-depth dryrun (reused
    for the memory-fit column to avoid recompiling).
    Returns (RooflineReport, info dict) or (None, skip info).
    """
    if lower_one is None:
        from repro.launch.dryrun import lower_one as _lo
        lower_one = _lo
    cfg = cfg_override or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = inp.shape_supported(cfg, shape)
    if not ok:
        return None, {"arch": arch, "shape": shape_name, "status": "SKIP",
                      "reason": reason}

    cals = {}        # direct-attention cal: exact FLOPs
    dcals = {}       # deploy (chunked) cal: bytes + collectives
    for L in (2, 4):
        ccfg = _cal_config(cfg, L, direct=True)
        compiled, _ = lower_one(arch, shape_name, mesh=mesh,
                                cfg_override=ccfg, unroll=True, remat=remat,
                                layout=layout)
        cals[L] = _extract(compiled)
        del compiled
        dcfg = _cal_config(cfg, L, direct=False)
        if dcfg == ccfg:
            dcals[L] = cals[L]      # decode paths have no inner loops
        else:
            compiled, _ = lower_one(arch, shape_name, mesh=mesh,
                                    cfg_override=dcfg, unroll=True,
                                    remat=remat, layout=layout)
            dcals[L] = _extract(compiled)
            del compiled

    L = cfg.n_layers
    flops = _lerp(cals[2]["flops"], cals[4]["flops"], L)
    nbytes = _lerp(dcals[2]["bytes"], dcals[4]["bytes"], L)
    coll = _lerp(dcals[2]["coll"], dcals[4]["coll"], L)

    scfg = inp.serve_config(cfg, shape) if shape.kind == "decode" else cfg
    params_sds = tr.abstract_params(scfg)
    chips = mesh.devices.size
    mesh_name = "x".join(map(str, mesh.devices.shape))
    report = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device_hbm=nbytes,
        coll_bytes_per_device=coll,
        collective_detail={"cal_L2": cals[2]["coll_detail"]["_counts"],
                           "cal_L4": cals[4]["coll_detail"]["_counts"]},
        model_flops_=model_flops(scfg, shape, params_sds),
        compute_s=flops / HW_V5E.peak_flops,
        memory_s=nbytes / HW_V5E.hbm_bw,
        collective_s=coll / HW_V5E.link_bw,
        peak_bytes_per_device=(deploy_info or {}).get("peak_bytes_per_device"),
    )
    info = {"arch": arch, "shape": shape_name, "status": "OK",
            "mesh": mesh_name, "roofline": report.row(),
            "cal": {str(k): {kk: vv for kk, vv in v.items() if kk != "coll_detail"}
                    for k, v in cals.items()}}
    return report, info
