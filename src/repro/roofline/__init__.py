from repro.roofline.analysis import (
    HW_V5E,
    Hardware,
    RooflineReport,
    collective_bytes_from_hlo,
    model_flops,
    active_param_count,
    roofline_terms,
)
from repro.roofline.engine_costs import (
    HW_CPU,
    achieved_vs_peak,
    detect_hardware,
    engine_kernel_report,
    hardware_info,
    kernel_probe,
    program_rows_from_snapshot,
)

__all__ = [
    "HW_CPU",
    "HW_V5E",
    "Hardware",
    "RooflineReport",
    "achieved_vs_peak",
    "collective_bytes_from_hlo",
    "detect_hardware",
    "engine_kernel_report",
    "hardware_info",
    "kernel_probe",
    "model_flops",
    "active_param_count",
    "program_rows_from_snapshot",
    "roofline_terms",
]
