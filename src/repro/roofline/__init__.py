from repro.roofline.analysis import (
    HW_V5E,
    Hardware,
    RooflineReport,
    collective_bytes_from_hlo,
    model_flops,
    active_param_count,
    roofline_terms,
)

__all__ = [
    "HW_V5E",
    "Hardware",
    "RooflineReport",
    "collective_bytes_from_hlo",
    "model_flops",
    "active_param_count",
    "roofline_terms",
]
