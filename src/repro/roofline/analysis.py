"""Roofline terms from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis()`` supplies FLOPs and bytes; collective bytes are NOT
in cost_analysis, so they are parsed from the post-SPMD HLO text: we sum
the larger of (result bytes, operand bytes) over every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute
instruction (= payload per participating device for ring algorithms, a
deliberate ~1-2x-accurate proxy; EXPERIMENTS.md reports the convention).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float        # per chip, bf16
    hbm_bw: float            # bytes/s per chip
    link_bw: float           # bytes/s per ICI link


HW_V5E = Hardware(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    if not dims:
        return nb
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * nb


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum payload bytes per collective kind from post-SPMD HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # instruction lines look like:  %name = TYPE[dims] op-name(args...)
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if re.search(rf"\b{kind}-done\(", rhs):
            continue  # counted at -start
        # result may be a tuple: take all shapes before the op token,
        # operands after it
        op_pos = rhs.find(kind)
        res_shapes = _SHAPE_RE.findall(rhs[:op_pos])
        arg_shapes = _SHAPE_RE.findall(rhs[op_pos:])
        res_b = sum(_shape_bytes(d, s) for d, s in res_shapes)
        arg_b = sum(_shape_bytes(d, s) for d, s in arg_shapes)
        out[kind] += max(res_b, arg_b)
        counts[kind] += 1
    out["_counts"] = counts
    return out


# ------------------------------------------------------------ model flops

def active_param_count(params_shape, n_experts: int = 0, top_k: int = 0) -> tuple[int, int]:
    """(total, active) parameter counts from an abstract params pytree.

    Expert leaves (paths containing 'moe/w_in'/'moe/w_out') contribute
    total*topk/E to the active count; everything else is fully active.
    """
    import jax

    total = 0
    active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        s = "/".join(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
        n = int(np.prod(leaf.shape))
        total += n
        if ("moe" in s) and ("w_in" in s or "w_out" in s):
            frac = top_k / max(1, n_experts)
            active += n * frac
        else:
            active += n
    return total, int(active)


def model_flops(cfg, shape, params_shape) -> float:
    """6·N_active·D for training; 2·N_active·tokens for decode/prefill fwd."""
    total, active = active_param_count(params_shape, cfg.n_experts, cfg.top_k)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * active * tokens


@dataclasses.dataclass
class RooflineReport:
    """Three-term roofline for one (arch, shape, mesh).

    NOTE on conventions: JAX's ``compiled.cost_analysis()`` and the
    post-SPMD HLO report *per-device* quantities (the partitioned
    module).  The spec formulas divide *global* quantities by ``chips``;
    both phrasings are identical, so we store per-device numbers and the
    terms come out as  per_device_X / per_chip_rate.  Global HLO FLOPs
    (= per_device * chips) are reported alongside for the
    MODEL_FLOPS / HLO_FLOPs usefulness ratio.
    """
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device_hbm: float
    coll_bytes_per_device: float
    collective_detail: dict
    model_flops_: float
    compute_s: float
    memory_s: float
    collective_s: float
    peak_bytes_per_device: Optional[float] = None

    @property
    def hlo_flops_global(self) -> float:
        return self.flops_per_device * self.chips

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> float:
        g = self.hlo_flops_global
        return self.model_flops_ / g if g else float("nan")

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_gflops_global": self.hlo_flops_global / 1e9,
            "model_gflops": self.model_flops_ / 1e9,
            "hbm_gbytes_per_dev": self.bytes_per_device_hbm / 1e9,
            "coll_gbytes_per_dev": self.coll_bytes_per_device / 1e9,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flop_ratio": self.useful_flop_ratio,
        }


def roofline_terms(*, arch: str, shape, mesh_name: str, chips: int,
                   cost: dict, hlo_text: str, cfg, params_shape,
                   hw: Hardware = HW_V5E,
                   bytes_per_device: float | None = None) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))          # per device
    nbytes = float(cost.get("bytes accessed", 0.0))  # per device
    coll = collective_bytes_from_hlo(hlo_text)       # per device payloads
    coll_total = float(sum(v for k, v in coll.items() if not k.startswith("_")))
    mf = model_flops(cfg, shape, params_shape)       # global
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device_hbm=nbytes,
        coll_bytes_per_device=coll_total,
        collective_detail=coll, model_flops_=mf,
        compute_s=flops / hw.peak_flops,
        memory_s=nbytes / hw.hbm_bw,
        collective_s=coll_total / hw.link_bw,
        peak_bytes_per_device=bytes_per_device,
    )
