"""Host checkpointing of pytrees: msgpack + zstd, atomic writes.

Layout: <dir>/step_<n>.ckpt, each a zstd-compressed msgpack of
{path: {dtype, shape, data}} plus a 'tree' structure descriptor.
Restores into the exact pytree structure given as template.
"""
from __future__ import annotations

import os
import re
import zlib

import jax
import msgpack
import numpy as np

try:                              # optional: fall back to stdlib zlib
    import zstandard
except ImportError:               # pragma: no cover - env-dependent
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(payload: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(payload)
    return zlib.compress(payload, 6)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError("checkpoint is zstd-compressed but the "
                               "'zstandard' module is unavailable")
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
        arr = np.asarray(leaf)
        out[key] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                    "data": arr.tobytes()}
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    payload = msgpack.packb(_flatten(tree), use_bin_type=True)
    compressed = _compress(payload)
    path = os.path.join(ckpt_dir, f"step_{step}.ckpt")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(compressed)
    os.replace(tmp, path)
    return path


def restore_checkpoint(ckpt_dir: str, step: int, tree_template):
    path = os.path.join(ckpt_dir, f"step_{step}.ckpt")
    with open(path, "rb") as f:
        payload = _decompress(f.read())
    stored = msgpack.unpackb(payload, raw=False)

    flat = jax.tree_util.tree_flatten_with_path(tree_template)
    leaves, treedef = flat[0], flat[1]
    out = []
    for p, leaf in leaves:
        key = "/".join(str(getattr(q, "key", getattr(q, "name", q))) for q in p)
        rec = stored[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        out.append(arr.reshape(rec["shape"]))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.ckpt$", f))]
    return max(steps) if steps else None
