import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any other import (jax locks device count on first init).

# Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).
_DOC = """

For each combination this proves, without hardware:
  * the sharding config is coherent (no mismatched specs, no unsupported
    collectives) — .lower().compile() would fail otherwise;
  * the memory footprint fits (memory_analysis bytes per device);
  * and it extracts cost_analysis + HLO collective schedule for the
    §Roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --json out.json
"""

import argparse
import json
import sys
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch import inputs as inp
from repro.launch.mesh import data_axes_of, make_production_mesh
from repro.launch.steps import (
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.models import transformer as tr
from repro.roofline import roofline_terms
from repro.sharding import (
    ShardingRules,
    batch_spec,
    cache_specs,
    opt_state_specs,
    param_specs,
)
from repro.sharding.activations import activation_sharding


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# FSDP is a training-memory trade (per-step weight all-gathers).  At
# serve time we replicate weights across the data axes whenever the
# model-parallel shard fits comfortably in HBM — otherwise every decoded
# token would pay the full FSDP gather tax.
SERVE_FSDP_THRESHOLD_BYTES = 10 * 2 ** 30


def make_rules(cfg, mesh, kind: str) -> ShardingRules:
    import numpy as _np

    from repro.utils import tree_size

    data_axes = data_axes_of(mesh)
    if kind == "train":
        return ShardingRules(data_axes=data_axes)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = sizes.get("model", 1)
    n_params = tree_size(tr.abstract_params(cfg))
    bytes_per_dev = n_params * _np.dtype(cfg.dtype).itemsize / msize
    return ShardingRules(data_axes=data_axes,
                         fsdp=bytes_per_dev > SERVE_FSDP_THRESHOLD_BYTES)


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              mesh=None, step_kind: str | None = None, donate: bool = True,
              remat: str = "full", cfg_override=None, unroll: bool = False,
              layout: str = "tp_fsdp"):
    """Lower + compile one combination. Returns (compiled, info dict).

    layout:
      tp_fsdp    — baseline: tensor parallel over 'model', FSDP+batch
                   over the data axes.
      pure_fsdp  — ZeRO-3 style: NO tensor parallelism; both mesh axes
                   act as data axes (batch + parameter sharding).
      odcl_local — the paper-faithful local phase: client axis on
                   'data', per-client parameter replicas (stacked
                   leading dim), zero cross-client collectives.
    """
    cfg = cfg_override or get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = inp.shape_supported(cfg, shape)
    if not ok:
        return None, {"arch": arch, "shape": shape_name, "status": "SKIP",
                      "reason": reason}

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    kind = step_kind or shape.kind
    if layout == "pure_fsdp":
        rules = ShardingRules(data_axes=tuple(mesh.axis_names),
                              model_axis=None, fsdp=True)
    elif layout == "odcl_local":
        assert kind == "train", "odcl_local is a training layout"
        rules = ShardingRules(data_axes=(), model_axis="model", fsdp=False,
                              client_axis="data")
    elif layout == "odcl_local_fsdp":
        # beyond-paper: each client runs ZeRO-3 over its own 16-device
        # column (model axis) instead of tensor parallelism — the only
        # remaining collectives are intra-client weight all-gathers
        assert kind == "train", "odcl_local_fsdp is a training layout"
        rules = ShardingRules(data_axes=("model",), model_axis=None,
                              fsdp=True, client_axis="data")
    else:
        rules = make_rules(cfg, mesh, kind)

    scfg = inp.serve_config(cfg, shape) if shape.kind == "decode" else cfg
    params_sds = tr.abstract_params(scfg)
    pspecs = param_specs(scfg, params_sds, rules, mesh)
    bspec_fn = batch_spec(scfg, rules, mesh)

    t0 = time.time()
    with mesh, activation_sharding(mesh, rules.data_axes, rules.model_axis):
        if kind == "train":
            if layout.startswith("odcl_local"):
                from repro.launch.steps import make_local_train_step

                step = make_local_train_step(scfg, remat=remat, unroll=unroll)
                n_clients = dict(zip(mesh.axis_names,
                                     mesh.devices.shape))["data"]
                specs = inp.input_specs(scfg, shape)
                stack = lambda t: jax.tree_util.tree_map(
                    lambda l: jax.ShapeDtypeStruct(
                        (n_clients,) + l.shape, l.dtype), t)
                # split the global batch across clients
                def split_batch(l):
                    return jax.ShapeDtypeStruct(
                        (n_clients, l.shape[0] // n_clients) + l.shape[1:],
                        l.dtype)
                specs = {"params": stack(specs["params"]),
                         "opt_state": stack(specs["opt_state"]),
                         "batch": jax.tree_util.tree_map(
                             split_batch, specs["batch"])}
                pspecs = param_specs(scfg, specs["params"], rules, mesh)
            else:
                step = make_train_step(scfg, remat=remat, unroll=unroll)
                specs = inp.input_specs(scfg, shape)
            in_shardings = (
                _named(mesh, pspecs),
                _named(mesh, opt_state_specs(pspecs)),
                _named(mesh, jax.tree_util.tree_map(
                    lambda l: bspec_fn(l), specs["batch"])),
            )
            out_shardings = (NamedSharding(mesh, P()), in_shardings[0],
                             in_shardings[1])
            jitted = jax.jit(step, in_shardings=in_shardings,
                             out_shardings=out_shardings,
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(specs["params"], specs["opt_state"],
                                   specs["batch"])
        elif kind == "prefill":
            step = make_prefill_step(scfg, unroll=unroll)
            specs = inp.input_specs(scfg, shape)
            in_shardings = (
                _named(mesh, pspecs),
                _named(mesh, jax.tree_util.tree_map(
                    lambda l: bspec_fn(l), specs["batch"])),
            )
            jitted = jax.jit(step, in_shardings=in_shardings)
            lowered = jitted.lower(specs["params"], specs["batch"])
        else:  # decode
            step = make_decode_step(scfg, unroll=unroll)
            cache_sds, tokens_sds = inp.decode_input_specs(cfg, shape)
            cspecs = cache_specs(scfg, cache_sds, rules, mesh)
            in_shardings = (
                _named(mesh, pspecs),
                _named(mesh, cspecs),
                NamedSharding(mesh, bspec_fn(tokens_sds)),
            )
            out_shardings = (NamedSharding(mesh, P()), in_shardings[1])
            jitted = jax.jit(step, in_shardings=in_shardings,
                             out_shardings=out_shardings,
                             donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_sds, cache_sds, tokens_sds)

        compiled = lowered.compile()
    elapsed = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    chips = mesh.devices.size
    info = {
        "arch": arch, "shape": shape_name, "status": "OK",
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "chips": chips, "step": kind,
        "compile_s": round(elapsed, 1),
        "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
        "peak_bytes_per_device": (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)),
        "flops_per_device": cost.get("flops"),
        "bytes_per_device": cost.get("bytes accessed"),
    }
    report = roofline_terms(
        arch=arch, shape=shape, mesh_name=info["mesh"], chips=chips,
        cost=cost, hlo_text=compiled.as_text(), cfg=scfg,
        params_shape=params_sds, bytes_per_device=info["peak_bytes_per_device"])
    info["roofline"] = report.row()
    info["collectives"] = report.collective_detail
    return compiled, info


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--step", default=None,
                    help="override step kind (train|prefill|decode)")
    ap.add_argument("--layout", default="tp_fsdp",
                    choices=["tp_fsdp", "pure_fsdp", "odcl_local",
                             "odcl_local_fsdp"])
    ap.add_argument("--json", default=None, help="append results to this file")
    args = ap.parse_args(argv)

    combos = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            combos.append((a, s))

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    results, failed = [], []
    for arch, shape in combos:
        try:
            compiled, info = lower_one(arch, shape, mesh=mesh,
                                       step_kind=args.step,
                                       layout=args.layout)
            del compiled
        except Exception as e:  # noqa: BLE001 - report and continue
            info = {"arch": arch, "shape": shape, "status": "FAIL",
                    "error": f"{type(e).__name__}: {e}"}
            failed.append(info)
        results.append(info)
        status = info["status"]
        extra = (info.get("reason") or info.get("error")
                 or f"compile {info.get('compile_s')}s "
                    f"peak/dev {(info.get('peak_bytes_per_device') or 0)/2**30:.2f}GiB")
        print(f"[{status:4s}] {arch:22s} {shape:12s} {extra}", flush=True)
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(info) + "\n")

    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    print(f"\n{n_ok} OK, {n_skip} SKIP, {len(failed)} FAIL "
          f"on mesh {'2x16x16' if args.multi_pod else '16x16'}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
