"""Mesh construction for the production TPU v5e topology.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — smoke tests must keep seeing a
single CPU device; only dryrun.py sets the 512-device host platform.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
