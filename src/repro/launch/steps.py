"""Step functions lowered by the dry-run and executed by the drivers.

  * ``make_train_step``   — dp_sync: conventional synchronous data-parallel
    training (the multi-round-communication baseline the paper compares
    against; gradients all-reduce over the data axes every step).
  * ``make_local_train_step`` — odcl_local: the paper-faithful local-ERM
    phase.  Parameters carry a leading client axis sharded over ``data``;
    the grad/optimizer update is vmapped per client, so NO cross-client
    collectives exist in the step (this is the entire communication saving
    of ODCL, visible in the §Roofline collective term).
  * ``make_prefill_step`` / ``make_decode_step`` — serving.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tr
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                    remat: str = "full", unroll: bool = False) -> Callable:
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: tr.train_loss(p, cfg, batch, remat=remat,
                                    unroll=unroll))(params)
        new_params, new_state = adamw_update(params, grads, opt_state, opt_cfg)
        return loss, new_params, new_state

    return train_step


def make_local_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig | None = None,
                          remat: str = "full", unroll: bool = False) -> Callable:
    """ODCL local phase: per-client params (leading C axis), per-client data
    (C, b, s).  vmap over clients => gradients never cross the client axis."""
    opt_cfg = opt_cfg or AdamWConfig()

    def one_client(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: tr.train_loss(p, cfg, batch, remat=remat,
                                    unroll=unroll))(params)
        new_params, new_state = adamw_update(params, grads, opt_state, opt_cfg)
        return loss, new_params, new_state

    def local_step(params_c, opt_state_c, batch_c):
        return jax.vmap(one_client)(params_c, opt_state_c, batch_c)

    return local_step


def make_aggregate_step(cfg: ModelConfig, k: int, sketch_dim: int = 256,
                        kmeans_iters: int = 32) -> Callable:
    """The one-shot clustered aggregation as ONE jittable SPMD step.

    params_c: per-client parameter stack (C, ...) sharded over the client
    (data) axis.  The step sketches every client's parameters (local
    matmuls), clusters the (C, sketch_dim) matrix with K-means++ (tiny,
    replicated), and replaces every client's parameters with its
    cluster's mean — a single masked all-reduce over the client axis.
    This IS the paper's entire communication round.
    """
    from repro.core.clustering.kmeans import kmeans
    from repro.core.sketch import sketch_tree

    def aggregate_step(params_c, key):
        sketches = jax.vmap(
            lambda p: sketch_tree(key, p, sketch_dim))(params_c)   # (C, s)
        res = kmeans(key, sketches, k, iters=kmeans_iters)
        c = sketches.shape[0]
        onehot = jax.nn.one_hot(res.labels, k, dtype=jnp.float32)  # (C, K)
        counts = jnp.maximum(jnp.sum(onehot, axis=0), 1.0)

        def cluster_avg(leaf):
            flat = leaf.reshape(c, -1).astype(jnp.float32)
            means = (onehot.T @ flat) / counts[:, None]
            back = onehot @ means
            return back.reshape(leaf.shape).astype(leaf.dtype)

        new_params = jax.tree_util.tree_map(cluster_avg, params_c)
        return new_params, res.labels

    return aggregate_step


def make_eval_batch(stream, *, n_clients: int, batch: int, seq_len: int,
                    step: int = 999_999) -> dict:
    """A held-out per-client eval batch from a ``ClusteredTokenStream``.

    Drawn at a step index far beyond any training step so it never
    collides with the training iterator; shared by train.py and the
    fig4 LM benchmark (previously duplicated as ``stream_eval``)."""
    import numpy as np

    toks = np.stack([
        stream.sample(c, batch, seq_len, step=step)
        for c in range(n_clients)
    ])
    return {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}


def make_prefill_step(cfg: ModelConfig, unroll: bool = False) -> Callable:
    def prefill_step(params, batch):
        logits, _ = tr.forward(params, cfg, batch, unroll=unroll)
        return logits

    return prefill_step


def make_decode_step(cfg: ModelConfig, unroll: bool = False) -> Callable:
    def decode_one(params, cache, tokens):
        return tr.decode_step(params, cfg, cache, tokens, unroll=unroll)

    return decode_one
