"""Federated ODCL training driver.

Runs the paper's protocol at LM scale: per-client local training (no
cross-client collectives), then ONE clustered aggregation round, then
optional continued local fine-tuning of the personalized models.

Production: launch one process per host with the production mesh and
``--arch <id>``; this container (CPU, 1 device) runs the same driver
with ``--reduced`` for the end-to-end example.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --clients 8 --clusters 2 --local-steps 100
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core.federated import (
    evaluate_per_client,
    init_federation,
    local_training,
    one_shot_aggregate,
)
from repro.core.clustering import (
    get_algorithm,
    is_device_algorithm,
    list_algorithms,
)
from repro.core.odcl import ODCLConfig
from repro.data import ClusteredTokenStream, make_lm_batch_iterator
from repro.optim import AdamWConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family variant")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=100)
    ap.add_argument("--post-steps", type=int, default=20,
                    help="continued local steps after aggregation")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--algo", default="kmeans++",
                    choices=list(list_algorithms()))
    ap.add_argument("--engine", choices=("host", "device"), default="host",
                    help="device = run the whole one-shot round jitted "
                         "on-device (engine.one_shot_aggregate_device)")
    ap.add_argument("--sketch-dim", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(max_vocab=256)
    print(f"arch={cfg.name} d_model={cfg.d_model} L={cfg.n_layers} "
          f"vocab={cfg.vocab_size} clients={args.clients} "
          f"true_clusters={args.clusters}")

    stream = ClusteredTokenStream(
        n_clients=args.clients, n_clusters=args.clusters,
        vocab_size=cfg.vocab_size, seed=args.seed)
    batches = make_lm_batch_iterator(
        stream, clients_per_batch=list(range(args.clients)),
        per_client_batch=args.batch, seq_len=args.seq_len)

    def batch_iter():
        for toks, labels in batches:
            yield {"tokens": toks, "labels": labels}

    it = batch_iter()
    opt = AdamWConfig(lr=args.lr, weight_decay=0.0)
    state = init_federation(jax.random.PRNGKey(args.seed), cfg, args.clients)

    # ---- phase 1: local ERM (zero cross-client communication) ----
    t0 = time.time()
    state, losses = local_training(state, cfg, it, args.local_steps, opt)
    print(f"[local] {args.local_steps} steps in {time.time()-t0:.1f}s  "
          f"loss {np.mean(losses[0]):.4f} -> {np.mean(losses[-1]):.4f}")

    # ---- phase 2: the ONE-SHOT round (Algorithm 1) ----
    if args.engine == "device":
        if is_device_algorithm(get_algorithm(args.algo)):
            # any registered DeviceClusteringAlgorithm passes straight
            # through (the extension point — see ROADMAP)
            algorithm, algo_options = args.algo, None
        else:
            # convenience: map the host Lloyd-family names onto the
            # engine's init option
            init_of = {"kmeans": "random", "kmeans++": "kmeans++",
                       "spectral": "spectral"}
            if args.algo not in init_of:
                raise SystemExit(
                    f"--engine device needs a device-capable algorithm "
                    f"(e.g. kmeans-device) or a Lloyd-family name, "
                    f"not {args.algo!r}")
            algorithm = "kmeans-device"
            algo_options = {"init": init_of[args.algo]}
        state2, labels, info = one_shot_aggregate(
            state, cfg, algorithm=algorithm, k=args.clusters,
            algo_options=algo_options, engine="device",
            sketch_dim=args.sketch_dim, seed=args.seed)
    else:
        odcl_cfg = ODCLConfig(algo=args.algo,
                              k=args.clusters if args.algo != "clusterpath" else None)
        state2, labels, info = one_shot_aggregate(
            state, cfg, odcl_cfg, sketch_dim=args.sketch_dim, seed=args.seed)
    agreement = _cluster_agreement(labels, stream.true_labels)
    print(f"[one-shot] engine={args.engine} recovered K'={info['n_clusters']} "
          f"cluster purity={agreement:.3f} labels={labels.tolist()}")

    eval_batch = {"tokens": None}
    toks, lab = stream_eval(stream, args)
    eval_batch = {"tokens": toks, "labels": lab}
    local_eval = evaluate_per_client(state, cfg, eval_batch)
    agg_eval = evaluate_per_client(state2, cfg, eval_batch)
    print(f"[eval] local-only loss {local_eval.mean():.4f}  "
          f"after one-shot {agg_eval.mean():.4f}")

    # ---- phase 3: continued personalized training ----
    if args.post_steps:
        state3, post_losses = local_training(state2, cfg, it, args.post_steps,
                                             opt)
        post_eval = evaluate_per_client(state3, cfg, eval_batch)
        print(f"[post] +{args.post_steps} steps -> loss {post_eval.mean():.4f}")
        state2 = state3

    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, state2.step, state2.params)
        print(f"[ckpt] saved {path}")
    return state2, labels


def stream_eval(stream, args):
    toks = np.stack([
        stream.sample(c, args.batch, args.seq_len, step=999_999)
        for c in range(args.clients)
    ])
    return toks[:, :, :-1], toks[:, :, 1:]


def _cluster_agreement(pred, true) -> float:
    from collections import Counter

    total = 0
    for c in np.unique(pred):
        total += Counter(true[pred == c]).most_common(1)[0][1]
    return total / len(true)


if __name__ == "__main__":
    main()
