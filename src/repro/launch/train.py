"""Federated training driver over the LM-scale method registry.

Runs any registered ``FederatedMethod`` (``core.federated_methods``) on
a clustered LM federation: ODCL's one-shot protocol (local training, ONE
clustered aggregation round, optional personalized fine-tuning), the
iterative IFCA baseline, global FedAvg, or local-only — selected with
``--method``; new methods registered via ``register_federated_method``
appear in the flag automatically.

Production: launch one process per host with the production mesh and
``--arch <id>``; this container (CPU, 1 device) runs the same driver
with ``--reduced`` for the end-to-end example.

  # Algorithm 1, host clustering (ODCL-KM++):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --clients 8 --clusters 2 --local-steps 100

  # same protocol, the whole round jitted on-device (add --restarts /
  # --batch-m for multi-restart or minibatch Lloyd at huge C):
  PYTHONPATH=src python -m repro.launch.train --reduced \
      --method odcl --engine device --algo kmeans++ --restarts 4

  # ODCL-CC on-device: K-free convex clustering in the jitted round
  PYTHONPATH=src python -m repro.launch.train --reduced \
      --method odcl --engine device --algo convex

  # the iterative baseline the paper compares against (R rounds);
  # --ifca-carry-opt carries per-cluster Adam moments across rounds
  PYTHONPATH=src python -m repro.launch.train --reduced \
      --method ifca --rounds 5 --local-steps 10 --warmup-steps 40 \
      --ifca-carry-opt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import obs
from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core.federated import evaluate_per_client, init_federation
from repro.core.federated_methods import (
    build_federated_method,
    cluster_agreement,
    list_federated_methods,
)
from repro.core.clustering import list_algorithms
from repro.core.engine.aggregators import list_aggregators
from repro.data import ClusteredTokenStream, make_lm_batch_iterator
from repro.launch.steps import make_eval_batch
from repro.optim import AdamWConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family variant")
    ap.add_argument("--method", default="odcl",
                    choices=list(list_federated_methods()),
                    help="registered FederatedMethod to run")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=100)
    ap.add_argument("--post-steps", type=int, default=20,
                    help="continued local steps after aggregation (odcl)")
    ap.add_argument("--rounds", type=int, default=5,
                    help="communication rounds (ifca / fedavg)")
    ap.add_argument("--warmup-steps", type=int, default=0,
                    help="pure local steps before the round loop (ifca)")
    ap.add_argument("--ifca-assign", choices=("loss", "sketch"),
                    default="loss", dest="assign",
                    help="IFCA cluster-estimate rule")
    ap.add_argument("--ifca-carry-opt", action="store_true",
                    dest="carry_opt_state",
                    help="FedOpt-style IFCA: carry per-cluster Adam "
                         "moments across rounds (averaged server-side "
                         "with the parameters) instead of re-initializing "
                         "every client's optimizer each round")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--algo", default="kmeans++",
                    choices=list(list_algorithms()),
                    help="admissible clustering algorithm; with --engine "
                         "device the Lloyd names map onto kmeans-device "
                         "and convex/clusterpath onto their -device twins")
    ap.add_argument("--engine", choices=("host", "device"), default="host",
                    help="device = run the whole one-shot round jitted "
                         "on-device (engine.one_shot_aggregate_device)")
    ap.add_argument("--restarts", type=int, default=1,
                    help="multi-restart Lloyd for the device kmeans "
                         "family: vmap this many inits and keep the "
                         "best-inertia clustering")
    ap.add_argument("--batch-m", type=int, default=None,
                    help="minibatch Lloyd: sample this many sketch rows "
                         "per iteration (device kmeans family; >= C runs "
                         "full Lloyd bit-exactly)")
    ap.add_argument("--sketch-dim", type=int, default=128)
    ap.add_argument("--aggregator", default="mean",
                    choices=list(list_aggregators()),
                    help="per-cluster step-3 reduction (odcl / ifca round "
                         "averaging): mean, or a robust registry variant")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write every obs span/event (engine round, "
                         "per-round comm) of this run as JSONL")
    args = ap.parse_args(argv)
    if args.trace:
        obs.add_sink(obs.JsonlSink(args.trace))

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(max_vocab=256)
    print(f"arch={cfg.name} d_model={cfg.d_model} L={cfg.n_layers} "
          f"vocab={cfg.vocab_size} clients={args.clients} "
          f"true_clusters={args.clusters} method={args.method}")

    stream = ClusteredTokenStream(
        n_clients=args.clients, n_clusters=args.clusters,
        vocab_size=cfg.vocab_size, seed=args.seed)
    batches = make_lm_batch_iterator(
        stream, clients_per_batch=list(range(args.clients)),
        per_client_batch=args.batch, seq_len=args.seq_len)

    def batch_iter():
        for toks, labels in batches:
            yield {"tokens": toks, "labels": labels}

    it = batch_iter()
    opt = AdamWConfig(lr=args.lr, weight_decay=0.0)
    state = init_federation(jax.random.PRNGKey(args.seed), cfg, args.clients)

    algo_options = {}
    if args.restarts > 1:
        algo_options["restarts"] = args.restarts
    if args.batch_m is not None:
        algo_options["batch_m"] = args.batch_m
    if algo_options and (args.engine != "device"
                         or args.algo.startswith(("convex", "clusterpath"))):
        # the registry adapters swallow unknown options, so say it loudly
        # rather than let the knobs silently no-op
        print(f"[warn] {sorted(algo_options)} only apply to the device "
              f"kmeans family; ignored for --engine {args.engine} "
              f"--algo {args.algo}")
        algo_options = {}

    # one flat kwargs superset — build_federated_method keeps only the
    # fields the chosen method declares (registry stays ladder-free)
    method = build_federated_method(
        args.method, algorithm=args.algo, k=args.clusters,
        engine=args.engine, sketch_dim=args.sketch_dim,
        algo_options=algo_options or None,
        local_steps=args.local_steps, post_steps=args.post_steps,
        rounds=args.rounds, warmup_steps=args.warmup_steps,
        assign=args.assign, carry_opt_state=args.carry_opt_state,
        aggregator=args.aggregator, opt=opt, seed=args.seed)

    t0 = time.time()
    res = method.run(jax.random.PRNGKey(args.seed), state, cfg, it)
    elapsed = time.time() - t0
    for r in res.round_metrics:
        print(f"[{method.name}] {r}")
    agreement = cluster_agreement(res.labels, stream.true_labels)
    print(f"[{method.name}] {elapsed:.1f}s  rounds={res.comm_rounds:g} "
          f"comm={res.comm_bytes / 1e6:.2f}MB  K'={res.n_clusters} "
          f"cluster purity={agreement:.3f} labels={res.labels.tolist()}")

    eval_batch = make_eval_batch(stream, n_clients=args.clients,
                                 batch=args.batch, seq_len=args.seq_len)
    final_eval = evaluate_per_client(res.state, cfg, eval_batch)
    print(f"[eval] per-client loss {final_eval.mean():.4f} "
          f"(min {final_eval.min():.4f} max {final_eval.max():.4f})")

    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, res.state.step, res.state.params)
        print(f"[ckpt] saved {path}")
    return res.state, res.labels


if __name__ == "__main__":
    main()
