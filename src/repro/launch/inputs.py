"""``input_specs`` — ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation: this is the only thing
the dry-run feeds to ``jit(...).lower``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.models import transformer as tr

N_PATCHES = 256          # stub vision patch count per sequence


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def serve_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-dependent serving variant of an arch config.

    decode_32k keeps the FULL 32k KV cache (the assignment's definition);
    long_500k selects the sliding-window variant for attention archs
    (cap = serve_window) — recurrent archs carry O(1) state natively.
    """
    if shape.name == "decode_32k":
        return dataclasses.replace(cfg, serve_window=None)
    return cfg


def shape_supported(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(supported, reason-if-skipped) per the assignment skip rules."""
    if shape.kind == "decode" and cfg.is_encoder_only:
        return False, "encoder-only: no autoregressive decode step"
    if shape.name == "long_500k":
        sub_quadratic = (cfg.block_pattern in ("xlstm", "hybrid")
                         or cfg.serve_window is not None)
        if not sub_quadratic:
            return False, "pure full-attention arch: quadratic at 500k"
    return True, ""


def train_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.input_mode == "tokens":
        return {"tokens": sds((b, s), jnp.int32),
                "labels": sds((b, s), jnp.int32)}
    if cfg.input_mode == "embeddings":
        return {"frames": sds((b, s, tr.FRONTEND_DIM), jnp.dtype(cfg.dtype)),
                "mask": sds((b, s), jnp.bool_),
                "labels": sds((b, s), jnp.int32)}
    if cfg.input_mode == "multimodal":
        return {"tokens": sds((b, s), jnp.int32),
                "patch_embeds": sds((b, N_PATCHES, tr.PATCH_DIM), jnp.dtype(cfg.dtype)),
                "patch_positions": sds((b, N_PATCHES), jnp.int32),
                "labels": sds((b, s), jnp.int32)}
    raise ValueError(cfg.input_mode)


def prefill_input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    specs = train_input_specs(cfg, shape)
    specs.pop("labels")
    if cfg.input_mode == "embeddings":
        specs.pop("mask")
    return specs


def decode_input_specs(cfg: ModelConfig, shape: InputShape):
    """(cache_sds, tokens_sds) for one-token decode against a seq_len cache."""
    scfg = serve_config(cfg, shape)
    cache = jax.eval_shape(
        lambda: tr.init_decode_cache(scfg, shape.global_batch, shape.seq_len))
    tokens = sds((shape.global_batch, 1), jnp.int32)
    return cache, tokens


def abstract_opt_state(params_sds):
    from repro.optim import adamw_init

    return jax.eval_shape(adamw_init, params_sds)


def input_specs(cfg: ModelConfig, shape: InputShape):
    """The full input bundle for the step matching ``shape.kind``."""
    params = tr.abstract_params(cfg)
    if shape.kind == "train":
        return {"params": params,
                "opt_state": abstract_opt_state(params),
                "batch": train_input_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": params, "batch": prefill_input_specs(cfg, shape)}
    cache, tokens = decode_input_specs(cfg, shape)
    return {"params": params, "cache": cache, "tokens": tokens}
