"""Large-C client simulation driving the streaming aggregation session.

Where ``launch/train.py`` runs the paper's protocol on a handful of
deep-model clients (heavy step 1, C ~ 10), this driver targets the
opposite regime the one-shot guarantee is actually about: C = 10k-100k
*shallow* clients (the paper's ridge / logistic settings, Section 5 /
Appendix E.2), IFCA- and k-FED-scale federations.

Clients are synthesized and solved in batched vmap **waves** — each
wave draws ``wave`` clients' covariates, responses, and closed-form /
Newton local ERMs in one jitted call, then feeds the wave straight into
``engine.session.AggregationSession.ingest`` (the step-1 upload): the
session sketches the wave on device and accumulates the (C, sketch_dim)
matrix in its fixed-capacity buffer, so peak memory is bounded by the
wave and nothing federation-sized crosses to host.  The one-shot server
round is then ``session.finalize()`` — the registered clustering +
cluster mean over the streamed-in sketches, bit-exact with the fused
``one_shot_aggregate(engine="device")`` round.  Iterative baselines
(``--method ifca|fedavg``) run over ``session.state()``, the same
streamed-in federation as a stacked ``FederatedState``.

  PYTHONPATH=src python -m repro.launch.simulate --clients 4096 --clusters 8

  # the convex family past the complete-graph wall: sparse kNN edges
  PYTHONPATH=src python -m repro.launch.simulate --clients 16384 \
      --algorithm convex-device --edges knn --knn-k 8 --sketch-dim 32
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.clustering import (
    device_twin,
    get_algorithm,
    is_device_algorithm,
    lambda_interval,
    list_algorithms,
)
from repro.core.engine import list_edge_sets, make_staleness_policy
from repro.core.engine.aggregators import list_aggregators, make_aggregator
from repro.core.engine.hierarchy import HierarchicalSession
from repro.core.engine.session import AggregationSession
from repro.core.erm import batched_ridge_erm, logistic_erm
from repro.core.federated_methods import (
    build_federated_method,
    cluster_agreement,
    list_federated_methods,
    params_bytes_per_client,
    sketch_round_bytes,
)
from repro.scenarios import build_scenario, list_scenarios


def staggered_optima(key, K: int, d: int):
    """Well-separated cluster optima in the style of Appendix E.1:
    cluster k draws coordinate magnitudes from U([k + 1, k + 2]) with an
    independent random sign per coordinate.  Staggered magnitudes keep
    min pairwise separation >= sqrt(d); the random signs scatter the
    optima across orthants (collinear centers are a Lloyd's-algorithm
    pathology, not the paper's setting)."""
    ks, ku = jax.random.split(key)
    signs = jax.random.rademacher(ks, (K, d), jnp.float32)
    base = jnp.arange(1.0, K + 1.0, dtype=jnp.float32)[:, None]
    return signs * (base + jax.random.uniform(ku, (K, d)))


@functools.partial(jax.jit, static_argnames=("wave", "n", "d", "task",
                                             "newton_iters"))
def _wave_erm(key, optima, labels, *, wave: int, n: int, d: int,
              task: str = "ridge", noise: float = 1.0, reg: float = 1e-6,
              newton_iters: int = 8):
    """One vmap wave of step 1: draw ``wave`` clients' data from their
    cluster's population model and solve every local ERM. Returns the
    (wave, d[+1]) stack of local models, device-resident."""
    kx, ke = jax.random.split(key)
    x = jax.random.normal(kx, (wave, n, d), jnp.float32)
    z = jnp.einsum("wnd,wd->wn", x, optima[labels])
    if task == "ridge":
        y = z + noise * jax.random.normal(ke, (wave, n), jnp.float32)
        return batched_ridge_erm(x, y, reg)                    # (wave, d)
    if task == "logistic":
        y = 2.0 * (jax.random.uniform(ke, (wave, n)) <
                   jax.nn.sigmoid(z)).astype(jnp.float32) - 1.0
        return jax.vmap(
            lambda xx, yy: logistic_erm(xx, yy, reg, newton_iters)
        )(x, y)                                                # (wave, d+1)
    raise ValueError(f"unknown task {task!r}")  # pragma: no cover - static


def simulate(*, clients: int, clusters: int, dim: int = 16, samples: int = 64,
             wave: int = 4096, task: str = "ridge", sketch_dim: int = 64,
             shards: int = 1,
             algorithm: str = "kmeans-device", init: str = "kmeans++",
             kmeans_iters: int = 50, restarts: int = 1, cc_iters: int = 300,
             edges: str = "complete", knn_k: int = 8,
             scenario=None, scenario_options: dict | None = None,
             aggregator: str = "mean", trim_beta: float = 0.1,
             seed: int = 0, method: str = "odcl", rounds: int = 5,
             trace: str | None = None, route_probes: int = 0,
             finalize_repeats: int = 1,
             reupload_frac: float = 0.0, churn: int = 0,
             max_age: int | None = None,
             refinalize_threshold: float | None = None,
             mutation_rounds: int = 3, drift_scale: float = 2.0,
             qps_callers: int = 0, qps_duration: float = 2.0,
             mesh=None) -> dict:
    """Generate a K-cluster federation of ``clients`` users, stream the
    wave-solved local ERMs into an ``AggregationSession``, run the
    requested federated method over it (default: the session's own
    streaming one-shot round), and return a summary dict (per-phase wall
    clock, recovered clustering quality).  Iterative methods run with
    zero per-round local steps — the shallow clients are already at
    their local ERMs — so IFCA here is pure sketch-assign/re-average
    rounds over ``session.state()``.

    ``algorithm`` selects the admissible clustering family: the Lloyd
    device loop by default (``init``/``kmeans_iters``/``restarts``
    apply), or the convex family — ``convex``/``convex-device`` runs
    the paper's E.1 exact-lambda ODCL-CC (the recovery bounds (17) on
    the true clustering are a host-side driver setup pass over the
    local models; the aggregation round itself stays one jitted device
    program), ``clusterpath``/``clusterpath-device`` the K-free ladder.
    ``edges``/``knn_k`` select the convex family's fusion graph
    (``knn`` breaks the complete graph's C=4k edge wall).

    ``scenario`` runs the federation through an adversity scenario
    (``repro.scenarios``): its population/drift hooks reshape the
    effective cluster labels (which become the scored truth), its
    ``corrupt_uploads`` hook attacks the wave ERMs before upload, and
    its sketch-channel hooks (DP release, colluding spoof) run inside
    the session's jitted ingest.  ``aggregator`` selects the robust
    step-3 reduction (``trim_beta`` specializes ``trimmed_mean``); a
    non-mean aggregator also drives the device Lloyd center update, so
    Byzantine rows stop dragging the recovered partition.

    ``trace`` attaches a JSONL event sink for the run (every obs span /
    event lands there).  ``route_probes``/``finalize_repeats`` exercise
    the serving path AFTER the scored run — fresh probe clients routed
    through ``session.route`` and warm finalize re-runs — so the
    summary's ``serving`` section gets real route/finalize latency
    histograms without touching the phase timings.

    The mutation knobs drive the drifted-population serving loop, also
    after the scored run: ``reupload_frac`` re-uploads that fraction of
    clients per mutation round with local ERMs re-solved against a
    SHIFTED set of cluster optima (in-place keyed replacement),
    ``churn`` joins that many fresh clients per round (``max_age``
    arms the sliding-window staleness policy so silent clients age
    out), drifted probes push the ``drift`` gauge, and
    ``refinalize_threshold`` arms ``session.maybe_refinalize`` — the
    summary's ``serving`` section then reports the drift value, the
    eviction count, warm re-finalize p50 vs the cold finalize column,
    and the batched-``route()`` throughput.
    """
    obs.reset()                       # per-run aggregates; sinks survive
    trace_sink = None
    if trace is not None:
        trace_sink = obs.JsonlSink(trace)
        obs.add_sink(trace_sink)
    key = jax.random.PRNGKey(seed)
    k_opt, k_data = jax.random.split(key)
    optima = staggered_optima(k_opt, clusters, dim)

    scen = (build_scenario(scenario, **(scenario_options or {}))
            if scenario is not None else None)
    scen_key = jax.random.fold_in(key, 0x5ce0)
    if scen is not None:
        base_labels = jnp.asarray(scen.population(scen_key, clients, clusters),
                                  jnp.int32)
        # drift hooks are per-global-index deterministic, so applying
        # them to the full index range once equals the per-wave calls
        true_labels = jnp.asarray(scen.wave_labels(
            scen_key, base_labels, 0, clients, clusters), jnp.int32)
        honest = np.asarray(scen.honest_mask(scen_key, clients), bool)
    else:
        true_labels = jnp.arange(clients, dtype=jnp.int32) % clusters
        honest = np.ones(clients, bool)

    agg = make_aggregator(aggregator, beta=trim_beta)
    sketch_hook = (
        (lambda sk, off: scen.sketch_transform(scen_key, sk, off))
        if scen is not None and scen.transforms_sketches else None)

    # mutation mode: keyed slots (stable int client ids), headroom for
    # the churned-in joiners, and the sliding-window staleness policy
    mutated = (reupload_frac > 0 or churn > 0 or max_age is not None
               or refinalize_threshold is not None)
    if shards > 1:
        # the hierarchical server is anonymous-only and one-shot-only:
        # keyed mutation and the iterative baselines both need the flat
        # session's single buffer
        if mutated:
            raise ValueError("--shards > 1 is incompatible with the "
                             "mutation knobs (--reupload-frac/--churn/"
                             "--max-age/--refinalize-threshold): keyed "
                             "slots need the flat session")
        if method != "odcl":
            raise ValueError(f"--shards > 1 only runs the one-shot round "
                             f"(method='odcl'), got method={method!r}")
    capacity = clients + (churn * mutation_rounds if mutated else 0)
    # the staleness window opens at the mutation loop (below), so the
    # initial federation — streamed in over clients/wave ingest waves —
    # counts as one snapshot rather than aging itself out
    if shards > 1:
        session = HierarchicalSession(capacity, shards=shards,
                                      sketch_dim=sketch_dim, seed=seed,
                                      sketch_transform=sketch_hook, mesh=mesh)
    else:
        session = AggregationSession(capacity, sketch_dim=sketch_dim,
                                     seed=seed, sketch_transform=sketch_hook,
                                     mesh=mesh)
    t0 = time.perf_counter()
    t_ingest = 0.0
    for start in range(0, clients, wave):
        w = min(wave, clients - start)
        lab_w = jax.lax.dynamic_slice_in_dim(true_labels, start, w)
        theta_w = _wave_erm(
            jax.random.fold_in(k_data, start), optima, lab_w,
            wave=w, n=samples, d=dim, task=task)
        if scen is not None:
            # step-1 attack: Byzantine clients replace their upload
            theta_w = scen.corrupt_uploads(scen_key, theta_w, lab_w,
                                           start, clients)
        ti = time.perf_counter()
        ids = range(start, start + w) if mutated else None
        session.ingest({"theta": theta_w},     # step-1 upload of the wave
                       client_ids=ids)
        t_ingest += time.perf_counter() - ti
    jax.block_until_ready(session.sketches)
    # disjoint phases: local_erm_s excludes the ingest dispatch measured
    # inside the same loop, so the columns stay comparable with the
    # pre-session BENCH_engine.json rows and sum to the loop wall clock
    t_erm = time.perf_counter() - t0 - t_ingest

    convex_family = algorithm.startswith(("convex", "clusterpath"))
    if algorithm.startswith("convex"):
        # paper E.1 exact-lambda selection: recovery bounds (17) on the
        # true clustering (the JL sketch is near-isometric, so the
        # theta-space midpoint lands inside the sketch-space interval)
        thetas = session.state().params["theta"]
        lo, hi = lambda_interval(np.asarray(thetas), np.asarray(true_labels))
        lam = 0.5 * (lo + hi) if lo < hi else lo
        algo_options = {"lam": lam, "iters": cc_iters}
    elif algorithm.startswith("clusterpath"):
        algo_options = {"iters": cc_iters}
    else:
        algo_options = {"init": init, "iters": kmeans_iters,
                        "restarts": restarts}
        if agg.name != "mean":
            # robust Lloyd: the same aggregator replaces the center
            # update inside device_kmeans — sign-flip sketch rows stop
            # dragging the centers, which is what keeps purity under
            # Byzantine fractions (post-hoc robust averaging alone
            # cannot fix an already-poisoned partition)
            algo_options["aggregator"] = agg
    if convex_family:
        algo_options.update({"edges": edges, "knn_k": knn_k})
    elif edges != "complete":
        print(f"[warn] --edges {edges} only applies to the convex family; "
              f"ignored for --algorithm {algorithm}")

    t1 = time.perf_counter()
    if method == "odcl":
        # the streaming server round: registered clustering + cluster
        # mean over the session's accumulated sketch matrix (bit-exact
        # with one_shot_aggregate(engine="device") on the same clients)
        new_state, labels, info = session.finalize(
            algorithm=algorithm, k=clusters, algo_options=algo_options,
            engine="device", aggregator=agg)
        jax.block_until_ready(new_state.params)
        comm_rounds = 1.0
        comm_bytes = sketch_round_bytes(
            clients, sketch_dim, params_bytes_per_client(new_state))
        n_clusters = info["n_clusters"]
        meta = {"engine": info["engine"], **info["meta"]}
        comm_level_bytes = info.get("comm_level_bytes")
    else:
        # iterative methods loop sketch-space rounds over the streamed-in
        # federation (C=10k+ states stay wholly on device)
        fed_method = build_federated_method(
            method, algorithm=algorithm, engine="device", k=clusters,
            algo_options=algo_options, aggregator=agg,
            sketch_dim=sketch_dim, seed=seed, local_steps=0, rounds=rounds,
            assign="sketch", init="clients")
        res = fed_method.run(jax.random.PRNGKey(seed), session.state(),
                             None, None, mesh=mesh)
        jax.block_until_ready(res.state.params)
        new_state = res.state
        labels = res.labels
        comm_rounds, comm_bytes = res.comm_rounds, res.comm_bytes
        n_clusters, meta = res.n_clusters, res.meta
        comm_level_bytes = None
    t_agg = time.perf_counter() - t1

    truth = np.asarray(true_labels)
    labels_np = np.asarray(labels)
    purity_all = cluster_agreement(labels_np, truth)
    # the score that matters under attack: agreement on the honest
    # clients only (attackers have no "right" cluster)
    purity = (cluster_agreement(labels_np[honest], truth[honest])
              if honest.any() else purity_all)
    mse = None
    if task == "ridge":
        # personalization error of the served models on honest clients:
        # per-coordinate MSE against each client's population optimum
        served = np.asarray(new_state.params["theta"])
        target = np.asarray(optima)[truth]
        mse = float(np.mean((served[honest] - target[honest]) ** 2))

    # serving exercise: deliberately OUTSIDE the phase timings (total_s
    # stays comparable with pre-serving bench rows); the latencies land
    # in the session.route.ms / session.finalize.ms histograms
    serving = None
    if method == "odcl" and (mutated or route_probes > 0
                             or finalize_repeats > 1):
        for _ in range(max(0, finalize_repeats - 1)):
            session.finalize(algorithm=algorithm, k=clusters,
                             algo_options=algo_options, engine="device",
                             aggregator=agg)
        routes_per_s = None
        if route_probes > 0:
            # fresh never-seen clients from the same population — the
            # paper's serving-time arrivals
            probe_labels = jnp.arange(route_probes, dtype=jnp.int32) % clusters
            theta_p = _wave_erm(
                jax.random.fold_in(k_data, 0x9e3779b9), optima, probe_labels,
                wave=route_probes, n=samples, d=dim, task=task)
            jax.block_until_ready(theta_p)
            session.route(params={"theta": theta_p[0]})        # warmup
            tr = time.perf_counter()
            for i in range(route_probes):
                session.route(params={"theta": theta_p[i]})
            routes_per_s = route_probes / (time.perf_counter() - tr)

        # drifted-population mutation loop: keyed re-uploads + churn-in
        # joiners against SHIFTED optima, then drifted probes to push
        # the drift gauge, then the drift-triggered warm re-finalize
        drift_after = None
        refinalize_fired = None
        route_batch_ms = None
        batched_routes_per_s = None
        if mutated:
            if max_age is not None:
                session.staleness = make_staleness_policy(
                    f"max_age={max_age}")
            k_mut = jax.random.fold_in(key, 0xd21f7)
            shifted = optima + drift_scale * jax.random.normal(
                k_mut, optima.shape, jnp.float32)
            n_re = int(round(reupload_frac * clients))
            for r in range(mutation_rounds):
                if n_re > 0:
                    sel = (np.arange(n_re) + r * n_re) % clients
                    lab_m = jnp.asarray(np.asarray(true_labels)[sel])
                    theta_m = _wave_erm(
                        jax.random.fold_in(k_mut, 100 + r), shifted, lab_m,
                        wave=n_re, n=samples, d=dim, task=task)
                    session.ingest({"theta": theta_m},
                                   client_ids=[int(i) for i in sel])
                if churn > 0:
                    lab_c = jnp.arange(churn, dtype=jnp.int32) % clusters
                    theta_c = _wave_erm(
                        jax.random.fold_in(k_mut, 200 + r), shifted, lab_c,
                        wave=churn, n=samples, d=dim, task=task)
                    session.ingest(
                        {"theta": theta_c},
                        client_ids=[("joiner", r, i) for i in range(churn)])
            # batched route() over drifted probes: one fused program per
            # request batch (the per-request loop above is the per-call
            # latency column; this is the throughput column)
            n_probe = min(max(route_probes, 256), 4096)
            lab_p = jnp.arange(n_probe, dtype=jnp.int32) % clusters
            theta_p2 = _wave_erm(
                jax.random.fold_in(k_mut, 300), shifted, lab_p,
                wave=n_probe, n=samples, d=dim, task=task)
            sk_p = session.sketch_params({"theta": theta_p2})
            jax.block_until_ready(sk_p)
            session.route(sk_p)                                # warmup
            reps = 10
            tb = time.perf_counter()
            for _ in range(reps):
                session.route(sk_p)
            batch_s = (time.perf_counter() - tb) / reps
            route_batch_ms = batch_s * 1e3
            batched_routes_per_s = n_probe / batch_s
            drift_after = session.drift
            if refinalize_threshold is not None:
                out = session.maybe_refinalize(
                    threshold=refinalize_threshold)
                refinalize_fired = out is not None
                # warm re-finalize repeats feed the refinalize histogram
                # (the warm-vs-cold p50 comparison column)
                for _ in range(max(0, finalize_repeats - 1)):
                    session.refinalize()
        snap = obs.snapshot()
        hists = snap["histograms"]
        h_route = hists.get("session.route.ms", {})
        h_fin = hists.get("session.finalize.ms", {})
        h_ref = hists.get("session.refinalize.ms", {})
        serving = {
            "route_probes": route_probes,
            "route_p50_ms": h_route.get("p50"),
            "route_p99_ms": h_route.get("p99"),
            "routes_per_s": routes_per_s,
            "finalize_repeats": finalize_repeats,
            "finalize_p50_ms": h_fin.get("p50"),
            "finalize_p99_ms": h_fin.get("p99"),
            "drift": getattr(session, "drift", None),
            # mutable-serving columns (None outside mutation mode)
            "reupload_frac": reupload_frac if mutated else None,
            "churn": churn if mutated else None,
            "max_age": max_age,
            "live_clients": session.count if mutated else None,
            "evictions": (int(snap["counters"].get("session.evictions", 0))
                          if mutated else None),
            "drift_after_mutation": drift_after,
            "refinalize_threshold": refinalize_threshold,
            "refinalize_fired": refinalize_fired,
            "refinalize_warm_p50_ms": h_ref.get("p50"),
            "route_batch_ms": route_batch_ms,
            "batched_routes_per_s": batched_routes_per_s,
        }

    # concurrent QPS serving: the RouteServer front-end over the same
    # finalized session — M closed-loop caller threads through the
    # cross-caller batcher vs the same callers on the per-request path
    qps_server = None
    if qps_callers > 0:
        if shards > 1 or method != "odcl":
            raise ValueError("--qps-callers needs the flat session's "
                             "one-shot round (shards=1, method='odcl')")
        from repro.serving.loadgen import closed_loop, warm_route_buckets
        from repro.serving.server import RouteServer
        n_probe = min(1024, clients)
        lab_q = jnp.arange(n_probe, dtype=jnp.int32) % clusters
        theta_q = _wave_erm(
            jax.random.fold_in(k_data, 0x9195), optima, lab_q,
            wave=n_probe, n=samples, d=dim, task=task)
        probes = np.asarray(session.sketch_params({"theta": theta_q}))
        warm_route_buckets(session, probes[0], 64)
        server = RouteServer(session, max_batch=64, max_wait_ms=0.5)
        server.start()
        try:
            direct = closed_loop(server, probes, callers=qps_callers,
                                 duration_s=qps_duration, batched=False)
            batched = closed_loop(server, probes, callers=qps_callers,
                                  duration_s=qps_duration, batched=True)
        finally:
            server.stop()
        qps_server = {
            "callers": int(qps_callers),
            "duration_s": float(qps_duration),
            "direct_qps": direct["qps"],
            "batched_qps": batched["qps"],
            "batched_p50_ms": batched["route_p50_ms"],
            "batched_p99_ms": batched["route_p99_ms"],
            "timeouts": batched["timeouts"] + direct["timeouts"],
            "errors": batched["n_errors"] + direct["n_errors"],
        }

    if trace_sink is not None:
        obs.remove_sink(trace_sink)
        trace_sink.close()

    return {
        "clients": clients, "clusters": clusters, "dim": dim,
        "samples": samples, "wave": wave, "task": task,
        "sketch_dim": sketch_dim, "seed": seed, "method": method,
        "algorithm": algorithm, "restarts": restarts, "shards": shards,
        "comm_level_bytes": comm_level_bytes,
        "edges": edges if convex_family else None,
        "knn_k": knn_k if (convex_family and edges.startswith("knn"))
                 else None,
        "scenario": getattr(scen, "name", None),
        "scenario_options": scenario_options or None,
        "aggregator": agg.name,
        "honest_frac": float(np.mean(honest)),
        "comm_rounds": comm_rounds, "comm_bytes": comm_bytes,
        "phases": {"local_erm_s": t_erm, "ingest_s": t_ingest,
                   "aggregate_s": t_agg,
                   "total_s": t_erm + t_ingest + t_agg},
        "n_clusters_recovered": n_clusters,
        "purity": purity,
        "purity_all": purity_all,
        "mse": mse,
        "meta": meta,
        "serving": serving,
        "qps_server": qps_server,
        "obs": obs.snapshot(),
    }


def _device_runnable_algorithms() -> list:
    """Registry names the device engine can actually run: device-capable
    algorithms, names with a registered '-device' twin, and the Lloyd
    host names the shared resolver maps onto kmeans-device inits."""
    lloyd = {"kmeans", "kmeans++", "spectral"}
    return [n for n in list_algorithms()
            if n in lloyd
            or is_device_algorithm(get_algorithm(n))
            or device_twin(get_algorithm(n)) is not None]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4096)
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--samples", type=int, default=64,
                    help="data points per client (n)")
    ap.add_argument("--wave", type=int, default=4096,
                    help="clients generated+solved+ingested per vmap wave")
    ap.add_argument("--task", choices=("ridge", "logistic"), default="ridge")
    ap.add_argument("--sketch-dim", type=int, default=64)
    ap.add_argument("--shards", type=int, default=1,
                    help="level-0 shards of the two-level hierarchical "
                         "round (1 = the flat bit-exact session; >1 "
                         "clusters per shard, then the S*k shard centers)")
    ap.add_argument("--algorithm", default="kmeans-device",
                    choices=_device_runnable_algorithms(),
                    help="admissible clustering family for the one-shot "
                         "round (device-runnable names only); convex/"
                         "clusterpath (and their -device twins) run the "
                         "K-free ODCL-CC path on device")
    ap.add_argument("--init", choices=("kmeans++", "spectral", "random"),
                    default="kmeans++")
    ap.add_argument("--kmeans-iters", type=int, default=50)
    ap.add_argument("--restarts", type=int, default=1,
                    help="multi-restart Lloyd: keep the best-inertia "
                         "clustering of this many vmapped inits")
    ap.add_argument("--cc-iters", type=int, default=300,
                    help="max AMA iterations for the convex family")
    ap.add_argument("--edges", default="complete",
                    choices=list(list_edge_sets()),
                    help="fusion graph for the convex family: 'complete' "
                         "(paper default, E=C(C-1)/2) or 'knn' (sparse "
                         "mutual-kNN, E=C*k — the C >> 4k edge set)")
    ap.add_argument("--knn-k", type=int, default=8,
                    help="neighbours per client for --edges knn")
    ap.add_argument("--scenario", default=None,
                    help="adversity scenario over the client population: "
                         f"one of {list(list_scenarios())} or a "
                         "'+'-composed spec (e.g. 'longtail+byzantine')")
    ap.add_argument("--byzantine-frac", type=float, default=None,
                    help="attacker fraction for --scenario byzantine")
    ap.add_argument("--byzantine-attack", default=None,
                    choices=("sign_flip", "noise", "spoof"),
                    help="attack mode for --scenario byzantine")
    ap.add_argument("--byzantine-scale", type=float, default=None,
                    help="noise/spoof magnitude for --scenario byzantine")
    ap.add_argument("--dp-epsilon", type=float, default=None,
                    help="privacy budget for --scenario dp")
    ap.add_argument("--dp-delta", type=float, default=None,
                    help="delta for --scenario dp")
    ap.add_argument("--dp-clip", type=float, default=None,
                    help="sketch L2 clip (sensitivity) for --scenario dp")
    ap.add_argument("--drift-frac", type=float, default=None,
                    help="migrating-client fraction for --scenario drift")
    ap.add_argument("--zipf-a", type=float, default=None,
                    help="Zipf exponent for --scenario longtail")
    ap.add_argument("--aggregator", default="mean",
                    choices=list(list_aggregators()),
                    help="per-cluster step-3 reduction (robust variants "
                         "also drive the device Lloyd center update)")
    ap.add_argument("--trim-beta", type=float, default=0.1,
                    help="trim fraction for --aggregator trimmed_mean")
    ap.add_argument("--method", default="odcl",
                    choices=list(list_federated_methods()),
                    help="registered federated method to run over the "
                         "streamed-in federation")
    ap.add_argument("--rounds", type=int, default=5,
                    help="communication rounds (ifca / fedavg)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write every obs span/event of the run as JSONL")
    ap.add_argument("--route-probes", type=int, default=0,
                    help="route this many fresh probe clients after the "
                         "round (serving latency histograms)")
    ap.add_argument("--finalize-repeats", type=int, default=1,
                    help="total finalize runs (warm re-finalizes feed the "
                         "finalize latency histogram)")
    ap.add_argument("--reupload-frac", type=float, default=0.0,
                    help="fraction of clients re-uploading drifted models "
                         "each mutation round (keyed slot replacement)")
    ap.add_argument("--churn", type=int, default=0,
                    help="fresh clients joining each mutation round")
    ap.add_argument("--max-age", type=int, default=None,
                    help="sliding-window staleness: evict slots older than "
                         "this many waves")
    ap.add_argument("--refinalize-threshold", type=float, default=None,
                    help="drift ratio above which maybe_refinalize() warm-"
                         "starts a re-finalize after the mutation rounds")
    ap.add_argument("--qps-callers", type=int, default=0,
                    help="run the RouteServer QPS probe: this many "
                         "closed-loop caller threads, per-request vs "
                         "cross-caller batched (0 = off)")
    ap.add_argument("--qps-duration", type=float, default=2.0,
                    help="seconds per QPS measurement loop")
    ap.add_argument("--out", default=None, help="write the summary JSON here")
    args = ap.parse_args(argv)

    # flat option superset -> per-scenario dataclass fields, filtered by
    # build_scenario exactly like build_federated_method filters methods
    scenario_options = {k: v for k, v in {
        "frac": args.byzantine_frac, "attack": args.byzantine_attack,
        "scale": args.byzantine_scale, "epsilon": args.dp_epsilon,
        "delta": args.dp_delta, "clip": args.dp_clip,
        "drift_frac": args.drift_frac, "zipf_a": args.zipf_a,
    }.items() if v is not None}

    summary = simulate(
        clients=args.clients, clusters=args.clusters, dim=args.dim,
        samples=args.samples, wave=args.wave, task=args.task,
        sketch_dim=args.sketch_dim, shards=args.shards,
        algorithm=args.algorithm,
        init=args.init, kmeans_iters=args.kmeans_iters,
        restarts=args.restarts, cc_iters=args.cc_iters,
        edges=args.edges, knn_k=args.knn_k,
        scenario=args.scenario, scenario_options=scenario_options or None,
        aggregator=args.aggregator, trim_beta=args.trim_beta,
        seed=args.seed, method=args.method, rounds=args.rounds,
        trace=args.trace, route_probes=args.route_probes,
        finalize_repeats=args.finalize_repeats,
        reupload_frac=args.reupload_frac, churn=args.churn,
        max_age=args.max_age,
        refinalize_threshold=args.refinalize_threshold,
        qps_callers=args.qps_callers, qps_duration=args.qps_duration)
    ph = summary["phases"]
    print(f"[simulate] C={summary['clients']} K={summary['clusters']} "
          f"task={summary['task']} wave={summary['wave']} "
          f"algo={summary['algorithm']} "
          f"shards={summary['shards']} "
          f"edges={summary['edges'] or '-'} "
          f"scenario={summary['scenario'] or '-'} "
          f"agg={summary['aggregator']} "
          f"method={summary['method']} rounds={summary['comm_rounds']:g}")
    print(f"[simulate] local ERMs {ph['local_erm_s']:.2f}s  "
          f"ingest {ph['ingest_s']:.2f}s  "
          f"server rounds {ph['aggregate_s']:.2f}s "
          f"({summary['comm_bytes'] / 1e6:.2f}MB moved)")
    clb = summary["comm_level_bytes"]
    if clb is not None:
        print(f"[simulate] hierarchy: level0 {clb['level0'] / 1e6:.2f}MB "
              f"(client uploads)  level1 {clb['level1'] / 1e6:.4f}MB "
              f"(shard centers)")
    mse = summary["mse"]
    print(f"[simulate] recovered K'={summary['n_clusters_recovered']} "
          f"purity={summary['purity']:.3f} "
          f"(all={summary['purity_all']:.3f}, "
          f"honest={summary['honest_frac']:.2f}) "
          f"mse={mse if mse is None else format(mse, '.3g')} "
          f"inertia={summary['meta'].get('inertia', float('nan')):.3g}")
    sv = summary["serving"]
    if sv is not None:
        rp50 = sv["route_p50_ms"]
        print(f"[simulate] serving: route p50="
              f"{'-' if rp50 is None else format(rp50, '.3f')}ms "
              f"p99={'-' if sv['route_p99_ms'] is None else format(sv['route_p99_ms'], '.3f')}ms "
              f"({'-' if sv['routes_per_s'] is None else format(sv['routes_per_s'], '.0f')}/s)  "
              f"finalize p50={'-' if sv['finalize_p50_ms'] is None else format(sv['finalize_p50_ms'], '.1f')}ms  "
              f"drift={'-' if sv['drift'] is None else format(sv['drift'], '.3f')}")
        if sv.get("live_clients") is not None:
            rw = sv["refinalize_warm_p50_ms"]
            bb = sv["route_batch_ms"]
            print(f"[simulate] mutation: live={sv['live_clients']} "
                  f"evictions={sv['evictions']} "
                  f"drift(after)={'-' if sv['drift_after_mutation'] is None else format(sv['drift_after_mutation'], '.3f')} "
                  f"refinalize={'fired' if sv['refinalize_fired'] else ('-' if sv['refinalize_fired'] is None else 'held')} "
                  f"warm p50={'-' if rw is None else format(rw, '.1f')}ms  "
                  f"batched route={'-' if bb is None else format(bb, '.2f')}ms "
                  f"({'-' if sv['batched_routes_per_s'] is None else format(sv['batched_routes_per_s'], '.0f')}/s)")
    qs = summary["qps_server"]
    if qs is not None:
        print(f"[simulate] qps: {qs['callers']} callers  "
              f"direct {qs['direct_qps']:.0f}/s  "
              f"batched {qs['batched_qps']:.0f}/s "
              f"({qs['batched_qps'] / max(qs['direct_qps'], 1e-9):.2f}x)  "
              f"p50={qs['batched_p50_ms']:.2f}ms "
              f"p99={qs['batched_p99_ms']:.2f}ms")
    if args.trace:
        print(f"[simulate] trace -> {args.trace}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"[simulate] wrote {args.out}")
    return summary


if __name__ == "__main__":
    main()
