"""Large-C client simulation driving the device aggregation engine.

Where ``launch/train.py`` runs the paper's protocol on a handful of
deep-model clients (heavy step 1, C ~ 10), this driver targets the
opposite regime the one-shot guarantee is actually about: C = 10k-100k
*shallow* clients (the paper's ridge / logistic settings, Section 5 /
Appendix E.2), IFCA- and k-FED-scale federations.

Clients are synthesized and solved in batched vmap **waves** — each
wave draws ``wave`` clients' covariates, responses, and closed-form /
Newton local ERMs in one jitted call — so peak memory is bounded by the
wave, not by C, and the (C, d) stack of local models never leaves the
device.  The one-shot round then runs through
``engine.one_shot_aggregate_device``: sketch -> kmeans-device ->
per-cluster mean, one jitted program.  The two drivers compose: this is
phase 1+2 for wide federations, ``train.py --engine device`` is the
same phase 2 behind deep-model phase 1.

  PYTHONPATH=src python -m repro.launch.simulate --clients 4096 --clusters 8
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.clustering import (
    device_twin,
    get_algorithm,
    is_device_algorithm,
    lambda_interval,
    list_algorithms,
)
from repro.core.erm import batched_ridge_erm, logistic_erm
from repro.core.federated import FederatedState
from repro.core.federated_methods import (
    build_federated_method,
    cluster_agreement,
    list_federated_methods,
)
from repro.optim import adamw_init


def staggered_optima(key, K: int, d: int):
    """Well-separated cluster optima in the style of Appendix E.1:
    cluster k draws coordinate magnitudes from U([k + 1, k + 2]) with an
    independent random sign per coordinate.  Staggered magnitudes keep
    min pairwise separation >= sqrt(d); the random signs scatter the
    optima across orthants (collinear centers are a Lloyd's-algorithm
    pathology, not the paper's setting)."""
    ks, ku = jax.random.split(key)
    signs = jax.random.rademacher(ks, (K, d), jnp.float32)
    base = jnp.arange(1.0, K + 1.0, dtype=jnp.float32)[:, None]
    return signs * (base + jax.random.uniform(ku, (K, d)))


@functools.partial(jax.jit, static_argnames=("wave", "n", "d", "task",
                                             "newton_iters"))
def _wave_erm(key, optima, labels, *, wave: int, n: int, d: int,
              task: str = "ridge", noise: float = 1.0, reg: float = 1e-6,
              newton_iters: int = 8):
    """One vmap wave of step 1: draw ``wave`` clients' data from their
    cluster's population model and solve every local ERM. Returns the
    (wave, d[+1]) stack of local models, device-resident."""
    kx, ke = jax.random.split(key)
    x = jax.random.normal(kx, (wave, n, d), jnp.float32)
    z = jnp.einsum("wnd,wd->wn", x, optima[labels])
    if task == "ridge":
        y = z + noise * jax.random.normal(ke, (wave, n), jnp.float32)
        return batched_ridge_erm(x, y, reg)                    # (wave, d)
    if task == "logistic":
        y = 2.0 * (jax.random.uniform(ke, (wave, n)) <
                   jax.nn.sigmoid(z)).astype(jnp.float32) - 1.0
        return jax.vmap(
            lambda xx, yy: logistic_erm(xx, yy, reg, newton_iters)
        )(x, y)                                                # (wave, d+1)
    raise ValueError(f"unknown task {task!r}")  # pragma: no cover - static


def simulate(*, clients: int, clusters: int, dim: int = 16, samples: int = 64,
             wave: int = 4096, task: str = "ridge", sketch_dim: int = 64,
             algorithm: str = "kmeans-device", init: str = "kmeans++",
             kmeans_iters: int = 50, restarts: int = 1, cc_iters: int = 300,
             seed: int = 0, method: str = "odcl", rounds: int = 5,
             mesh=None) -> dict:
    """Generate a K-cluster federation of ``clients`` users, solve the
    local ERMs in waves, run any registered federated method over the
    resulting ``FederatedState`` (default: ODCL's device one-shot
    round), and return a summary dict (per-phase wall clock, recovered
    clustering quality).  Iterative methods run with zero per-round
    local steps — the shallow clients are already at their local ERMs —
    so IFCA here is pure sketch-assign/re-average rounds.

    ``algorithm`` selects the admissible clustering family: the Lloyd
    device loop by default (``init``/``kmeans_iters``/``restarts``
    apply), or the convex family — ``convex``/``convex-device`` runs
    the paper's E.1 exact-lambda ODCL-CC (the recovery bounds (17) on
    the true clustering are a host-side driver setup pass over the
    local models; the aggregation round itself stays one jitted device
    program), ``clusterpath``/``clusterpath-device`` the K-free ladder.
    """
    key = jax.random.PRNGKey(seed)
    k_opt, k_data = jax.random.split(key)
    optima = staggered_optima(k_opt, clusters, dim)
    true_labels = jnp.arange(clients, dtype=jnp.int32) % clusters

    t0 = time.perf_counter()
    thetas = []
    for start in range(0, clients, wave):
        w = min(wave, clients - start)
        thetas.append(_wave_erm(
            jax.random.fold_in(k_data, start), optima,
            jax.lax.dynamic_slice_in_dim(true_labels, start, w),
            wave=w, n=samples, d=dim, task=task))
    thetas = jnp.concatenate(thetas, axis=0)       # (C, d[+1]) on device
    jax.block_until_ready(thetas)
    t_erm = time.perf_counter() - t0

    params = {"theta": thetas}
    state = FederatedState(params=params,
                           opt_state=jax.vmap(adamw_init)(params),
                           n_clients=clients)

    if algorithm.startswith("convex"):
        # paper E.1 exact-lambda selection: recovery bounds (17) on the
        # true clustering (the JL sketch is near-isometric, so the
        # theta-space midpoint lands inside the sketch-space interval)
        lo, hi = lambda_interval(np.asarray(thetas), np.asarray(true_labels))
        lam = 0.5 * (lo + hi) if lo < hi else lo
        algo_options = {"lam": lam, "iters": cc_iters}
    elif algorithm.startswith("clusterpath"):
        algo_options = {"iters": cc_iters}
    else:
        algo_options = {"init": init, "iters": kmeans_iters,
                        "restarts": restarts}

    # C=10k+ states stay wholly on device: ODCL runs the jitted engine
    # round; iterative methods (ifca/fedavg) loop sketch-space rounds
    fed_method = build_federated_method(
        method, algorithm=algorithm, engine="device", k=clusters,
        algo_options=algo_options,
        sketch_dim=sketch_dim, seed=seed, local_steps=0, rounds=rounds,
        assign="sketch", init="clients")

    t1 = time.perf_counter()
    res = fed_method.run(jax.random.PRNGKey(seed), state, None, None,
                         mesh=mesh)
    jax.block_until_ready(res.state.params)
    t_agg = time.perf_counter() - t1

    return {
        "clients": clients, "clusters": clusters, "dim": dim,
        "samples": samples, "wave": wave, "task": task,
        "sketch_dim": sketch_dim, "seed": seed, "method": method,
        "algorithm": algorithm, "restarts": restarts,
        "comm_rounds": res.comm_rounds, "comm_bytes": res.comm_bytes,
        "phases": {"local_erm_s": t_erm, "aggregate_s": t_agg,
                   "total_s": t_erm + t_agg},
        "n_clusters_recovered": res.n_clusters,
        "purity": cluster_agreement(res.labels, np.asarray(true_labels)),
        "meta": res.meta,
    }


def _device_runnable_algorithms() -> list:
    """Registry names the device engine can actually run: device-capable
    algorithms, names with a registered '-device' twin, and the Lloyd
    host names ODCLFederated maps onto kmeans-device inits."""
    lloyd = {"kmeans", "kmeans++", "spectral"}
    return [n for n in list_algorithms()
            if n in lloyd
            or is_device_algorithm(get_algorithm(n))
            or device_twin(get_algorithm(n)) is not None]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=4096)
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--samples", type=int, default=64,
                    help="data points per client (n)")
    ap.add_argument("--wave", type=int, default=4096,
                    help="clients generated+solved per vmap wave")
    ap.add_argument("--task", choices=("ridge", "logistic"), default="ridge")
    ap.add_argument("--sketch-dim", type=int, default=64)
    ap.add_argument("--algorithm", default="kmeans-device",
                    choices=_device_runnable_algorithms(),
                    help="admissible clustering family for the one-shot "
                         "round (device-runnable names only); convex/"
                         "clusterpath (and their -device twins) run the "
                         "K-free ODCL-CC path on device")
    ap.add_argument("--init", choices=("kmeans++", "spectral", "random"),
                    default="kmeans++")
    ap.add_argument("--kmeans-iters", type=int, default=50)
    ap.add_argument("--restarts", type=int, default=1,
                    help="multi-restart Lloyd: keep the best-inertia "
                         "clustering of this many vmapped inits")
    ap.add_argument("--cc-iters", type=int, default=300,
                    help="max AMA iterations for the convex family")
    ap.add_argument("--method", default="odcl",
                    choices=list(list_federated_methods()),
                    help="registered federated method to run over the "
                         "wave-batched federation")
    ap.add_argument("--rounds", type=int, default=5,
                    help="communication rounds (ifca / fedavg)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write the summary JSON here")
    args = ap.parse_args(argv)

    summary = simulate(
        clients=args.clients, clusters=args.clusters, dim=args.dim,
        samples=args.samples, wave=args.wave, task=args.task,
        sketch_dim=args.sketch_dim, algorithm=args.algorithm,
        init=args.init, kmeans_iters=args.kmeans_iters,
        restarts=args.restarts, cc_iters=args.cc_iters, seed=args.seed,
        method=args.method, rounds=args.rounds)
    ph = summary["phases"]
    print(f"[simulate] C={summary['clients']} K={summary['clusters']} "
          f"task={summary['task']} wave={summary['wave']} "
          f"algo={summary['algorithm']} "
          f"method={summary['method']} rounds={summary['comm_rounds']:g}")
    print(f"[simulate] local ERMs {ph['local_erm_s']:.2f}s  "
          f"server rounds {ph['aggregate_s']:.2f}s "
          f"({summary['comm_bytes'] / 1e6:.2f}MB moved)")
    print(f"[simulate] recovered K'={summary['n_clusters_recovered']} "
          f"purity={summary['purity']:.3f} "
          f"inertia={summary['meta'].get('inertia', float('nan')):.3g}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"[simulate] wrote {args.out}")
    return summary


if __name__ == "__main__":
    main()
