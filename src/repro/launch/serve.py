"""Batched serving driver: prefill + autoregressive generation with the
KV-cache/recurrent-state serving path (per-cluster personalized models
from a federated checkpoint, or a fresh init).

Two ways to pick the served model from a stacked federated checkpoint:

  * ``--client i`` — the raw per-client slice (legacy behaviour);
  * ``--route-by-sketch`` — the paper's own serving rule: rebuild the
    cluster structure from the checkpoint through a streaming
    ``AggregationSession`` (ingest the stacked parameters, finalize the
    registered clustering over their sketches), route the requested
    client's sketch to its nearest recovered cluster, and serve that
    cluster's *averaged* model — step 4 of Algorithm 1 at serving time,
    which also handles clients the training run never saw.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16

  PYTHONPATH=src python -m repro.launch.serve --reduced --ckpt-dir ckpts \
      --route-by-sketch --clusters 2 --client 3

``--server`` upgrades --route-by-sketch into the concurrent serving
frontend: instead of one route for one client, the rebuilt session goes
behind a ``RouteServer`` and every checkpointed client's sketch is
routed by concurrent caller threads through the cross-caller batcher:

  PYTHONPATH=src python -m repro.launch.serve --reduced --ckpt-dir ckpts \
      --route-by-sketch --server --server-callers 4 --clusters 2
"""
from __future__ import annotations

import argparse
import time

from repro import runtime

runtime.apply_env_presets()  # REPRO_PLATFORM etc. — before jax loads

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import obs  # noqa: E402
from repro.checkpoint import latest_step, restore_checkpoint  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.models.transformer import (  # noqa: E402
    abstract_params,
    decode_step,
    init_decode_cache,
    prefill_with_cache,
)


def generate(params, cfg, prompts, gen: int, *, temperature: float = 0.0,
             seed: int = 0):
    """prompts (b, s) int32 -> (b, s+gen) tokens + timing stats."""
    b, s = prompts.shape
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, t: prefill_with_cache(p, cfg, {"tokens": t},
                                        capacity=s + gen))(params, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    key = jax.random.PRNGKey(seed)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(gen - 1):
        lg, cache = step(params, cache, tok)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, lg[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    tokens = jnp.concatenate([prompts] + out, axis=1)
    return tokens, {"prefill_s": t_prefill, "decode_s": t_decode,
                    "tok_per_s": b * (gen - 1) / max(t_decode, 1e-9)}


def route_from_checkpoint(stacked, cfg, client: int, *, algorithm: str,
                          clusters: int, sketch_dim: int, seed: int = 0):
    """Cluster a stacked federated checkpoint and pick the served model
    by sketch routing.  Returns (cluster model pytree, cluster id, info).
    """
    from repro.core.engine.session import AggregationSession

    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    session = AggregationSession(n, sketch_dim=sketch_dim, cfg=cfg,
                                 seed=seed)
    session.ingest(stacked)
    _, labels, info = session.finalize(algorithm=algorithm, k=clusters,
                                       engine="device")
    client_params = jax.tree_util.tree_map(lambda l: l[client], stacked)
    cid = session.route(params=client_params)
    if not 0 <= cid < session.n_clusters:
        # belt over cluster_model's own IndexError: a routed id outside
        # the recovered range means the session state is corrupt, and a
        # serving driver should say so rather than wrap around
        raise SystemExit(f"routed cluster id {cid} out of range for "
                         f"{session.n_clusters} recovered clusters")
    return session.cluster_model(cid), cid, {"labels": labels, **info}


def serve_routes(stacked, cfg, *, algorithm: str, clusters: int,
                 sketch_dim: int, callers: int, duration_s: float,
                 seed: int = 0) -> dict:
    """``--server``: rebuild the cluster structure from a stacked
    checkpoint exactly like ``route_from_checkpoint``, then put the
    session behind a ``RouteServer`` and route every checkpointed
    client's sketch from concurrent caller threads through the
    cross-caller batcher.  Returns a small report dict."""
    from repro.core.engine.session import AggregationSession
    from repro.serving.loadgen import closed_loop, warm_route_buckets
    from repro.serving.server import RouteServer

    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    session = AggregationSession(n, sketch_dim=sketch_dim, cfg=cfg,
                                 seed=seed)
    session.ingest(stacked)
    session.finalize(algorithm=algorithm, k=clusters, engine="device")
    probes = np.asarray(session.sketch_params(stacked))
    max_batch = min(32, max(1, n))
    warm_route_buckets(session, probes[0], max_batch)
    with RouteServer(session, max_batch=max_batch, max_wait_ms=0.5) as srv:
        # every checkpointed client once, through the batched path —
        # the routed ids are the serving-time cluster assignment
        routed = [srv.route(p, timeout=30.0) for p in probes]
        stats = closed_loop(srv, probes, callers=callers,
                            duration_s=duration_s, batched=True)
    counts = np.bincount(routed, minlength=session.n_clusters)
    return {
        "clients": n,
        "n_clusters": session.n_clusters,
        "routed": routed,
        "cluster_sizes": counts.tolist(),
        "callers": callers,
        **stats,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--client", type=int, default=0,
                    help="which client to serve from a stacked federated "
                         "checkpoint (its raw slice, or — with "
                         "--route-by-sketch — its routed cluster model)")
    ap.add_argument("--route-by-sketch", action="store_true",
                    help="rebuild the cluster structure from the stacked "
                         "checkpoint (AggregationSession) and serve the "
                         "cluster model the client's sketch routes to")
    ap.add_argument("--clusters", type=int, default=2,
                    help="k for the routing clustering (--route-by-sketch)")
    ap.add_argument("--route-algorithm", default="kmeans-device",
                    help="registered clustering for --route-by-sketch")
    ap.add_argument("--route-sketch-dim", type=int, default=64)
    ap.add_argument("--server", action="store_true",
                    help="concurrent serving mode: rebuild the cluster "
                         "structure (like --route-by-sketch) and route "
                         "ALL clients through a RouteServer with "
                         "concurrent caller threads; without --ckpt-dir "
                         "a synthetic stacked checkpoint is generated")
    ap.add_argument("--server-callers", type=int, default=4,
                    help="closed-loop caller threads for --server")
    ap.add_argument("--server-duration", type=float, default=2.0,
                    help="seconds of closed-loop load for --server")
    ap.add_argument("--server-clients", type=int, default=16,
                    help="synthetic stacked-checkpoint size when --server "
                         "runs without --ckpt-dir")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write every obs span/event (routing, finalize) "
                         "of this serve run as JSONL")
    args = ap.parse_args(argv)
    if args.trace:
        obs.add_sink(obs.JsonlSink(args.trace))

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(max_vocab=256)
    if cfg.is_encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)

    if args.server:
        if args.ckpt_dir:
            step = latest_step(args.ckpt_dir)
            if step is None:
                raise SystemExit(f"no checkpoints found in {args.ckpt_dir}")
            stacked = restore_checkpoint(args.ckpt_dir, step, params)
            leading = jax.tree_util.tree_leaves(stacked)[0].shape
            if leading == jax.tree_util.tree_leaves(params)[0].shape:
                raise SystemExit("--server needs a stacked federated "
                                 "checkpoint (leading client axis); this "
                                 "one is a single model")
            stacked = jax.tree_util.tree_map(
                lambda l, r: jnp.asarray(l, r.dtype), stacked, params)
            src = f"checkpoint step {step} ({leading[0]} clients)"
        else:
            # no checkpoint: a synthetic stacked federated checkpoint —
            # per-cluster offsets + small per-client noise, so routing
            # has real structure to recover
            n, k = args.server_clients, args.clusters
            group = jnp.arange(n) % k
            leaves, treedef = jax.tree_util.tree_flatten(params)
            stacked_leaves = []
            for i, leaf in enumerate(leaves):
                k1, k2 = jax.random.split(jax.random.fold_in(key, i + 1))
                offs = jax.random.normal(k1, (k,) + leaf.shape, leaf.dtype)
                noise = 0.05 * jax.random.normal(
                    k2, (n,) + leaf.shape, leaf.dtype)
                stacked_leaves.append(leaf[None] + offs[group] + noise)
            stacked = jax.tree_util.tree_unflatten(treedef, stacked_leaves)
            src = f"{n} synthetic clients"
        report = serve_routes(
            stacked, cfg, algorithm=args.route_algorithm,
            clusters=args.clusters, sketch_dim=args.route_sketch_dim,
            callers=args.server_callers, duration_s=args.server_duration,
            seed=args.seed)
        print(f"[server] {src}: K'={report['n_clusters']} "
              f"cluster sizes {report['cluster_sizes']}")
        print(f"[server] {report['callers']} callers  "
              f"{report['qps']:.0f} routes/s  "
              f"p50={report['route_p50_ms']:.2f}ms "
              f"p99={report['route_p99_ms']:.2f}ms  "
              f"errors={report['n_errors']} timeouts={report['timeouts']}")
        return report

    if args.ckpt_dir:
        step = latest_step(args.ckpt_dir)
        if step is None:
            raise SystemExit(f"no checkpoints found in {args.ckpt_dir}")
        stacked = restore_checkpoint(args.ckpt_dir, step, params)
        leading = jax.tree_util.tree_leaves(stacked)[0].shape
        is_stacked = leading != jax.tree_util.tree_leaves(params)[0].shape
        if args.route_by_sketch:
            if not is_stacked:
                raise SystemExit("--route-by-sketch needs a stacked "
                                 "federated checkpoint (leading client "
                                 "axis); this one is a single model")
            n = leading[0]
            if not 0 <= args.client < n:
                raise SystemExit(f"client index {args.client} out of range "
                                 f"for {n} checkpointed clients")
            stacked = jax.tree_util.tree_map(
                lambda l, r: jnp.asarray(l, r.dtype), stacked, params)
            params, cid, info = route_from_checkpoint(
                stacked, cfg, args.client, algorithm=args.route_algorithm,
                clusters=args.clusters, sketch_dim=args.route_sketch_dim,
                seed=args.seed)
            print(f"[ckpt] restored step {step}; client {args.client} "
                  f"routed to cluster {cid}/{info['n_clusters']} "
                  f"(labels {info['labels'].tolist()})")
            h = obs.snapshot()["histograms"].get("session.route.ms")
            if h and h.get("count"):
                print(f"[route] {h['count']} request(s), "
                      f"p50={h['p50']:.3f}ms max={h['max']:.3f}ms")
        else:
            def select(restored, ref):
                # federated checkpoints stack params along a leading
                # client axis; single-model checkpoints restore as-is
                if restored.shape == ref.shape:
                    return jnp.asarray(restored, ref.dtype)
                if restored.shape[1:] != ref.shape or \
                        not 0 <= args.client < restored.shape[0]:
                    raise SystemExit(
                        f"checkpoint leaf {restored.shape} does not match "
                        f"model {ref.shape} (client index {args.client})")
                return jnp.asarray(restored[args.client], ref.dtype)

            params = jax.tree_util.tree_map(select, stacked, params)
            print(f"[ckpt] restored step {step} (client {args.client})")

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    tokens, stats = generate(params, cfg, prompts, args.gen,
                             temperature=args.temperature, seed=args.seed)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill {stats['prefill_s']*1e3:.1f}ms  "
          f"decode {stats['decode_s']*1e3:.1f}ms  "
          f"throughput {stats['tok_per_s']:.1f} tok/s")
    print("sample row:", np.asarray(tokens[0, -args.gen:]).tolist())
    return tokens


if __name__ == "__main__":
    main()
