from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.sgd import sgd_init, sgd_update
from repro.optim.schedule import cosine_schedule, linear_warmup

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "sgd_init",
    "sgd_update",
    "cosine_schedule",
    "linear_warmup",
]
