"""AdamW (decoupled weight decay) as pure functions over pytrees.

Moments are kept in fp32 regardless of parameter dtype (mixed-precision
training convention); the update casts back to the parameter dtype.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """One AdamW step. Returns (new_params, new_state)."""
    step = state["step"] + 1
    if cfg.grad_clip is not None:
        gn = _global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - cfg.lr * lr_scale * (delta + decay)
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}
