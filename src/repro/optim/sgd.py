"""(Projected) SGD with optional momentum — the Appendix-D local solver."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return {"step": jnp.zeros((), jnp.int32)}
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"vel": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def sgd_update(params, grads, state, *, lr: float, momentum: float = 0.0,
               radius: float | None = None):
    """One SGD step; optional projection onto ||theta|| <= radius
    (Assumption 2's compact parameter space)."""
    step = state["step"] + 1
    if momentum > 0.0:
        vel = jax.tree_util.tree_map(
            lambda v, g: momentum * v + g.astype(jnp.float32),
            state["vel"], grads)
        upd_tree = vel
        new_state = {"vel": vel, "step": step}
    else:
        upd_tree = grads
        new_state = {"step": step}
    new_p = jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) - lr * u.astype(jnp.float32)).astype(p.dtype),
        params, upd_tree)
    if radius is not None:
        norm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                            for l in jax.tree_util.tree_leaves(new_p)))
        scale = jnp.minimum(1.0, radius / jnp.maximum(norm, 1e-30))
        new_p = jax.tree_util.tree_map(lambda p: (p * scale).astype(p.dtype), new_p)
    return new_p, new_state
