"""PartitionSpec rule tables for every architecture family.

Strategy (single pod, mesh ("data", "model")):

  * tensor parallelism over ``model``: attention heads / FFN hidden /
    expert (or expert-hidden) dims;
  * FSDP over ``data`` (+ ``pod`` when present): the *other* large dim of
    each weight is sharded over the data axes, so Grok-314B's
    params+optimizer fit per chip; XLA inserts the per-layer
    all-gathers (FSDP semantics) automatically;
  * batch over the data axes (and pod).

For the ODCL one-shot mode (``federated.py``) parameters instead carry a
leading client axis sharded over ``data`` — clients must NOT share
parameters — and FSDP moves to the remaining axes.

Rules are *name-based*: each parameter path is matched to a (tp_dim,
fsdp_dim) pair.  This keeps one table for all ten architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Axis names of the mesh roles (None disables that role)."""
    data_axes: tuple = ("data",)        # batch / FSDP axes ("pod","data") multi-pod
    model_axis: Optional[str] = "model"
    fsdp: bool = True                   # shard params over data axes too
    client_axis: Optional[str] = None   # ODCL mode: leading client dim

    @property
    def fsdp_axes(self):
        return self.data_axes if self.fsdp else ()


# (tp_dim, fsdp_dim) per parameter leaf, counted from the END of the
# shape (negative), ignoring any leading layer-stack axis. None = skip.
_RULES: list[tuple[str, tuple[Optional[int], Optional[int]]]] = [
    # attention projections: shard head dim over model, d_model over data
    ("attn/wq", (-1, -2)),
    ("attn/wk", (-1, -2)),
    ("attn/wv", (-1, -2)),
    ("attn/wo", (-2, -1)),
    ("attn/bq", (-1, None)),
    ("attn/bk", (-1, None)),
    ("attn/bv", (-1, None)),
    # dense MLP: hidden over model
    ("mlp/w_in", (-1, -2)),
    ("mlp/w_out", (-2, -1)),
    # MoE: router replicated-ish; experts sharded (see param_specs)
    ("moe/router", (-1, None)),
    ("moe/shared/w_in", (-1, -2)),
    ("moe/shared/w_out", (-2, -1)),
    # xLSTM
    ("m/w_up", (-1, -2)),
    ("m/w_q", (-1, -2)),
    ("m/w_k", (-1, -2)),
    ("m/w_v", (-1, -2)),
    ("m/w_if", (None, -2)),
    ("m/w_down", (-2, -1)),
    ("s/w_zifo", (-1, -2)),
    ("s/w_out", (-2, -1)),
    # hybrid SSM branch: inner dim over model
    ("ssm/w_in", (-1, -2)),
    ("ssm/w_xdb", (None, -2)),
    ("ssm/w_dt", (-1, None)),
    ("ssm/a_log", (-2, None)),
    ("ssm/d_skip", (-1, None)),
    ("ssm/w_out", (-2, -1)),
    ("ssm/conv_w", (-1, None)),
    # embeddings / head: vocab over model, d_model over data
    ("embed", (-2, -1)),
    ("lm_head", (-1, -2)),
    ("frontend_proj", (-1, -2)),
    ("patch_proj", (-1, -2)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
    return "/".join(parts)


def _divides(n: int, mesh_axis_size: int) -> bool:
    return mesh_axis_size > 0 and n % mesh_axis_size == 0


def _leaf_spec(path_s, leaf, cfg, rules: ShardingRules, mesh_sizes,
               stacked: bool):
    ndim = leaf.ndim
    entries = [None] * ndim
    lead = 0
    if rules.client_axis is not None:
        entries[0] = rules.client_axis
        lead += 1
    if stacked:
        lead += 1  # layer axis unsharded

    tp_dim = fsdp_dim = None
    matched = False
    for pat, (tp, fs) in _RULES:
        if path_s.endswith(pat):
            tp_dim, fsdp_dim = tp, fs
            matched = True
            break

    # MoE expert tensors: special-case expert sharding
    if "moe/w_in" in path_s or "moe/w_out" in path_s:
        # shape (..., E, D, F) or (..., E, F, D)
        e_size = leaf.shape[-3]
        m_ax = rules.model_axis
        msize = mesh_sizes.get(m_ax, 1) if m_ax else 1
        if _divides(e_size, msize):
            entries[-3] = m_ax                         # expert parallel
            fsdp_dim = -2 if path_s.endswith("w_in") else -1
        else:
            # hidden-dim tensor parallel inside each expert
            tp_target = -1 if path_s.endswith("w_in") else -2
            entries[tp_target] = m_ax
            fsdp_dim = -2 if path_s.endswith("w_in") else -1
        entries = _apply_fsdp(entries, leaf, fsdp_dim, rules, mesh_sizes)
        return P(*entries)

    if not matched:
        return P(*entries)

    m_ax = rules.model_axis
    if tp_dim is not None and -tp_dim > ndim:
        tp_dim = None      # pattern matched a lower-rank leaf (e.g. bias)
    if fsdp_dim is not None and -fsdp_dim > ndim:
        fsdp_dim = None
    if tp_dim is not None and m_ax is not None:
        msize = mesh_sizes.get(m_ax, 1)
        if _divides(leaf.shape[tp_dim], msize) and entries[tp_dim] is None:
            entries[tp_dim] = m_ax
    alt = tp_dim if (tp_dim is not None and entries[tp_dim] is None) else None
    entries = _apply_fsdp(entries, leaf, fsdp_dim, rules, mesh_sizes,
                          alt_dim=alt)
    return P(*entries)


def _apply_fsdp(entries, leaf, fsdp_dim, rules: ShardingRules, mesh_sizes,
                alt_dim=None):
    """Shard one dim over the FSDP axes; falls back to ``alt_dim`` and to
    axis subsets when the preferred dim is not divisible (e.g. hymba's
    d_model=1600 does not divide 256 but its d_ff=5504 divides 16)."""
    if fsdp_dim is None or not rules.fsdp_axes:
        return entries
    full = tuple(rules.fsdp_axes)
    candidates = []
    for ax in (full,) + tuple((a,) for a in full if len(full) > 1):
        size = 1
        for a in ax:
            size *= mesh_sizes.get(a, 1)
        for dim in (fsdp_dim, alt_dim):
            if dim is None:
                continue
            candidates.append((dim, ax, size))
    for dim, ax, size in candidates:
        if size <= 1:
            continue
        if entries[dim] is None and leaf.shape[dim] % size == 0:
            entries[dim] = ax if len(ax) > 1 else ax[0]
            return entries
    return entries


def param_specs(cfg: ModelConfig, params_shape, rules: ShardingRules, mesh):
    """PartitionSpec pytree mirroring the parameter pytree.

    ``params_shape`` — pytree of ShapeDtypeStruct (from abstract_params)
    WITHOUT the client axis; if rules.client_axis is set the specs assume
    a prepended client dim on every leaf.
    """
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(path, leaf):
        s = _path_str(path)
        stacked = s.startswith("layers")
        return _leaf_spec(s, leaf, cfg, rules, mesh_sizes, stacked)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_spec(cfg: ModelConfig, rules: ShardingRules, mesh=None):
    """Input batch sharding: leading (client?, batch) over the data axes.

    The batch dim is left unsharded when it does not divide the data
    axes (e.g. long_500k's global_batch=1).
    """
    data = tuple(rules.data_axes)
    data_entry = (data if len(data) > 1 else data[0]) if data else None
    dsize = 1
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for a in data:
            dsize *= sizes.get(a, 1)

    def spec_for(leaf):
        ndim = getattr(leaf, "ndim", None)
        shape = getattr(leaf, "shape", None)
        if ndim is None:  # backwards compat: an int ndim was passed
            ndim, shape = leaf, None
        entries = [None] * ndim
        idx = 0
        if rules.client_axis is not None:
            entries[0] = rules.client_axis
            idx = 1
        if data_entry is not None and ndim > idx and (
                shape is None or dsize <= 1 or shape[idx] % dsize == 0):
            entries[idx] = data_entry
        return P(*entries)

    return spec_for


def cache_specs(cfg: ModelConfig, cache_shape, rules: ShardingRules, mesh):
    """Decode-cache sharding: batch over data axes, heads/state over model."""
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data = tuple(rules.data_axes)
    data_entry = data if len(data) > 1 else data[0]
    dsize = 1
    for a in data:
        dsize *= mesh_sizes.get(a, 1)
    msize = mesh_sizes.get(rules.model_axis, 1) if rules.model_axis else 1

    def one(path, leaf):
        s = _path_str(path)
        if s.endswith("pos"):
            return P()
        entries = [None] * leaf.ndim
        # leading layer-stack axis then batch
        bdim = 1 if s.startswith("layers") else 0
        if leaf.ndim > bdim and leaf.shape[bdim] % dsize == 0:
            entries[bdim] = data_entry
        if s.endswith("/k") or s.endswith("/v"):
            # ring buffers (L, b, hkv, cap, dh).
            if getattr(cfg, "splitk_decode", False):
                # split-K serving: shard the LENGTH dim (the write is an
                # elementwise select, so no dynamic-slice shard issues)
                if leaf.ndim > bdim + 2 and leaf.shape[bdim + 2] % msize == 0 \
                        and msize > 1:
                    entries[bdim + 2] = rules.model_axis
                return P(*entries)
            # default: only the heads dim may shard — sharding the
            # capacity dim would put the per-token dynamic-update-slice
            # at an unknown shard boundary and SPMD falls back to full
            # rematerialization (replicate+repartition)
            if leaf.ndim > bdim + 1 and leaf.shape[bdim + 1] % msize == 0 \
                    and msize > 1:
                entries[bdim + 1] = rules.model_axis
            return P(*entries)
        # recurrent states are replaced wholesale each step: shard the
        # first big divisible axis over model
        for dim in range(bdim + 1, leaf.ndim):
            if msize > 1 and leaf.shape[dim] % msize == 0 and leaf.shape[dim] >= msize:
                entries[dim] = rules.model_axis
                break
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def opt_state_specs(param_spec_tree):
    """AdamW moments mirror the parameter specs; step is replicated."""
    return {
        "mu": param_spec_tree,
        "nu": param_spec_tree,
        "step": P(),
    }
