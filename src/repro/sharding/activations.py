"""Activation sharding constraints (logical-role based).

Model code calls ``constrain(x, role_0, role_1, ...)`` with one logical
role per axis: 'batch', 'heads', 'model', 'vocab', 'experts' or None.
Outside an ``activation_sharding`` context this is a no-op (smoke tests,
single-device runs); inside (dry-run / production launch) it emits
``with_sharding_constraint`` with the mesh-resolved PartitionSpec —
skipping any role whose axis size does not divide the mesh axis, so the
same model code lowers on every mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_TLS = threading.local()


@dataclasses.dataclass(frozen=True)
class ActivationCtx:
    mesh: object
    data_axes: tuple        # axes carrying batch (and FSDP)
    model_axis: Optional[str]
    sizes: dict


@contextlib.contextmanager
def activation_sharding(mesh, data_axes: tuple, model_axis: Optional[str]):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = ActivationCtx(mesh=mesh, data_axes=tuple(data_axes),
                             model_axis=model_axis, sizes=sizes)
    try:
        yield
    finally:
        _TLS.ctx = prev


def current_ctx() -> Optional[ActivationCtx]:
    return getattr(_TLS, "ctx", None)


def constrain(x, *roles):
    ctx = current_ctx()
    if ctx is None:
        return x
    assert len(roles) == x.ndim, (roles, x.shape)
    entries = []
    dsize = 1
    for a in ctx.data_axes:
        dsize *= ctx.sizes.get(a, 1)
    msize = ctx.sizes.get(ctx.model_axis, 1) if ctx.model_axis else 1
    model_used = False
    for dim, role in enumerate(roles):
        if role == "batch" and x.shape[dim] % dsize == 0 and dsize > 1:
            entries.append(ctx.data_axes if len(ctx.data_axes) > 1
                           else ctx.data_axes[0])
        elif role in ("heads", "model", "vocab", "experts") and \
                ctx.model_axis and not model_used and \
                x.shape[dim] % msize == 0 and msize > 1:
            entries.append(ctx.model_axis)
            model_used = True
        else:
            entries.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*entries)))
