from repro.sharding.specs import (
    ShardingRules,
    param_specs,
    batch_spec,
    cache_specs,
    opt_state_specs,
)

__all__ = [
    "ShardingRules",
    "param_specs",
    "batch_spec",
    "cache_specs",
    "opt_state_specs",
]
