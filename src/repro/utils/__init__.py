from repro.utils.tree import (
    tree_to_vector,
    vector_to_tree,
    tree_size,
    tree_axis_mean,
    tree_select,
    tree_l2_norm,
    tree_cast,
)
from repro.utils.prng import key_fold, split_like

__all__ = [
    "tree_to_vector",
    "vector_to_tree",
    "tree_size",
    "tree_axis_mean",
    "tree_select",
    "tree_l2_norm",
    "tree_cast",
    "key_fold",
    "split_like",
]
