"""Tiny PRNG helpers (pure JAX, no flax)."""
from __future__ import annotations

import jax


def key_fold(key, *data: int):
    """Fold a sequence of ints into a PRNG key (stable derivation)."""
    for d in data:
        key = jax.random.fold_in(key, d)
    return key


def split_like(key, tree):
    """Split a key into one key per leaf of ``tree``, returned as a pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(treedef, list(keys))
