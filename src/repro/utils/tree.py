"""Pytree <-> flat-vector utilities used by the ODCL aggregation path.

The server side of ODCL operates on model *vectors*: each client's
parameter pytree is flattened to a single 1-D array (or a sketched
projection of it).  These helpers are shape-preserving inverses of each
other and jit-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree) -> int:
    """Total number of scalar elements in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) if hasattr(l, "shape") else 1 for l in leaves))


def tree_to_vector(tree) -> jnp.ndarray:
    """Flatten a pytree of arrays into a single 1-D float32 vector."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])


def vector_to_tree(vec, tree_like):
    """Inverse of :func:`tree_to_vector` given a structural template."""
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    out = []
    offset = 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.ndim else 1
        out.append(jnp.reshape(vec[offset : offset + n], l.shape).astype(l.dtype))
        offset += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_axis_mean(tree, axis: int = 0):
    """Mean over a leading (stacked) axis of every leaf."""
    return jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=axis), tree)


def tree_select(tree, idx: int):
    """Index every leaf along its leading axis."""
    return jax.tree_util.tree_map(lambda l: l[idx], tree)


def tree_l2_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda l: l.astype(dtype) if jnp.issubdtype(l.dtype, jnp.floating) else l, tree
    )
