"""Pure-jnp oracles for every Pallas kernel in this package.

Each function here is the semantic ground truth the Pallas kernels are
validated against (tests sweep shapes/dtypes and assert_allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_sqdist(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distances between rows of a (m,d) and b (k,d).

    Uses the expansion ||x-y||^2 = ||x||^2 + ||y||^2 - 2<x,y> (one matmul),
    clamped at zero against rounding.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    a2 = jnp.sum(a * a, axis=1, keepdims=True)          # (m, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True).T        # (1, k)
    d2 = a2 + b2 - 2.0 * (a @ b.T)
    return jnp.maximum(d2, 0.0)


def kmeans_assign(points: jnp.ndarray, centers: jnp.ndarray):
    """Fused assignment + accumulation step of Lloyd's algorithm.

    Returns (labels (m,), sums (k,d), counts (k,)) where sums/counts are
    the per-cluster sums and cardinalities of the assigned points.
    """
    d2 = pairwise_sqdist(points, centers)
    labels = jnp.argmin(d2, axis=1).astype(jnp.int32)
    k = centers.shape[0]
    onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32)
    sums = onehot.T @ points.astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    return labels, sums, counts


def group_ball_proj(v: jnp.ndarray, radius) -> jnp.ndarray:
    """Row-wise projection of v (e,d) onto the L2 ball of ``radius``.

    This is the dual update of the AMA solver for convex clustering.
    ``radius`` may be scalar or per-row (e,).
    """
    v = v.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(v * v, axis=1, keepdims=True))
    radius = jnp.broadcast_to(jnp.asarray(radius, jnp.float32), (v.shape[0],))[:, None]
    scale = jnp.where(norms > radius, radius / jnp.maximum(norms, 1e-30), 1.0)
    return v * scale


def group_ball_proj_batched(v: jnp.ndarray, radius) -> jnp.ndarray:
    """Batched row-wise ball projection: v (b, e, d), radius (b, e).

    The lambda-ladder AMA sweep of the device clusterpath advances every
    solve in lock-step, so all L dual blocks project at once.
    """
    v = v.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(v * v, axis=2, keepdims=True))      # (b, e, 1)
    radius = jnp.broadcast_to(
        jnp.asarray(radius, jnp.float32), v.shape[:2])[..., None]
    scale = jnp.where(norms > radius, radius / jnp.maximum(norms, 1e-30), 1.0)
    return v * scale


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None):
    """Reference attention: q (b,h,sq,dh), k/v (b,hkv,skv,dh) with GQA.

    ``window`` limits attention to the trailing ``window`` positions
    (sliding-window / sub-quadratic serving mode). Positions are aligned
    so that query i attends to kv positions <= i + (skv - sq).
    """
    b, h, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    mask = kpos <= qpos if causal else jnp.ones((sq, skv), bool)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
