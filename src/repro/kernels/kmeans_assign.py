"""Pallas TPU kernel: fused K-means assign + accumulate.

One pass over the points computes, per grid step of ``bm`` points:
  * nearest-center labels (argmin over the (bm, k) distance tile), and
  * the per-cluster running sums / counts, accumulated across grid steps
    into a single (k, d) / (k,) VMEM-resident output block.

Fusing the scatter-add into the distance pass removes the separate
one-hot matmul of the reference implementation (which materializes an
(m, k) one-hot in HBM).  Centers are small enough (k <= a few hundred,
d = sketch dim) to keep the whole (k, d) accumulator in VMEM.

  grid = (m/bm,)
  P tile: (bm, d)   C tile: (k, d)   outs: labels (bm,), sums (k, d), counts (k,)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(p_ref, c_ref, lab_ref, sum_ref, cnt_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    p = p_ref[...].astype(jnp.float32)           # (bm, d)
    c = c_ref[...].astype(jnp.float32)           # (k, d)
    p2 = jnp.sum(p * p, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1, keepdims=True)
    d2 = p2 + c2.T - 2.0 * jax.lax.dot_general(
        p, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (bm, k)
    labels = jnp.argmin(d2, axis=1).astype(jnp.int32)
    lab_ref[...] = labels
    onehot = (labels[:, None] == jnp.arange(c.shape[0])[None, :]).astype(jnp.float32)
    sum_ref[...] += jax.lax.dot_general(
        onehot, p, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # (k, d)
    cnt_ref[...] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def kmeans_assign_pallas(points, centers, *, bm: int = 256, interpret: bool = False):
    m, d = points.shape
    k, _ = centers.shape
    bm = min(bm, _rup(m, 8))
    mp = _rup(m, bm)
    # pad points far away so padded rows never contaminate real clusters:
    # label of padded rows is still computed, we slice labels back and
    # subtract the pad contribution from cluster 0's stats is avoided by
    # padding with the first center (assigns to its true nearest center);
    # instead we pad with +inf-ish offset and mask contributions below.
    pad = mp - m
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    labels, sums, counts = pl.pallas_call(
        _assign_kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp,), jnp.int32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=interpret,
    )(pts, centers)
    if pad:
        # remove the padded rows' contribution (they all hashed to the
        # nearest center of the zero vector)
        zlab, _, _ = _ref_assign_tail(jnp.zeros((pad, d), points.dtype), centers)
        onehot = jax.nn.one_hot(zlab, k, dtype=jnp.float32)
        sums = sums - onehot.T @ jnp.zeros((pad, d), jnp.float32)
        counts = counts - jnp.sum(onehot, axis=0)
    return labels[:m], sums, counts


def _ref_assign_tail(points, centers):
    from repro.kernels import ref

    return ref.kmeans_assign(points, centers)


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
