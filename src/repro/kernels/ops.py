"""Jit'd public wrappers for the Pallas kernels.

On TPU the compiled Pallas kernels run natively; everywhere else (this
container is CPU-only) the wrappers dispatch to the pure-jnp oracles in
``ref.py`` so the rest of the framework is backend-agnostic.  Tests call
the ``*_pallas(..., interpret=True)`` entry points directly to validate
the kernel bodies against the oracles.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.pairwise_l2 import pairwise_sqdist_pallas
from repro.kernels.kmeans_assign import kmeans_assign_pallas
from repro.kernels.group_prox import (
    group_ball_proj_batched_pallas,
    group_ball_proj_pallas,
)
from repro.kernels.flash_attention import flash_attention_pallas

# Force-enable pallas-in-interpret-mode everywhere (slow; tests only).
_FORCE_PALLAS = os.environ.get("REPRO_FORCE_PALLAS", "0") == "1"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pairwise_sqdist(a, b):
    """(m,d) x (k,d) -> (m,k) squared Euclidean distances."""
    if _on_tpu():
        return pairwise_sqdist_pallas(a, b)
    if _FORCE_PALLAS:
        return pairwise_sqdist_pallas(a, b, interpret=True)
    return ref.pairwise_sqdist(a, b)


def kmeans_assign(points, centers):
    """Fused Lloyd assign+accumulate: (labels, sums, counts)."""
    if _on_tpu():
        return kmeans_assign_pallas(points, centers)
    if _FORCE_PALLAS:
        return kmeans_assign_pallas(points, centers, interpret=True)
    return ref.kmeans_assign(points, centers)


def group_ball_proj(v, radius):
    """Row-wise projection onto the L2 ball (convex-clustering dual prox)."""
    if _on_tpu():
        return group_ball_proj_pallas(v, radius)
    if _FORCE_PALLAS:
        return group_ball_proj_pallas(v, radius, interpret=True)
    return ref.group_ball_proj(v, radius)


def group_ball_proj_batched(v, radius):
    """Batched ball projection (b,e,d) — the lambda-ladder dual prox."""
    if _on_tpu():
        return group_ball_proj_batched_pallas(v, radius)
    if _FORCE_PALLAS:
        return group_ball_proj_batched_pallas(v, radius, interpret=True)
    return ref.group_ball_proj_batched(v, radius)


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None):
    """Block attention. q (b,h,sq,dh), k/v (b,hkv,skv,dh)."""
    if _on_tpu():
        return flash_attention_pallas(q, k, v, causal=causal, window=window)
    return ref.flash_attention(q, k, v, causal=causal, window=window)
