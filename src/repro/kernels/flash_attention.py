"""Pallas TPU kernel: block (flash) attention with online softmax.

Used by the serving path for 32k prefill and the sliding-window 500k
configs: attention is computed in (bq, bk) logit tiles that never leave
VMEM, with the streaming max/denominator recurrence, so the full
(sq, skv) score matrix is never materialized in HBM.

  grid = (batch*heads, sq/bq, skv/bk)   (kv axis innermost, sequential)
  Q tile: (bq, dh)   K/V tiles: (bk, dh)   O tile: (bq, dh) + (bq,) stats

GQA is handled by the wrapper (head replication), causal and
sliding-window masks are applied per tile with absolute positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, scale, causal,
                  window, sq, skv, bq, bk):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                     # (bk, dh)
    v = v_ref[0].astype(jnp.float32)                     # (bk, dh)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                            # (bq, bk)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (skv - sq)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < skv  # guard kv padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]                                    # (bq,)
    l_prev = l_ref[0]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows would give exp(NEG_INF - NEG_INF) = 1; zero them
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    o_ref[0] = o_ref[0] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[0] = m_new
    l_ref[0] = l_new

    @pl.when(kj == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret")
)
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int | None = None,
                           bq: int = 128, bk: int = 128, interpret: bool = False):
    """q (b,h,sq,dh), k/v (b,hkv,skv,dh) -> (b,h,sq,dh)."""
    b, h, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = 1.0 / (dh ** 0.5)
    bq = min(bq, _rup(sq, 8))
    bk = min(bk, _rup(skv, 128))
    sqp, skvp = _rup(sq, bq), _rup(skv, bk)
    g = b * h
    qf = jnp.pad(q.reshape(g, sq, dh), ((0, 0), (0, sqp - sq), (0, 0)))
    kf = jnp.pad(k.reshape(g, skv, dh), ((0, 0), (0, skvp - skv), (0, 0)))
    vf = jnp.pad(v.reshape(g, skv, dh), ((0, 0), (0, skvp - skv), (0, 0)))
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        sq=sq, skv=skv, bq=bq, bk=bk,
    )
    out, _, _ = pl.pallas_call(
        kern,
        grid=(g, sqp // bq, skvp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda gi, i, j: (gi, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda gi, i, j: (gi, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda gi, i, j: (gi, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, dh), lambda gi, i, j: (gi, i, 0)),
            pl.BlockSpec((1, bq), lambda gi, i, j: (gi, i)),
            pl.BlockSpec((1, bq), lambda gi, i, j: (gi, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, sqp, dh), jnp.float32),
            jax.ShapeDtypeStruct((g, sqp), jnp.float32),
            jax.ShapeDtypeStruct((g, sqp), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :sq].reshape(b, h, sq, dh).astype(q.dtype)


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
