"""Pallas TPU kernels for the ODCL hot spots + a block-attention kernel.

Layout (per the repo convention):
  <name>.py  — pl.pallas_call + BlockSpec kernel
  ops.py     — jit'd public wrappers (TPU: pallas, CPU: ref fallback)
  ref.py     — pure-jnp oracles, the correctness ground truth
"""
