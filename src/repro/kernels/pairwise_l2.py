"""Pallas TPU kernel: pairwise squared-Euclidean distance matrix.

This is the compute hot spot of the ODCL server clustering step: for
``m`` clients and sketch dimension ``d`` the K-means / convex-clustering
inner loops need the (m, k) (or (m, m)) distance matrix every iteration.

TPU mapping: one MXU matmul per (bm, bk) output tile using the
``||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b`` decomposition.  The d
(feature) axis is blocked as the innermost *reduction* grid dimension
with an accumulator held in the output VMEM tile, so arbitrarily large
sketch dims stream through VMEM:

  grid = (m/bm, k/bk, d/bd)
  A tile: (bm, bd) VMEM     B tile: (bk, bd) VMEM     O tile: (bm, bk)

All tile sizes are MXU-aligned multiples of 128 (8 for the sublane dim
would suffice for fp32 but 128 keeps the matmul shapes square).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pairwise_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)          # (bm, bd)
    b = b_ref[...].astype(jnp.float32)          # (bk, bd)
    a2 = jnp.sum(a * a, axis=1, keepdims=True)  # (bm, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)  # (bk, 1)
    ab = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # (bm, bk)
    o_ref[...] += a2 + b2.T - 2.0 * ab


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bd", "interpret"))
def pairwise_sqdist_pallas(a, b, *, bm: int = 128, bk: int = 128, bd: int = 512,
                           interpret: bool = False):
    """(m,d) x (k,d) -> (m,k) squared distances, fp32 accumulate."""
    m, d = a.shape
    k, _ = b.shape
    bm = min(bm, _rup(m, 8))
    bk = min(bk, _rup(k, 128))
    bd = min(bd, _rup(d, 128))
    mp, kp, dp = _rup(m, bm), _rup(k, bk), _rup(d, bd)
    a = jnp.pad(a, ((0, mp - m), (0, dp - d)))
    b = jnp.pad(b, ((0, kp - k), (0, dp - d)))
    grid = (mp // bm, kp // bk, dp // bd)
    out = pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bd), lambda i, j, l: (j, l)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, kp), jnp.float32),
        interpret=interpret,
    )(a, b)
    return jnp.maximum(out[:m, :k], 0.0)


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
