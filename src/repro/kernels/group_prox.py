"""Pallas TPU kernel: row-wise L2-ball projection (group prox).

The AMA solver for convex clustering (repro.core.clustering.convex and
its device twin repro.core.engine.device_convex) projects every edge's
dual variable onto the ball of radius lambda each iteration: for
E = m(m-1)/2 edges and sketch dim d this is an (E, d)
row-normalization — memory bound, so we tile rows through VMEM in
(be, d) blocks and fuse the norm + rescale.

  grid = (E/be,)
  V tile: (be, d) VMEM    radius tile: (be,)    out: (be, d)

The batched variant below runs the same projection over a leading batch
axis — the lambda-ladder sweep of the device clusterpath advances all L
solves in lock-step, so its dual state is (L, E, d) with a per-(l, e)
radius.  The grid grows a batch dimension; edge tiles keep the same
(be, d) VMEM footprint and E is padded to a multiple of ``be`` exactly
as in the unbatched kernel (pad radius 1.0 => pad rows pass through
unscaled and are sliced off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _proj_kernel(v_ref, r_ref, o_ref):
    v = v_ref[...].astype(jnp.float32)                    # (be, d)
    r = r_ref[...].astype(jnp.float32)                    # (be,)
    n = jnp.sqrt(jnp.sum(v * v, axis=1))                  # (be,)
    scale = jnp.where(n > r, r / jnp.maximum(n, 1e-30), 1.0)
    o_ref[...] = v * scale[:, None]


@functools.partial(jax.jit, static_argnames=("be", "interpret"))
def group_ball_proj_pallas(v, radius, *, be: int = 512, interpret: bool = False):
    e, d = v.shape
    if e == 0:          # degenerate edge set (m=1): nothing to project
        return jnp.zeros((0, d), jnp.float32)
    radius = jnp.broadcast_to(jnp.asarray(radius, jnp.float32), (e,))
    be = min(be, _rup(e, 8))
    ep = _rup(e, be)
    vp = jnp.pad(v, ((0, ep - e), (0, 0)))
    rp = jnp.pad(radius, (0, ep - e), constant_values=1.0)
    out = pl.pallas_call(
        _proj_kernel,
        grid=(ep // be,),
        in_specs=[
            pl.BlockSpec((be, d), lambda i: (i, 0)),
            pl.BlockSpec((be,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((be, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ep, d), jnp.float32),
        interpret=interpret,
    )(vp, rp)
    return out[:e]


def _batched_proj_kernel(v_ref, r_ref, o_ref):
    v = v_ref[0].astype(jnp.float32)                      # (be, d)
    r = r_ref[0].astype(jnp.float32)                      # (be,)
    n = jnp.sqrt(jnp.sum(v * v, axis=1))                  # (be,)
    scale = jnp.where(n > r, r / jnp.maximum(n, 1e-30), 1.0)
    o_ref[0] = v * scale[:, None]


@functools.partial(jax.jit, static_argnames=("be", "interpret"))
def group_ball_proj_batched_pallas(v, radius, *, be: int = 512,
                                   interpret: bool = False):
    """Batched row-wise ball projection: v (b, e, d), radius (b, e)."""
    b, e, d = v.shape
    if e == 0:          # degenerate edge set (m=1): nothing to project
        return jnp.zeros((b, 0, d), jnp.float32)
    radius = jnp.broadcast_to(jnp.asarray(radius, jnp.float32), (b, e))
    be = min(be, _rup(e, 8))
    ep = _rup(e, be)
    vp = jnp.pad(v, ((0, 0), (0, ep - e), (0, 0)))
    rp = jnp.pad(radius, ((0, 0), (0, ep - e)), constant_values=1.0)
    out = pl.pallas_call(
        _batched_proj_kernel,
        grid=(b, ep // be),
        in_specs=[
            pl.BlockSpec((1, be, d), lambda l, i: (l, i, 0)),
            pl.BlockSpec((1, be), lambda l, i: (l, i)),
        ],
        out_specs=pl.BlockSpec((1, be, d), lambda l, i: (l, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ep, d), jnp.float32),
        interpret=interpret,
    )(vp, rp)
    return out[:, :e]


def _rup(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
