"""hubert-xlarge [arXiv:2106.07447]

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 — encoder-only
(bidirectional) transformer backbone; the conv/mel frontend is a stub
per the assignment carve-out (``input_mode='embeddings'``).  vocab=504
is the HuBERT codebook size (masked-frame prediction targets).
Encoder-only => no decode shapes (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    mlp_variant="relu",     # w2v2/HuBERT use plain GELU/ReLU FFNs
    causal=False,
    input_mode="embeddings",
    source="arXiv:2106.07447",
)
