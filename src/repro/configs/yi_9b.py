"""yi-9b [arXiv:2403.04652]

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000 — llama-style
dense decoder with GQA and SwiGLU.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    arch_type="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11_008,
    vocab_size=64_000,
    serve_window=4096,
    rope_theta=10_000.0,
    source="arXiv:2403.04652",
)
