"""Architecture + run configuration dataclasses.

``ModelConfig`` is the single source of truth consumed by
``repro.models``: every assigned architecture is expressed as an
instance (one module per arch under ``repro/configs/``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    mlp_variant: str = "swiglu"    # swiglu | geglu | relu
    qkv_bias: bool = False
    causal: bool = True            # False -> encoder-only (bidirectional)
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # routed expert hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- recurrent / hybrid ---
    block_pattern: str = "attn"    # attn | xlstm | hybrid
    ssm_state: int = 0             # mamba state size (hybrid)
    conv_width: int = 4            # mamba short conv width
    # --- attention geometry ---
    window: Optional[int] = None        # training attention window
    serve_window: Optional[int] = None  # decode cache window for long ctx
    rope_theta: float = 10_000.0
    # --- implementation knobs (not architecture identity) ---
    attn_chunk: int = 1024         # flash-style chunk; 0 = direct einsum
    mlstm_chunk: int = 256         # mLSTM chunkwise width; 0 = one chunk
    ssm_chunk: int = 256           # selective-scan chunk; 0 = one assoc scan
    splitk_decode: bool = False    # shard decode KV cache length over model
    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # inputs: 'tokens' | 'embeddings' (audio frontend stub) | 'multimodal'
    input_mode: str = "tokens"
    source: str = ""               # provenance citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    def reduced(self, *, n_layers: int = 2, max_d_model: int = 512,
                max_experts: int = 4, max_vocab: int = 1024) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        scale = min(1.0, max_d_model / self.d_model)
        d_model = max(64, int(self.d_model * scale) // 32 * 32)
        n_heads = max(2, min(self.n_heads, d_model // 32))
        ratio = max(1, self.n_heads // max(1, self.n_kv_heads))
        n_kv_heads = max(1, n_heads // ratio)
        while n_heads % n_kv_heads:
            n_kv_heads -= 1
        head_dim = d_model // n_heads
        updates = dict(
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv_heads,
            head_dim=head_dim,
            d_ff=max(32, int(self.d_ff * scale)) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, max_vocab),
            dtype="float32",
        )
        if self.is_moe:
            updates.update(
                n_experts=min(self.n_experts, max_experts),
                top_k=min(self.top_k, min(self.n_experts, max_experts)),
                moe_d_ff=max(32, int(self.moe_d_ff * scale)),
            )
        if self.window:
            updates["window"] = min(self.window, 64)
        if self.serve_window:
            updates["serve_window"] = min(self.serve_window, 64)
        return dataclasses.replace(self, **updates)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
