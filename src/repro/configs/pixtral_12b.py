"""pixtral-12b [hf:mistralai/Pixtral-12B-2409]

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072 — the
Mistral-Nemo-style multimodal decoder (head_dim=128).  The Pixtral ViT
vision encoder + projector is a stub per the assignment carve-out:
``input_mode='multimodal'`` consumes precomputed patch embeddings
scattered into the token sequence at given positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131_072,
    input_mode="multimodal",
    serve_window=4096,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Pixtral-12B-2409",
)
