"""qwen2-0.5b [arXiv:2407.10671]

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936 — GQA with QKV
bias, tied embeddings (the 0.5B variant ties lm_head to the embedding).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_936,
    qkv_bias=True,
    tie_embeddings=True,
    serve_window=4096,
    rope_theta=1_000_000.0,
    source="arXiv:2407.10671",
)
