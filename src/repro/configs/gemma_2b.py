"""gemma-2b [arXiv:2403.08295]

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000 — GeGLU MLP,
head_dim=256 (8 x 256 = 2048), multi-query attention, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    arch_type="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=256_000,
    mlp_variant="geglu",
    tie_embeddings=True,
    serve_window=4096,
    source="arXiv:2403.08295",
)
