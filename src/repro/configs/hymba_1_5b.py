"""hymba-1.5b [arXiv:2411.13676]

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16 —
hybrid-head layers: every layer runs attention heads and a Mamba-style
selective-SSM branch in PARALLEL on the same normalized input, fusing
them as the mean of the per-branch RMS-normalized outputs (the paper's
normalized hybrid fusion).  Attention uses a sliding window at serve
time; the SSM branch carries O(1) state => long_500k native.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    block_pattern="hybrid",
    ssm_state=16,
    serve_window=1024,       # Hymba's SWA window
    source="arXiv:2411.13676",
)
