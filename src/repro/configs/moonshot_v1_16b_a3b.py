"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]

48L d_model=2048 16H (kv=16) d_ff=1408 vocab=163840, MoE 64 experts
top-6 (+2 shared) — Moonlight's DeepSeek-V3-style fine-grained MoE at
16B total / ~3B active parameters.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    arch_type="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=163_840,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    serve_window=4096,
    rope_theta=50_000.0,
    source="hf:moonshotai/Moonlight-16B-A3B",
)
