"""Assigned architecture configs (one module per arch) + registry."""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

ARCH_IDS = [
    "deepseek_moe_16b",
    "hubert_xlarge",
    "qwen2_0_5b",
    "pixtral_12b",
    "xlstm_125m",
    "grok_1_314b",
    "gemma_2b",
    "hymba_1_5b",
    "moonshot_v1_16b_a3b",
    "yi_9b",
]

# CLI ids (with dashes) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen2-0.5b": "qwen2_0_5b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "hymba-1.5b": "hymba_1_5b",
})


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ModelConfig", "InputShape", "INPUT_SHAPES", "ARCH_IDS",
           "get_config", "all_configs"]
