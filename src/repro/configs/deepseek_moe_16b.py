"""deepseek-moe-16b [arXiv:2401.06066]

28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
fine-grained MoE: 2 shared + 64 routed experts, top-6.
d_ff is the routed-expert hidden size; shared experts are two fused
1408-wide SwiGLU paths (DeepSeekMoE's always-on shared experts).
``serve_window`` enables the sub-quadratic sliding-window serving
variant required by long_500k (beyond-paper serving feature).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,                 # all-MoE layers; experts carry the FFN capacity
    vocab_size=102_400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    serve_window=4096,
    rope_theta=10_000.0,
    source="arXiv:2401.06066",
)
