"""grok-1-314b [hf:xai-org/grok-1]

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2.  d_ff is the per-expert hidden size (Grok's MoE
FFN).  The flagship scale config: requires FSDP over the data (+pod)
axes on top of tensor parallelism to fit (see repro.sharding).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=131_072,
    n_experts=8,
    n_shared_experts=0,
    top_k=2,
    moe_d_ff=32_768,
    serve_window=4096,
    source="hf:xai-org/grok-1",
)
