"""xlstm-125m [arXiv:2405.04517]

12L d_model=768 4H d_ff=0 vocab=50304 — alternating sLSTM + mLSTM
blocks.  Our stacking pairs one mLSTM and one sLSTM block per scan step
(12 layers = 6 pairs), matching the paper's mixed xLSTM[m:s] stacks while
keeping the layer scan homogeneous (DESIGN.md §3).  d_ff=0: xLSTM blocks
carry their own up/down projections instead of a separate FFN.
Fully recurrent => native sub-quadratic long_500k decode.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50_304,
    block_pattern="xlstm",
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
