"""Clustered synthetic LM token streams.

For the deep-architecture integration we need per-client token data
whose distribution depends on the client's (hidden) cluster, mirroring
Assumption 1 at LM scale.  Each cluster k gets its own bigram transition
table (a random markov chain over the vocab); clients sample sequences
from their cluster's chain.  Clients in the same cluster therefore share
a population optimum, clients in different clusters do not.

Everything is generated on the fly from a seed — no disk, no downloads —
and shaped for sharding over the ("data" = client) mesh axis.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClusteredTokenStream:
    """Infinite token stream for one federation of LM clients."""
    n_clients: int
    n_clusters: int
    vocab_size: int
    seed: int = 0
    branching: int = 16     # out-degree of each markov state

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        assert self.n_clients % self.n_clusters == 0
        self.true_labels = np.repeat(
            np.arange(self.n_clusters), self.n_clients // self.n_clusters)
        # per-cluster sparse bigram tables: successors + logits
        self.succ = np.stack([
            rng.integers(0, self.vocab_size,
                         size=(self.vocab_size, self.branching))
            for _ in range(self.n_clusters)
        ])                                              # (K, V, B)
        logits = rng.normal(size=(self.n_clusters, self.vocab_size, self.branching))
        e = np.exp(logits - logits.max(-1, keepdims=True))
        self.probs = e / e.sum(-1, keepdims=True)       # (K, V, B)

    def sample(self, client: int, batch: int, seq_len: int, step: int) -> np.ndarray:
        """(batch, seq_len+1) tokens for one client at a given step."""
        k = int(self.true_labels[client])
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + client) * 1_000_003 + step)
        toks = np.empty((batch, seq_len + 1), np.int32)
        state = rng.integers(0, self.vocab_size, size=batch)
        toks[:, 0] = state
        for t in range(1, seq_len + 1):
            u = rng.uniform(size=batch)
            cdf = np.cumsum(self.probs[k][state], axis=-1)
            choice = (u[:, None] < cdf).argmax(axis=-1)
            state = self.succ[k][state, choice]
            toks[:, t] = state
        return toks


def make_lm_batch_iterator(stream: ClusteredTokenStream, *, clients_per_batch,
                           per_client_batch: int, seq_len: int):
    """Yield (tokens, labels) of shape (C, b, S) stacked over clients.

    ``tokens[c]`` comes from client ``clients_per_batch[c]``'s cluster
    distribution; the training loop shards axis 0 over the data axis.
    """
    step = 0
    while True:
        toks = np.stack([
            stream.sample(c, per_client_batch, seq_len, step)
            for c in clients_per_batch
        ])                                              # (C, b, S+1)
        yield toks[:, :, :-1], toks[:, :, 1:]
        step += 1
