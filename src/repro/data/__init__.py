from repro.data.synthetic import (
    make_linear_regression_federation,
    make_logistic_federation,
    make_mnist_like_federation,
    paper_synthetic_optima,
)
from repro.data.lm_data import ClusteredTokenStream, make_lm_batch_iterator

__all__ = [
    "make_linear_regression_federation",
    "make_logistic_federation",
    "make_mnist_like_federation",
    "paper_synthetic_optima",
    "ClusteredTokenStream",
    "make_lm_batch_iterator",
]
