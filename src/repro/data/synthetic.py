"""Clustered synthetic federations — the paper's data-generating processes.

Section 5 linear regression: y = <x, u*_k> + eps, eps ~ N(0,1); K = 10
clusters, d = 20; x has 5 random nonzero N(0,1) components; cluster
optima drawn from the staggered uniform intervals of Appendix E.1.

Appendix E.2 logistic regression: y = 2 Bernoulli(sigmoid(<x, th*_k> +
b*_k)) - 1 with per-cluster Gaussian covariate covariances.

Table 2 "MNIST" stand-in (offline container -> no dataset downloads):
a two-class Gaussian-blob "digit" problem where the second cluster
flips the labels — the paper's opposite-preference scenario — matched
in size (m=100, K=2, n=4 points/user).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Federation:
    """Per-user datasets + ground truth for a clustered DL system."""
    xs: np.ndarray            # (m, n, d) covariates per user
    ys: np.ndarray            # (m, n) responses per user
    true_labels: np.ndarray   # (m,) true cluster of each user
    optima: np.ndarray        # (K, d[+1]) population-optimal models
    D: float                  # min pairwise separation of the optima
    xs_test: np.ndarray | None = None
    ys_test: np.ndarray | None = None
    honest: np.ndarray | None = None   # (m,) bool; None = all honest

    @property
    def m(self) -> int:
        return self.xs.shape[0]

    @property
    def n(self) -> int:
        return self.xs.shape[1]

    @property
    def K(self) -> int:
        return self.optima.shape[0]


def paper_synthetic_optima(rng: np.random.Generator, d: int = 20) -> np.ndarray:
    """Appendix E.1 optima: u*_{k,i} ~ U([3k-2, 3k-1]) for k=1..5 and the
    mirrored negative intervals for k=6..10 -> K=10, guaranteed D > 0."""
    lows = np.array([1, 4, 7, 10, 13, -2, -5, -8, -11, -14], float)
    highs = np.array([2, 5, 8, 11, 14, -1, -4, -7, -10, -13], float)
    lo = np.minimum(lows, highs)
    hi = np.maximum(lows, highs)
    return rng.uniform(lo[:, None], hi[:, None], size=(10, d))


def _sparse_gaussian_x(rng, n, d, nnz=5):
    """Covariates with ``nnz`` random N(0,1) components, rest zero."""
    x = np.zeros((n, d), np.float32)
    for row in range(n):
        idx = rng.choice(d, size=nnz, replace=False)
        x[row, idx] = rng.normal(size=nnz)
    return x


def min_separation(optima: np.ndarray) -> float:
    diff = optima[:, None] - optima[None, :]
    dist = np.sqrt((diff ** 2).sum(-1))
    np.fill_diagonal(dist, np.inf)
    return float(dist.min())


def make_linear_regression_federation(
    seed: int, m: int = 100, K: int = 10, n: int = 100, d: int = 20,
    noise_std: float = 1.0, optima: np.ndarray | None = None,
    scenario=None,
) -> Federation:
    """Section 5 synthetic setup. Balanced clusters |C_k| = m/K.

    ``scenario`` (a name, '+'-composed spec, or ``Scenario`` instance
    from ``repro.scenarios``) reshapes the federation adversarially:
    its ``population``/``wave_labels`` hooks replace the balanced
    round-robin occupancy (longtail Zipf, mid-stream drift — the
    effective labels ARE the recorded truth), ``honest_mask`` is stored
    on ``Federation.honest``, and ``corrupt_uploads`` is applied to the
    (m, n) response matrix — the ridge ERM is linear in y, so the
    sign-flip attack on responses produces exactly the sign-flipped
    model upload (the noise attack becomes response poisoning).
    """
    rng = np.random.default_rng(seed)
    if optima is None:
        if K == 10:
            optima = paper_synthetic_optima(rng, d)
        else:
            # staggered intervals like E.3: U([k, k+1]) alternating sign
            lows = np.array([(k // 2 + k % 2) * (1 if k % 2 == 0 else -1) - (1 if k % 2 else 0)
                             for k in range(K)], float)
            optima = rng.uniform(lows[:, None], lows[:, None] + 1.0, size=(K, d))
    honest = None
    scen = None
    if scenario is None:
        assert m % K == 0, "balanced clustering requires K | m"
        per = m // K
        true_labels = np.repeat(np.arange(K), per)
    else:
        import jax.numpy as jnp
        from jax.random import PRNGKey
        from repro.scenarios import build_scenario

        scen = build_scenario(scenario)
        skey = PRNGKey(seed)
        labels = jnp.asarray(scen.population(skey, m, K), jnp.int32)
        labels = scen.wave_labels(skey, labels, 0, m, K)
        true_labels = np.asarray(labels, np.int64)
        honest = np.asarray(scen.honest_mask(skey, m), bool)
    xs = np.zeros((m, n, d), np.float32)
    ys = np.zeros((m, n), np.float32)
    for i in range(m):
        k = true_labels[i]
        x = _sparse_gaussian_x(rng, n, d)
        eps = rng.normal(scale=noise_std, size=n)
        xs[i] = x
        ys[i] = x @ optima[k] + eps
    if scen is not None:
        import jax.numpy as jnp
        from jax.random import PRNGKey

        ys = np.asarray(scen.corrupt_uploads(
            PRNGKey(seed), jnp.asarray(ys), jnp.asarray(true_labels), 0, m),
            np.float32)
    return Federation(xs=xs, ys=ys, true_labels=true_labels,
                      optima=optima.astype(np.float32),
                      D=min_separation(optima), honest=honest)


def make_logistic_federation(
    seed: int, m: int = 100, K: int = 4, n: int = 1000, d: int = 2,
) -> Federation:
    """Appendix E.2 logistic setup (K=4, d=2, per-cluster covariances)."""
    rng = np.random.default_rng(seed)
    thetas = np.array([[1, -1], [1, 0], [-1, 1], [0, -1]], np.float32)[:K]
    covs = [np.eye(2), np.array([[2, 1], [1, 2.]]),
            np.array([[1, .5], [.5, 1.]]), np.array([[2, 0], [0, 2.]])][:K]
    assert m % K == 0
    per = m // K
    true_labels = np.repeat(np.arange(K), per)
    xs = np.zeros((m, n, d), np.float32)
    ys = np.zeros((m, n), np.float32)
    for i in range(m):
        k = true_labels[i]
        x = rng.multivariate_normal(np.zeros(d), covs[k], size=n)
        p = 1.0 / (1.0 + np.exp(-(x @ thetas[k])))
        y = 2.0 * (rng.uniform(size=n) < p) - 1.0
        xs[i] = x
        ys[i] = y
    # optima include the zero intercept as last component
    optima = np.concatenate([thetas, np.zeros((K, 1), np.float32)], axis=1)
    return Federation(xs=xs, ys=ys, true_labels=true_labels, optima=optima,
                      D=min_separation(thetas))


def make_mnist_like_federation(
    seed: int, m: int = 100, n: int = 4, d: int = 20, sep: float = 2.0,
    n_test: int = 200,
) -> Federation:
    """Table 2 stand-in: binary '1 vs 2' blobs; cluster 2 flips labels.

    Each user gets n=4 points (two per class) as in the paper.  Test
    sets are per-user draws from the same cluster distribution.
    """
    rng = np.random.default_rng(seed)
    mu1 = rng.normal(size=d); mu1 *= sep / np.linalg.norm(mu1)
    mu2 = -mu1
    assert m % 2 == 0
    true_labels = np.repeat(np.arange(2), m // 2)

    def draw(n_pts, flip):
        half = n_pts // 2
        xa = mu1 + rng.normal(scale=1.0, size=(half, d))
        xb = mu2 + rng.normal(scale=1.0, size=(n_pts - half, d))
        x = np.concatenate([xa, xb]).astype(np.float32)
        y = np.concatenate([np.ones(half), -np.ones(n_pts - half)]).astype(np.float32)
        if flip:
            y = -y
        perm = rng.permutation(n_pts)
        return x[perm], y[perm]

    xs = np.zeros((m, n, d), np.float32); ys = np.zeros((m, n), np.float32)
    xs_t = np.zeros((m, n_test, d), np.float32); ys_t = np.zeros((m, n_test), np.float32)
    for i in range(m):
        flip = bool(true_labels[i])
        xs[i], ys[i] = draw(n, flip)
        xs_t[i], ys_t[i] = draw(n_test, flip)
    # population optima of the logistic problem are +/- c*mu1 direction;
    # report the Bayes direction with unit intercept slot
    w = (mu1 - mu2).astype(np.float32)
    optima = np.stack([np.append(w, 0.0), np.append(-w, 0.0)])
    return Federation(xs=xs, ys=ys, true_labels=true_labels, optima=optima,
                      D=float(np.linalg.norm(2 * w)), xs_test=xs_t, ys_test=ys_t)
